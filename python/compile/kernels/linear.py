"""Pallas fused linear (+ReLU) kernel.

The shared table-feature MLPs of the cost and policy networks apply the
same small dense layer to thousands of table-feature rows per call; this
kernel tiles the row dimension into VMEM-resident blocks so each grid step
streams one row-tile HBM->VMEM, runs the (I x O) matmul on the MXU, adds the
bias, and optionally fuses the ReLU — one pass over HBM instead of the
three (matmul, add, max) an unfused graph would take.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see DESIGN.md
section Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    # One row-tile of x, the full (small) weight in VMEM.
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = y + b_ref[...][None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def linear(x, w, b, relu: bool = False, block_rows: int = 128):
    """Fused ``relu(x @ w + b)``.

    x: [B, I] f32, w: [I, O] f32, b: [O] f32 -> [B, O] f32.
    ``B`` must be a multiple of ``block_rows`` (callers pad; the L2 model
    always works on padded slot grids so this holds by construction).
    """
    B, I = x.shape
    O = w.shape[1]
    if B % block_rows != 0:
        # Degenerate/small cases: single block over all rows.
        block_rows = B
    grid = (B // block_rows,)
    return pl.pallas_call(
        functools.partial(_linear_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, I), lambda i: (i, 0)),
            pl.BlockSpec((I, O), lambda i: (0, 0)),
            pl.BlockSpec((O,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, O), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, O), jnp.float32),
        interpret=True,
    )(x, w, b)
