"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has an oracle here with the exact same
signature; pytest (and hypothesis sweeps) assert allclose between the two.
These references are also what the L2 model uses when ``use_pallas=False``
(e.g. for fast AOT lowering of very large variants).
"""

import jax.numpy as jnp


def linear_ref(x, w, b, relu: bool = False):
    """y = x @ w + b, optionally ReLU'd. x: [B, I], w: [I, O], b: [O]."""
    y = jnp.dot(x, w) + b
    return jnp.maximum(y, 0.0) if relu else y


def device_sum_ref(h, mask):
    """Masked segment-sum of table reps into device reps.

    h:    [D, S, L]  per-slot table representations
    mask: [D, S]     1.0 where the slot holds a real table
    ->    [D, L]     element-wise sum over the real slots of each device
    """
    return jnp.sum(h * mask[..., None], axis=-2)


def overall_max_ref(hdev, dmask):
    """Masked element-wise max over device reps (paper's max reduction).

    hdev:  [D, L] device representations
    dmask: [D]    1.0 for devices that exist in this task
    ->     [L]    element-wise max over existing devices
    """
    neg = jnp.float32(-1e30)
    masked = jnp.where(dmask[..., None] > 0, hdev, neg)
    return jnp.max(masked, axis=-2)


def embedding_bag_ref(table, indices, weights):
    """Fused embedding-bag (sum pooling) over one table.

    table:   [V, E]     embedding rows
    indices: [B, P] i32 indices into the table (padded)
    weights: [B, P]     per-index weights; 0.0 marks padding
    ->       [B, E]     sum_p weights[b,p] * table[indices[b,p]]
    """
    gathered = table[indices]                      # [B, P, E]
    return jnp.sum(gathered * weights[..., None], axis=1)
