"""Pallas fused embedding-bag kernel (the paper's compute hot spot).

FBGEMM's fused TBE op — the operation whose cost DreamShard learns — is a
CUDA gather + segment-sum tuned around warps and the L1/L2 cache. The TPU
rethink (DESIGN.md section Hardware-Adaptation): tile the [B, E] output into
VMEM-resident batch-blocks; each grid step streams one slab of (indices,
weights) HBM->VMEM, gathers the referenced rows, and performs weighted sum
pooling inside the tile. On a real TPU the pooled reduction of hot rows is
expressed as a one-hot x table matmul so the MXU does the reduction in
bf16; under interpret=True (mandatory on CPU PJRT) the same kernel runs as
a plain gather + masked sum, which is numerically identical and is what the
hypothesis suite checks against ``ref.embedding_bag_ref``.

Padding convention: ``indices`` is padded per sample to the max pooling
factor P; ``weights`` carries 1.0 for real indices and 0.0 for padding, so
the pooled sum ignores padding without branching (and also supports
weighted pooling for free).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(tbl_ref, idx_ref, w_ref, o_ref):
    tbl = tbl_ref[...]                    # [V, E] (whole table in VMEM)
    idx = idx_ref[...]                    # [Bt, P]
    w = w_ref[...]                        # [Bt, P]
    rows = jnp.take(tbl, idx, axis=0)     # [Bt, P, E]
    o_ref[...] = jnp.sum(rows * w[..., None], axis=1)


def embedding_bag(table, indices, weights, block_batch: int = 64):
    """Weighted sum-pool lookup: [V,E],[B,P]i32,[B,P] -> [B,E] f32."""
    V, E = table.shape
    B, P = indices.shape
    if B % block_batch != 0:
        block_batch = B
    grid = (B // block_batch,)
    return pl.pallas_call(
        _bag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((V, E), lambda i: (0, 0)),
            pl.BlockSpec((block_batch, P), lambda i: (i, 0)),
            pl.BlockSpec((block_batch, P), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_batch, E), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, E), jnp.float32),
        interpret=True,
    )(table, indices, weights)
