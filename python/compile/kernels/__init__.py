"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles."""

from . import ref  # noqa: F401
from .embedding_bag import embedding_bag  # noqa: F401
from .linear import linear  # noqa: F401
from .seg_reduce import device_sum, overall_max  # noqa: F401
