"""Pallas masked segment-reduction kernels.

DreamShard's generalizable architecture hinges on two reductions
(paper section 3.2 / B.3): an element-wise **sum** of table representations
within each device (fixed-size device rep regardless of #tables) and an
element-wise **max** across device representations (fixed-size overall rep
regardless of #devices). At the ultra variant (128 devices x 32 slots)
these reductions over the [D, S, L] rep grid are the cost-network hot
spot, so both are fused Pallas kernels: one grid step per device streams
that device's slot-tile into VMEM, applies the padding mask, and reduces —
a single HBM pass with no materialized [D, S, L] * mask intermediate.

All kernels lower with interpret=True (CPU PJRT cannot run Mosaic
custom-calls).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _device_sum_kernel(h_ref, m_ref, o_ref):
    h = h_ref[...]                       # [1, S, L] slot reps of one device
    m = m_ref[...]                       # [1, S]
    o_ref[...] = jnp.sum(h * m[..., None], axis=1)  # [1, L]


def device_sum(h, mask):
    """Masked sum of slot reps into device reps: [D,S,L],[D,S] -> [D,L]."""
    D, S, L = h.shape
    return pl.pallas_call(
        _device_sum_kernel,
        grid=(D,),
        in_specs=[
            pl.BlockSpec((1, S, L), lambda d: (d, 0, 0)),
            pl.BlockSpec((1, S), lambda d: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, L), lambda d: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((D, L), jnp.float32),
        interpret=True,
    )(h, mask)


def _overall_max_kernel(h_ref, m_ref, o_ref):
    h = h_ref[...]                       # [D, L]
    m = m_ref[...]                       # [D]
    neg = jnp.float32(-1e30)
    masked = jnp.where(m[..., None] > 0, h, neg)
    o_ref[...] = jnp.max(masked, axis=0)


def overall_max(hdev, dmask):
    """Masked element-wise max over device reps: [D,L],[D] -> [L]."""
    D, L = hdev.shape
    return pl.pallas_call(
        _overall_max_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((D, L), lambda i: (0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((L,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((L,), jnp.float32),
        interpret=True,
    )(hdev, dmask)
