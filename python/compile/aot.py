"""AOT compiler: lower every Layer-2 function to HLO text + a manifest.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the rust ``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md). Lowered with ``return_tuple=True``; the
rust side unwraps with ``to_tuple``.

The manifest (``manifest.txt``) is a whitespace-separated line format the
rust loader parses without a JSON dependency:

    const <name> <int>
    params <net> <total_len>
    segment <net> <param> <offset> <len> <init_bound>
    dlrm_hash <v0> <v1> ...
    artifact <name> <file> <k=v> ...
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dlrm as dlrm_mod
from . import model

F32 = jnp.float32
I32 = jnp.int32

# Device-count x slots-per-device variants; (128, 16) is the Table-13
# "ultra" scale (inference-only: the paper's generalization claim is that
# nets trained at small D transfer to large D, so no ultra train artifacts).
TRAIN_VARIANTS = [(2, 48), (4, 48), (8, 48)]
ULTRA = (128, 16)
E_FWD = 16          # episode batch for forward artifacts
B_COST = 64         # cost-net train batch (paper N_batch)
B_POLS = [512, 2048]  # policy-train step batches (rust picks smallest fit)
N_TBL = 256         # table_cost batch
T_RNN = 256         # RNN controller max sequence length
E_RNN = 10          # RNN train episode batch (paper N_episode)
DLRM_B = 256        # DLRM train/serve batch


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Emitter:
    def __init__(self, out_dir):
        self.out = out_dir
        self.lines = []

    def const(self, name, val):
        self.lines.append(f"const {name} {val}")

    def params(self, net, spec):
        self.lines.extend(spec.manifest_lines(net))

    def artifact(self, name, fn, specs, **meta):
        text = to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        kv = " ".join(f"{k}={v}" for k, v in meta.items())
        self.lines.append(f"artifact {name} {fname} {kv}".rstrip())
        print(f"  {name}: {len(text) / 1e6:.2f} MB")

    def finish(self):
        with open(os.path.join(self.out, "manifest.txt"), "w") as f:
            f.write("\n".join(self.lines) + "\n")


def emit_cost_policy(em):
    P_c = model.cost_spec().total
    P_p = model.policy_spec().total
    em.params("cost", model.cost_spec())
    em.params("policy", model.policy_spec())

    for D, S in TRAIN_VARIANTS + [ULTRA]:
        E = E_FWD
        em.artifact(
            f"cost_fwd_d{D}s{S}",
            functools.partial(model.cost_forward, use_pallas=True),
            [s((P_c,)), s((E, D, S, model.F)), s((E, D, S)), s((E, D)),
             s((model.F,))],
            E=E, D=D, S=S)
        em.artifact(
            f"policy_fwd_d{D}s{S}",
            functools.partial(model.policy_logits, use_pallas=True),
            [s((P_p,)), s((E, D, S, model.F)), s((E, D, S)), s((E, D, 3)),
             s((E, model.F)), s((E, D)), s((model.F,)), s((3,))],
            E=E, D=D, S=S)

    for D, S in TRAIN_VARIANTS:
        B = B_COST
        em.artifact(
            f"cost_train_d{D}s{S}",
            model.cost_train_step,
            [s((P_c,)), s((P_c,)), s((P_c,)), s((1,)), s((1,)),
             s((B, D, S, model.F)), s((B, D, S)), s((B, D)), s((B, D, 3)),
             s((B,)), s((model.F,))],
            B=B, D=D, S=S)
        for B in B_POLS:
            em.artifact(
                f"policy_train_d{D}s{S}_b{B}",
                model.policy_train_step,
                [s((P_p,)), s((P_p,)), s((P_p,)), s((1,)), s((1,)),
                 s((B, D, S, model.F)), s((B, D, S)), s((B, D, 3)),
                 s((B, model.F)), s((B, D)), s((B,), I32), s((B,)), s((B,)),
                 s((model.F,)), s((3,))],
                B=B, D=D, S=S)

    # Fused per-step artifact (cost fwd + policy fwd in one call) — the
    # placement hot path. E=16 serves lockstep training episodes, E=1
    # serves greedy inference without paying for idle lanes.
    for D, S in TRAIN_VARIANTS + [ULTRA]:
        for E in (E_FWD, 1):
            em.artifact(
                f"mdp_step_d{D}s{S}_e{E}",
                model.mdp_step,
                [s((P_c,)), s((P_p,)), s((E, D, S, model.F)), s((E, D, S)),
                 s((E, D)), s((E, model.F)), s((E, D)), s((model.F,)),
                 s((3,))],
                E=E, D=D, S=S)

    em.artifact(
        "table_cost",
        functools.partial(model.table_cost_forward, use_pallas=True),
        [s((P_c,)), s((N_TBL, model.F)), s((model.F,))],
        N=N_TBL)


def emit_reduction_ablation(em):
    """Alternate reductions for Figures 13-14 (D=4 variant only)."""
    P_c = model.cost_spec().total
    D, S, B = 4, 48, B_COST
    combos = [("max", "max"), ("mean", "max"), ("sum", "sum"), ("sum", "mean")]
    for tr, dr in combos:
        em.artifact(
            f"cost_train_red_{tr}_{dr}_d{D}s{S}",
            functools.partial(model.cost_train_step, table_red=tr, dev_red=dr),
            [s((P_c,)), s((P_c,)), s((P_c,)), s((1,)), s((1,)),
             s((B, D, S, model.F)), s((B, D, S)), s((B, D)), s((B, D, 3)),
             s((B,)), s((model.F,))],
            B=B, D=D, S=S, table_red=tr, dev_red=dr)
        em.artifact(
            f"cost_fwd_red_{tr}_{dr}_d{D}s{S}",
            functools.partial(model.cost_forward, table_red=tr, dev_red=dr),
            [s((P_c,)), s((E_FWD, D, S, model.F)), s((E_FWD, D, S)),
             s((E_FWD, D)), s((model.F,))],
            E=E_FWD, D=D, S=S, table_red=tr, dev_red=dr)


def emit_rnn(em):
    for D in (2, 4, 8):
        spec = model.rnn_spec(D)
        em.params(f"rnn_d{D}", spec)
        P = spec.total
        em.artifact(
            f"rnn_fwd_d{D}",
            functools.partial(model.rnn_logits, n_devices=D),
            [s((P,)), s((E_FWD, T_RNN, model.F)), s((E_FWD, T_RNN)),
             s((E_FWD, T_RNN, D)), s((model.F,))],
            E=E_FWD, T=T_RNN, D=D)
        em.artifact(
            f"rnn_train_d{D}",
            functools.partial(model.rnn_train_step, n_devices=D),
            [s((P,)), s((P,)), s((P,)), s((1,)), s((1,)),
             s((E_RNN, T_RNN, model.F)), s((E_RNN, T_RNN)),
             s((E_RNN, T_RNN, D)), s((E_RNN, T_RNN), I32), s((E_RNN,)),
             s((model.F,))],
            E=E_RNN, T=T_RNN, D=D)


def emit_dlrm(em):
    hs = dlrm_mod.dlrm_hash_sizes()
    spec = dlrm_mod.dlrm_spec(hs)
    em.params("dlrm", spec)
    em.lines.append("dlrm_hash " + " ".join(str(v) for v in hs))
    em.const("DLRM_B", DLRM_B)
    em.const("DLRM_POOL", dlrm_mod.POOL)
    em.const("DLRM_NDENSE", dlrm_mod.N_DENSE)
    em.const("DLRM_DIM", dlrm_mod.EMB_DIM)
    P = spec.total
    B, N, Pl = DLRM_B, len(hs), dlrm_mod.POOL
    em.artifact(
        "dlrm_fwd",
        functools.partial(dlrm_mod.dlrm_forward, hash_sizes=hs, use_pallas=True),
        [s((P,)), s((B, dlrm_mod.N_DENSE)), s((B, N, Pl), I32), s((B, N, Pl))],
        B=B, N=N, P=Pl)
    em.artifact(
        "dlrm_train",
        functools.partial(dlrm_mod.dlrm_train_step, hash_sizes=hs),
        [s((P,)), s((P,)), s((P,)), s((1,)), s((1,)),
         s((B, dlrm_mod.N_DENSE)), s((B, N, Pl), I32), s((B, N, Pl)), s((B,))],
        B=B, N=N, P=Pl)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma list of groups: core,rnn,ablation,dlrm")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    groups = set((args.only or "core,rnn,ablation,dlrm").split(","))

    em = Emitter(args.out)
    em.const("F", model.F)
    em.const("L", model.L)
    em.const("E_FWD", E_FWD)
    em.const("B_COST", B_COST)
    em.const("N_TBL", N_TBL)
    em.const("T_RNN", T_RNN)
    em.const("E_RNN", E_RNN)
    if "core" in groups:
        emit_cost_policy(em)
    if "ablation" in groups:
        emit_reduction_ablation(em)
    if "rnn" in groups:
        emit_rnn(em)
    if "dlrm" in groups:
        emit_dlrm(em)
    em.finish()
    print(f"manifest: {len(em.lines)} lines -> {args.out}/manifest.txt")


if __name__ == "__main__":
    main()
