"""Layer-2 JAX models: DreamShard's cost network, policy network, and the
RNN-based baseline controller (Mirhoseini et al. 2017, adapted per paper
section D.2).

Everything here is build-time only: ``aot.py`` lowers these functions to HLO
text once, and the rust coordinator executes them via PJRT. Parameters are
flat f32 vectors (see ``params.py``).

Design notes
------------
* Forward (request-path) artifacts route their dense layers and reductions
  through the Pallas kernels (``use_pallas=True``); training artifacts use
  the pure-jnp references because ``pallas_call`` does not define reverse-
  mode AD rules — XLA fuses the jnp path identically. The pytest suite
  asserts the two paths agree to float tolerance.
* ``fmask`` (21) and ``qscale`` (3) inputs let the rust harness run the
  paper's feature ablations (Table 3/11/12: drop dim / hash / pooling /
  size / distribution / cost features) against the SAME artifacts by
  zeroing feature columns at train+inference time.
* Reductions are parameters (``table_red``, ``dev_red``) so ``aot.py`` can
  emit the sum/mean/max ablation variants of Figures 13-14.
"""

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .params import ParamSpec, adam_update

F = 21          # table features (section A.2)
L = 32          # latent dim
H_TBL = 128     # shared table-MLP hidden
H_HEAD = 64     # prediction-head hidden
H_COST = 64     # policy cost-feature MLP hidden
ENTROPY_W = 0.001


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------

def cost_spec():
    s = ParamSpec()
    s.linear("tbl1", F, H_TBL).linear("tbl2", H_TBL, L)
    for head in ("fwd", "bwd", "comm"):
        s.linear(f"{head}1", L, H_HEAD).linear(f"{head}2", H_HEAD, 1)
    s.linear("ovr1", L, H_HEAD).linear("ovr2", H_HEAD, 1)
    return s


def policy_spec():
    s = ParamSpec()
    s.linear("tbl1", F, H_TBL).linear("tbl2", H_TBL, L)
    s.linear("cost1", 3, H_COST).linear("cost2", H_COST, L)
    # Head input: [device rep ; cost rep ; current-table rep] (see DESIGN.md)
    s.linear("head", 3 * L, 1)
    return s


def rnn_spec(n_devices):
    s = ParamSpec()
    s.linear("tbl1", F, H_TBL).linear("tbl2", H_TBL, L)
    for gate in ("z", "r", "n"):
        s.linear(f"gru_x{gate}", L, L)
        s.linear(f"gru_h{gate}", L, L)
    s.linear("head", 2 * L, n_devices)
    return s


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def _mlp2(p, pre, x, use_pallas):
    """Two-layer MLP with ReLU hidden, over rows of a 2-D x."""
    if use_pallas:
        h = kernels.linear(x, p[f"{pre}1.w"], p[f"{pre}1.b"], relu=True)
        return kernels.linear(h, p[f"{pre}2.w"], p[f"{pre}2.b"], relu=False)
    h = ref.linear_ref(x, p[f"{pre}1.w"], p[f"{pre}1.b"], relu=True)
    return ref.linear_ref(h, p[f"{pre}2.w"], p[f"{pre}2.b"], relu=False)


def _table_reps(p, pre, feats, fmask, use_pallas):
    """Shared table-feature MLP over an arbitrarily-shaped [..., F] grid."""
    shape = feats.shape
    x = (feats * fmask).reshape(-1, F)
    h = _mlp2(p, pre, x, use_pallas)
    return h.reshape(*shape[:-1], L)


def _device_reduce(h, mask, table_red, use_pallas):
    """[E,D,S,L],[E,D,S] -> [E,D,L] with the chosen table reduction."""
    E, D, S, _ = h.shape
    if table_red == "sum":
        if use_pallas:
            out = kernels.device_sum(h.reshape(E * D, S, L), mask.reshape(E * D, S))
            return out.reshape(E, D, L)
        return ref.device_sum_ref(h, mask)
    m = mask[..., None]
    if table_red == "mean":
        return jnp.sum(h * m, axis=-2) / jnp.maximum(jnp.sum(m, axis=-2), 1.0)
    if table_red == "max":
        neg = jnp.float32(-1e30)
        r = jnp.max(jnp.where(m > 0, h, neg), axis=-2)
        return jnp.where(jnp.sum(m, axis=-2) > 0, r, 0.0)
    raise ValueError(table_red)


def _overall_reduce(hdev, dmask, dev_red, use_pallas):
    """[E,D,L],[E,D] -> [E,L] with the chosen device reduction."""
    if dev_red == "max":
        if use_pallas:
            E, D, _ = hdev.shape
            return jax.vmap(kernels.overall_max)(hdev, dmask)
        return jax.vmap(ref.overall_max_ref)(hdev, dmask)
    m = dmask[..., None]
    if dev_red == "sum":
        return jnp.sum(hdev * m, axis=-2)
    if dev_red == "mean":
        return jnp.sum(hdev * m, axis=-2) / jnp.maximum(jnp.sum(m, axis=-2), 1.0)
    raise ValueError(dev_red)


# --------------------------------------------------------------------------
# Cost network (paper section 3.2 / B.1)
# --------------------------------------------------------------------------

def cost_forward(theta, feats, mask, dmask, fmask, *, use_pallas=False,
                 table_red="sum", dev_red="max"):
    """Predict per-device cost features and the overall step cost.

    feats [E,D,S,F], mask [E,D,S], dmask [E,D], fmask [F]
    -> q [E,D,3] (fwd comp, bwd comp, bwd comm; ms), cost [E] (ms)
    """
    p = cost_spec().unflatten(theta)
    h = _table_reps(p, "tbl", feats, fmask, use_pallas)        # [E,D,S,L]
    hdev = _device_reduce(h, mask, table_red, use_pallas)      # [E,D,L]
    E, D, _ = hdev.shape
    flat = hdev.reshape(E * D, L)
    qs = [
        _mlp2(p, head, flat, use_pallas).reshape(E, D)
        for head in ("fwd", "bwd", "comm")
    ]
    q = jnp.stack(qs, axis=-1) * dmask[..., None]              # [E,D,3]
    hall = _overall_reduce(hdev, dmask, dev_red, use_pallas)   # [E,L]
    cost = _mlp2(p, "ovr", hall, use_pallas).reshape(E)
    return q, cost


def table_cost_forward(theta, feats, fmask, *, use_pallas=False):
    """Predicted single-table total cost (used to sort tables before an
    episode, section B.4.2): feats [N,F] -> [N] (sum of the three heads)."""
    p = cost_spec().unflatten(theta)
    h = _table_reps(p, "tbl", feats, fmask, use_pallas)        # [N,L]
    total = sum(
        _mlp2(p, head, h, use_pallas).reshape(-1)
        for head in ("fwd", "bwd", "comm")
    )
    return total


def cost_loss(theta, batch, fmask, table_red="sum", dev_red="max"):
    """Eq. 1: sum of cost-feature MSE and overall-cost MSE."""
    feats, mask, dmask, q_tgt, c_tgt = batch
    q, c = cost_forward(theta, feats, mask, dmask, fmask,
                        table_red=table_red, dev_red=dev_red)
    dn = jnp.maximum(jnp.sum(dmask), 1.0)
    mse_q = jnp.sum(((q - q_tgt) ** 2) * dmask[..., None]) / (dn * 3.0)
    mse_c = jnp.mean((c - c_tgt) ** 2)
    return mse_q + mse_c


def cost_train_step(theta, m, v, t, lr, feats, mask, dmask, q_tgt, c_tgt,
                    fmask, table_red="sum", dev_red="max"):
    batch = (feats, mask, dmask, q_tgt, c_tgt)
    loss, grads = jax.value_and_grad(cost_loss)(
        theta, batch, fmask, table_red=table_red, dev_red=dev_red)
    theta2, m2, v2 = adam_update(None, theta, m, v, t, lr, grads)
    return theta2, m2, v2, jnp.reshape(loss, (1,))


# --------------------------------------------------------------------------
# Policy network (paper section 3.3 / B.2)
# --------------------------------------------------------------------------

def policy_logits(phi, feats, mask, q, cur, legal, fmask, qscale,
                  *, use_pallas=False):
    """Device logits for the table currently being placed.

    feats [E,D,S,F], mask [E,D,S], q [E,D,3] (cost features from the
    estimated MDP), cur [E,F] (current table), legal [E,D], fmask [F],
    qscale [3] -> logits [E,D] (illegal devices = -1e9).
    """
    p = policy_spec().unflatten(phi)
    h = _table_reps(p, "tbl", feats, fmask, use_pallas)        # [E,D,S,L]
    hdev = _device_reduce(h, mask, "sum", use_pallas)          # [E,D,L]
    E, D, _ = hdev.shape
    hq = _mlp2(p, "cost", (q * qscale).reshape(E * D, 3), use_pallas)
    hq = hq.reshape(E, D, L)
    hcur = _table_reps(p, "tbl", cur, fmask, use_pallas)       # [E,L]
    hcur = jnp.broadcast_to(hcur[:, None, :], (E, D, L))
    x = jnp.concatenate([hdev, hq, hcur], axis=-1).reshape(E * D, 3 * L)
    score = ref.linear_ref(x, p["head.w"], p["head.b"]).reshape(E, D)
    return jnp.where(legal > 0, score, -1e9)


def _reinforce_loss(logits, legal, action, adv, smask):
    """REINFORCE with baseline-subtracted advantage + entropy bonus (Eq. 2)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    B = logits.shape[0]
    lp_a = logp[jnp.arange(B), action]
    pr = jnp.exp(logp)
    ent = -jnp.sum(jnp.where(legal > 0, pr * logp, 0.0), axis=-1)
    per_step = lp_a * adv + ENTROPY_W * ent
    n = jnp.maximum(jnp.sum(smask), 1.0)
    return -jnp.sum(per_step * smask) / n


def policy_loss(phi, batch, fmask, qscale):
    feats, mask, q, cur, legal, action, adv, smask = batch
    logits = policy_logits(phi, feats, mask, q, cur, legal, fmask, qscale)
    return _reinforce_loss(logits, legal, action, adv, smask)


def policy_train_step(phi, m, v, t, lr, feats, mask, q, cur, legal, action,
                      adv, smask, fmask, qscale):
    batch = (feats, mask, q, cur, legal, action, adv, smask)
    loss, grads = jax.value_and_grad(policy_loss)(phi, batch, fmask, qscale)
    phi2, m2, v2 = adam_update(None, phi, m, v, t, lr, grads)
    return phi2, m2, v2, jnp.reshape(loss, (1,))


def mdp_step(theta, phi, feats, mask, dmask, cur, legal, fmask, qscale,
             *, use_pallas=True):
    """Fused estimated-MDP step: one PJRT call per placement decision.

    Runs the cost network to get the augmented state's cost features and
    overall cost, then the policy network on top of them — halving the
    per-step call count on the rust hot path (see EXPERIMENTS.md §Perf).
    Returns (logits [E,D], q [E,D,3], cost [E]).
    """
    q, cost = cost_forward(theta, feats, mask, dmask, fmask,
                           use_pallas=use_pallas)
    logits = policy_logits(phi, feats, mask, q, cur, legal, fmask, qscale,
                           use_pallas=use_pallas)
    return logits, q, cost


# --------------------------------------------------------------------------
# RNN-based baseline (Mirhoseini et al. 2017, adapted per section D.2)
# --------------------------------------------------------------------------

def _gru_cell(p, x, h):
    z = jax.nn.sigmoid(x @ p["gru_xz.w"] + p["gru_xz.b"] + h @ p["gru_hz.w"] + p["gru_hz.b"])
    r = jax.nn.sigmoid(x @ p["gru_xr.w"] + p["gru_xr.b"] + h @ p["gru_hr.w"] + p["gru_hr.b"])
    n = jnp.tanh(x @ p["gru_xn.w"] + p["gru_xn.b"] + (r * h) @ p["gru_hn.w"] + p["gru_hn.b"])
    return (1.0 - z) * h + z * n


def rnn_logits(psi, feats, tmask, legal, fmask, n_devices):
    """GRU + content attention over the table sequence -> per-step logits.

    feats [E,T,F], tmask [E,T], legal [E,T,D] -> [E,T,D].
    The controller sees the whole (known) table list; the same feature-
    extraction MLP as DreamShard is used for fairness (section D.2).
    """
    p = rnn_spec(n_devices).unflatten(psi)
    reps = _table_reps(p, "tbl", feats, fmask, use_pallas=False)  # [E,T,L]

    def step(h, x):
        h2 = _gru_cell(p, x, h)
        return h2, h2

    E, T, _ = reps.shape
    h0 = jnp.zeros((E, L))
    _, hs = jax.lax.scan(step, h0, jnp.swapaxes(reps, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)                                   # [E,T,L]
    att = jnp.einsum("etl,eul->etu", hs, hs) / jnp.sqrt(jnp.float32(L))
    att = jnp.where(tmask[:, None, :] > 0, att, -1e9)
    ctx = jnp.einsum("etu,eul->etl", jax.nn.softmax(att, axis=-1), hs)
    x = jnp.concatenate([hs, ctx], axis=-1)                       # [E,T,2L]
    score = x @ p["head.w"] + p["head.b"]                         # [E,T,D]
    return jnp.where(legal > 0, score, -1e9)


def rnn_loss(psi, batch, fmask, n_devices):
    feats, tmask, legal, action, adv = batch
    logits = rnn_logits(psi, feats, tmask, legal, fmask, n_devices)
    E, T, D = logits.shape
    flat = logits.reshape(E * T, D)
    return _reinforce_loss(
        flat, legal.reshape(E * T, D), action.reshape(E * T),
        jnp.repeat(adv, T), tmask.reshape(E * T))


def rnn_train_step(psi, m, v, t, lr, feats, tmask, legal, action, adv,
                   fmask, n_devices):
    batch = (feats, tmask, legal, action, adv)
    loss, grads = jax.value_and_grad(rnn_loss)(psi, batch, fmask, n_devices)
    psi2, m2, v2 = adam_update(None, psi, m, v, t, lr, grads)
    return psi2, m2, v2, jnp.reshape(loss, (1,))
