"""Flat-parameter plumbing shared by every AOT artifact.

All learnable parameters of a network are packed into ONE flat f32 vector
(and matching flat Adam ``m``/``v`` vectors). The rust coordinator then
threads a fixed, tiny literal arity through every PJRT call instead of
dozens of tensors, and can (re)initialize parameters itself: the manifest
records each segment's (offset, length, init bound) so rust draws
uniform(-bound, +bound) exactly like PyTorch's default Linear init, which
is what the paper uses (section B.1).
"""

import math

import jax.numpy as jnp
import numpy as np


class ParamSpec:
    """Ordered list of named tensors living inside one flat vector."""

    def __init__(self):
        self.entries = []  # (name, shape, offset, length, init_bound)
        self.total = 0

    def add(self, name, shape, fan_in=None):
        length = int(np.prod(shape))
        # PyTorch nn.Linear default: U(-1/sqrt(fan_in), 1/sqrt(fan_in))
        # for both weight and bias.
        bound = 1.0 / math.sqrt(fan_in) if fan_in else 0.0
        self.entries.append((name, tuple(shape), self.total, length, bound))
        self.total += length
        return self

    def linear(self, name, n_in, n_out):
        """Register a dense layer's weight [n_in, n_out] and bias [n_out]."""
        self.add(f"{name}.w", (n_in, n_out), fan_in=n_in)
        self.add(f"{name}.b", (n_out,), fan_in=n_in)
        return self

    def unflatten(self, theta):
        """Slice the flat vector into a {name: tensor} dict (in-graph)."""
        out = {}
        for name, shape, off, length, _ in self.entries:
            out[name] = jnp.reshape(theta[off : off + length], shape)
        return out

    def init(self, seed):
        """Host-side init (used for artifact freezing + python tests)."""
        rng = np.random.default_rng(seed)
        theta = np.zeros((self.total,), dtype=np.float32)
        for _, _, off, length, bound in self.entries:
            theta[off : off + length] = rng.uniform(-bound, bound, length)
        return jnp.asarray(theta)

    def manifest_lines(self, net):
        """``segment <net> <name> <offset> <len> <bound>`` manifest rows."""
        lines = [f"params {net} {self.total}"]
        for name, _, off, length, bound in self.entries:
            lines.append(f"segment {net} {name} {off} {length} {bound:.8f}")
        return lines


def adam_update(spec_total, theta, m, v, t, lr, grads, eps=1e-8, b1=0.9, b2=0.999):
    """One Adam step over flat vectors. ``t`` is the 1-step count AFTER this
    update (f32[1]); ``lr`` is the already-decayed learning rate (f32[1])."""
    del spec_total
    m2 = b1 * m + (1.0 - b1) * grads
    v2 = b2 * v + (1.0 - b2) * grads * grads
    mhat = m2 / (1.0 - b1 ** t[0])
    vhat = v2 / (1.0 - b2 ** t[0])
    theta2 = theta - lr[0] * mhat / (jnp.sqrt(vhat) + eps)
    return theta2, m2, v2
