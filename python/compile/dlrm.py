"""Layer-2 DLRM model (Naumov et al. 2019) for the end-to-end driver.

This is the recommendation model whose embedding tables DreamShard places.
``examples/dlrm_e2e.rs`` trains it for a few hundred steps on synthetic
click data through the AOT ``dlrm_train`` artifact, logging the loss curve,
and reports the simulated distributed step time under different placements
(the placement does not change the math — the tables are sharded
model-parallel — so a single-process run validates numerics while the
simulator accounts the distributed cost; see DESIGN.md Substitutions).

Architecture (section A.1 / Figure 9): bottom MLP over dense features,
embedding-bag lookup per sparse feature (the Pallas hot-spot kernel on the
forward path), pairwise-dot feature interaction, top MLP, BCE loss.
"""

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .params import ParamSpec, adam_update

N_DENSE = 13
EMB_DIM = 32
POOL = 8          # max pooling factor per sample (padded)


def dlrm_hash_sizes(n_tables=26, seed=7):
    """Deterministic per-table vocabulary sizes, power-law-ish like the
    DLRM dataset (Figure 15): most ~1e4, a few large."""
    import numpy as np
    rng = np.random.default_rng(seed)
    sizes = (10 ** rng.uniform(3.3, 4.6, n_tables)).astype(int)
    return [int(s) for s in sizes]


def dlrm_spec(hash_sizes):
    s = ParamSpec()
    for i, v in enumerate(hash_sizes):
        s.add(f"emb{i}", (v, EMB_DIM), fan_in=EMB_DIM)
    s.linear("bot1", N_DENSE, 128).linear("bot2", 128, 64).linear("bot3", 64, EMB_DIM)
    n = len(hash_sizes) + 1
    n_int = n * (n - 1) // 2
    s.linear("top1", n_int + EMB_DIM, 256).linear("top2", 256, 64).linear("top3", 64, 1)
    return s


def _mlp3(p, pre, x):
    h = ref.linear_ref(x, p[f"{pre}1.w"], p[f"{pre}1.b"], relu=True)
    h = ref.linear_ref(h, p[f"{pre}2.w"], p[f"{pre}2.b"], relu=True)
    return ref.linear_ref(h, p[f"{pre}3.w"], p[f"{pre}3.b"])


def dlrm_forward(theta, dense, idx, w, hash_sizes, *, use_pallas=False):
    """Click logits. dense [B,13], idx [B,N,P] i32, w [B,N,P] -> [B]."""
    p = dlrm_spec(hash_sizes).unflatten(theta)
    bags = []
    for i in range(len(hash_sizes)):
        bag = kernels.embedding_bag if use_pallas else ref.embedding_bag_ref
        bags.append(bag(p[f"emb{i}"], idx[:, i, :], w[:, i, :]))  # [B,E]
    bot = _mlp3(p, "bot", dense)                                  # [B,E]
    feats = jnp.stack([bot] + bags, axis=1)                       # [B,n,E]
    inter = jnp.einsum("bne,bme->bnm", feats, feats)
    n = feats.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    pairs = inter[:, iu, ju]                                      # [B,n(n-1)/2]
    top_in = jnp.concatenate([bot, pairs], axis=-1)
    return _mlp3(p, "top", top_in).reshape(-1)


def dlrm_loss(theta, batch, hash_sizes):
    dense, idx, w, labels = batch
    logits = dlrm_forward(theta, dense, idx, w, hash_sizes)
    # numerically-stable BCE with logits
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def dlrm_train_step(theta, m, v, t, lr, dense, idx, w, labels, hash_sizes):
    batch = (dense, idx, w, labels)
    loss, grads = jax.value_and_grad(dlrm_loss)(theta, batch, hash_sizes)
    theta2, m2, v2 = adam_update(None, theta, m, v, t, lr, grads)
    return theta2, m2, v2, jnp.reshape(loss, (1,))
