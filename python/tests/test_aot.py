"""AOT pipeline tests: HLO-text lowering round-trips, manifest grammar,
and parameter-spec bookkeeping."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.params import ParamSpec, adam_update

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_basic():
    f = lambda x: (x * 2.0 + 1.0,)
    text = aot.to_hlo_text(f, aot.s((3,)))
    assert "HloModule" in text
    assert "f32[3]" in text


def test_to_hlo_text_cost_forward_has_all_params():
    P = model.cost_spec().total
    import functools
    fn = functools.partial(model.cost_forward)
    text = aot.to_hlo_text(
        fn, aot.s((P,)), aot.s((2, 4, 8, model.F)), aot.s((2, 4, 8)),
        aot.s((2, 4)), aot.s((model.F,)))
    # all five inputs must survive lowering as entry parameters (the rust
    # runtime passes literals positionally)
    assert text.count("parameter(") >= 5
    assert f"f32[{P}]" in text


def test_param_spec_offsets_contiguous():
    spec = model.cost_spec()
    off = 0
    for name, shape, o, length, bound in spec.entries:
        assert o == off, name
        assert length == int(np.prod(shape))
        off += length
    assert off == spec.total


def test_param_spec_init_bounds():
    spec = model.policy_spec()
    theta = np.asarray(spec.init(0))
    for name, _, off, length, bound in spec.entries:
        seg = theta[off : off + length]
        assert np.all(np.abs(seg) <= bound + 1e-7), name


def test_adam_update_moves_toward_gradient():
    theta = jnp.zeros((4,))
    g = jnp.asarray([1.0, -1.0, 2.0, 0.0])
    t2, m2, v2 = adam_update(None, theta, theta, theta, jnp.asarray([1.0]),
                             jnp.asarray([0.1]), g)
    assert float(t2[0]) < 0 and float(t2[1]) > 0 and float(t2[3]) == 0.0
    assert m2.shape == v2.shape == theta.shape


def test_manifest_lines_grammar():
    spec = ParamSpec().linear("l1", 3, 5)
    lines = spec.manifest_lines("net")
    assert lines[0] == "params net 20"
    assert lines[1].startswith("segment net l1.w 0 15 ")
    assert lines[2].startswith("segment net l1.b 15 5 ")


def test_emitted_manifest_consistent_with_artifacts(tmp_path=None):
    """If artifacts were built (make artifacts), the manifest must point at
    existing files and declare the networks rust expects."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        import pytest
        pytest.skip("artifacts not built")
    nets = set()
    files = []
    for line in open(manifest):
        parts = line.split()
        if not parts:
            continue
        if parts[0] == "params":
            nets.add(parts[1])
        elif parts[0] == "artifact":
            files.append(parts[2])
    for need in ("cost", "policy", "dlrm"):
        assert need in nets, f"network {need} missing from manifest"
    for fn in files:
        assert os.path.exists(os.path.join(art, fn)), fn
