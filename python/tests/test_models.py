"""Layer-2 model tests: shapes, masking/generalization invariants,
pallas/jnp path parity, optimizer behaviour, and DLRM learning signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dlrm, model

jax.config.update("jax_platform_name", "cpu")

F = model.F


def rnd(seed, *shape):
    return jnp.asarray(np.random.default_rng(seed).random(shape).astype(np.float32))


@pytest.fixture(scope="module")
def theta():
    return model.cost_spec().init(0)


@pytest.fixture(scope="module")
def phi():
    return model.policy_spec().init(1)


ONES_F = jnp.ones((F,), jnp.float32)


def state(seed, e=2, d=4, s=8, frac=0.5):
    rng = np.random.default_rng(seed)
    feats = jnp.asarray(rng.random((e, d, s, F)).astype(np.float32))
    mask = jnp.asarray((rng.random((e, d, s)) < frac).astype(np.float32))
    dmask = jnp.ones((e, d), jnp.float32)
    return feats, mask, dmask


# ------------------------------------------------------------ cost network

def test_cost_forward_shapes(theta):
    feats, mask, dmask = state(0)
    q, c = model.cost_forward(theta, feats, mask, dmask, ONES_F)
    assert q.shape == (2, 4, 3) and c.shape == (2,)


def test_cost_pallas_parity(theta):
    feats, mask, dmask = state(1)
    q1, c1 = model.cost_forward(theta, feats, mask, dmask, ONES_F)
    q2, c2 = model.cost_forward(theta, feats, mask, dmask, ONES_F, use_pallas=True)
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)


def test_cost_masked_devices_output_zero_q(theta):
    feats, mask, _ = state(2)
    dmask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]] * 2)
    q, _ = model.cost_forward(theta, feats, mask, dmask, ONES_F)
    np.testing.assert_allclose(q[:, 2:, :], np.zeros((2, 2, 3)))


def test_cost_generalizes_padding_invariance(theta):
    """A state padded with extra empty slots/devices must predict the same
    q for real devices — the paper's variable-size generalization."""
    feats, mask, dmask = state(3, e=1, d=2, s=4)
    q_small, c_small = model.cost_forward(theta, feats, mask, dmask, ONES_F)
    # embed into d=4, s=8 padding
    feats_big = jnp.zeros((1, 4, 8, F)).at[:, :2, :4, :].set(feats)
    mask_big = jnp.zeros((1, 4, 8)).at[:, :2, :4].set(mask)
    dmask_big = jnp.zeros((1, 4)).at[:, :2].set(1.0)
    q_big, _ = model.cost_forward(theta, feats_big, mask_big, dmask_big, ONES_F)
    np.testing.assert_allclose(q_small[0], q_big[0, :2, :], rtol=1e-4, atol=1e-5)


def test_cost_fmask_removes_feature_influence(theta):
    feats, mask, dmask = state(4)
    fmask = ONES_F.at[0].set(0.0)
    q1, _ = model.cost_forward(theta, feats, mask, dmask, fmask)
    feats2 = feats.at[..., 0].set(99.0)  # perturb the masked feature
    q2, _ = model.cost_forward(theta, feats2, mask, dmask, fmask)
    np.testing.assert_allclose(q1, q2, rtol=1e-5, atol=1e-6)


def test_cost_train_step_reduces_loss(theta):
    feats, mask, dmask = state(5, e=8)
    q_tgt = rnd(6, 8, 4, 3)
    c_tgt = rnd(7, 8)
    t = theta
    m = jnp.zeros_like(t)
    v = jnp.zeros_like(t)
    losses = []
    for i in range(25):
        t, m, v, loss = model.cost_train_step(
            t, m, v, jnp.asarray([float(i + 1)]), jnp.asarray([5e-3]),
            feats, mask, dmask, q_tgt, c_tgt, ONES_F)
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


@settings(max_examples=10, deadline=None)
@given(tr=st.sampled_from(["sum", "mean", "max"]), dr=st.sampled_from(["max", "sum", "mean"]))
def test_reduction_variants_shapes(tr, dr):
    theta = model.cost_spec().init(0)
    feats, mask, dmask = state(8)
    q, c = model.cost_forward(theta, feats, mask, dmask, ONES_F, table_red=tr, dev_red=dr)
    assert q.shape == (2, 4, 3) and c.shape == (2,)
    assert np.isfinite(np.asarray(q)).all() and np.isfinite(np.asarray(c)).all()


def test_table_cost_matches_singleton_device(theta):
    """Single-table cost head == cost_forward on a device with 1 table."""
    feats = rnd(9, 3, F)
    singles = model.table_cost_forward(theta, feats, ONES_F)
    big = jnp.zeros((1, 4, 8, F)).at[0, 0, 0].set(feats[0])
    mask = jnp.zeros((1, 4, 8)).at[0, 0, 0].set(1.0)
    dmask = jnp.zeros((1, 4)).at[0, 0].set(1.0)
    q, _ = model.cost_forward(theta, big, mask, dmask, ONES_F)
    np.testing.assert_allclose(float(singles[0]), float(jnp.sum(q[0, 0])), rtol=1e-4)


# ---------------------------------------------------------- policy network

def test_policy_logits_mask_illegal(phi):
    feats, mask, _ = state(10)
    q = rnd(11, 2, 4, 3)
    cur = rnd(12, 2, F)
    legal = jnp.asarray([[1.0, 0.0, 1.0, 1.0], [1.0, 1.0, 0.0, 1.0]])
    logits = model.policy_logits(phi, feats, mask, q, cur, legal, ONES_F, jnp.ones((3,)))
    assert float(logits[0, 1]) < -1e8
    assert float(logits[1, 2]) < -1e8
    assert np.isfinite(np.asarray(logits)[0, 0])


def test_policy_depends_on_current_table(phi):
    feats, mask, _ = state(13)
    q = rnd(14, 2, 4, 3)
    legal = jnp.ones((2, 4))
    l1 = model.policy_logits(phi, feats, mask, q, rnd(15, 2, F), legal, ONES_F, jnp.ones((3,)))
    l2 = model.policy_logits(phi, feats, mask, q, rnd(16, 2, F), legal, ONES_F, jnp.ones((3,)))
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_policy_qscale_zero_removes_cost_influence(phi):
    feats, mask, _ = state(17)
    cur = rnd(18, 2, F)
    legal = jnp.ones((2, 4))
    z = jnp.zeros((3,))
    l1 = model.policy_logits(phi, feats, mask, rnd(19, 2, 4, 3), cur, legal, ONES_F, z)
    l2 = model.policy_logits(phi, feats, mask, rnd(20, 2, 4, 3), cur, legal, ONES_F, z)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)


def test_policy_train_improves_selected_action_prob(phi):
    """REINFORCE with positive advantage on one action raises its prob."""
    feats, mask, _ = state(21, e=4)
    q = jnp.zeros((4, 4, 3))
    cur = rnd(22, 4, F)
    legal = jnp.ones((4, 4))
    action = jnp.asarray([1, 1, 1, 1], jnp.int32)
    adv = jnp.ones((4,))
    smask = jnp.ones((4,))
    p = phi
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    def prob_of_1(pp):
        lg = model.policy_logits(pp, feats, mask, q, cur, legal, ONES_F, jnp.ones((3,)))
        return float(jax.nn.softmax(lg, axis=-1)[0, 1])
    before = prob_of_1(p)
    for i in range(20):
        p, m, v, _ = model.policy_train_step(
            p, m, v, jnp.asarray([float(i + 1)]), jnp.asarray([5e-3]),
            feats, mask, q, cur, legal, action, adv, smask, ONES_F, jnp.ones((3,)))
    assert prob_of_1(p) > before


def test_mdp_step_fused_matches_separate(theta, phi):
    feats, mask, dmask = state(23)
    cur = rnd(24, 2, F)
    legal = jnp.ones((2, 4))
    qs = jnp.ones((3,))
    lg, q, c = model.mdp_step(theta, phi, feats, mask, dmask, cur, legal, ONES_F, qs,
                              use_pallas=False)
    q2, c2 = model.cost_forward(theta, feats, mask, dmask, ONES_F)
    lg2 = model.policy_logits(phi, feats, mask, q2, cur, legal, ONES_F, qs)
    np.testing.assert_allclose(q, q2, rtol=1e-6)
    np.testing.assert_allclose(c, c2, rtol=1e-6)
    np.testing.assert_allclose(lg, lg2, rtol=1e-6)


# ------------------------------------------------------------ RNN baseline

def test_rnn_logits_shape_and_mask():
    psi = model.rnn_spec(4).init(2)
    feats = rnd(25, 2, 6, F)
    tmask = jnp.ones((2, 6))
    legal = jnp.ones((2, 6, 4)).at[0, 0, 2].set(0.0)
    lg = model.rnn_logits(psi, feats, tmask, legal, ONES_F, 4)
    assert lg.shape == (2, 6, 4)
    assert float(lg[0, 0, 2]) < -1e8


def test_rnn_is_sequential_not_pointwise():
    """Changing an early table's features must affect later steps' logits
    (the GRU carries state)."""
    psi = model.rnn_spec(2).init(3)
    feats = rnd(26, 1, 5, F)
    tmask = jnp.ones((1, 5))
    legal = jnp.ones((1, 5, 2))
    lg1 = model.rnn_logits(psi, feats, tmask, legal, ONES_F, 2)
    feats2 = feats.at[0, 0].set(feats[0, 0] + 1.0)
    lg2 = model.rnn_logits(psi, feats2, tmask, legal, ONES_F, 2)
    assert float(jnp.max(jnp.abs(lg1[0, 3:] - lg2[0, 3:]))) > 1e-7


def test_rnn_train_step_runs():
    psi = model.rnn_spec(4).init(4)
    feats = rnd(27, 2, 6, F)
    out = model.rnn_train_step(
        psi, psi * 0, psi * 0, jnp.ones((1,)), jnp.asarray([5e-4]),
        feats, jnp.ones((2, 6)), jnp.ones((2, 6, 4)),
        jnp.zeros((2, 6), jnp.int32), jnp.asarray([0.5, -0.5]), ONES_F, 4)
    assert out[0].shape == psi.shape
    assert np.isfinite(float(out[3][0]))


# -------------------------------------------------------------------- DLRM

def test_dlrm_learns_separable_labels():
    hs = dlrm.dlrm_hash_sizes(4)
    spec = dlrm.dlrm_spec(hs)
    theta = spec.init(5)
    rng = np.random.default_rng(6)
    b = 64
    dense = jnp.asarray(rng.random((b, dlrm.N_DENSE)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, min(hs), (b, 4, dlrm.POOL)).astype(np.int32))
    w = jnp.ones((b, 4, dlrm.POOL))
    labels = jnp.asarray((np.asarray(dense[:, 0]) > 0.5).astype(np.float32))
    t, m, v = theta, theta * 0, theta * 0
    losses = []
    for i in range(30):
        t, m, v, loss = dlrm.dlrm_train_step(
            t, m, v, jnp.asarray([float(i + 1)]), jnp.asarray([1e-2]),
            dense, idx, w, labels, hs)
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_dlrm_param_count_reported():
    hs = dlrm.dlrm_hash_sizes()
    total = dlrm.dlrm_spec(hs).total
    emb = sum(hs) * dlrm.EMB_DIM
    assert total > emb  # MLPs on top of the tables
    assert emb / total > 0.8  # embeddings dominate, as in real DLRM
