"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes/dtypes with hypothesis. This is the core numeric signal
for the AOT artifacts (the same kernel code lowers into them)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


# ----------------------------------------------------------------- linear

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 200),
    i=st.integers(1, 40),
    o=st.integers(1, 40),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(b, i, o, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, bb = rand(rng, b, i), rand(rng, i, o), rand(rng, o)
    got = kernels.linear(x, w, bb, relu=relu)
    want = ref.linear_ref(x, w, bb, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_linear_blocks_divide_batch():
    rng = np.random.default_rng(0)
    x, w, b = rand(rng, 256, 8), rand(rng, 8, 4), rand(rng, 4)
    got = kernels.linear(x, w, b, block_rows=128)
    np.testing.assert_allclose(got, ref.linear_ref(x, w, b), rtol=1e-5, atol=1e-5)


def test_linear_relu_clamps():
    rng = np.random.default_rng(1)
    x, w, b = rand(rng, 16, 8), rand(rng, 8, 4), rand(rng, 4)
    got = kernels.linear(x, w, b, relu=True)
    assert float(jnp.min(got)) >= 0.0


# ------------------------------------------------------------- seg_reduce

@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 10),
    s=st.integers(1, 32),
    l=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_device_sum_matches_ref(d, s, l, seed):
    rng = np.random.default_rng(seed)
    h = rand(rng, d, s, l)
    mask = jnp.asarray((rng.random((d, s)) > 0.4).astype(np.float32))
    got = kernels.device_sum(h, mask)
    want = ref.device_sum_ref(h, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 16),
    l=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_overall_max_matches_ref(d, l, seed):
    rng = np.random.default_rng(seed)
    h = rand(rng, d, l)
    dmask = jnp.asarray((rng.random(d) > 0.3).astype(np.float32))
    if float(jnp.sum(dmask)) == 0.0:
        dmask = dmask.at[0].set(1.0)
    got = kernels.overall_max(h, dmask)
    want = ref.overall_max_ref(h, dmask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_device_sum_ignores_masked_slots():
    h = jnp.ones((1, 4, 3))
    mask = jnp.asarray([[1.0, 0.0, 1.0, 0.0]])
    got = kernels.device_sum(h, mask)
    np.testing.assert_allclose(got, np.full((1, 3), 2.0))


def test_overall_max_ignores_masked_devices():
    h = jnp.asarray([[1.0, 5.0], [9.0, 0.5]])
    got = kernels.overall_max(h, jnp.asarray([1.0, 0.0]))
    np.testing.assert_allclose(got, [1.0, 5.0])


# ---------------------------------------------------------- embedding bag

@settings(max_examples=20, deadline=None)
@given(
    v=st.integers(2, 500),
    e=st.integers(1, 32),
    b=st.integers(1, 64),
    p=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_embedding_bag_matches_ref(v, e, b, p, seed):
    rng = np.random.default_rng(seed)
    table = rand(rng, v, e)
    idx = jnp.asarray(rng.integers(0, v, (b, p)).astype(np.int32))
    # random padding pattern via 0/1 weights
    w = jnp.asarray((rng.random((b, p)) > 0.3).astype(np.float32))
    got = kernels.embedding_bag(table, idx, w)
    want = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_embedding_bag_zero_weights_zero_output():
    rng = np.random.default_rng(2)
    table = rand(rng, 10, 4)
    idx = jnp.zeros((3, 5), jnp.int32)
    got = kernels.embedding_bag(table, idx, jnp.zeros((3, 5)))
    np.testing.assert_allclose(got, np.zeros((3, 4)))


def test_embedding_bag_weighted_pooling():
    table = jnp.asarray([[1.0, 2.0], [10.0, 20.0]])
    idx = jnp.asarray([[0, 1]], jnp.int32)
    w = jnp.asarray([[0.5, 2.0]])
    got = kernels.embedding_bag(table, idx, w)
    np.testing.assert_allclose(got, [[0.5 + 20.0, 1.0 + 40.0]])


def test_embedding_bag_under_jit():
    rng = np.random.default_rng(3)
    table = rand(rng, 50, 8)
    idx = jnp.asarray(rng.integers(0, 50, (16, 4)).astype(np.int32))
    w = jnp.ones((16, 4))
    got = jax.jit(kernels.embedding_bag)(table, idx, w)
    np.testing.assert_allclose(got, ref.embedding_bag_ref(table, idx, w), rtol=1e-5)
