//! Property-based invariant tests (seeded randomized sweeps — the offline
//! dependency closure has no proptest, so each property draws many random
//! cases from a deterministic RNG and asserts the invariant on every one).

use dreamshard::baselines::{greedy_placement, random_placement, ALL_EXPERTS};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools, NUM_FEATURES};
use dreamshard::util::Rng;

const CASES: usize = 40;

#[test]
fn prop_placements_complete_and_legal() {
    let ds = gen_dlrm(856, 1);
    let (pool, _) = split_pools(&ds, 2);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(3);
    for case in 0..CASES {
        let n_tables = 5 + rng.below(80);
        let n_dev = [2, 4, 8][rng.below(3)];
        let task = sample_tasks(&pool, n_tables, n_dev, 1, 100 + case as u64).remove(0);
        for e in ALL_EXPERTS {
            let p = greedy_placement(&ds, &task, &sim, e);
            assert_eq!(p.len(), n_tables);
            assert!(p.iter().all(|&d| d < n_dev), "{e:?} produced illegal device");
        }
        let p = random_placement(&ds, &task, &sim, &mut rng);
        assert!(p.iter().all(|&d| d < n_dev));
    }
}

#[test]
fn prop_latency_is_sum_of_phase_maxima() {
    let ds = gen_dlrm(856, 1);
    let (pool, _) = split_pools(&ds, 2);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(4);
    for case in 0..CASES {
        let task = sample_tasks(&pool, 10 + rng.below(60), 4, 1, 200 + case as u64).remove(0);
        let p = random_placement(&ds, &task, &sim, &mut rng);
        let eval = sim.evaluate(&ds, &task, &p);
        let phase = |f: fn(&dreamshard::sim::DeviceTrace) -> f64| {
            eval.devices.iter().map(f).fold(0.0, f64::max)
        };
        let expect = phase(|t| t.fwd_comp)
            + phase(|t| t.fwd_comm)
            + phase(|t| t.bwd_comm)
            + phase(|t| t.bwd_comp);
        assert!((eval.latency - expect).abs() < 1e-9);
        assert!(eval.latency.is_finite() && eval.latency > 0.0);
    }
}

#[test]
fn prop_adding_a_table_roughly_monotone() {
    // Strict monotonicity is deliberately NOT an invariant: the fusion
    // speedup is mix-dependent (Fig. 12), and on real FBGEMM adding a
    // table can shift the fused op into a better-vectorized regime. We
    // assert soft monotonicity (no >15% drop) plus strict monotonicity of
    // the unfused sum.
    let ds = gen_dlrm(400, 5);
    let k = &Simulator::new(SimConfig::default()).kernel;
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let n = 1 + rng.below(12);
        let ids = rng.sample_indices(ds.len(), n + 1);
        let base: Vec<_> = ids[..n].iter().map(|&i| &ds.tables[i]).collect();
        let mut bigger = base.clone();
        bigger.push(&ds.tables[ids[n]]);
        let (f1, b1) = k.device_ms(&base);
        let (f2, b2) = k.device_ms(&bigger);
        assert!(f2 >= f1 * 0.85, "fwd dropped too much: {f1} -> {f2}");
        assert!(b2 >= b1 * 0.85, "bwd dropped too much: {b1} -> {b2}");
        let sum1: f64 = base.iter().map(|t| k.fwd_ms(t)).sum();
        let sum2: f64 = bigger.iter().map(|t| k.fwd_ms(t)).sum();
        assert!(sum2 > sum1, "unfused sum must strictly grow");
    }
}

#[test]
fn prop_features_finite_and_bounded() {
    type Gen = fn(usize, u64) -> dreamshard::tables::Dataset;
    for (seed, gen) in [(7u64, gen_dlrm as Gen), (8, gen_prod as Gen)] {
        let ds = gen(856, seed);
        for t in &ds.tables {
            let f = t.features();
            assert_eq!(f.len(), NUM_FEATURES);
            for (i, &x) in f.iter().enumerate() {
                assert!(x.is_finite() && (-1.0..=60.0).contains(&x), "feature {i} = {x}");
            }
            let reuse = t.reuse_factor();
            assert!((0.0..=1.0).contains(&reuse));
        }
    }
}

/// Smallest f32 whose f64 widening is `>= x` (x > 0).
fn f32_at_least(x: f64) -> f32 {
    let mut c = x as f32; // round-to-nearest: at most one ulp off
    while (c as f64) < x {
        c = f32::from_bits(c.to_bits() + 1);
    }
    c
}

/// Largest f32 whose f64 widening is `< x` (x > 0).
fn f32_just_below(x: f64) -> f32 {
    let mut c = x as f32;
    while (c as f64) >= x {
        c = f32::from_bits(c.to_bits() - 1);
    }
    c
}

#[test]
fn prop_fits_is_inclusive_at_the_exact_memory_boundary() {
    // The MDP's legality rule is `mem + table <= cap`, not `<`: a device
    // filled to the byte is legal. Pin that at the exact f32 boundary —
    // the tightest cap that still admits the table must fit, and one ulp
    // under it must not.
    let ds = gen_dlrm(400, 11);
    let mut rng = Rng::new(12);
    for _ in 0..CASES {
        let n = 1 + rng.below(10);
        let ids = rng.sample_indices(ds.len(), n + 1);
        let group: Vec<&dreamshard::tables::Table> =
            ids[..n].iter().map(|&i| &ds.tables[i]).collect();
        let t = &ds.tables[ids[n]];
        // fp16 weights + fp32 momentum, the same 3x fits() accounts
        let need = Simulator::mem_gb(&group) + t.size_gb() as f64 * 3.0;

        let at = Simulator::new(SimConfig { mem_cap_gb: f32_at_least(need), ..SimConfig::default() });
        assert!(at.fits(&group, t), "cap {} >= need {need} must fit (inclusive)", at.cfg.mem_cap_gb);

        let under =
            Simulator::new(SimConfig { mem_cap_gb: f32_just_below(need), ..SimConfig::default() });
        assert!(
            !under.fits(&group, t),
            "cap {} one ulp under need {need} must not fit",
            under.cfg.mem_cap_gb
        );
    }
}

#[test]
fn prop_train_test_pools_never_leak() {
    for seed in 0..20u64 {
        let ds = gen_dlrm(300, seed);
        let (tr, te) = split_pools(&ds, seed * 13 + 1);
        let tr_set: std::collections::HashSet<_> = tr.iter().collect();
        let tasks = sample_tasks(&te, 20, 4, 5, seed * 7 + 2);
        for task in tasks {
            assert!(task.table_ids.iter().all(|id| !tr_set.contains(id)), "test task uses train table");
        }
    }
}

#[test]
fn prop_comm_monotone_in_added_volume() {
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(9);
    for _ in 0..CASES {
        let d = 2 + rng.below(7);
        let mut dims: Vec<f64> = (0..d).map(|_| 16.0 + rng.below(512) as f64).collect();
        let base: f64 = sim.comm.all_to_all_ms(&dims).iter().cloned().fold(0.0, f64::max);
        let i = rng.below(d);
        dims[i] += 64.0;
        let more: f64 = sim.comm.all_to_all_ms(&dims).iter().cloned().fold(0.0, f64::max);
        assert!(more >= base * 0.999, "adding volume reduced max comm: {base} -> {more}");
    }
}

#[test]
fn prop_expert_greedy_balances_its_own_cost_metric() {
    // The invariant of greedy load balancing: max load <= min load + the
    // largest single item (classic LPT bound witness).
    let ds = gen_prod(856, 3);
    let (pool, _) = split_pools(&ds, 4);
    let sim = Simulator::new(SimConfig::v100());
    let mut rng = Rng::new(10);
    for case in 0..CASES {
        let task = sample_tasks(&pool, 20 + rng.below(40), 4, 1, 300 + case as u64).remove(0);
        for e in ALL_EXPERTS {
            let p = greedy_placement(&ds, &task, &sim, e);
            let cost = |tid: usize| {
                let t = &ds.tables[task.table_ids[tid]];
                match e {
                    dreamshard::baselines::Expert::Size => t.size_gb() as f64,
                    dreamshard::baselines::Expert::Dim => t.dim as f64,
                    dreamshard::baselines::Expert::Lookup => t.dim as f64 * t.pooling as f64,
                    dreamshard::baselines::Expert::SizeLookup => {
                        t.dim as f64 * t.pooling as f64 * t.size_gb() as f64
                    }
                }
            };
            let mut loads = vec![0.0f64; task.n_devices];
            let mut max_item = 0.0f64;
            for (i, &d) in p.iter().enumerate() {
                loads[d] += cost(i);
                max_item = max_item.max(cost(i));
            }
            let max = loads.iter().cloned().fold(0.0, f64::max);
            let min = loads.iter().cloned().fold(f64::MAX, f64::min);
            assert!(max <= min + max_item + 1e-9, "{e:?}: loads {loads:?} item {max_item}");
        }
    }
}
