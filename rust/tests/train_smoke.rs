//! End-to-end integration: train DreamShard for a couple of iterations on
//! tiny tasks through the default (pure-Rust reference) backend, then
//! check that inference produces legal placements. Runs from a bare
//! toolchain — no `make artifacts`, no native libraries.

use std::sync::Arc;

use dreamshard::coordinator::{DreamShard, RnnBaseline, TrainCfg};
use dreamshard::placer::{DreamShardPlacer, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};
use dreamshard::util::Rng;

/// Mean test-task latency of an agent's argmax plans, via the facade.
fn mean_cost(
    rt: &Arc<Runtime>,
    agent: &DreamShard,
    sim: &Simulator,
    ds: &Dataset,
    tasks: &[Task],
) -> f64 {
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(rt, ds, t, sim).unwrap())
        .collect();
    let plans = DreamShardPlacer::from_agent(rt, agent).place_many(&reqs).unwrap();
    plans.iter().map(|p| p.eval.latency).sum::<f64>() / plans.len() as f64
}

fn smoke_cfg() -> TrainCfg {
    TrainCfg {
        n_iterations: 2,
        n_collect: 4,
        n_cost: 20,
        n_batch: 16,
        n_rl: 2,
        n_episode: 6,
        ..Default::default()
    }
}

#[test]
fn trains_and_places() {
    let rt = Arc::new(Runtime::open_default().unwrap());
    let ds = gen_dlrm(120, 0);
    let (pool_tr, pool_te) = split_pools(&ds, 1);
    let train = sample_tasks(&pool_tr, 10, 4, 4, 2);
    let test = sample_tasks(&pool_te, 10, 4, 4, 3);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(7);
    let mut agent = DreamShard::new(&rt, 4, smoke_cfg(), &mut rng).unwrap();

    let before = mean_cost(&rt, &agent, &sim, &ds, &test);
    agent.train(&rt, &sim, &ds, &train, &mut rng).unwrap();
    let after = mean_cost(&rt, &agent, &sim, &ds, &test);

    assert_eq!(agent.log.len(), 2);
    assert!(agent.buffer.len() >= 16, "buffer got {} samples", agent.buffer.len());
    for st in &agent.log {
        assert!(st.cost_loss.is_finite(), "cost loss diverged: {}", st.cost_loss);
        assert!(st.policy_loss.is_finite(), "policy loss diverged: {}", st.policy_loss);
    }
    // placements are legal device ids and complete
    let p = agent.place(&rt, &sim, &ds, &test[0]).unwrap();
    assert_eq!(p.len(), 10);
    assert!(p.iter().all(|&d| d < 4));
    // training should not make things dramatically worse; usually better
    assert!(
        after < before * 1.25,
        "after-training cost {after:.2} way above untrained {before:.2}"
    );
    println!("untrained {before:.2} ms -> trained {after:.2} ms");
}

#[test]
fn rnn_baseline_runs() {
    let rt = Runtime::open_default().unwrap();
    let ds = gen_dlrm(80, 1);
    let (pool, _) = split_pools(&ds, 1);
    let tasks = sample_tasks(&pool, 8, 4, 2, 5);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(9);
    let mut rnn = RnnBaseline::new(&rt, 4, &mut rng).unwrap();
    rnn.train(&rt, &sim, &ds, &tasks, 2, &mut rng).unwrap();
    let p = rnn.place(&rt, &sim, &ds, &tasks[0]).unwrap();
    assert_eq!(p.len(), 8);
    assert!(p.iter().all(|&d| d < 4));
}

#[test]
fn generalizes_across_device_counts() {
    // The paper's headline generalization: a policy trained at one device
    // count runs unchanged at another (smaller) count via masking.
    let rt = Runtime::open_default().unwrap();
    let ds = gen_dlrm(80, 2);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let mut rng = Rng::new(11);
    let agent = DreamShard::new(&rt, 8, TrainCfg::default(), &mut rng).unwrap();
    // untrained is fine here: we only check the mechanics of D-masking
    let task2 = sample_tasks(&pool, 6, 2, 1, 4).remove(0);
    let task8 = sample_tasks(&pool, 12, 8, 1, 5).remove(0);
    for task in [&task2, &task8] {
        let p = agent.place(&rt, &sim, &ds, task).unwrap();
        assert!(p.iter().all(|&d| d < task.n_devices), "{p:?}");
    }
}
