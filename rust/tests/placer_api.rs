//! Placer-facade integration tests: lane-batched `place_many` parity
//! with sequential planning, the one-backend-call-per-MDP-step contract,
//! registry round-trips, and uniform slot-cap legality.

use std::sync::Arc;

use dreamshard::baselines::ALL_EXPERTS;
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::placer::{
    self, DreamShardPlacer, GreedyPlacer, MigrationBudget, Placer, PlacementPlan,
    PlacementRequest, RandomPlacer,
};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};
use dreamshard::util::Rng;

fn setup(n_tasks: usize, n_tables: usize, n_devices: usize) -> (Dataset, Vec<Task>, Simulator) {
    let ds = gen_dlrm(300, 0);
    let (pool, _) = split_pools(&ds, 1);
    let tasks = sample_tasks(&pool, n_tables, n_devices, n_tasks, 2);
    (ds, tasks, Simulator::new(SimConfig::default()))
}

/// An agent with deterministic random-init weights (no training needed:
/// parity and call-count contracts are independent of weight quality).
fn untrained_agent(rt: &Runtime, n_devices: usize) -> DreamShard {
    let mut rng = Rng::new(42);
    DreamShard::new(rt, n_devices, TrainCfg::default(), &mut rng).unwrap()
}

#[test]
fn batched_place_many_matches_sequential_place() {
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(5, 20, 4);
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();
    let plans = placer.place_many(&reqs).unwrap();
    assert_eq!(plans.len(), tasks.len());
    for (task, plan) in tasks.iter().zip(&plans) {
        // the raw single-episode path must agree lane-for-lane
        let sequential = agent.place(&rt, &sim, &ds, task).unwrap();
        assert_eq!(plan.placement, sequential);
        assert_eq!(plan.strategy, "dreamshard");
        assert!(plan.placement.iter().all(|&d| d < task.n_devices));
    }
}

#[test]
fn batched_place_many_handles_heterogeneous_task_lengths() {
    // lanes finish at different MDP steps: shorter tasks idle while the
    // longest lane drains, and every plan still matches its sequential run
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 3);
    let (pool, _) = split_pools(&ds, 4);
    let sim = Simulator::new(SimConfig::default());
    let mut tasks = sample_tasks(&pool, 8, 4, 2, 5);
    tasks.extend(sample_tasks(&pool, 25, 4, 2, 6));
    tasks.extend(sample_tasks(&pool, 14, 2, 1, 7)); // fewer devices too
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();
    let plans = placer.place_many(&reqs).unwrap();
    for (task, plan) in tasks.iter().zip(&plans) {
        assert_eq!(plan.placement.len(), task.n_tables());
        assert!(plan.placement.iter().all(|&d| d < task.n_devices));
    }
}

#[test]
fn place_many_is_one_backend_call_per_mdp_step() {
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(4, 20, 4);
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();

    let before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    placer.place_many(&reqs).unwrap();
    let batched = rt.run_count() - before;
    // ONE concatenated table_cost call orders the whole chunk (4 tasks x
    // 20 tables = 80 rows <= the 256-row cap) + one fused mdp_step call
    // per MDP step shared by ALL lanes
    assert_eq!(batched, (1 + 20) as u64, "lane-batched call budget");
    assert_eq!(rt.run_count_for("table_cost") - ordering_before, 1, "chunk-batched ordering");

    let before = rt.run_count();
    for r in &reqs {
        placer.place(r).unwrap();
    }
    let sequential = rt.run_count() - before;
    // sequential pays the ordering call AND the per-step call per *task*
    assert_eq!(sequential, (tasks.len() * (1 + 20)) as u64);
    assert!(batched < sequential);
}

#[test]
fn dreamshard_placer_respects_request_slot_cap() {
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(1, 20, 4);
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let req = PlacementRequest::new(&ds, &tasks[0], &sim).with_max_slots(5);
    let plan = placer.place(&req).unwrap();
    let mut counts = vec![0usize; 4];
    for &d in &plan.placement {
        counts[d] += 1;
    }
    // 20 tables over 4 devices x 5 slots: the cap binds exactly
    assert!(counts.iter().all(|&c| c <= 5), "slot cap violated: {counts:?}");
}

#[test]
fn baseline_placers_respect_request_slot_cap() {
    let (ds, tasks, sim) = setup(1, 12, 4);
    let task = &tasks[0];
    let req = PlacementRequest::new(&ds, task, &sim).with_max_slots(3);
    let mut placers: Vec<Box<dyn Placer>> = vec![Box::new(RandomPlacer::new(7))];
    for e in ALL_EXPERTS {
        placers.push(Box::new(GreedyPlacer::new(e)));
    }
    for p in placers.iter_mut() {
        // several draws so the stochastic placer gets chances to violate
        for _ in 0..5 {
            let plan = p.place(&req).unwrap();
            let mut counts = vec![0usize; task.n_devices];
            for &d in &plan.placement {
                counts[d] += 1;
            }
            assert!(
                counts.iter().all(|&c| c <= 3),
                "{} violated the slot cap: {counts:?}",
                p.name()
            );
        }
    }
}

#[test]
fn registry_learned_placers_fit_then_plan() {
    // by_name("dreamshard") -> fit on a tiny budget -> lane-batched plans
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(3, 8, 4);
    let mut p = placer::by_name(&rt, "dreamshard").unwrap();
    assert!(p.needs_fit());
    p.fit(&placer::FitRequest {
        ds: &ds,
        tasks: &tasks,
        sim: &sim,
        cfg: TrainCfg {
            n_iterations: 1,
            n_collect: 2,
            n_cost: 5,
            n_batch: 8,
            n_rl: 1,
            n_episode: 4,
            ..Default::default()
        },
        seed: 0,
        verbose: false,
    })
    .unwrap();
    assert!(!p.needs_fit());
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();
    let plans = p.place_many(&reqs).unwrap();
    for (task, plan) in tasks.iter().zip(&plans) {
        assert_eq!(plan.placement.len(), task.n_tables());
        assert!(plan.placement.iter().all(|&d| d < task.n_devices));
    }
}

#[test]
fn replace_with_no_prior_matches_place_for_every_strategy() {
    // the cold-start parity contract: an all-vacant prior plus an
    // unlimited budget must reproduce `place` bit for bit, whatever the
    // strategy (two same-seeded placers so stateful streams align)
    let (ds, tasks, sim) = setup(1, 12, 4);
    let task = &tasks[0];
    let rt = Arc::new(Runtime::reference());
    for name in placer::PLACER_NAMES {
        let req = PlacementRequest::for_runtime(&rt, &ds, task, &sim).unwrap();
        let mut cold = placer::by_name_seeded(&rt, name, 5).unwrap();
        let mut warm = placer::by_name_seeded(&rt, name, 5).unwrap();
        let placed = cold.place(&req).unwrap();
        let replaced = warm.replace(&PlacementPlan::no_prior(task), &req).unwrap();
        assert_eq!(placed.placement, replaced.placement, "{name}");
        assert_eq!(placed.strategy, replaced.strategy, "{name}");
        assert_eq!(replaced.eval.moved_tables, 0, "{name}: nothing pre-existed to move");
        assert_eq!(replaced.eval.migration_ms, 0.0, "{name}");
    }
}

#[test]
fn tight_budget_caps_discretionary_moves() {
    // a valid prior (every device still alive -> zero forced moves), so
    // the migration budget alone bounds what may change
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(1, 20, 4);
    let task = &tasks[0];
    let req = PlacementRequest::for_runtime(&rt, &ds, task, &sim)
        .unwrap()
        .with_migration(MigrationBudget::moves(3));
    let prev = placer::by_name(&rt, "greedy:size").unwrap().place(&req).unwrap();
    for name in ["greedy:dim", "greedy:lookup", "greedy:size-lookup", "dreamshard"] {
        let mut p = placer::by_name_seeded(&rt, name, 9).unwrap();
        let plan = p.replace(&prev, &req).unwrap();
        assert!(
            plan.eval.moved_tables <= 3,
            "{name} moved {} tables on a 3-move budget",
            plan.eval.moved_tables
        );
        let diffs = plan
            .placement
            .iter()
            .zip(&prev.placement)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(diffs, plan.eval.moved_tables, "{name}: moved == placement diffs");
        assert!(plan.placement.iter().all(|&d| d < task.n_devices), "{name}");
    }
    // greedy:size itself, warm-started from a different expert's plan
    let prev = placer::by_name(&rt, "greedy:dim").unwrap().place(&req).unwrap();
    let plan = placer::by_name(&rt, "greedy:size").unwrap().replace(&prev, &req).unwrap();
    assert!(plan.eval.moved_tables <= 3, "greedy:size moved {}", plan.eval.moved_tables);
}

#[test]
fn dreamshard_replace_call_budget_tracks_the_move_budget() {
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(4, 20, 4);
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();
    let prevs = placer.place_many(&reqs).unwrap();

    // vacant priors + unlimited budget: the full re-rollout, at exactly
    // the cold lane-batched budget (1 ordering + one fused call per step)
    let vacant: Vec<PlacementPlan> = tasks.iter().map(PlacementPlan::no_prior).collect();
    let before = rt.run_count();
    let cold = placer.replace_many(&vacant, &reqs).unwrap();
    assert_eq!(rt.run_count() - before, 1 + 20, "vacant replace = cold call budget");
    for (plan, prev) in cold.iter().zip(&prevs) {
        assert_eq!(plan.placement, prev.placement, "vacant replace = place, bit for bit");
        assert_eq!(plan.eval.moved_tables, 0);
    }

    // budget K over a valid prior with no forced moves: the warm
    // re-rollout only rolls K tables, so the chunk costs 1 + K calls
    let budget_reqs: Vec<PlacementRequest> =
        reqs.iter().map(|r| r.with_migration(MigrationBudget::moves(5))).collect();
    let before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    let warmed = placer.replace_many(&prevs, &budget_reqs).unwrap();
    assert_eq!(rt.run_count() - before, 1 + 5, "1 ordering + one fused call per moved slot");
    assert_eq!(rt.run_count_for("table_cost") - ordering_before, 1, "chunk-batched ordering");
    for (plan, prev) in warmed.iter().zip(&prevs) {
        assert!(plan.eval.moved_tables <= 5);
        let diffs = plan
            .placement
            .iter()
            .zip(&prev.placement)
            .filter(|(a, b)| a != b)
            .count();
        assert_eq!(diffs, plan.eval.moved_tables);
        assert!(plan.placement.iter().all(|&d| d < 4));
    }
}

#[test]
fn oversized_batches_chunk_across_lanes() {
    // more requests than the fused artifact's E=16 lanes: chunked, all planned
    let rt = Arc::new(Runtime::reference());
    let (ds, tasks, sim) = setup(20, 6, 4);
    let agent = untrained_agent(&rt, 4);
    let mut placer = DreamShardPlacer::from_agent(&rt, &agent);
    let reqs: Vec<PlacementRequest> = tasks
        .iter()
        .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap())
        .collect();
    let before = rt.run_count();
    let plans = placer.place_many(&reqs).unwrap();
    let calls = rt.run_count() - before;
    assert_eq!(plans.len(), 20);
    // ONE concatenated ordering call for the whole group (20 x 6 = 120
    // rows <= the 256 cap), then 2 lane-chunks (16 + 4) x 6 fused steps
    assert_eq!(calls, 1 + 2 * 6);
    for (task, plan) in tasks.iter().zip(&plans) {
        let sequential = agent.place(&rt, &sim, &ds, task).unwrap();
        assert_eq!(plan.placement, sequential);
    }
}
