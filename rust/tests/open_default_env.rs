//! `Runtime::open_default` must hard-error when `DREAMSHARD_ARTIFACTS`
//! is explicitly set but unusable, instead of silently substituting the
//! reference backend (a misconfigured production deploy would otherwise
//! serve plans from the wrong backend without anyone noticing).
//!
//! Kept in its own integration binary with a single test: it mutates a
//! process-global environment variable, which must not race other tests.

use dreamshard::runtime::Runtime;

#[test]
fn explicit_artifacts_dir_never_silently_falls_back() {
    std::env::set_var("DREAMSHARD_ARTIFACTS", "/nonexistent/dreamshard-artifacts");
    let res = Runtime::open_default();
    std::env::remove_var("DREAMSHARD_ARTIFACTS");

    // without `--features xla` the error names the missing backend; with
    // the feature on, opening the nonexistent directory fails — either
    // way the explicit setting is honored with a hard error, never a
    // silent reference-backend substitution
    let err = res.expect_err("explicit DREAMSHARD_ARTIFACTS must be honored or rejected");
    let msg = err.to_string();
    assert!(
        msg.contains("DREAMSHARD_ARTIFACTS") || msg.contains("manifest"),
        "error should explain the misconfiguration: {msg}"
    );

    // with the variable unset the default quietly works again
    let rt = Runtime::open_default().expect("default runtime without the variable");
    assert!(rt.workers() >= 1);
}
