//! Backend-seam tests: the pure-Rust reference backend must be finite,
//! deterministic under a fixed seed, and — once fitted on simulator
//! measurements — predict costs that grow with table count. Also covers
//! the all-devices-full dead end through the full inference path.

use dreamshard::coordinator::{CostNet, CostSample, DreamShard, ReplayBuffer, TrainCfg, Variant};
use dreamshard::mdp::{heuristic_order, PlacementState};
use dreamshard::runtime::{Runtime, TensorF32};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task, NUM_FEATURES};
use dreamshard::util::Rng;

fn prefix_sample(
    ds: &Dataset,
    task: &Task,
    sim: &Simulator,
    placement: &[usize],
    keep: usize,
    d: usize,
    s: usize,
) -> (CostSample, f64) {
    let mut st = PlacementState::new(ds, task, heuristic_order(ds, task), s);
    for _ in 0..keep {
        let idx = st.current();
        st.apply(placement[idx]);
    }
    let eval = st.evaluate(sim);
    let mut feats = TensorF32::zeros(&[1, d, s, NUM_FEATURES]);
    let mut mask = TensorF32::zeros(&[1, d, s]);
    let mut dmask = TensorF32::zeros(&[1, d]);
    st.fill_feats(0, d, s, &mut feats, &mut mask, &mut dmask).unwrap();
    let mut q = vec![0.0f32; d * 3];
    for (dev, qd) in eval.q.iter().enumerate() {
        q[dev * 3..dev * 3 + 3].copy_from_slice(qd);
    }
    let sample = CostSample {
        feats: feats.data,
        mask: mask.data,
        dmask: dmask.data,
        q,
        cost: eval.latency as f32,
    };
    (sample, eval.latency)
}

#[test]
fn reference_predictions_finite_and_deterministic() {
    let rt = Runtime::reference();
    let ds = gen_dlrm(60, 4);
    let feats: Vec<[f32; NUM_FEATURES]> = ds.tables.iter().map(|t| t.features()).collect();
    let run = || {
        let mut rng = Rng::new(5);
        let net = CostNet::new(&rt, &mut rng).unwrap();
        net.predict_table_costs(&rt, &feats).unwrap()
    };
    let a = run();
    assert_eq!(a.len(), feats.len());
    assert!(a.iter().all(|v| v.is_finite()), "non-finite cost prediction");
    // bit-identical replay under the same seed
    let b = run();
    assert_eq!(a, b);
    // a fresh runtime changes nothing either (stateless backend)
    let rt2 = Runtime::reference();
    let mut rng = Rng::new(5);
    let net = CostNet::new(&rt2, &mut rng).unwrap();
    let c = net.predict_table_costs(&rt2, &feats).unwrap();
    assert_eq!(a, c);
}

#[test]
fn fitted_cost_net_is_monotone_in_table_count() {
    let rt = Runtime::reference();
    let ds = gen_dlrm(120, 3);
    let (pool_tr, pool_te) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let n_tables = 12usize;
    let var = Variant::for_devices(&rt, 2).unwrap();
    let (d, s) = (var.d, var.s);

    // supervised set: nested prefixes of round-robin placements
    let train_tasks = sample_tasks(&pool_tr, n_tables, 2, 5, 21);
    let mut buf = ReplayBuffer::new(256);
    for task in &train_tasks {
        let placement: Vec<usize> = (0..n_tables).map(|i| i % 2).collect();
        for keep in 1..=n_tables {
            let (sample, _) = prefix_sample(&ds, task, &sim, &placement, keep, d, s);
            buf.push(sample);
        }
    }
    let mut rng = Rng::new(33);
    let mut net = CostNet::new(&rt, &mut rng).unwrap();
    for _ in 0..300 {
        let (feats, mask, dmask, q, c) = buf.sample_batch(16, d, s, &mut rng);
        let loss = net.train_batch(&rt, &var, &feats, &mask, &dmask, &q, &c, 1e-3).unwrap();
        assert!(loss.is_finite(), "training diverged");
    }

    // held-out task: predicted cost should grow with placed-table count
    let task = sample_tasks(&pool_te, n_tables, 2, 1, 22).remove(0);
    let placement: Vec<usize> = (0..n_tables).map(|i| i % 2).collect();
    let mut preds = vec![];
    for keep in 1..=n_tables {
        let mut st = PlacementState::new(&ds, &task, heuristic_order(&ds, &task), s);
        for _ in 0..keep {
            let idx = st.current();
            st.apply(placement[idx]);
        }
        let pred = net.predict_states(&rt, &var, &[&st]).unwrap().remove(0);
        assert!(pred.cost.is_finite());
        preds.push(pred.cost);
    }
    let head: f32 = preds[..4].iter().sum::<f32>() / 4.0;
    let tail: f32 = preds[n_tables - 4..].iter().sum::<f32>() / 4.0;
    assert!(
        tail > head,
        "fitted cost net not monotone in table count: head {head:.2} tail {tail:.2} ({preds:?})"
    );
    assert!(
        preds[n_tables - 1] > preds[0],
        "full placement predicted cheaper than a single table: {preds:?}"
    );
}

#[test]
fn dead_end_placement_completes_via_fallback() {
    // a memory cap so small that legal() is all-false from step one:
    // inference must still emit a complete placement (fallback path)
    let rt = Runtime::reference();
    let ds = gen_dlrm(60, 6);
    let (pool, _) = split_pools(&ds, 1);
    let task = sample_tasks(&pool, 8, 4, 1, 7).remove(0);
    let sim = Simulator::new(SimConfig { mem_cap_gb: 1e-6, ..SimConfig::default() });
    let mut rng = Rng::new(8);
    let agent = DreamShard::new(&rt, 4, TrainCfg::default(), &mut rng).unwrap();
    let p = agent.place(&rt, &sim, &ds, &task).unwrap();
    assert_eq!(p.len(), 8);
    assert!(p.iter().all(|&dev| dev < 4), "{p:?}");
}
