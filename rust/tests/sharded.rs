//! Sharded-front-end integration tests: every request routes to its
//! serving variant's shard (tenants isolate further), the concurrent
//! per-shard drain is bit-identical to draining the same shards
//! sequentially — plans *and* exact backend-call budgets — on the mixed
//! 2/4/8/128-device workload, the global cap sheds overload at the front
//! door, and a saturated 128-device shard cannot head-of-line-block an
//! 8-device stream (proved two ways: structurally via `drain_shard`, and
//! by a gated placer that would deadlock a single FIFO).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::placer::{DreamShardPlacer, Placer, PlacementPlan, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    synthetic_arrivals, PlanService, Planned, ServeConfig, ShardConfig, ShardKey,
    ShardedFrontEnd, WorkloadCfg,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};
use dreamshard::util::Rng;
use dreamshard::Result;

/// 64 heterogeneous arrivals: mixed 2/4/8/128-device tasks of 5-12
/// tables (the same shape `tests/serve.rs` pins the single service on).
fn mixed_workload(ds: &Dataset) -> Vec<dreamshard::serve::Arrival> {
    let (pool, _) = split_pools(ds, 1);
    synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 5,
        max_tables: 12,
        mean_gap_ms: 1.0,
        seed: 4,
        ..WorkloadCfg::default()
    })
}

/// Deterministic random-init weights; routing, parity, and call budgets
/// are independent of weight quality.
fn untrained_agent(rt: &Runtime) -> DreamShard {
    let mut rng = Rng::new(42);
    DreamShard::new(rt, 8, TrainCfg::default(), &mut rng).unwrap()
}

/// A front end whose shards (and router) all snapshot the same agent, so
/// every instance routes and plans identically.
fn agent_front<'a>(
    rt: &Arc<Runtime>,
    agent: &'a DreamShard,
    cfg: ShardConfig,
) -> ShardedFrontEnd<'a> {
    let rt2 = Arc::clone(rt);
    ShardedFrontEnd::new(
        rt,
        move || Ok(Box::new(DreamShardPlacer::from_agent(&rt2, agent)) as Box<dyn Placer>),
        cfg,
    )
    .unwrap()
}

#[test]
fn routing_lands_every_request_in_its_variant_shard() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let cfg = ShardConfig {
        per_shard: ServeConfig { capacity: 64, chunk: 16, ..ServeConfig::default() },
        global_cap: 64,
    };
    let mut front = agent_front(&rt, &agent, cfg);

    let mut expected_by_shard = [0usize; 2]; // [d8s48, d128s16]
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        let routed = front.submit(req).unwrap().expect("global cap fits the workload");
        // the d=8 agent lane-shares all 2/4/8-device traffic under its
        // own variant; only 128-device tasks need the ultra variant
        let expect = if a.task.n_devices <= 8 { (8, 48) } else { (128, 16) };
        assert_eq!(routed.shard.variant, expect, "task with {} devices", a.task.n_devices);
        assert_eq!(routed.shard.tenant, None);
        let slot = if expect == (8, 48) { 0 } else { 1 };
        // per-shard tickets are dense FIFO sequences: the receipt's
        // ticket is exactly how many requests that shard took before
        assert_eq!(routed.ticket, expected_by_shard[slot] as u64);
        expected_by_shard[slot] += 1;
    }
    assert_eq!(expected_by_shard[0] + expected_by_shard[1], 64);
    assert!(expected_by_shard[0] > 0 && expected_by_shard[1] > 0, "the mix hits both shards");
    assert_eq!(front.stats().shards, 2);

    // drained plans report the variant of the shard that served them,
    // in the same per-shard counts the routing receipts promised
    let reports = front.try_drain();
    assert_eq!(reports.len(), 2);
    for (key, drained) in &reports {
        let done = drained.as_ref().expect("drain succeeds");
        let slot = if key.variant == (8, 48) { 0 } else { 1 };
        assert_eq!(done.len(), expected_by_shard[slot], "shard {}", key.label());
        for p in done {
            assert_eq!(p.variant, key.variant);
            assert_eq!(p.plan.strategy, "dreamshard");
        }
        // FIFO within the shard
        assert!(done.windows(2).all(|w| w[0].ticket < w[1].ticket));
    }
}

#[test]
fn tenants_get_their_own_shards_on_one_variant() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let tasks = sample_tasks(&pool, 8, 4, 6, 2);
    let sim = Simulator::new(SimConfig::default());
    let rt2 = Arc::clone(&rt);
    let mut front = ShardedFrontEnd::new(
        &rt,
        move || dreamshard::placer::by_name(&rt2, "greedy:size"),
        ShardConfig::default(),
    )
    .unwrap();
    for (i, t) in tasks.iter().enumerate() {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        let tenant = ["acme", "globex"][i % 2];
        let routed = front.submit_for(req, Some(tenant)).unwrap().unwrap();
        assert_eq!(routed.shard.variant, (4, 48));
        assert_eq!(routed.shard.tenant.as_deref(), Some(tenant));
    }
    assert_eq!(front.stats().shards, 2, "same variant, two tenants, two shards");
    let acme = ShardKey { variant: (4, 48), tenant: Some("acme".into()) };
    let done = front.drain_shard(&acme).unwrap();
    assert_eq!(done.len(), 3);
    assert_eq!(front.queued(), 3, "globex untouched by acme's drain");
    assert_eq!(front.drain().unwrap().len(), 3);
}

/// A *lazily-initializing* factory (untrained `dreamshard` out of the
/// registry, exactly what `serve-sim --sharded` and the example use):
/// shard creation warms the shard's own placer to the shard key's device
/// count, so the service's internal grouping agrees with the routing key
/// even when the shard's first request is smaller than the variant the
/// router lane-shares it under — tenant shards included. Every plan's
/// variant matches its routing receipt, and a tenant shard's mixed
/// 2/8-device requests share one lane-chunk instead of fracturing by
/// device count.
#[test]
fn lazy_factory_shards_agree_with_routing_keys() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let eight = sample_tasks(&pool, 6, 8, 2, 1);
    let two = sample_tasks(&pool, 6, 2, 1, 2);
    let rt2 = Arc::clone(&rt);
    let mut front = ShardedFrontEnd::new(
        &rt,
        move || dreamshard::placer::by_name(&rt2, "dreamshard"),
        ShardConfig::default(),
    )
    .unwrap();

    // the first request sizes the lazy *router* agent at d=8, so the
    // router lane-shares 2-device traffic under (8, 48) from then on
    let r0 = front
        .submit(PlacementRequest::for_runtime(&rt, &ds, &eight[0], &sim).unwrap())
        .unwrap()
        .unwrap();
    assert_eq!(r0.shard.variant, (8, 48));
    // tenant shard opened by a *2-device* request: without the creation
    // warm-up its lazy agent would be sized d=2 and disagree with the key
    let reqs = [
        (&two[0], Some("acme")),
        (&eight[1], Some("acme")),
    ];
    for (t, tenant) in reqs {
        let routed = front
            .submit_for(PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap(), tenant)
            .unwrap()
            .unwrap();
        assert_eq!(routed.shard.variant, (8, 48), "{} devices", t.n_devices);
        assert_eq!(routed.shard.tenant.as_deref(), tenant);
    }
    assert_eq!(front.stats().shards, 2);

    for (key, drained) in front.try_drain() {
        let done = drained.expect("drain succeeds");
        for p in &done {
            assert_eq!(
                p.variant, key.variant,
                "plan variant must match the routing key (ticket {})",
                p.ticket
            );
        }
    }
    // the tenant shard's 2- and 8-device requests shared one lane-chunk
    let acme = front
        .shards()
        .find(|sh| sh.key.tenant.as_deref() == Some("acme"))
        .expect("tenant shard exists");
    assert_eq!(acme.stats.chunks, 1, "mixed device counts lane-share one chunk");
    assert_eq!(acme.stats.planned, 2);
}

/// The tentpole acceptance contract: draining every shard concurrently
/// (one thread per shard, shared runtime worker pool) must reproduce
/// draining the same per-variant services sequentially **bit-for-bit**
/// on the mixed 2/4/8/128-device workload — same plans per (shard,
/// ticket), same variants — and spend **exactly** the same backend
/// calls, both in total and on the `table_cost` ordering artifact:
/// concurrency moves waits, never work.
#[test]
fn concurrent_drain_matches_sequential_drain_and_call_budgets() {
    let rt = Arc::new(Runtime::reference().with_workers(4));
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let cfg = ShardConfig {
        per_shard: ServeConfig { capacity: 64, chunk: 16, ..ServeConfig::default() },
        global_cap: 64,
    };

    // sequential reference: the same shards, drained one after another
    let mut seq_front = agent_front(&rt, &agent, cfg);
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        seq_front.submit(req).unwrap().unwrap();
    }
    let calls_before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    let seq = seq_front.drain_sequential().unwrap();
    let seq_calls = rt.run_count() - calls_before;
    let seq_ordering = rt.run_count_for("table_cost") - ordering_before;
    assert_eq!(seq.len(), 64);
    assert_eq!(
        seq_front.stats().aggregate.backend_calls,
        seq_calls,
        "the front end's own call accounting matches the runtime's"
    );

    // concurrent pass: fresh identical front end, per-shard drain threads
    let mut con_front = agent_front(&rt, &agent, cfg);
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        con_front.submit(req).unwrap().unwrap();
    }
    let calls_before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    let con = con_front.drain().unwrap();
    let con_calls = rt.run_count() - calls_before;
    let con_ordering = rt.run_count_for("table_cost") - ordering_before;
    assert_eq!(con.len(), 64);
    assert_eq!(con_front.stats().aggregate.planned, 64);
    assert_eq!(
        con_front.stats().aggregate.backend_calls,
        con_calls,
        "aggregate backend_calls stays exact under concurrent shard drains"
    );

    // bit-identical plans: (variant, ticket) identifies a request across
    // both front ends, because routing is deterministic
    let key = |p: &Planned| (p.variant, p.ticket);
    let mut seq_sorted = seq.clone();
    seq_sorted.sort_by_key(&key);
    let mut con_sorted = con.clone();
    con_sorted.sort_by_key(&key);
    for (s, c) in seq_sorted.iter().zip(&con_sorted) {
        assert_eq!(key(s), key(c));
        assert_eq!(s.plan.placement, c.plan.placement, "shard {:?} ticket {}", s.variant, s.ticket);
    }
    // exact backend-call budgets, total and per the ordering artifact
    assert_eq!(con_calls, seq_calls, "concurrent drain must not change the call budget");
    assert_eq!(con_ordering, seq_ordering, "table_cost ordering budget");
    assert_eq!(
        con_calls - con_ordering,
        seq_calls - seq_ordering,
        "one fused mdp_step call per lockstep MDP step, either way"
    );
}

#[test]
fn global_cap_sheds_overload_across_shards() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds); // 64 requests
    let agent = untrained_agent(&rt);
    let cfg = ShardConfig {
        // roomy per-shard queues: only the global cap can shed here
        per_shard: ServeConfig { capacity: 64, chunk: 16, ..ServeConfig::default() },
        global_cap: 8,
    };
    let mut front = agent_front(&rt, &agent, cfg);
    let mut accepted = 0;
    let mut shed = 0;
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        match front.submit(req).unwrap() {
            Some(_) => accepted += 1,
            None => shed += 1,
        }
    }
    assert_eq!(accepted, 8, "exactly the global cap is admitted");
    assert_eq!(shed, 56);
    assert!(front.is_full());
    let fs = front.stats();
    assert_eq!(fs.shed_global, 56);
    assert_eq!(fs.routed, 8);
    assert_eq!(fs.aggregate.submitted, 8);
    assert_eq!(fs.aggregate.rejected, 0, "no per-shard queue ever filled");

    // draining frees the cap: the front door admits again
    assert_eq!(front.drain().unwrap().len(), 8);
    assert!(!front.is_full());
    let req = PlacementRequest::for_runtime(&rt, &ds, &arrivals[0].task, &sim).unwrap();
    assert!(front.submit(req).unwrap().is_some());
}

/// Structural no-head-of-line-blocking proof: with every 128-device
/// request submitted *ahead* of the 8-device stream, a single FIFO
/// serves the 128s first — but the front end can drain the 8-device
/// shard to completion while the 128-device shard still holds its whole
/// queue.
#[test]
fn eight_device_stream_completes_while_128_shard_is_saturated() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let large = sample_tasks(&pool, 8, 128, 3, 1);
    let small = sample_tasks(&pool, 8, 8, 3, 2);
    let submit_order: Vec<&Task> = large.iter().chain(&small).collect();

    // the single-FIFO contrast: the head of the queue is a 128-device
    // request, so the first drained chunk is all 128s — the 8-device
    // stream waits behind work it does not share a variant with
    let rt2 = Arc::clone(&rt);
    let mut single = PlanService::new(
        &rt,
        dreamshard::placer::by_name(&rt2, "greedy:size").unwrap(),
        ServeConfig { capacity: 16, chunk: 16, ..ServeConfig::default() },
    );
    for &t in &submit_order {
        single.submit(PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap()).unwrap();
    }
    let first = single.drain_chunk().unwrap();
    assert!(!first.is_empty());
    assert!(
        first.iter().all(|p| p.variant == (128, 16)),
        "single FIFO: the 128-device group drains first"
    );
    assert_eq!(single.queued(), 3, "8-device requests still queued behind the 128s");

    // the sharded front end: same submit order, but the 8-device shard
    // is independently drainable while the 128 shard stays saturated
    let rt3 = Arc::clone(&rt);
    let mut front = ShardedFrontEnd::new(
        &rt,
        move || dreamshard::placer::by_name(&rt3, "greedy:size"),
        ShardConfig::default(),
    )
    .unwrap();
    for &t in &submit_order {
        front.submit(PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap()).unwrap().unwrap();
    }
    let key8 = ShardKey { variant: (8, 48), tenant: None };
    let done = front.drain_shard(&key8).unwrap();
    assert_eq!(done.len(), 3, "the whole 8-device stream completed");
    let view128 = front
        .shards()
        .find(|sh| sh.key.variant == (128, 16))
        .expect("128 shard exists");
    assert_eq!(view128.queued, 3, "the saturated 128 shard was never touched");
    assert_eq!(front.drain().unwrap().len(), 3, "and drains on its own schedule");
}

/// A placer whose 128-device plans *block* until enough small-device
/// plans have completed. Under a single FIFO with the 128s at the head
/// this deadlocks — the gate waits on plans stuck behind it in the same
/// queue. The sharded front end's per-shard drain threads make progress
/// on the 8-device shard while the 128 shard waits, so the drain
/// completes. (A timeout turns a would-be deadlock into a test failure.)
struct GatedPlacer {
    small_planned: Arc<AtomicUsize>,
    need: usize,
}

impl Placer for GatedPlacer {
    fn name(&self) -> &str {
        "gated"
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        if req.task.n_devices == 128 {
            let start = Instant::now();
            while self.small_planned.load(Ordering::SeqCst) < self.need {
                if start.elapsed() > Duration::from_secs(30) {
                    return Err(dreamshard::err!(
                        "gate timed out: only {}/{} small plans completed — the \
                         128-device stream head-of-line-blocked the small stream",
                        self.small_planned.load(Ordering::SeqCst),
                        self.need
                    ));
                }
                std::thread::yield_now();
            }
        }
        let plan = PlacementPlan::new(req, vec![0; req.task.n_tables()], "gated");
        if req.task.n_devices != 128 {
            self.small_planned.fetch_add(1, Ordering::SeqCst);
        }
        Ok(plan)
    }
}

/// A placer whose first `place` call fails (the rest succeed): the
/// drain-failure fixture. A failed drain must requeue its whole batch in
/// FIFO order and record nothing — proven by the *next* drain returning
/// every ticket in the original order.
struct FlakyPlacer {
    failures_left: usize,
}

impl Placer for FlakyPlacer {
    fn name(&self) -> &str {
        "flaky"
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        if self.failures_left > 0 {
            self.failures_left -= 1;
            return Err(dreamshard::err!("transient backend failure"));
        }
        Ok(PlacementPlan::new(req, vec![0; req.task.n_tables()], "flaky"))
    }
}

#[test]
fn failed_shard_drain_requeues_fifo_and_keeps_front_stats_clean() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let tasks = sample_tasks(&pool, 8, 4, 3, 2);
    let factory = || Ok(Box::new(FlakyPlacer { failures_left: 1 }) as Box<dyn Placer>);
    let mut front = ShardedFrontEnd::new(&rt, factory, ShardConfig::default()).unwrap();
    let mut receipts = vec![];
    for t in &tasks {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        receipts.push(front.submit(req).unwrap().unwrap());
    }
    assert_eq!(front.queued(), 3);

    let key = receipts[0].shard.clone();
    let e = front.drain_shard(&key).expect_err("the shard's placer fails its first call");
    assert!(e.to_string().contains("transient backend failure"), "{e}");
    assert_eq!(front.queued(), 3, "the failed drain requeued every request");
    let fs = front.stats();
    assert_eq!(fs.routed, 3, "routing receipts unaffected by the failure");
    assert_eq!(fs.aggregate.submitted, 3);
    assert_eq!(fs.aggregate.planned, 0, "no phantom plans recorded");
    assert_eq!(fs.aggregate.backend_calls, 0, "no backend work was dispatched");
    for sh in front.shards() {
        assert!(sh.last_drain.is_none(), "a failed drain completed nothing");
    }

    // the next drain succeeds and returns the original tickets in the
    // original order: the requeue preserved the FIFO exactly
    let done = front.drain_shard(&key).unwrap();
    assert_eq!(done.len(), 3);
    let tickets: Vec<u64> = done.iter().map(|p| p.ticket).collect();
    assert_eq!(tickets, vec![0, 1, 2], "FIFO order survived the failed drain");
    assert_eq!(front.stats().aggregate.planned, 3);
}

#[test]
fn concurrent_shard_drains_have_no_head_of_line_blocking() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let large = sample_tasks(&pool, 8, 128, 4, 1);
    let small = sample_tasks(&pool, 8, 8, 4, 2);

    let small_planned = Arc::new(AtomicUsize::new(0));
    let factory = {
        let small_planned = Arc::clone(&small_planned);
        move || {
            Ok(Box::new(GatedPlacer { small_planned: Arc::clone(&small_planned), need: 4 })
                as Box<dyn Placer>)
        }
    };
    let mut front = ShardedFrontEnd::new(&rt, factory, ShardConfig::default()).unwrap();
    // every 128-device request submitted before any 8-device one: a
    // single FIFO would drain the gated 128 chunk first and deadlock
    for t in large.iter().chain(&small) {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        front.submit(req).unwrap().unwrap();
    }
    assert_eq!(front.stats().shards, 2);
    let done = front.drain().expect("concurrent shard drains make progress past the gate");
    assert_eq!(done.len(), 8);
    assert_eq!(small_planned.load(Ordering::SeqCst), 4);
    let fs = front.stats();
    assert_eq!(fs.aggregate.planned, 8);
    for sh in front.shards() {
        assert!(sh.last_drain.is_some(), "shard {} stamped its drain clock", sh.key.label());
    }
}
