//! Kernel parity / property suite for the blocked reference kernels.
//!
//! The blocked `linear_fwd` / `linear_bwd` tilings and the scratch-pooled
//! MLP paths in `runtime/reference/math.rs` promise BIT-IDENTICAL results
//! to the naive kernels they replaced (the naive versions are kept as
//! oracles, suffixed `_naive`). This suite pins that promise:
//!
//! * randomized sweeps over (rows, n_in, n_out) crossing every block
//!   boundary (`ROW_BLOCK`/`COL_BLOCK` ± 1), with injected all-zero rows
//!   to exercise the sparsity skip guard, compared with `to_bits()`;
//! * finite-difference gradchecks through the blocked backward paths at
//!   shapes that straddle a block boundary;
//! * masked-reduce edge cases whose semantics are easy to break silently
//!   (NaN under Max, argmax ties, all-masked groups, l=0 / n=0);
//! * the `table_cost` intra-op row split: bit-identical outputs and
//!   identical dispatch budgets at widths 1/2/4, and a panicking split
//!   that must surface exactly one error while the pool and counters
//!   survive.

use dreamshard::runtime::reference::math::{
    fd_check, linear_bwd, linear_bwd_naive, linear_fwd, linear_fwd_naive, masked_reduce,
    masked_reduce_bwd, mlp2_bwd, mlp2_bwd_naive, mlp2_fwd, mlp2_fwd_naive, with_scratch, Lin, Red,
    COL_BLOCK, ROW_BLOCK,
};
use dreamshard::runtime::reference::{reference_manifest, INTRA_OP_MIN_ROWS};
use dreamshard::runtime::{to_f32_vec, ReferenceBackend, Runtime, TensorF32, Value};
use dreamshard::util::Rng;

// ---------------------------------------------------------------------
// deterministic value generator (self-contained so the suite's inputs
// can never drift with changes to util::Rng)
// ---------------------------------------------------------------------

struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Self {
        Lcg(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1))
    }
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0
    }
    /// Uniform-ish f32 in [-0.5, 0.5] with plenty of distinct mantissas.
    fn f32(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 24) as f32) - 0.5
    }
    fn vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32() * scale).collect()
    }
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: bit mismatch at index {i}: {x} vs {y}"
        );
    }
}

/// A single dense layer laid out at the front of a flat theta.
fn lin(k: usize, m: usize) -> (Lin, usize) {
    (Lin { w: 0, b: k * m, n_in: k, n_out: m }, k * m + m)
}

// ---------------------------------------------------------------------
// blocked linear kernels vs the naive oracles
// ---------------------------------------------------------------------

#[test]
fn linear_fwd_blocked_matches_naive_bitwise() {
    let rows_sweep = [1, 3, ROW_BLOCK - 1, ROW_BLOCK, ROW_BLOCK + 1, 2 * ROW_BLOCK + 2];
    let k_sweep = [1, 3, COL_BLOCK, COL_BLOCK + 1];
    let m_sweep = [1, 5, COL_BLOCK - 1, COL_BLOCK, COL_BLOCK + 1];
    let mut lcg = Lcg::new(42);
    for &rows in &rows_sweep {
        for &k in &k_sweep {
            for &m in &m_sweep {
                let (l, total) = lin(k, m);
                let theta = lcg.vec(total, 1.0);
                let mut x = lcg.vec(rows * k, 1.0);
                // all-zero rows and scattered exact zeros exercise the
                // `xi != 0.0` skip guard on both sides
                for r in (0..rows).step_by(3) {
                    x[r * k..(r + 1) * k].fill(0.0);
                }
                if rows * k > 1 {
                    x[1] = 0.0;
                }
                for relu in [false, true] {
                    let fast = linear_fwd(&theta, l, &x, rows, relu);
                    let slow = linear_fwd_naive(&theta, l, &x, rows, relu);
                    assert_bits(&fast, &slow, &format!("fwd rows={rows} k={k} m={m} relu={relu}"));
                }
            }
        }
    }
}

#[test]
fn linear_bwd_blocked_matches_naive_bitwise() {
    let rows_sweep = [1, ROW_BLOCK - 1, ROW_BLOCK, ROW_BLOCK + 1, 97];
    let k_sweep = [1, 3, COL_BLOCK + 1];
    let m_sweep = [1, COL_BLOCK - 1, COL_BLOCK + 1];
    let mut lcg = Lcg::new(1007);
    for &rows in &rows_sweep {
        for &k in &k_sweep {
            for &m in &m_sweep {
                let (l, total) = lin(k, m);
                let theta = lcg.vec(total, 1.0);
                let mut x = lcg.vec(rows * k, 1.0);
                for r in (0..rows).step_by(4) {
                    x[r * k..(r + 1) * k].fill(0.0);
                }
                let dy = lcg.vec(rows * m, 1.0);
                // pre-seed both grad buffers identically: the kernels
                // ACCUMULATE, and the += order is part of the contract
                let seed_grad = lcg.vec(total, 0.25);
                let mut g_fast = seed_grad.clone();
                let mut g_slow = seed_grad;
                let dx_fast = linear_bwd(&theta, &mut g_fast, l, &x, &dy, rows, true);
                let dx_slow = linear_bwd_naive(&theta, &mut g_slow, l, &x, &dy, rows, true);
                let what = format!("bwd rows={rows} k={k} m={m}");
                assert_bits(&g_fast, &g_slow, &format!("{what} grad"));
                assert_bits(&dx_fast, &dx_slow, &format!("{what} dx"));
            }
        }
    }
}

#[test]
fn mlp2_blocked_scratch_matches_naive_bitwise_and_is_pool_stable() {
    let (rows, k, hid, m) = (ROW_BLOCK + 3, 7, COL_BLOCK + 1, 5);
    let l1 = Lin { w: 0, b: k * hid, n_in: k, n_out: hid };
    let w2 = k * hid + hid;
    let l2 = Lin { w: w2, b: w2 + hid * m, n_in: hid, n_out: m };
    let total = w2 + hid * m + m;
    let mut lcg = Lcg::new(9);
    let theta = lcg.vec(total, 0.5);
    let x = lcg.vec(rows * k, 1.0);
    let dy = lcg.vec(rows * m, 1.0);

    let (y_naive, cache_naive) = mlp2_fwd_naive(&theta, l1, l2, x.clone(), rows);
    let mut g_naive = vec![0.0f32; total];
    let dx_naive = mlp2_bwd_naive(&theta, &mut g_naive, l1, l2, &cache_naive, &dy, true);

    // two passes: the second runs against a warm scratch pool whose
    // buffers hold the first pass's garbage — take() must re-zero them
    for pass in 0..2 {
        let (y, dx, g) = with_scratch(|scr| {
            let (y, cache) = mlp2_fwd(&theta, l1, l2, x.clone(), rows, scr);
            let mut g = vec![0.0f32; total];
            let dx = mlp2_bwd(&theta, &mut g, l1, l2, &cache, &dy, true, scr);
            cache.recycle(scr);
            let out = (y.clone(), dx.clone(), g);
            scr.give(y);
            scr.give(dx);
            out
        });
        assert_bits(&y, &y_naive, &format!("mlp2 fwd pass={pass}"));
        assert_bits(&dx, &dx_naive, &format!("mlp2 dx pass={pass}"));
        assert_bits(&g, &g_naive, &format!("mlp2 grad pass={pass}"));
    }
}

// ---------------------------------------------------------------------
// finite-difference gradchecks through the blocked backward paths
// ---------------------------------------------------------------------

#[test]
fn linear_bwd_gradcheck_across_block_boundary() {
    // n_out straddles COL_BLOCK so the dW tiling's second tile is live
    let (rows, k, m) = (5usize, 3usize, COL_BLOCK + 1);
    let (l, total) = lin(k, m);
    let mut lcg = Lcg::new(77);
    let theta = lcg.vec(total, 0.3);
    let x = lcg.vec(rows * k, 1.0);
    // loss = 0.5 * sum(y^2)  =>  dL/dy = y
    let loss = |th: &[f32]| -> f32 {
        let y = linear_fwd(th, l, &x, rows, false);
        y.iter().map(|v| 0.5 * v * v).sum()
    };
    let y = linear_fwd(&theta, l, &x, rows, false);
    let mut grad = vec![0.0f32; total];
    linear_bwd(&theta, &mut grad, l, &x, &y, rows, false);
    fd_check(loss, &theta, &grad, 25, 7);
}

#[test]
fn mlp2_bwd_gradcheck_across_block_boundary() {
    let (rows, k, hid, m) = (ROW_BLOCK + 1, 4usize, COL_BLOCK + 1, 3usize);
    let l1 = Lin { w: 0, b: k * hid, n_in: k, n_out: hid };
    let w2 = k * hid + hid;
    let l2 = Lin { w: w2, b: w2 + hid * m, n_in: hid, n_out: m };
    let total = w2 + hid * m + m;
    let mut lcg = Lcg::new(78);
    let theta = lcg.vec(total, 0.3);
    let x = lcg.vec(rows * k, 1.0);
    let loss = |th: &[f32]| -> f32 {
        with_scratch(|scr| {
            let (y, cache) = mlp2_fwd(th, l1, l2, x.clone(), rows, scr);
            let v: f32 = y.iter().map(|v| 0.5 * v * v).sum();
            cache.recycle(scr);
            scr.give(y);
            v
        })
    };
    let grad = with_scratch(|scr| {
        let (y, cache) = mlp2_fwd(&theta, l1, l2, x.clone(), rows, scr);
        let mut grad = vec![0.0f32; total];
        mlp2_bwd(&theta, &mut grad, l1, l2, &cache, &y, false, scr);
        cache.recycle(scr);
        scr.give(y);
        grad
    });
    fd_check(loss, &theta, &grad, 25, 8);
}

// ---------------------------------------------------------------------
// masked-reduce edge cases (pinned semantics)
// ---------------------------------------------------------------------

#[test]
fn masked_max_nan_first_sticks_and_pins_argmax_zero() {
    // first masked item is NaN: it wins initially and every later
    // `hv > NaN` comparison is false, so NaN and argmax=0 both stick
    with_scratch(|scr| {
        let h = [f32::NAN, 2.0, 1.0];
        let mask = [1.0f32, 1.0, 1.0];
        let (out, cache) = masked_reduce(&h, &mask, 1, 3, 1, Red::Max, scr);
        assert!(out[0].is_nan(), "NaN-first must propagate, got {}", out[0]);
        assert_eq!(cache.argmax[0], 0);
        let dh = masked_reduce_bwd(&[1.5], &mask, 1, 3, 1, Red::Max, &cache, scr);
        assert_eq!(dh, vec![1.5, 0.0, 0.0]);
    });
}

#[test]
fn masked_max_nan_later_is_ignored() {
    with_scratch(|scr| {
        let h = [2.0f32, f32::NAN, 5.0];
        let mask = [1.0f32, 1.0, 1.0];
        let (out, cache) = masked_reduce(&h, &mask, 1, 3, 1, Red::Max, scr);
        assert_eq!(out[0], 5.0);
        assert_eq!(cache.argmax[0], 2);
    });
}

#[test]
fn masked_max_tie_picks_earliest_index() {
    with_scratch(|scr| {
        let h = [3.0f32, 3.0];
        let mask = [1.0f32, 1.0];
        let (out, cache) = masked_reduce(&h, &mask, 1, 2, 1, Red::Max, scr);
        assert_eq!(out[0], 3.0);
        assert_eq!(cache.argmax[0], 0, "strict > must keep the earliest winner");
        let dh = masked_reduce_bwd(&[1.0], &mask, 1, 2, 1, Red::Max, &cache, scr);
        assert_eq!(dh, vec![1.0, 0.0], "tie gradient flows to one item only");
    });
}

#[test]
fn all_masked_group_reduces_to_zero_with_empty_argmax() {
    with_scratch(|scr| {
        let h = [7.0f32, -2.0, 9.0, 1.0];
        let mask = [0.0f32, 0.0, 1.0, 1.0]; // group 0 fully masked out
        for red in [Red::Sum, Red::Mean, Red::Max] {
            let (out, cache) = masked_reduce(&h, &mask, 2, 2, 1, red, scr);
            assert_eq!(out[0], 0.0, "{red:?}: empty group must reduce to 0");
            if red == Red::Max {
                assert_eq!(cache.argmax[0], usize::MAX);
                assert_ne!(cache.argmax[1], usize::MAX);
            }
            let dh = masked_reduce_bwd(&[1.0, 1.0], &mask, 2, 2, 1, red, &cache, scr);
            assert_eq!(&dh[..2], &[0.0, 0.0], "{red:?}: no gradient into a masked-out group");
            cache.recycle(scr);
        }
    });
}

#[test]
fn degenerate_shapes_l0_and_n0() {
    with_scratch(|scr| {
        // l = 0: zero channels, outputs are empty but counts still tally
        let mask = [1.0f32, 0.0];
        let (out, cache) = masked_reduce(&[], &mask, 1, 2, 0, Red::Max, scr);
        assert!(out.is_empty());
        assert_eq!(cache.count[0], 1.0);
        let dh = masked_reduce_bwd(&[], &mask, 1, 2, 0, Red::Max, &cache, scr);
        assert!(dh.is_empty());
        cache.recycle(scr);

        // n = 0: zero items per group, every group is empty
        for red in [Red::Sum, Red::Mean, Red::Max] {
            let (out, cache) = masked_reduce(&[], &[], 2, 0, 3, red, scr);
            assert_eq!(out, vec![0.0f32; 6], "{red:?}: n=0 groups reduce to 0");
            assert_eq!(cache.count, vec![0.0f32, 0.0]);
            let dh = masked_reduce_bwd(&[1.0; 6], &[], 2, 0, 3, red, &cache, scr);
            assert!(dh.is_empty());
            cache.recycle(scr);
        }
    });
}

// ---------------------------------------------------------------------
// table_cost intra-op split: bit-identity, budgets, panic containment
// ---------------------------------------------------------------------

fn rt_with_intra(intra: usize) -> Runtime {
    Runtime::with_backend(reference_manifest(), Box::new(ReferenceBackend::with_intra_op(intra)))
}

/// Deterministic `table_cost` inputs for an arbitrary row count `n`
/// (execution is shape-polymorphic: dims are read from the inputs).
fn table_cost_inputs(rt: &Runtime, n: usize, seed: u64) -> Vec<Value> {
    let mut rng = Rng::new(seed);
    let theta = rt.init_params("cost", &mut rng).unwrap();
    let f = rt.manifest.consts["F"] as usize;
    let mut feats = TensorF32::zeros(&[n, f]);
    for x in feats.data.iter_mut() {
        *x = rng.uniform(0.0, 1.0) as f32;
    }
    vec![
        TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
        feats.value(),
        TensorF32::ones(&[f]).value(),
    ]
}

#[test]
fn table_cost_split_is_bit_identical_across_widths() {
    // odd n: chunks of unequal size, last one short
    let n = 3 * INTRA_OP_MIN_ROWS + 7;
    let serial = {
        let rt = rt_with_intra(1);
        let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 5)).unwrap();
        to_f32_vec(&out[0], n).unwrap()
    };
    for intra in [2usize, 4] {
        let rt = rt_with_intra(intra);
        let before = rt.run_count_for("table_cost");
        let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 5)).unwrap();
        let got = to_f32_vec(&out[0], n).unwrap();
        assert_bits(&got, &serial, &format!("table_cost intra={intra}"));
        assert_eq!(
            rt.run_count_for("table_cost") - before,
            1,
            "a split dispatch is ONE logical call, not {intra}"
        );
    }
}

#[test]
fn table_cost_below_threshold_stays_serial_and_identical() {
    let n = INTRA_OP_MIN_ROWS - 1;
    let serial = {
        let rt = rt_with_intra(1);
        let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 6)).unwrap();
        to_f32_vec(&out[0], n).unwrap()
    };
    let rt = rt_with_intra(4);
    let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 6)).unwrap();
    assert_bits(&to_f32_vec(&out[0], n).unwrap(), &serial, "below-threshold table_cost");
}

#[test]
fn default_runtime_split_matches_serial_reference() {
    // Runtime::reference() wires intra_op from DREAMSHARD_WORKERS — CI
    // runs this suite at 1 and 4 workers, so this covers the env path
    let n = rt_with_intra(1).manifest.artifact_meta("table_cost", "N").unwrap() as usize;
    let serial = {
        let rt = rt_with_intra(1);
        let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 11)).unwrap();
        to_f32_vec(&out[0], n).unwrap()
    };
    let rt = Runtime::reference();
    let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 11)).unwrap();
    assert_bits(&to_f32_vec(&out[0], n).unwrap(), &serial, "default-runtime table_cost");
}

#[test]
fn panicking_split_surfaces_one_error_and_pool_survives() {
    let rt = rt_with_intra(4);
    let n = 4 * INTRA_OP_MIN_ROWS;
    let f = rt.manifest.consts["F"] as usize;
    // theta far too short: every shard's kernel slices out of bounds and
    // panics; the scoped join must re-raise exactly ONE panic, which the
    // session worker converts to exactly one Err
    let bad = vec![
        TensorF32::from_vec(vec![0.0f32; 8], &[8]).value(),
        TensorF32::zeros(&[n, f]).value(),
        TensorF32::ones(&[f]).value(),
    ];
    let err = rt.run("table_cost", &bad).expect_err("short theta must panic inside the kernel");
    assert!(err.to_string().contains("panicked"), "unexpected error: {err}");
    assert_eq!(rt.run_count_for("table_cost"), 1, "panicked dispatch still counted once");

    // the pool survives: a valid run on the same runtime succeeds
    let out = rt.run("table_cost", &table_cost_inputs(&rt, n, 12)).unwrap();
    let got = to_f32_vec(&out[0], n).unwrap();
    assert!(got.iter().all(|x| x.is_finite()));
    assert_eq!(rt.run_count_for("table_cost"), 2);
}
