//! Serving front-end integration tests: drained plans are bit-identical
//! to sequential `Placer::place`, FIFO completion order holds per
//! serving-variant group, and the lane-batched drain + chunk-batched
//! `order_tables` spend strictly fewer backend calls than sequential
//! planning (with the `table_cost` budget pinned per drained chunk).

use dreamshard::coordinator::{CostNet, DreamShard, TrainCfg};
use dreamshard::placer::{DreamShardPlacer, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{synthetic_arrivals, PlanService, Planned, ServeConfig, WorkloadCfg};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, split_pools, Dataset};
use dreamshard::util::Rng;

/// 64 heterogeneous arrivals: mixed 2/4/8/128-device tasks of 5-12 tables.
fn mixed_workload(ds: &Dataset) -> Vec<dreamshard::serve::Arrival> {
    let (pool, _) = split_pools(ds, 1);
    synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 5,
        max_tables: 12,
        mean_gap_ms: 1.0,
        seed: 4,
    })
}

/// Deterministic random-init weights; plan parity and call budgets are
/// independent of weight quality.
fn untrained_agent(rt: &Runtime) -> DreamShard {
    let mut rng = Rng::new(42);
    DreamShard::new(rt, 8, TrainCfg::default(), &mut rng).unwrap()
}

#[test]
fn drained_plans_are_bit_identical_to_sequential_place() {
    let rt = Runtime::reference();
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);

    let service_placer = Box::new(DreamShardPlacer::from_agent(&rt, &agent));
    let mut svc = PlanService::new(&rt, service_placer, ServeConfig { capacity: 64, chunk: 16 });
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        assert!(svc.submit(req).unwrap().is_some(), "capacity fits the whole workload");
    }
    let seq_calls_before = rt.run_count();
    let mut done = svc.drain().unwrap();
    let batched_calls = rt.run_count() - seq_calls_before;
    assert_eq!(done.len(), 64);

    // tickets are assigned in submission order: sort back to arrival order
    done.sort_by_key(|p| p.ticket);
    let mut sequential = DreamShardPlacer::from_agent(&rt, &agent);
    let seq_before = rt.run_count();
    for (a, p) in arrivals.iter().zip(&done) {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        let direct = sequential.place(&req).unwrap();
        assert_eq!(p.plan.placement, direct.placement, "ticket {}", p.ticket);
        assert_eq!(p.plan.placement.len(), a.task.n_tables());
        assert!(p.plan.placement.iter().all(|&d| d < a.task.n_devices));
        assert_eq!(p.plan.strategy, "dreamshard");
    }
    let sequential_calls = rt.run_count() - seq_before;
    // the acceptance contract: lane-batched drain + chunk-batched
    // ordering spend strictly fewer backend executions
    assert!(
        batched_calls < sequential_calls,
        "batched drain used {batched_calls} calls, sequential {sequential_calls}"
    );
}

#[test]
fn fifo_completion_order_is_preserved_per_variant_group() {
    let rt = Runtime::reference();
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let mut svc = PlanService::new(
        &rt,
        Box::new(DreamShardPlacer::from_agent(&rt, &agent)),
        ServeConfig { capacity: 64, chunk: 4 }, // small chunks: many drains
    );
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let mut completed: Vec<Planned> = vec![];
    let first_chunk = svc.drain_chunk().unwrap();
    assert!(!first_chunk.is_empty());
    assert_eq!(first_chunk[0].ticket, 0, "oldest request drains first");
    completed.extend(first_chunk);
    completed.extend(svc.drain().unwrap());
    assert_eq!(completed.len(), 64);

    // the d=8 agent lane-shares all 2/4/8-device traffic under its own
    // variant (Placer::serving_variant); only 128-device tasks need the
    // ultra variant — so exactly two serving groups
    let mut keys: Vec<(usize, usize)> = completed.iter().map(|p| p.variant).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys, vec![(8, 48), (128, 16)], "serving groups: {keys:?}");
    // within each serving-variant group, completion order == submit order
    for key in keys {
        let tickets: Vec<u64> =
            completed.iter().filter(|p| p.variant == key).map(|p| p.ticket).collect();
        assert!(
            tickets.windows(2).all(|w| w[0] < w[1]),
            "variant {key:?} completed out of FIFO order: {tickets:?}"
        );
    }
}

#[test]
fn chunk_batched_ordering_pins_the_table_cost_budget() {
    let rt = Runtime::reference();
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let mut svc = PlanService::new(
        &rt,
        Box::new(DreamShardPlacer::from_agent(&rt, &agent)),
        ServeConfig { capacity: 64, chunk: 16 },
    );
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let n_cap = CostNet::table_cost_cap(&rt);
    let mut total_chunks = 0u64;
    while !svc.is_empty() {
        let before = rt.run_count_for("table_cost");
        let chunk = svc.drain_chunk().unwrap();
        let ordering_calls = rt.run_count_for("table_cost") - before;
        let total_tables: usize = chunk.iter().map(|p| p.plan.placement.len()).sum();
        let budget = ((total_tables + n_cap - 1) / n_cap).max(1) as u64;
        assert!(
            ordering_calls <= budget,
            "chunk of {} tables spent {ordering_calls} table_cost calls (budget {budget})",
            total_tables
        );
        total_chunks += 1;
    }
    let stats = svc.stats();
    assert_eq!(stats.planned, 64);
    assert_eq!(stats.chunks, total_chunks);
    // one ordering pass per chunk beats one per task by construction
    assert!(total_chunks < 64);
    assert!(stats.mean_queue_ms() >= 0.0);
    assert!(stats.median_queue_ms() >= 0.0);
    assert!(stats.plans_per_sec() > 0.0);
    assert!(stats.backend_calls > 0);
}
