//! Serving front-end integration tests: drained plans are bit-identical
//! to sequential `Placer::place`, FIFO completion order holds per
//! serving-variant group, the lane-batched drain + chunk-batched
//! `order_tables` spend strictly fewer backend calls than sequential
//! planning (with the `table_cost` budget pinned per drained chunk), and
//! the pipelined drain (sessions on a multi-worker runtime) reproduces
//! the blocking drain bit-for-bit — plans *and* backend-call budgets.

use std::sync::Arc;

use dreamshard::coordinator::{CostNet, DreamShard, TrainCfg};
use dreamshard::placer::{self, DreamShardPlacer, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    synthetic_arrivals, Clock, PlanService, Planned, ServeConfig, TestClock, WorkloadCfg,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, split_pools, Dataset};
use dreamshard::util::Rng;

/// 64 heterogeneous arrivals: mixed 2/4/8/128-device tasks of 5-12 tables.
fn mixed_workload(ds: &Dataset) -> Vec<dreamshard::serve::Arrival> {
    let (pool, _) = split_pools(ds, 1);
    synthetic_arrivals(&pool, &WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 5,
        max_tables: 12,
        mean_gap_ms: 1.0,
        seed: 4,
        ..WorkloadCfg::default()
    })
}

/// Deterministic random-init weights; plan parity and call budgets are
/// independent of weight quality.
fn untrained_agent(rt: &Runtime) -> DreamShard {
    let mut rng = Rng::new(42);
    DreamShard::new(rt, 8, TrainCfg::default(), &mut rng).unwrap()
}

/// The closed-loop satellite's determinism pin: a fixed seed fully
/// determines the closed-loop arrival stream (tasks, gap offsets, SLO
/// classes — bit-for-bit), and replaying it through a `TestClock`ed
/// service yields bit-identical plans *and* queue latencies run to run —
/// the property every controller convergence assertion stands on.
#[test]
fn closed_loop_workload_replays_deterministically_under_a_fixed_seed() {
    let ds = gen_dlrm(300, 0);
    let (pool, _) = split_pools(&ds, 1);
    let sim = Simulator::new(SimConfig::default());
    let cfg = WorkloadCfg {
        n_requests: 64,
        device_mix: vec![2, 4, 8, 128],
        min_tables: 5,
        max_tables: 12,
        mean_gap_ms: 1.0,
        closed_loop: true,
        batch_pct: 25,
        seed: 4,
        ..WorkloadCfg::default()
    };
    let a = synthetic_arrivals(&pool, &cfg);
    let b = synthetic_arrivals(&pool, &cfg);
    assert_eq!(a.len(), 64);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.task.table_ids, y.task.table_ids);
        assert_eq!(x.task.n_devices, y.task.n_devices);
        assert_eq!(x.at_ms.to_bits(), y.at_ms.to_bits(), "gaps are bit-deterministic");
        assert_eq!(x.class, y.class);
        assert!(x.at_ms > 0.0, "closed-loop at_ms is a strictly positive gap");
    }
    // the same tasks as the open-loop stream, in the same order
    let open = synthetic_arrivals(&pool, &WorkloadCfg { closed_loop: false, ..cfg.clone() });
    for (c, o) in a.iter().zip(open.iter()) {
        assert_eq!(c.task.table_ids, o.task.table_ids);
    }

    // replay twice on frozen test clocks: everything the serving layer
    // measures must reproduce bit-for-bit
    let replay = || {
        let rt = Arc::new(Runtime::reference());
        let clock = Arc::new(TestClock::new());
        let placer = placer::by_name(&rt, "greedy:size").unwrap();
        let mut svc = PlanService::with_clock(
            &rt,
            placer,
            ServeConfig { capacity: 64, chunk: 8, ..ServeConfig::default() },
            Arc::clone(&clock) as Arc<dyn Clock>,
        );
        let mut done: Vec<Planned> = vec![];
        for arr in &a {
            clock.advance_ms(arr.at_ms); // the gap from the last progress
            let req = PlacementRequest::for_runtime(&rt, &ds, &arr.task, &sim).unwrap();
            svc.submit_class(req, arr.class).unwrap().unwrap();
            if svc.queued() >= 8 {
                done.extend(svc.drain().unwrap());
            }
        }
        done.extend(svc.drain().unwrap());
        done
    };
    let r1 = replay();
    let r2 = replay();
    assert_eq!(r1.len(), 64);
    for (x, y) in r1.iter().zip(r2.iter()) {
        assert_eq!(x.ticket, y.ticket);
        assert_eq!(x.plan.placement, y.plan.placement);
        assert_eq!(x.class, y.class);
        assert_eq!(x.queue_ms.to_bits(), y.queue_ms.to_bits(), "latencies reproduce exactly");
    }
}

#[test]
fn drained_plans_are_bit_identical_to_sequential_place() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);

    let service_placer = Box::new(DreamShardPlacer::from_agent(&rt, &agent));
    let mut svc = PlanService::new(&rt, service_placer, ServeConfig {
        capacity: 64,
        chunk: 16,
        ..ServeConfig::default()
    });
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        assert!(svc.submit(req).unwrap().is_some(), "capacity fits the whole workload");
    }
    let seq_calls_before = rt.run_count();
    let mut done = svc.drain().unwrap();
    let batched_calls = rt.run_count() - seq_calls_before;
    assert_eq!(done.len(), 64);

    // tickets are assigned in submission order: sort back to arrival order
    done.sort_by_key(|p| p.ticket);
    let mut sequential = DreamShardPlacer::from_agent(&rt, &agent);
    let seq_before = rt.run_count();
    for (a, p) in arrivals.iter().zip(&done) {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        let direct = sequential.place(&req).unwrap();
        assert_eq!(p.plan.placement, direct.placement, "ticket {}", p.ticket);
        assert_eq!(p.plan.placement.len(), a.task.n_tables());
        assert!(p.plan.placement.iter().all(|&d| d < a.task.n_devices));
        assert_eq!(p.plan.strategy, "dreamshard");
    }
    let sequential_calls = rt.run_count() - seq_before;
    // the acceptance contract: lane-batched drain + chunk-batched
    // ordering spend strictly fewer backend executions
    assert!(
        batched_calls < sequential_calls,
        "batched drain used {batched_calls} calls, sequential {sequential_calls}"
    );
}

#[test]
fn fifo_completion_order_is_preserved_per_variant_group() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let mut svc = PlanService::new(
        &rt,
        Box::new(DreamShardPlacer::from_agent(&rt, &agent)),
        // small chunks: many drains
        ServeConfig { capacity: 64, chunk: 4, ..ServeConfig::default() },
    );
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let mut completed: Vec<Planned> = vec![];
    let first_chunk = svc.drain_chunk().unwrap();
    assert!(!first_chunk.is_empty());
    assert_eq!(first_chunk[0].ticket, 0, "oldest request drains first");
    completed.extend(first_chunk);
    completed.extend(svc.drain().unwrap());
    assert_eq!(completed.len(), 64);

    // the d=8 agent lane-shares all 2/4/8-device traffic under its own
    // variant (Placer::serving_variant); only 128-device tasks need the
    // ultra variant — so exactly two serving groups
    let mut keys: Vec<(usize, usize)> = completed.iter().map(|p| p.variant).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys, vec![(8, 48), (128, 16)], "serving groups: {keys:?}");
    // within each serving-variant group, completion order == submit order
    for key in keys {
        let tickets: Vec<u64> =
            completed.iter().filter(|p| p.variant == key).map(|p| p.ticket).collect();
        assert!(
            tickets.windows(2).all(|w| w[0] < w[1]),
            "variant {key:?} completed out of FIFO order: {tickets:?}"
        );
    }
}

#[test]
fn chunk_batched_ordering_pins_the_table_cost_budget() {
    let rt = Arc::new(Runtime::reference());
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let mut svc = PlanService::new(
        &rt,
        Box::new(DreamShardPlacer::from_agent(&rt, &agent)),
        ServeConfig { capacity: 64, chunk: 16, ..ServeConfig::default() },
    );
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let n_cap = CostNet::table_cost_cap(&rt);
    let mut total_chunks = 0u64;
    while !svc.is_empty() {
        let before = rt.run_count_for("table_cost");
        let chunk = svc.drain_chunk().unwrap();
        let ordering_calls = rt.run_count_for("table_cost") - before;
        let total_tables: usize = chunk.iter().map(|p| p.plan.placement.len()).sum();
        let budget = ((total_tables + n_cap - 1) / n_cap).max(1) as u64;
        assert!(
            ordering_calls <= budget,
            "chunk of {} tables spent {ordering_calls} table_cost calls (budget {budget})",
            total_tables
        );
        total_chunks += 1;
    }
    let stats = svc.stats();
    assert_eq!(stats.planned, 64);
    assert_eq!(stats.chunks, total_chunks);
    // one ordering pass per chunk beats one per task by construction
    assert!(total_chunks < 64);
    assert!(stats.mean_queue_ms() >= 0.0);
    assert!(stats.median_queue_ms() >= 0.0);
    assert!(stats.plans_per_sec() > 0.0);
    assert!(stats.backend_calls > 0);
}

/// The pipelined-drain acceptance contract: on a multi-worker runtime,
/// `drain()` (sessions, chunk k+1 filling while chunk k executes) must
/// reproduce the blocking drain **bit-for-bit** on the 64-task
/// mixed-device workload — same plans per ticket, same serving variants,
/// same FIFO-per-group emission — and spend **exactly** the same backend
/// calls: 1 fused `mdp_step` call per lockstep MDP step and
/// `ceil(total_tables / N_cap)` `table_cost` ordering calls per chunk
/// (the per-chunk budgets are pinned on the blocking pass, and the
/// pipelined pass must match its totals to the call).
#[test]
fn pipelined_drain_matches_blocking_drain_and_call_budgets() {
    let rt = Arc::new(Runtime::reference().with_workers(4));
    assert!(rt.workers() > 1, "the pipelined contract must hold with workers > 1");
    let ds = gen_dlrm(300, 0);
    let sim = Simulator::new(SimConfig::default());
    let arrivals = mixed_workload(&ds);
    let agent = untrained_agent(&rt);
    let cfg = ServeConfig { capacity: 64, chunk: 16, ..ServeConfig::default() };
    let n_cap = CostNet::table_cost_cap(&rt);

    // blocking reference pass, chunk by chunk, per-chunk budgets pinned
    let mut svc = PlanService::new(&rt, Box::new(DreamShardPlacer::from_agent(&rt, &agent)), cfg);
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let calls_before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    let mut blocking: Vec<Planned> = vec![];
    loop {
        let tc_before = rt.run_count_for("table_cost");
        let chunk = svc.drain_chunk().unwrap();
        if chunk.is_empty() {
            break;
        }
        let total_tables: usize = chunk.iter().map(|p| p.plan.placement.len()).sum();
        let budget = ((total_tables + n_cap - 1) / n_cap).max(1) as u64;
        assert!(
            rt.run_count_for("table_cost") - tc_before <= budget,
            "blocking chunk of {total_tables} tables blew the ordering budget {budget}"
        );
        blocking.extend(chunk);
    }
    let blocking_calls = rt.run_count() - calls_before;
    let blocking_ordering = rt.run_count_for("table_cost") - ordering_before;
    assert_eq!(blocking.len(), 64);

    // pipelined pass: same workload, fresh service, multi-worker overlap
    let mut svc = PlanService::new(&rt, Box::new(DreamShardPlacer::from_agent(&rt, &agent)), cfg);
    for a in &arrivals {
        let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim).unwrap();
        svc.submit(req).unwrap().unwrap();
    }
    let calls_before = rt.run_count();
    let ordering_before = rt.run_count_for("table_cost");
    let piped = svc.drain().unwrap();
    let piped_calls = rt.run_count() - calls_before;
    let piped_ordering = rt.run_count_for("table_cost") - ordering_before;
    assert_eq!(piped.len(), 64);
    assert_eq!(svc.stats().planned, 64);

    // bit-identical plans, variants, and tickets
    let mut by_ticket = piped.clone();
    by_ticket.sort_by_key(|p| p.ticket);
    let mut blocking_by_ticket = blocking.clone();
    blocking_by_ticket.sort_by_key(|p| p.ticket);
    for (b, p) in blocking_by_ticket.iter().zip(&by_ticket) {
        assert_eq!(b.ticket, p.ticket);
        assert_eq!(b.variant, p.variant, "ticket {}", b.ticket);
        assert_eq!(b.plan.placement, p.plan.placement, "ticket {}", b.ticket);
    }
    // identical backend spend: the overlap moves waits, never adds calls —
    // so the per-chunk budgets pinned on the blocking pass carry over
    assert_eq!(piped_calls, blocking_calls, "pipelining must not change the call budget");
    assert_eq!(piped_ordering, blocking_ordering, "table_cost ordering budget");
    assert_eq!(
        piped_calls - piped_ordering,
        blocking_calls - blocking_ordering,
        "one fused mdp_step call per lockstep MDP step"
    );
    // emission order: FIFO within each serving-variant group, unsorted
    for key in [(8usize, 48usize), (128, 16)] {
        let tickets: Vec<u64> =
            piped.iter().filter(|p| p.variant == key).map(|p| p.ticket).collect();
        assert!(
            tickets.windows(2).all(|w| w[0] < w[1]),
            "variant {key:?} emitted out of FIFO order: {tickets:?}"
        );
    }
}
