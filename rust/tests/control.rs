//! Closed-loop controller integration tests: whole control trajectories
//! (overload -> pressure -> convergence back under target) run on a
//! [`TestClock`], so every latency sample, hysteresis flip, AIMD step,
//! and drain decision is deterministic — the ISSUE's acceptance bar is a
//! *unit test*, not a timing race.

use std::sync::Arc;

use dreamshard::placer::{self, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    Clock, ControlConfig, Controller, ServeConfig, ShardConfig, ShardedFrontEnd, SloClass,
    TestClock, TickReport,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};

const TARGET_MS: f64 = 50.0;

fn setup() -> (Dataset, Vec<Task>, Simulator) {
    let ds = gen_dlrm(200, 0);
    let (pool, _) = split_pools(&ds, 1);
    let tasks = sample_tasks(&pool, 8, 4, 12, 2);
    (ds, tasks, Simulator::new(SimConfig::default()))
}

fn test_front<'a>(
    rt: &Arc<Runtime>,
    clock: &Arc<TestClock>,
    cfg: ShardConfig,
) -> ShardedFrontEnd<'a> {
    let rt2 = Arc::clone(rt);
    ShardedFrontEnd::with_clock(
        rt,
        move || placer::by_name(&rt2, "greedy:size"),
        cfg,
        Arc::clone(clock) as Arc<dyn Clock>,
    )
    .unwrap()
}

/// The ISSUE's acceptance scenario, end to end: 4 requests sit 400 ms
/// (8x the 50 ms target), then a steady trickle arrives 5 ms before each
/// tick. Returns every tick's report plus whatever a final flush drain
/// still held. Shared by the convergence and the determinism tests.
fn overload_trajectory(ds: &Dataset, tasks: &[Task], sim: &Simulator) -> (Vec<TickReport>, usize) {
    let rt = Arc::new(Runtime::reference());
    let clock = Arc::new(TestClock::new());
    let mut front = test_front(
        &rt,
        &clock,
        ShardConfig {
            per_shard: ServeConfig { chunk: 4, ..ServeConfig::default() },
            global_cap: 64,
        },
    );
    let mut ctl = Controller::new(ControlConfig { target_ms: TARGET_MS, ..Default::default() });

    // overload: a burst queues for 400 ms before the loop starts ticking
    for t in tasks.iter().take(4) {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        front.submit(req).unwrap().expect("under the global cap");
    }
    clock.advance_ms(400.0);

    let mut reports = vec![];
    for i in 0..50 {
        // steady trickle: two requests, 5 ms ahead of the tick
        for t in tasks.iter().skip(4 + (2 * i) % 8).take(2) {
            let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
            front.submit(req).unwrap().expect("trickle stays under the cap");
        }
        clock.advance_ms(5.0);
        reports.push(ctl.tick(&mut front).unwrap());
    }
    let leftovers = front.drain().unwrap().len();
    (reports, leftovers)
}

/// The tentpole acceptance test: the overloaded shard's queue-latency
/// tail starts far above target, the controller enters pressure mode,
/// actuates (AIMD cap decrease, chunk growth, scheduled drains), and the
/// tail converges back within 20% of target — all within the 50-tick
/// trajectory, deterministically.
#[test]
fn controller_converges_an_overloaded_shard_under_target() {
    let (ds, tasks, sim) = setup();
    let (reports, leftovers) = overload_trajectory(&ds, &tasks, &sim);

    // tick 1 drains the overload blind (no latency evidence yet); tick 2
    // observes the damage: the tail is the full 405 ms backlog
    assert_eq!(reports[0].worst_p_ms, 0.0, "no samples before the first drain");
    assert!(!reports[0].pressure);
    assert!(
        reports[1].worst_p_ms > TARGET_MS * 2.0,
        "overload observed: p95 {} ms",
        reports[1].worst_p_ms
    );
    assert!(reports[1].pressure, "hysteresis latch entered pressure mode");

    // while under pressure the controller actually actuated: the
    // admission cap walked down to its floor (multiplicative decrease)
    // and the lane-chunk grew to amortize drain throughput
    let cfg = ControlConfig::default();
    let pressed: Vec<&TickReport> = reports.iter().filter(|r| r.pressure).collect();
    assert!(pressed.len() >= 5, "pressure persisted while bad samples dominated");
    assert_eq!(
        pressed.iter().map(|r| r.global_cap).min().unwrap(),
        cfg.min_cap,
        "AIMD decrease reached the admission floor"
    );
    assert!(
        pressed.iter().any(|r| r.shards[0].chunk >= 32),
        "chunks grew under pressure"
    );
    // every pressed tick drained the (only) shard: backlog is latency
    assert!(pressed.iter().all(|r| r.shards[0].drained));

    // convergence: the tail comes back within 20% of target and stays
    // there; pressure exits and the cap recovers additively
    let last = reports.last().unwrap();
    assert!(
        last.worst_p_ms <= TARGET_MS * 1.2,
        "converged: final p95 {} ms vs target {TARGET_MS} ms",
        last.worst_p_ms
    );
    assert!(!last.pressure, "pressure cleared after recovery");
    assert!(last.global_cap > cfg.min_cap, "cap recovered off the floor");
    assert!(
        last.shards[0].chunk <= 4,
        "chunks shrank back toward latency mode, got {}",
        last.shards[0].chunk
    );
    let first_ok = reports
        .iter()
        .position(|r| r.worst_p_ms > 0.0 && r.worst_p_ms <= TARGET_MS * 1.2)
        .expect("the tail came under target within the trajectory");
    assert!(first_ok < reports.len() - 1, "and not only on the last tick");

    // nothing was lost: overload + 50 ticks x 2 all planned
    let planned: usize = reports.iter().map(|r| r.planned.len()).sum::<usize>() + leftovers;
    assert_eq!(planned, 4 + 100, "every admitted request was eventually planned");
}

/// Same trajectory, run twice from scratch: every observation and
/// decision must reproduce bit-for-bit. This is the property that makes
/// the convergence assertions above trustworthy.
#[test]
fn control_trajectory_is_deterministic() {
    let (ds, tasks, sim) = setup();
    let (a, la) = overload_trajectory(&ds, &tasks, &sim);
    let (b, lb) = overload_trajectory(&ds, &tasks, &sim);
    assert_eq!(la, lb);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.tick, y.tick);
        assert_eq!(x.worst_p_ms.to_bits(), y.worst_p_ms.to_bits(), "tick {}", x.tick);
        assert_eq!(x.pressure, y.pressure);
        assert_eq!(x.global_cap, y.global_cap);
        assert_eq!(x.shards[0].chunk, y.shards[0].chunk);
        assert_eq!(x.shards[0].drained, y.shards[0].drained);
        let tx: Vec<u64> = x.planned.iter().map(|p| p.ticket).collect();
        let ty: Vec<u64> = y.planned.iter().map(|p| p.ticket).collect();
        assert_eq!(tx, ty, "tick {} drained the same tickets", x.tick);
    }
}

/// Under controller-driven pressure, batch traffic absorbs the global
/// cap first: batch submits shed, interactive submits evict the youngest
/// queued batch request and take its slot — zero interactive loss while
/// batch work is available to displace.
#[test]
fn pressure_sheds_batch_before_interactive_at_the_global_cap() {
    let (ds, tasks, sim) = setup();
    let rt = Arc::new(Runtime::reference());
    let clock = Arc::new(TestClock::new());
    let mut front = test_front(
        &rt,
        &clock,
        ShardConfig { per_shard: ServeConfig::default(), global_cap: 4 },
    );
    let mut ctl = Controller::new(ControlConfig {
        target_ms: 10.0,
        min_cap: 2,
        max_cap: 8,
        ..Default::default()
    });

    // induce pressure: 4 requests wait 100 ms against a 10 ms target
    for t in tasks.iter().take(4) {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        front.submit(req).unwrap().unwrap();
    }
    clock.advance_ms(100.0);
    ctl.tick(&mut front).unwrap(); // drains blind, records the 100 ms tail
    let rep = ctl.tick(&mut front).unwrap(); // observes it
    assert!(rep.pressure, "100 ms tail vs 10 ms target");
    assert!(front.class_order(), "pressure propagated SLO ordering to the front end");
    let cap = front.global_cap();
    assert!(cap >= 2 && cap < 4, "AIMD decreased the cap, floored at min_cap");

    // fill the shrunken cap with batch work
    let mut queued = 0;
    for t in tasks.iter().cycle() {
        let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
        match front.submit_slo(req, SloClass::Batch, None).unwrap() {
            Some(_) => queued += 1,
            None => break, // the cap shed this batch submit
        }
    }
    assert_eq!(queued, cap, "batch filled exactly to the live cap");

    // at the cap the classes part ways: batch shed above, interactive
    // admitted by displacing the youngest queued batch request
    let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[0], &sim).unwrap();
    let routed = front.submit_slo(req, SloClass::Interactive, None).unwrap();
    assert!(routed.is_some(), "interactive rides an evicted batch slot");

    let fs = front.stats();
    assert_eq!(fs.shed_global, 1, "only the probing batch submit was shed at the door");
    assert_eq!(fs.shed_global_batch, 1, "...and it was batch");
    assert_eq!(
        fs.shed_global - fs.shed_global_batch,
        0,
        "zero interactive loss under pressure"
    );
    assert_eq!(fs.aggregate.shed_batch, 1, "the eviction shows up in shard stats");

    // the displaced + admitted mix still drains: interactive first
    let done = front.drain().unwrap();
    assert_eq!(done.len(), cap, "evicted batch slot went to the interactive request");
    assert_eq!(done[0].class, SloClass::Interactive, "class-ordered drain under pressure");
}
