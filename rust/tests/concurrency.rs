//! Concurrent-runtime integration tests: N threads hammering
//! `submit`/`wait` on one shared `Arc<Runtime>` get results bit-identical
//! to sequential `run`, the lock-free call counters stay exact under the
//! race, and a panicking backend neither kills the worker pool nor
//! poisons the counters (the pre-redesign `Mutex<HashMap>` counters were
//! poisonable — this file is the regression net).

use std::sync::Arc;
use std::thread;

use dreamshard::runtime::{
    reference::reference_manifest, to_f32_vec, Backend, Runtime, TensorF32, Value,
};
use dreamshard::util::Rng;

/// Distinct, deterministic `table_cost` inputs per caller id.
fn table_cost_inputs(rt: &Runtime, id: u64) -> (Vec<Value>, usize) {
    let mut rng = Rng::new(1000 + id);
    let theta = rt.init_params("cost", &mut rng).unwrap();
    let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
    let f = rt.manifest.consts["F"] as usize;
    let mut feats = TensorF32::zeros(&[n, f]);
    for x in feats.data.iter_mut() {
        *x = (rng.uniform(0.0, 1.0)) as f32;
    }
    let inputs = vec![
        TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
        feats.value(),
        TensorF32::ones(&[f]).value(),
    ];
    (inputs, n)
}

#[test]
fn concurrent_submit_wait_is_bit_identical_and_counts_exactly() {
    const THREADS: u64 = 8;
    const REPS: usize = 5;
    let rt = Arc::new(Runtime::reference().with_workers(4));

    // sequential reference outputs, one distinct input set per thread id
    let mut expected: Vec<Vec<f32>> = vec![];
    for id in 0..THREADS {
        let (inputs, n) = table_cost_inputs(&rt, id);
        let out = rt.run("table_cost", &inputs).unwrap();
        expected.push(to_f32_vec(&out[0], n).unwrap());
    }
    let calls_before = rt.run_count();
    let named_before = rt.run_count_for("table_cost");

    let handles: Vec<_> = (0..THREADS)
        .map(|id| {
            let rt = Arc::clone(&rt);
            thread::spawn(move || {
                let (inputs, n) = table_cost_inputs(&rt, id);
                let mut outs = Vec::with_capacity(REPS);
                for _ in 0..REPS {
                    let ticket = rt.submit("table_cost", inputs.clone()).unwrap();
                    outs.push(to_f32_vec(&ticket.wait().unwrap()[0], n).unwrap());
                }
                outs
            })
        })
        .collect();
    for (id, h) in handles.into_iter().enumerate() {
        for out in h.join().expect("worker thread panicked") {
            assert_eq!(out, expected[id], "thread {id} diverged from sequential run");
        }
    }

    // totals are exact under the race: every dispatch counted once
    let raced = THREADS * REPS as u64;
    assert_eq!(rt.run_count() - calls_before, raced);
    assert_eq!(rt.run_count_for("table_cost") - named_before, raced);
}

#[test]
fn concurrent_blocking_run_shares_one_runtime() {
    // the blocking path is submit+wait underneath — same pool, same
    // counters, callable from any thread without a &mut anywhere
    let rt = Arc::new(Runtime::reference().with_workers(2));
    let calls_before = rt.run_count();
    let handles: Vec<_> = (0..4u64)
        .map(|id| {
            let rt = Arc::clone(&rt);
            thread::spawn(move || {
                let (inputs, n) = table_cost_inputs(&rt, id);
                let out = rt.run("table_cost", &inputs).unwrap();
                to_f32_vec(&out[0], n).unwrap().iter().all(|x| x.is_finite())
            })
        })
        .collect();
    for h in handles {
        assert!(h.join().unwrap(), "non-finite output under concurrency");
    }
    assert_eq!(rt.run_count() - calls_before, 4);
}

/// A backend that panics on every execution (counter-poisoning fixture).
struct PanickingBackend;
impl Backend for PanickingBackend {
    fn name(&self) -> &'static str {
        "panicking"
    }
    fn execute(&self, artifact: &str, _inputs: &[Value]) -> dreamshard::Result<Vec<Value>> {
        panic!("deliberate test panic in {artifact}")
    }
}

#[test]
fn backend_panic_surfaces_as_error_and_counters_stay_readable() {
    let rt = Runtime::with_backend(reference_manifest(), Box::new(PanickingBackend));
    let err = rt.run("table_cost", &[]).expect_err("a backend panic must surface as Err");
    assert!(err.to_string().contains("panicked"), "unexpected error: {err}");
    assert!(err.to_string().contains("table_cost"), "error names the artifact: {err}");

    // the regression this pins: a panic mid-execute used to poison the
    // counter mutex, turning every later run_count_for into a second
    // panic. The atomic counters must have recorded the dispatch and
    // stay readable.
    assert_eq!(rt.run_count(), 1);
    assert_eq!(rt.run_count_for("table_cost"), 1);

    // and the worker survives: the pool keeps serving dispatches
    let err2 = rt.run("table_cost", &[]).expect_err("still panics, still served");
    assert!(err2.to_string().contains("panicked"));
    assert_eq!(rt.run_count(), 2);
    assert_eq!(rt.run_count_for("table_cost"), 2);
}

#[test]
fn backend_panic_does_not_wedge_concurrent_waiters() {
    let rt = Arc::new(
        Runtime::with_backend(reference_manifest(), Box::new(PanickingBackend)).with_workers(2),
    );
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let rt = Arc::clone(&rt);
            thread::spawn(move || {
                let ticket = rt.submit("table_cost", vec![]).unwrap();
                ticket.wait().expect_err("every execution panics").to_string()
            })
        })
        .collect();
    for h in handles {
        let msg = h.join().expect("waiter must not propagate the backend panic");
        assert!(msg.contains("panicked"), "{msg}");
    }
    assert_eq!(rt.run_count(), 4, "every panicked dispatch still counted");
}
