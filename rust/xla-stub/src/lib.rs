//! Compile-only stand-in for the `xla-rs` PJRT bindings.
//!
//! The dreamshard crate's `xla` feature gates an `XlaBackend` that executes
//! AOT-lowered HLO artifacts through the PJRT C API. The real binding crate
//! links a native `libxla_extension` shared library that offline CI images
//! do not carry, so this stub provides exactly the API surface the backend
//! uses and fails — with a clear message — at client construction time.
//!
//! To run the accelerated backend, point the workspace's `xla` path
//! dependency at a real xla-rs checkout (and run `make artifacts`); the
//! `runtime::pjrt` module documents the required surface.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs's: carries a message, no backtrace.
pub struct Error {
    msg: String,
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "xla-stub: this build links the in-tree compile-only stub; \
     point the workspace `xla` path dependency at a real xla-rs checkout \
     (native PJRT library required) to enable the XLA backend";

fn stub_err() -> Error {
    Error { msg: STUB_MSG.to_string() }
}

/// Element types the literal container understands.
pub trait NativeType: Copy + Default {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host literal (dense array + shape). The stub keeps no data: it can only
/// be produced by an executing client, which the stub never constructs.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(stub_err())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(stub_err())
    }
}

/// Parsed HLO module (text format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(stub_err())
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

/// PJRT client. `cpu()` always fails in the stub — this is the single
/// choke point that keeps every other method unreachable.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}
