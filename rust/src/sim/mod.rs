//! GPU execution simulator — the substrate standing in for the paper's
//! 2080Ti/V100 testbed + FBGEMM fused embedding ops (see DESIGN.md
//! §Substitutions).
//!
//! The simulator exposes exactly what real hardware exposed to DreamShard:
//! given a placement, per-device **forward computation**, **backward
//! computation** and **backward communication** times plus the overall
//! step latency. Its cost surface deliberately reproduces the paper's
//! measured phenomena, and its functional form is *never* shown to the
//! learner (the cost network only sees (features, measured cost) samples):
//!
//! * non-linear single-table kernel time in dim / hash size / pooling /
//!   access distribution, with cache effects (Appendix A.3.1, Figs 10-11);
//! * data-dependent multi-table fusion speedup of 1-3x over the sum of
//!   single-table costs (Appendix A.3.2, Fig 12);
//! * all-to-all communication that degrades with dimension imbalance
//!   (Appendix A.3.3, Table 4);
//! * forward-communication idle-time coupling: a device that finishes
//!   forward compute early waits for the slowest device (Appendix A.4);
//! * deterministic per-measurement noise (the paper's PARAM-bench median
//!   latency has low but non-zero variance).

mod kernel;
mod comm;
mod eval;

pub use comm::CommModel;
pub use eval::{DeviceTrace, Evaluation, Simulator};
pub use kernel::KernelModel;

/// Simulator configuration. Defaults are calibrated so DLRM-50 (4) random
/// placements land near the paper's ~50 ms (Table 6) — see EXPERIMENTS.md.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Global batch size (the paper fixes 65,536).
    pub batch: usize,
    /// Per-device memory capacity in GB (11 GB ~ 2080Ti for DLRM runs,
    /// 32 GB ~ V100 for Prod runs).
    pub mem_cap_gb: f32,
    /// Relative measurement noise (std of a multiplicative factor).
    pub noise: f32,
    /// Seed for the measurement-noise stream.
    pub seed: u64,
    /// Effective bandwidth for live table migration between devices, in
    /// GB/s. Moving a table charges its full device footprint (weights +
    /// optimizer state) over this link — see
    /// [`Simulator::evaluate_migration`].
    /// 16 GB/s ~ PCIe-gen3-x16-era host-mediated copies, deliberately well
    /// below the all-to-all fabric: migration is not free.
    pub migration_gbps: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { batch: 65_536, mem_cap_gb: 11.0, noise: 0.01, seed: 0, migration_gbps: 16.0 }
    }
}

impl SimConfig {
    /// V100-like config used for Prod tasks (larger tables fit).
    pub fn v100() -> Self {
        SimConfig { mem_cap_gb: 32.0, ..Default::default() }
    }
}
