//! All-to-all communication model (Appendix A.3.3 + Table 4).
//!
//! In DLRM's hybrid parallelism every device sends its pooled embedding
//! vectors to every other device (forward) and receives the corresponding
//! gradients back (backward). The bytes a device injects are
//! `batch_per_device * sum_of_dims_on_device * (D-1)/D * 2B`; with limited
//! per-link bandwidth, the phase completes when the most-loaded device
//! finishes, and congestion grows with dimension imbalance: Table 4 shows
//! per-device comm times rising from ~11 ms (balanced) to ~17 ms (very
//! imbalanced) at 1,024 total dims over 4 GPUs — this model is calibrated
//! to those nine rows.

/// All-to-all time model over identical devices.
#[derive(Clone, Debug)]
pub struct CommModel {
    /// Global batch size.
    pub batch: usize,
    /// Per-device all-to-all goodput, bytes/s.
    pub bw: f64,
    /// Per-phase latency floor (ms): launch + sync.
    pub base_ms: f64,
}

impl CommModel {
    pub fn new(batch: usize) -> Self {
        // Calibration targets (Table 4, D=4, 1,024 total dims, batch
        // 65,536): balanced => ~11.2 ms/device; most imbalanced
        // (64/64/64/832) => max ~17.7 ms, light devices ~13 ms.
        CommModel { batch, bw: 0.627e9, base_ms: 1.2 }
    }

    /// Per-device all-to-all completion times (ms) for one direction,
    /// given each device's sum of embedding dimensions.
    ///
    /// Fitted to Table 4: with constant *total* volume, the collective's
    /// cost grows with imbalance roughly as the square root of the total
    /// volume deviation (concave — the nine measured rows pin this), and
    /// the overloaded device pays the full deviation term while
    /// underloaded devices still pay about 40% of it (they cannot finish
    /// before the slices destined to them arrive).
    pub fn all_to_all_ms(&self, dim_sums: &[f64]) -> Vec<f64> {
        let d = dim_sums.len();
        if d <= 1 {
            return vec![0.0; d];
        }
        let batch_per_dev = self.batch as f64 / d as f64;
        // per-device injected volume, in ms at fabric goodput
        let v_ms: Vec<f64> = dim_sums
            .iter()
            .map(|&dims| {
                batch_per_dev * dims * 2.0 * (d as f64 - 1.0) / d as f64 / self.bw * 1e3
            })
            .collect();
        let v_mean = v_ms.iter().sum::<f64>() / d as f64;
        let v_max = v_ms.iter().cloned().fold(0.0, f64::max);
        let dev_total: f64 = v_ms.iter().map(|&v| (v - v_mean).abs()).sum();
        let dev_term = dev_total.sqrt();
        v_ms.iter()
            .map(|&v| {
                // overloaded devices bear the deviation term fully
                let w = if v_max > v_mean + 1e-9 {
                    (0.55 + 0.45 * (v - v_mean) / (v_max - v_mean)).clamp(0.4, 1.0)
                } else {
                    0.0
                };
                self.base_ms + v_mean + w * dev_term
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn max(xs: &[f64]) -> f64 {
        xs.iter().cloned().fold(0.0, f64::max)
    }

    #[test]
    fn table4_balanced_magnitude() {
        // Perfectly balanced: 256 dims x 4 devices, batch 65,536 -> ~11 ms
        let c = CommModel::new(65_536);
        let t = c.all_to_all_ms(&[256.0, 256.0, 256.0, 256.0]);
        let m = max(&t);
        assert!((9.0..14.0).contains(&m), "balanced max {m} not ~11ms");
        // all devices roughly equal when balanced
        let spread = max(&t) - t.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 0.5);
    }

    #[test]
    fn table4_imbalance_ordering() {
        // Same 1,024 total dims, increasingly imbalanced -> increasing max
        let c = CommModel::new(65_536);
        let rows: Vec<Vec<f64>> = vec![
            vec![256.0, 256.0, 256.0, 256.0],
            vec![192.0, 256.0, 320.0, 384.0],
            vec![128.0, 128.0, 384.0, 384.0],
            vec![64.0, 64.0, 448.0, 448.0],
            vec![64.0, 64.0, 64.0, 832.0],
        ];
        let maxes: Vec<f64> = rows.iter().map(|r| max(&c.all_to_all_ms(r))).collect();
        for w in maxes.windows(2) {
            assert!(w[1] > w[0], "imbalance must raise comm cost: {maxes:?}");
        }
        // the most imbalanced row lands near Table 4's ~17.7 ms
        assert!((14.0..22.0).contains(&maxes[4]), "very imbalanced {maxes:?}");
    }

    #[test]
    fn loaded_device_pays_more() {
        let c = CommModel::new(65_536);
        let t = c.all_to_all_ms(&[64.0, 64.0, 64.0, 832.0]);
        assert!(t[3] > t[0]);
    }

    #[test]
    fn single_device_no_comm() {
        let c = CommModel::new(65_536);
        assert_eq!(c.all_to_all_ms(&[512.0]), vec![0.0]);
    }

    #[test]
    fn more_devices_less_per_device_traffic() {
        let c = CommModel::new(65_536);
        // same total dims spread over more devices -> cheaper phase
        let t4 = max(&c.all_to_all_ms(&vec![256.0; 4]));
        let t8 = max(&c.all_to_all_ms(&vec![128.0; 8]));
        assert!(t8 < t4);
    }
}
