//! Embedding-kernel time model: single-table costs and multi-table fusion.
//!
//! Shapes are taken from the paper's measurements on 2080Ti + FBGEMM:
//! Fig. 10 (kernel time vs hash size x dim), Fig. 11 (vs pooling factor x
//! access sparsity), Fig. 12 (fusion speedup 1-3x, not linear in the sum
//! of single-table costs). Constants are calibrated so task costs land in
//! the paper's millisecond ranges; the *shape* is what matters — the cost
//! network has to learn it from samples, exactly as on real hardware.

use crate::tables::Table;

/// Single-table and fused multi-table kernel-time model.
#[derive(Clone, Debug)]
pub struct KernelModel {
    /// Global batch size.
    pub batch: usize,
}

impl KernelModel {
    pub fn new(batch: usize) -> Self {
        KernelModel { batch }
    }

    /// Cache efficiency factor in (0, 1]: fraction of the nominal memory
    /// traffic actually paid after L1/L2 caching. Small working sets and
    /// hot access distributions are cheaper (Fig. 10 hash-size effect +
    /// Fig. 11 access-ratio effect).
    fn cache_factor(&self, t: &Table) -> f64 {
        let reuse = t.reuse_factor() as f64; // share of traffic on hot rows
        // working set the cold traffic walks over, in cache-size units
        // (~6 MB L2 on a 2080Ti)
        let row_bytes = t.dim as f64 * 2.0;
        let ws = (t.hash_size as f64 * row_bytes) / 6e6;
        // cold traffic pays more as the working set overflows cache
        let cold_penalty = 0.35 + 0.65 * (1.0 - (-ws / 8.0).exp());
        let hot_cost = 0.25; // hot rows mostly hit cache
        reuse * hot_cost + (1.0 - reuse) * cold_penalty
    }

    /// Single-table forward-computation time (ms): gather + pooled sum of
    /// `batch * pooling` rows of `dim` halfs, modulated by caching, plus a
    /// kernel-launch floor. Non-linear in every feature on purpose.
    pub fn fwd_ms(&self, t: &Table) -> f64 {
        let pool = t.pooling.max(0.2) as f64;
        let dim = t.dim as f64;
        let traffic = self.batch as f64 * pool.powf(0.82) * dim.powf(0.92) * 2.0;
        // random-gather effective bandwidth: a few % of the 2080Ti's
        // 616 GB/s — scattered rows defeat coalescing (why embedding
        // lookup dominates, §1)
        let eff_bw = 5.5e9;
        0.06 + 1e3 * traffic * self.cache_factor(t) / eff_bw
    }

    /// Single-table backward-computation time (ms): gradient scatter-add +
    /// optimizer update touches rows twice and is atomics-bound, so it is
    /// systematically more expensive than the forward and *more* sensitive
    /// to pooling (the paper's traces show bwd comp > fwd comp).
    pub fn bwd_ms(&self, t: &Table) -> f64 {
        let pool = t.pooling.max(0.2) as f64;
        let dim = t.dim as f64;
        let traffic = self.batch as f64 * pool.powf(0.88) * dim.powf(0.9) * 2.0 * 1.8;
        let eff_bw = 5.5e9;
        0.08 + 1e3 * traffic * (0.2 + 0.8 * self.cache_factor(t)) / eff_bw
    }

    /// Marginal-cost floor for fused execution, in (0, 1): the fraction of
    /// its standalone cost a deeply-fused table still pays. Lower floor =
    /// more fusion benefit. Mix-dependent (Fig. 12's point): homogeneous
    /// dims vectorize together better, and small-pooling tables gain most
    /// from amortized launches.
    fn fusion_floor(&self, tables: &[&Table]) -> f64 {
        let n = tables.len() as f64;
        let mean_dim: f64 = tables.iter().map(|t| t.dim as f64).sum::<f64>() / n;
        let var_dim: f64 = tables
            .iter()
            .map(|t| {
                let d = t.dim as f64 / mean_dim - 1.0;
                d * d
            })
            .sum::<f64>()
            / n;
        let homo = (-var_dim * 4.0).exp(); // 1 = perfectly homogeneous
        let mean_pool: f64 = tables.iter().map(|t| t.pooling as f64).sum::<f64>() / n;
        let pool_gain = (-mean_pool / 24.0).exp(); // small poolings fuse best
        0.55 - 0.08 * homo - 0.05 * pool_gain // in [0.42, 0.55]
    }

    /// Fused forward/backward computation time for one device (ms).
    ///
    /// Rank-weighted marginal costs: tables are sorted by standalone cost
    /// descending; the largest pays its full cost (fusion cannot beat the
    /// op's own memory traffic) and each further table pays
    /// `floor + (1-floor) * 0.75^rank` of its standalone cost. This keeps
    /// the fused total below the unfused sum with a data-dependent 1-3x
    /// speedup (Fig. 12) while staying (softly) monotone in added work.
    pub fn device_ms(&self, tables: &[&Table]) -> (f64, f64) {
        if tables.is_empty() {
            return (0.0, 0.0);
        }
        let floor = self.fusion_floor(tables);
        let mut costs: Vec<(f64, f64)> =
            tables.iter().map(|t| (self.fwd_ms(t), self.bwd_ms(t))).collect();
        // total_cmp: a NaN-featured table (corrupt input) must not panic
        // the fused-cost model — NaNs order deterministically instead
        costs.sort_by(|a, b| (b.0 + b.1).total_cmp(&(a.0 + a.1)));
        let mut fwd = 0.0;
        let mut bwd = 0.0;
        let mut decay = 1.0; // 0.75^rank
        for (f, b) in costs {
            let w = floor + (1.0 - floor) * decay;
            fwd += f * w;
            bwd += b * w;
            decay *= 0.75;
        }
        (fwd, bwd)
    }

    /// Realized fusion speedup: unfused sum / fused time (1x for a single
    /// table, saturating below ~2.4x; within Fig. 12's 1-3x band).
    pub fn fusion_speedup(&self, tables: &[&Table]) -> f64 {
        if tables.len() <= 1 {
            return 1.0;
        }
        let sum: f64 = tables.iter().map(|t| self.fwd_ms(t) + self.bwd_ms(t)).sum();
        let (f, b) = self.device_ms(tables);
        sum / (f + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{gen_dlrm, NUM_BINS};

    fn table(dim: u32, hash: u64, pool: f32) -> Table {
        let mut bins = [0.0; NUM_BINS];
        bins[2] = 1.0;
        Table { dim, hash_size: hash, pooling: pool, bins }
    }

    #[test]
    fn fwd_monotone_in_dim_and_pooling() {
        let k = KernelModel::new(65_536);
        // Fig. 10: higher dim -> higher time
        assert!(k.fwd_ms(&table(64, 1 << 20, 32.0)) > k.fwd_ms(&table(8, 1 << 20, 32.0)));
        // Fig. 11: higher pooling -> higher time
        assert!(k.fwd_ms(&table(32, 1 << 20, 128.0)) > k.fwd_ms(&table(32, 1 << 20, 2.0)));
    }

    #[test]
    fn hash_size_moderate_effect() {
        let k = KernelModel::new(65_536);
        let small = k.fwd_ms(&table(32, 200_000, 32.0));
        let large = k.fwd_ms(&table(32, 20_000_000, 32.0));
        assert!(large > small, "bigger hash -> less caching -> slower");
        assert!(large / small < 3.5, "hash effect is moderate (Fig. 10)");
    }

    #[test]
    fn hot_distribution_is_cheaper() {
        let k = KernelModel::new(65_536);
        let mut hot = table(32, 1 << 21, 32.0);
        hot.bins = [0.0; NUM_BINS];
        hot.bins[NUM_BINS - 1] = 1.0;
        let mut cold = table(32, 1 << 21, 32.0);
        cold.bins = [0.0; NUM_BINS];
        cold.bins[0] = 1.0;
        assert!(k.fwd_ms(&hot) < k.fwd_ms(&cold), "Fig. 11 access-ratio effect");
    }

    #[test]
    fn bwd_exceeds_fwd() {
        let k = KernelModel::new(65_536);
        let t = table(16, 1 << 20, 10.0);
        assert!(k.bwd_ms(&t) > k.fwd_ms(&t));
    }

    #[test]
    fn fusion_speedup_in_paper_range() {
        let k = KernelModel::new(65_536);
        let d = gen_dlrm(856, 3);
        let mut rng = crate::util::Rng::new(9);
        for _ in 0..50 {
            let ids = rng.sample_indices(d.len(), 10);
            let tables: Vec<&Table> = ids.iter().map(|&i| &d.tables[i]).collect();
            let s = k.fusion_speedup(&tables);
            assert!((1.0..=3.0).contains(&s), "speedup {s} outside the 1-3x range");
        }
        // single table: no fusion
        assert_eq!(k.fusion_speedup(&[&d.tables[0]]), 1.0);
    }

    #[test]
    fn device_ms_survives_nan_features() {
        // regression: the rank-weighting sort used partial_cmp().unwrap(),
        // so one NaN-costed table panicked the whole fused-cost model
        let k = KernelModel::new(65_536);
        let good = table(32, 1 << 20, 16.0);
        let mut bad = table(32, 1 << 20, 16.0);
        // a NaN bin poisons reuse_factor -> cache_factor -> fwd/bwd cost
        // (NaN pooling would be laundered by the .max(0.2) clamp)
        bad.bins[6] = f32::NAN;
        assert!(k.fwd_ms(&bad).is_nan(), "NaN must reach the standalone cost");
        let tables = vec![&good, &bad, &good];
        let (f, b) = k.device_ms(&tables); // must not panic
        assert!(f.is_nan() || f >= 0.0);
        assert!(b.is_nan() || b >= 0.0);
        let _ = k.fusion_speedup(&tables); // must not panic either
    }

    #[test]
    fn fusion_grows_with_count() {
        let k = KernelModel::new(65_536);
        let d = gen_dlrm(64, 3);
        let few: Vec<&Table> = d.tables[..2].iter().collect();
        let many: Vec<&Table> = d.tables[..20].iter().collect();
        assert!(k.fusion_speedup(&many) > k.fusion_speedup(&few));
    }

    #[test]
    fn fused_cost_below_sum_and_nonlinear() {
        // Fig. 12: fused < sum of singles, ratio data-dependent
        let k = KernelModel::new(65_536);
        let d = gen_dlrm(856, 3);
        let mut rng = crate::util::Rng::new(10);
        let mut ratios = vec![];
        for _ in 0..30 {
            let ids = rng.sample_indices(d.len(), 10);
            let tables: Vec<&Table> = ids.iter().map(|&i| &d.tables[i]).collect();
            let sum: f64 = tables.iter().map(|t| k.fwd_ms(t) + k.bwd_ms(t)).sum();
            let (f, b) = k.device_ms(&tables);
            assert!(f + b < sum);
            ratios.push(sum / (f + b));
        }
        let (_, spread) = crate::util::mean_std(&ratios);
        assert!(spread > 0.005, "speedup must be mix-dependent, spread {spread}");
    }
}
