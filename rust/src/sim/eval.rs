//! Placement evaluation: compose the kernel and communication models into
//! the four-stage embedding pipeline (Fig. 1): forward computation ->
//! forward all-to-all -> backward all-to-all -> backward computation,
//! each phase gated by its slowest device.

use super::comm::CommModel;
use super::kernel::KernelModel;
use super::SimConfig;
use crate::tables::{Dataset, Table, Task};
use crate::util::Rng;

/// Per-device timing breakdown of one training step.
#[derive(Clone, Debug, Default)]
pub struct DeviceTrace {
    pub fwd_comp: f64,
    /// Forward comm *as PyTorch reports it*: actual transfer + the idle
    /// time spent waiting for the slowest forward compute (Appendix A.4).
    pub fwd_comm_reported: f64,
    /// Actual forward transfer time.
    pub fwd_comm: f64,
    pub bwd_comm: f64,
    pub bwd_comp: f64,
    pub dim_sum: f64,
    pub n_tables: usize,
    pub mem_gb: f64,
}

/// Result of "running" a placement on the simulated cluster.
#[derive(Clone, Debug, Default)]
pub struct Evaluation {
    pub devices: Vec<DeviceTrace>,
    /// Overall step latency (ms) — the quantity DreamShard minimizes.
    pub latency: f64,
    /// The paper's 3 cost features per device:
    /// [fwd comp, bwd comp, bwd comm] (section 3.1).
    pub q: Vec<[f32; 3]>,
    /// One-off cost of migrating into this placement from a previous one
    /// (ms) — zero unless the evaluation came through
    /// [`Simulator::evaluate_migration`].
    pub migration_ms: f64,
    /// Tables that changed device relative to the previous placement.
    pub moved_tables: usize,
}

impl Evaluation {
    /// Step latency plus the (amortized-as-one-step) migration charge —
    /// the quantity a re-placement strategy should minimize.
    pub fn total_ms(&self) -> f64 {
        self.latency + self.migration_ms
    }
}

/// The simulated GPU cluster.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub cfg: SimConfig,
    pub kernel: KernelModel,
    pub comm: CommModel,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Self {
        let kernel = KernelModel::new(cfg.batch);
        let comm = CommModel::new(cfg.batch);
        Simulator { cfg, kernel, comm }
    }

    /// Memory used by a set of tables on one device (weights + optimizer
    /// state; fp16 weights, fp32 momentum ~ 3x weight bytes).
    pub fn mem_gb(tables: &[&Table]) -> f64 {
        tables.iter().map(|t| t.size_gb() as f64 * 3.0).sum()
    }

    /// Would adding `table` to a device currently holding `current` still
    /// satisfy the memory cap? (Defines the MDP's legal actions.)
    pub fn fits(&self, current: &[&Table], table: &Table) -> bool {
        Self::mem_gb(current) + table.size_gb() as f64 * 3.0 <= self.cfg.mem_cap_gb as f64
    }

    /// Evaluate a full or partial placement. `placement[i]` is the device
    /// of `task.table_ids[i]`; entries == `usize::MAX` are not yet placed
    /// (partial states during an MDP episode).
    pub fn evaluate(&self, ds: &Dataset, task: &Task, placement: &[usize]) -> Evaluation {
        let d = task.n_devices;
        let mut per_dev: Vec<Vec<&Table>> = vec![vec![]; d];
        for (i, &p) in placement.iter().enumerate() {
            if p != usize::MAX {
                per_dev[p].push(&ds.tables[task.table_ids[i]]);
            }
        }
        self.evaluate_groups(&per_dev, placement)
    }

    /// Evaluate explicit per-device table groups.
    pub fn evaluate_groups(&self, per_dev: &[Vec<&Table>], noise_key: &[usize]) -> Evaluation {
        let d = per_dev.len();
        let mut traces: Vec<DeviceTrace> = Vec::with_capacity(d);
        for tables in per_dev {
            let (fwd, bwd) = self.kernel.device_ms(tables);
            traces.push(DeviceTrace {
                fwd_comp: fwd,
                bwd_comp: bwd,
                dim_sum: tables.iter().map(|t| t.dim as f64).sum(),
                n_tables: tables.len(),
                mem_gb: Self::mem_gb(tables),
                ..Default::default()
            });
        }
        let dim_sums: Vec<f64> = traces.iter().map(|t| t.dim_sum).collect();
        let fwd_comm = self.comm.all_to_all_ms(&dim_sums);
        let bwd_comm = self.comm.all_to_all_ms(&dim_sums); // same volume, opposite direction
        for (i, tr) in traces.iter_mut().enumerate() {
            tr.fwd_comm = fwd_comm[i];
            tr.bwd_comm = bwd_comm[i];
        }

        // measurement noise: deterministic in (seed, placement)
        let mut h = self.cfg.seed ^ 0xC0FFEE;
        for &p in noise_key {
            // wrapping: unplaced entries are usize::MAX, so `+ 1` would
            // overflow (a debug-build panic on every partial placement)
            h = h.wrapping_mul(0x100000001B3).wrapping_add((p as u64).wrapping_add(1));
        }
        let mut rng = Rng::new(h);
        let jitter = |rng: &mut Rng, x: f64| x * (1.0 + self.cfg.noise as f64 * rng.normal());

        let mut q = Vec::with_capacity(d);
        for tr in traces.iter_mut() {
            tr.fwd_comp = jitter(&mut rng, tr.fwd_comp);
            tr.bwd_comp = jitter(&mut rng, tr.bwd_comp);
            tr.bwd_comm = jitter(&mut rng, tr.bwd_comm);
            tr.fwd_comm = jitter(&mut rng, tr.fwd_comm);
            q.push([tr.fwd_comp as f32, tr.bwd_comp as f32, tr.bwd_comm as f32]);
        }

        // PyTorch books the wait-for-stragglers into fwd comm (§A.4).
        // Derived AFTER the jitter so the reported idle time is consistent
        // with the (jittered) trace it ships with — deriving it from the
        // pre-jitter values could even go negative against them.
        let max_fwd_comp = traces.iter().map(|t| t.fwd_comp).fold(0.0, f64::max);
        for tr in traces.iter_mut() {
            tr.fwd_comm_reported = (max_fwd_comp - tr.fwd_comp) + tr.fwd_comm;
        }

        let phase = |f: fn(&DeviceTrace) -> f64| traces.iter().map(f).fold(0.0, f64::max);
        let latency = phase(|t| t.fwd_comp)
            + phase(|t| t.fwd_comm)
            + phase(|t| t.bwd_comm)
            + phase(|t| t.bwd_comp);
        Evaluation { devices: traces, latency, q, migration_ms: 0.0, moved_tables: 0 }
    }

    /// Time to migrate one table between devices: its full device
    /// footprint (weights + optimizer state, the same 3x accounting as
    /// [`Simulator::mem_gb`]) over the configured migration link.
    pub fn transfer_ms(&self, table: &Table) -> f64 {
        table.size_gb() as f64 * 3.0 / self.cfg.migration_gbps * 1e3
    }

    /// Evaluate `next` as a *re*-placement of `prev`: the usual
    /// [`Simulator::evaluate`] (all shared fields, including the noise
    /// key, depend only on `next`), plus a migration charge proportional
    /// to the bytes of every moved table.
    ///
    /// `prev[i]` is the previous device of `task.table_ids[i]`;
    /// `usize::MAX` means the table had no prior placement (free to land
    /// anywhere), and any other device the task no longer has (`>=
    /// n_devices`, e.g. after a device loss) still charges the transfer —
    /// the bytes must move off the lost device either way. An empty
    /// `prev` is shorthand for "no prior placement at all".
    pub fn evaluate_migration(
        &self,
        ds: &Dataset,
        task: &Task,
        prev: &[usize],
        next: &[usize],
    ) -> Evaluation {
        let mut eval = self.evaluate(ds, task, next);
        if prev.is_empty() {
            return eval;
        }
        assert_eq!(prev.len(), next.len(), "prev/next placement length mismatch");
        for (i, (&p, &n)) in prev.iter().zip(next).enumerate() {
            if p != usize::MAX && p != n {
                eval.moved_tables += 1;
                eval.migration_ms += self.transfer_ms(&ds.tables[task.table_ids[i]]);
            }
        }
        eval
    }

    /// Render a Fig.-1-style ASCII trace of a placement evaluation.
    pub fn render_trace(&self, eval: &Evaluation, label: &str) -> String {
        let mut out = if eval.moved_tables > 0 {
            format!(
                "{label}: overall {:.2} ms + {:.2} ms migration ({} tables moved)\n",
                eval.latency, eval.migration_ms, eval.moved_tables
            )
        } else {
            format!("{label}: overall {:.2} ms\n", eval.latency)
        };
        let width = 60.0;
        let scale = width
            / eval
                .devices
                .iter()
                .map(|t| t.fwd_comp + t.fwd_comm + t.bwd_comm + t.bwd_comp)
                .fold(1e-9, f64::max);
        for (i, t) in eval.devices.iter().enumerate() {
            let seg = |x: f64, c: char| c.to_string().repeat((x * scale).round() as usize);
            out.push_str(&format!(
                "  GPU{i}: {}{}{}{} ({:.1}/{:.1}/{:.1}/{:.1} ms, {} tables, dims {})\n",
                seg(t.fwd_comp, 'F'),
                seg(t.fwd_comm, 'f'),
                seg(t.bwd_comm, 'b'),
                seg(t.bwd_comp, 'B'),
                t.fwd_comp,
                t.fwd_comm,
                t.bwd_comm,
                t.bwd_comp,
                t.n_tables,
                t.dim_sum as i64,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{gen_dlrm, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 50, 4, 1, 2).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    fn round_robin(task: &Task) -> Vec<usize> {
        (0..task.n_tables()).map(|i| i % task.n_devices).collect()
    }

    #[test]
    fn latency_is_positive_and_calibrated() {
        let (ds, task, sim) = setup();
        let eval = sim.evaluate(&ds, &task, &round_robin(&task));
        // paper magnitude: DLRM-50 (4) in the tens of ms
        assert!(
            (15.0..150.0).contains(&eval.latency),
            "latency {} outside calibration band",
            eval.latency
        );
        assert_eq!(eval.q.len(), 4);
        assert_eq!(eval.devices.len(), 4);
    }

    #[test]
    fn balanced_beats_skewed() {
        let (ds, task, sim) = setup();
        let balanced = sim.evaluate(&ds, &task, &round_robin(&task));
        let skewed = sim.evaluate(&ds, &task, &vec![0; task.n_tables()]);
        assert!(balanced.latency < skewed.latency, "balance must help");
    }

    #[test]
    fn partial_placement_supported() {
        let (ds, task, sim) = setup();
        let mut placement = vec![usize::MAX; task.n_tables()];
        placement[0] = 0;
        placement[1] = 1;
        let eval = sim.evaluate(&ds, &task, &placement);
        assert!(eval.latency > 0.0);
        assert_eq!(eval.devices[2].n_tables, 0);
    }

    #[test]
    fn noise_is_deterministic_and_small() {
        let (ds, task, sim) = setup();
        let p = round_robin(&task);
        let a = sim.evaluate(&ds, &task, &p);
        let b = sim.evaluate(&ds, &task, &p);
        assert_eq!(a.latency, b.latency, "same placement+seed must replay");
        let mut sim2 = Simulator::new(SimConfig::default());
        sim2.cfg.seed = 99;
        let c = sim2.evaluate(&ds, &task, &p);
        assert_ne!(a.latency, c.latency);
        assert!((a.latency - c.latency).abs() / a.latency < 0.15);
    }

    #[test]
    fn fwd_comm_reported_includes_idle(){
        let (ds, task, sim) = setup();
        // skew compute: all tables on GPU0 except one on GPU1
        let mut p = vec![0; task.n_tables()];
        p[0] = 1;
        let eval = sim.evaluate(&ds, &task, &p);
        // GPU1 finishes fwd comp early, so its *reported* fwd comm
        // includes waiting for GPU0 (§A.4)
        assert!(eval.devices[1].fwd_comm_reported > eval.devices[1].fwd_comm);
    }

    #[test]
    fn fwd_comm_reported_consistent_with_jittered_trace() {
        let (ds, task, sim) = setup();
        let eval = sim.evaluate(&ds, &task, &round_robin(&task));
        let max_fwd = eval.devices.iter().map(|t| t.fwd_comp).fold(0.0, f64::max);
        for tr in &eval.devices {
            // idle = straggler wait against the *jittered* compute times
            let idle = tr.fwd_comm_reported - tr.fwd_comm;
            assert!((idle - (max_fwd - tr.fwd_comp)).abs() < 1e-12);
            assert!(idle >= 0.0, "reported idle can never be negative");
        }
    }

    #[test]
    fn memory_constraint() {
        let (ds, _, sim) = setup();
        let big = Table { dim: 768, hash_size: 30_000_000, pooling: 1.0, bins: ds.tables[0].bins };
        // 30M x 768 x 2B x3 = 138 GB >> 11 GB cap
        assert!(!sim.fits(&[], &big));
        assert!(sim.fits(&[], &ds.tables[0]));
    }

    #[test]
    fn q_matches_trace() {
        let (ds, task, sim) = setup();
        let eval = sim.evaluate(&ds, &task, &round_robin(&task));
        for (qd, tr) in eval.q.iter().zip(eval.devices.iter()) {
            // q is stored in f32; compare at f32 precision
            assert!((qd[0] as f64 - tr.fwd_comp).abs() < 1e-4 * (1.0 + tr.fwd_comp));
            assert!((qd[1] as f64 - tr.bwd_comp).abs() < 1e-4 * (1.0 + tr.bwd_comp));
            assert!((qd[2] as f64 - tr.bwd_comm).abs() < 1e-4 * (1.0 + tr.bwd_comm));
        }
    }

    #[test]
    fn migration_zero_without_prior_placement() {
        let (ds, task, sim) = setup();
        let next = round_robin(&task);
        // empty prev and all-MAX prev are both "no prior placement"
        let a = sim.evaluate_migration(&ds, &task, &[], &next);
        let b = sim.evaluate_migration(&ds, &task, &vec![usize::MAX; next.len()], &next);
        let plain = sim.evaluate(&ds, &task, &next);
        for e in [&a, &b] {
            assert_eq!(e.moved_tables, 0);
            assert_eq!(e.migration_ms, 0.0);
            // shared fields bit-identical to the plain evaluation
            assert_eq!(e.latency, plain.latency);
            assert_eq!(e.total_ms(), plain.latency);
        }
    }

    #[test]
    fn migration_charges_moved_bytes() {
        let (ds, task, sim) = setup();
        let prev = round_robin(&task);
        let mut next = prev.clone();
        // move exactly tables 0 and 1
        next[0] = (prev[0] + 1) % task.n_devices;
        next[1] = (prev[1] + 1) % task.n_devices;
        let eval = sim.evaluate_migration(&ds, &task, &prev, &next);
        assert_eq!(eval.moved_tables, 2);
        let expect = sim.transfer_ms(&ds.tables[task.table_ids[0]])
            + sim.transfer_ms(&ds.tables[task.table_ids[1]]);
        assert!((eval.migration_ms - expect).abs() < 1e-12);
        assert!(eval.migration_ms > 0.0, "moving real tables costs real time");
        assert!((eval.total_ms() - (eval.latency + eval.migration_ms)).abs() < 1e-12);
        // identical placement -> nothing moved
        let same = sim.evaluate_migration(&ds, &task, &prev, &prev);
        assert_eq!((same.moved_tables, same.migration_ms), (0, 0.0));
    }

    #[test]
    fn migration_charges_forced_moves_off_lost_devices() {
        let (ds, task, sim) = setup();
        // prev planned on 4 devices; the task now has 3, so every table
        // that lived on device 3 is a forced move and still pays transfer
        let prev = round_robin(&task);
        let small = Task { table_ids: task.table_ids.clone(), n_devices: 3 };
        let next: Vec<usize> = prev.iter().map(|&p| p % 3).collect();
        let eval = sim.evaluate_migration(&ds, &small, &prev, &next);
        let forced = prev.iter().filter(|&&p| p == 3).count();
        assert!(forced > 0);
        assert_eq!(eval.moved_tables, forced);
        assert!(eval.migration_ms > 0.0);
    }

    #[test]
    fn transfer_scales_with_bandwidth() {
        let (ds, _, sim) = setup();
        let mut fast = Simulator::new(SimConfig::default());
        fast.cfg.migration_gbps *= 2.0;
        let t = &ds.tables[0];
        assert!((sim.transfer_ms(t) / fast.transfer_ms(t) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn render_trace_shows_migration() {
        let (ds, task, sim) = setup();
        let prev = round_robin(&task);
        let mut next = prev.clone();
        next[0] = (prev[0] + 1) % task.n_devices;
        let eval = sim.evaluate_migration(&ds, &task, &prev, &next);
        let s = sim.render_trace(&eval, "rebalance");
        assert!(s.contains("migration") && s.contains("1 tables moved"), "{s}");
        // and the plain path stays clean
        let plain = sim.render_trace(&sim.evaluate(&ds, &task, &prev), "plain");
        assert!(!plain.contains("migration"));
    }

    #[test]
    fn render_trace_smoke() {
        let (ds, task, sim) = setup();
        let eval = sim.evaluate(&ds, &task, &round_robin(&task));
        let s = sim.render_trace(&eval, "test");
        assert!(s.contains("GPU0") && s.contains("GPU3"));
    }
}
