//! Tables 1, 6, 7: overall cost comparison (ms + speedup over random) of
//! random / four greedy experts / RNN-based RL / DreamShard, on train and
//! test tasks, across dataset x table-count x device-count configs.

use crate::util::error::Result;

use super::common::{
    best_expert, eval_agent, eval_expert, eval_random, make_suite, seeded_agent_eval, train_agent,
    Ctx, Suite, Which,
};
use crate::baselines::ALL_EXPERTS;
use crate::coordinator::RnnBaseline;
use crate::util::table::{ms_pm, speedup_vs, TextTable};
use crate::util::{mean_std, Rng};

pub const TABLE1_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 20, 4),
    (Which::Dlrm, 40, 4),
    (Which::Dlrm, 60, 4),
    (Which::Dlrm, 80, 4),
    (Which::Dlrm, 100, 4),
    (Which::Dlrm, 40, 8),
    (Which::Dlrm, 80, 8),
    (Which::Dlrm, 120, 8),
    (Which::Dlrm, 160, 8),
    (Which::Dlrm, 200, 8),
    (Which::Prod, 20, 2),
    (Which::Prod, 40, 4),
    (Which::Prod, 80, 8),
];

pub const TABLE6_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 10, 4),
    (Which::Dlrm, 30, 4),
    (Which::Dlrm, 50, 4),
    (Which::Dlrm, 70, 4),
    (Which::Dlrm, 90, 4),
];

pub const TABLE7_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 10, 2),
    (Which::Dlrm, 20, 2),
    (Which::Dlrm, 30, 2),
    (Which::Dlrm, 40, 2),
    (Which::Dlrm, 50, 2),
];

/// Train + evaluate the RNN baseline; per-seed mean costs on train/test.
fn rnn_eval(ctx: &Ctx, suite: &Suite) -> Result<(Vec<f64>, Vec<f64>)> {
    let updates = ctx.train_cfg().n_iterations * ctx.train_cfg().n_rl;
    let mut tr = vec![];
    let mut te = vec![];
    for seed in 0..ctx.seeds as u64 {
        let mut rng = Rng::new(77_000 + seed);
        let mut rnn = RnnBaseline::new(&ctx.rt, suite.train[0].n_devices, &mut rng)?;
        rnn.train(&ctx.rt, &suite.sim, &suite.ds, &suite.train, updates, &mut rng)?;
        for (tasks, out) in [(&suite.train, &mut tr), (&suite.test, &mut te)] {
            let costs: Vec<f64> = tasks
                .iter()
                .map(|t| {
                    let p = rnn.place(&ctx.rt, &suite.sim, &suite.ds, t)?;
                    Ok(suite.sim.evaluate(&suite.ds, t, &p).latency)
                })
                .collect::<Result<_>>()?;
            out.push(crate::util::mean(&costs));
        }
    }
    Ok((tr, te))
}

pub fn run_configs(ctx: &Ctx, name: &str, configs: &[(Which, usize, usize)]) -> Result<()> {
    // optional row cap for time-boxed runs: DREAMSHARD_MAX_CONFIGS=N
    let cap: usize = std::env::var("DREAMSHARD_MAX_CONFIGS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let configs = &configs[..configs.len().min(cap)];
    let mut tbl = TextTable::new(vec![
        "Task", "Split", "Random", "Size", "Dim", "Lookup", "Size-lookup", "RNN", "DreamShard",
    ]);
    for &(which, n_tables, n_devices) in configs {
        let suite = make_suite(which, n_tables, n_devices, ctx.n_tasks(), 7);
        eprintln!("[{name}] {} ...", suite.name);
        let (agent_tr, agent_te) = seeded_agent_eval(ctx, &suite, &ctx.train_cfg())?;
        let rnn_rows = rnn_eval(ctx, &suite);
        let (rnn_tr, rnn_te) = match rnn_rows {
            Ok(r) => r,
            Err(e) => {
                eprintln!("  (RNN baseline unavailable: {e})");
                (vec![], vec![])
            }
        };
        for (split, tasks, agent_runs, rnn_runs) in [
            ("Train", &suite.train, &agent_tr, &rnn_tr),
            ("Test", &suite.test, &agent_te, &rnn_te),
        ] {
            let (r_m, r_s) = eval_random(&suite, tasks, 3);
            let expert_cells: Vec<String> = ALL_EXPERTS
                .iter()
                .map(|&e| {
                    let (m, s) = eval_expert(&suite, tasks, e);
                    format!("{} ({})", ms_pm(m, s), speedup_vs(r_m, m))
                })
                .collect();
            let (a_m, a_s) = mean_std(agent_runs);
            let rnn_cell = if rnn_runs.is_empty() {
                "-".to_string()
            } else {
                let (m, s) = mean_std(rnn_runs);
                format!("{} ({})", ms_pm(m, s), speedup_vs(r_m, m))
            };
            tbl.row(vec![
                suite.name.clone(),
                split.to_string(),
                ms_pm(r_m, r_s),
                expert_cells[0].clone(),
                expert_cells[1].clone(),
                expert_cells[2].clone(),
                expert_cells[3].clone(),
                rnn_cell,
                format!("{} ({})", ms_pm(a_m, a_s), speedup_vs(r_m, a_m)),
            ]);
        }
    }
    ctx.emit(name, &format!("{name}: overall cost (ms) and speedup over random\n{}", tbl.render()))
}

pub fn table1(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table1", TABLE1_CONFIGS)
}

pub fn table6(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table6", TABLE6_CONFIGS)
}

pub fn table7(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table7", TABLE7_CONFIGS)
}

/// Shared helper for other experiments: one trained agent + its headline
/// numbers on a single suite.
pub fn quick_headline(ctx: &Ctx, which: Which, n_tables: usize, n_devices: usize) -> Result<(Suite, crate::coordinator::DreamShard, f64, f64)> {
    let suite = make_suite(which, n_tables, n_devices, ctx.n_tasks(), 7);
    let agent = train_agent(ctx, &suite, ctx.train_cfg(), 0)?;
    let (test_m, _) = eval_agent(ctx, &suite, &agent, &suite.test)?;
    let (_, be) = best_expert(&suite, &suite.test);
    Ok((suite, agent, test_m, be))
}
