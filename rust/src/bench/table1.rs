//! Tables 1, 6, 7: overall cost comparison (ms + speedup over random) of
//! random / four greedy experts / RNN-based RL / DreamShard, on train and
//! test tasks, across dataset x table-count x device-count configs.

use crate::util::error::Result;

use super::common::{
    best_expert, eval_placer, make_suite, seeded_agent_eval, train_agent, Ctx, Suite, Which,
};
use crate::baselines::ALL_EXPERTS;
use crate::placer::{FitRequest, GreedyPlacer, Placer, RandomPlacer, RnnPlacer};
use crate::util::table::{ms_pm, speedup_vs, TextTable};
use crate::util::mean_std;

pub const TABLE1_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 20, 4),
    (Which::Dlrm, 40, 4),
    (Which::Dlrm, 60, 4),
    (Which::Dlrm, 80, 4),
    (Which::Dlrm, 100, 4),
    (Which::Dlrm, 40, 8),
    (Which::Dlrm, 80, 8),
    (Which::Dlrm, 120, 8),
    (Which::Dlrm, 160, 8),
    (Which::Dlrm, 200, 8),
    (Which::Prod, 20, 2),
    (Which::Prod, 40, 4),
    (Which::Prod, 80, 8),
];

pub const TABLE6_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 10, 4),
    (Which::Dlrm, 30, 4),
    (Which::Dlrm, 50, 4),
    (Which::Dlrm, 70, 4),
    (Which::Dlrm, 90, 4),
];

pub const TABLE7_CONFIGS: &[(Which, usize, usize)] = &[
    (Which::Dlrm, 10, 2),
    (Which::Dlrm, 20, 2),
    (Which::Dlrm, 30, 2),
    (Which::Dlrm, 40, 2),
    (Which::Dlrm, 50, 2),
];

/// Train + evaluate the RNN baseline; per-seed mean costs on train/test.
fn rnn_eval(ctx: &Ctx, suite: &Suite) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut tr = vec![];
    let mut te = vec![];
    for seed in 0..ctx.seeds as u64 {
        let mut rnn = RnnPlacer::untrained(&ctx.rt);
        rnn.fit(&FitRequest {
            ds: &suite.ds,
            tasks: &suite.train,
            sim: &suite.sim,
            cfg: ctx.train_cfg(),
            seed: 77_000 + seed,
            verbose: false,
        })?;
        tr.push(eval_placer(ctx, suite, &mut rnn, &suite.train, 1)?.0);
        te.push(eval_placer(ctx, suite, &mut rnn, &suite.test, 1)?.0);
    }
    Ok((tr, te))
}

pub fn run_configs(ctx: &Ctx, name: &str, configs: &[(Which, usize, usize)]) -> Result<()> {
    // optional row cap for time-boxed runs: DREAMSHARD_MAX_CONFIGS=N
    let cap: usize = std::env::var("DREAMSHARD_MAX_CONFIGS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(usize::MAX);
    let configs = &configs[..configs.len().min(cap)];
    let mut tbl = TextTable::new(vec![
        "Task", "Split", "Random", "Size", "Dim", "Lookup", "Size-lookup", "RNN", "DreamShard",
    ]);
    for &(which, n_tables, n_devices) in configs {
        let suite = make_suite(which, n_tables, n_devices, ctx.n_tasks(), 7);
        eprintln!("[{name}] {} ...", suite.name);
        let (agent_tr, agent_te) = seeded_agent_eval(ctx, &suite, &ctx.train_cfg())?;
        let rnn_rows = rnn_eval(ctx, &suite);
        let (rnn_tr, rnn_te) = match rnn_rows {
            Ok(r) => r,
            Err(e) => {
                eprintln!("  (RNN baseline unavailable: {e})");
                (vec![], vec![])
            }
        };
        for (split, tasks, agent_runs, rnn_runs) in [
            ("Train", &suite.train, &agent_tr, &rnn_tr),
            ("Test", &suite.test, &agent_te, &rnn_te),
        ] {
            let (r_m, r_s) = eval_placer(ctx, &suite, &mut RandomPlacer::new(3), tasks, 5)?;
            let expert_cells: Vec<String> = ALL_EXPERTS
                .iter()
                .map(|&e| -> Result<String> {
                    let (m, s) = eval_placer(ctx, &suite, &mut GreedyPlacer::new(e), tasks, 1)?;
                    Ok(format!("{} ({})", ms_pm(m, s), speedup_vs(r_m, m)))
                })
                .collect::<Result<_>>()?;
            let (a_m, a_s) = mean_std(agent_runs);
            let rnn_cell = if rnn_runs.is_empty() {
                "-".to_string()
            } else {
                let (m, s) = mean_std(rnn_runs);
                format!("{} ({})", ms_pm(m, s), speedup_vs(r_m, m))
            };
            tbl.row(vec![
                suite.name.clone(),
                split.to_string(),
                ms_pm(r_m, r_s),
                expert_cells[0].clone(),
                expert_cells[1].clone(),
                expert_cells[2].clone(),
                expert_cells[3].clone(),
                rnn_cell,
                format!("{} ({})", ms_pm(a_m, a_s), speedup_vs(r_m, a_m)),
            ]);
        }
    }
    ctx.emit(name, &format!("{name}: overall cost (ms) and speedup over random\n{}", tbl.render()))
}

pub fn table1(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table1", TABLE1_CONFIGS)
}

pub fn table6(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table6", TABLE6_CONFIGS)
}

pub fn table7(ctx: &Ctx) -> Result<()> {
    run_configs(ctx, "table7", TABLE7_CONFIGS)
}

/// Shared helper for other experiments: one trained agent + its headline
/// numbers on a single suite.
pub fn quick_headline(ctx: &Ctx, which: Which, n_tables: usize, n_devices: usize) -> Result<(Suite, crate::coordinator::DreamShard, f64, f64)> {
    let suite = make_suite(which, n_tables, n_devices, ctx.n_tasks(), 7);
    let agent = train_agent(ctx, &suite, ctx.train_cfg(), 0)?;
    let (test_m, _) =
        eval_placer(ctx, &suite, &mut super::common::agent_placer(ctx, &agent), &suite.test, 1)?;
    let (_, be) = best_expert(ctx, &suite, &suite.test)?;
    Ok((suite, agent, test_m, be))
}
