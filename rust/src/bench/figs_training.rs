//! Training-dynamics experiments:
//! Fig. 5 / 19 / 20 — performance vs iterations and wall-clock;
//! Fig. 6 / 21 / 22 — N_RL and N_cost hyperparameter sweeps;
//! Fig. 7 — cost-net accuracy vs data size, and resulting policy quality;
//! Fig. 8 — estimated vs real MDP (training curves, hardware budget,
//!          inference time vs number of tables).

use crate::util::error::Result;
use std::time::Instant;

use super::common::{agent_placer, eval_placer, make_suite, train_agent, Ctx, Suite, Which};
use super::costfit::{collect_cost_dataset, fit_cost_net, test_mse};
use crate::coordinator::{DreamShard, TrainCfg};
use crate::placer::{Placer, PlacementRequest};
use crate::tables::NUM_FEATURES;
use crate::util::table::TextTable;
use crate::util::Rng;

/// Test-split mean of one agent through the facade (the recurring
/// evaluation of every training-dynamics figure).
fn test_mean(ctx: &Ctx, suite: &Suite, agent: &DreamShard) -> Result<f64> {
    Ok(eval_placer(ctx, suite, &mut agent_placer(ctx, agent), &suite.test, 1)?.0)
}

/// Fig. 5: test-task cost after each training iteration + wall time.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    let cfg = ctx.train_cfg();
    let iters = cfg.n_iterations.max(8);
    let mut rng = Rng::new(10_000);
    let mut agent = DreamShard::new(&ctx.rt, 4, TrainCfg { n_iterations: iters, ..cfg }, &mut rng)?;
    let mut out = String::from("fig5: DLRM-50 (4) — test cost vs training iteration\niter\ttest_ms\twall_s\n");
    let t0 = Instant::now();
    let eval0 = test_mean(ctx, &suite, &agent)?;
    out.push_str(&format!("0\t{eval0:.2}\t0.0\n"));
    for it in 0..iters {
        agent.train_iteration(&ctx.rt, &suite.sim, &suite.ds, &suite.train, it, false, &mut rng)?;
        let m = test_mean(ctx, &suite, &agent)?;
        out.push_str(&format!("{}\t{m:.2}\t{:.1}\n", it + 1, t0.elapsed().as_secs_f64()));
        eprintln!("[fig5] iter {} -> {m:.2} ms", it + 1);
    }
    ctx.emit("fig5", &out)
}

/// Fig. 6: sweep N_RL (left) and N_cost (right) on DLRM-50 (4).
pub fn fig6(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    let base = ctx.train_cfg();
    let n_rls: &[usize] = if ctx.fast { &[1, 4, 10] } else { &[1, 2, 5, 10, 20, 40] };
    let n_costs: &[usize] = if ctx.fast { &[10, 60, 150] } else { &[10, 30, 100, 300, 600] };
    let mut tbl = TextTable::new(vec!["knob", "value", "test_ms"]);
    for &n_rl in n_rls {
        let cfg = TrainCfg { n_rl, ..base.clone() };
        let agent = train_agent(ctx, &suite, cfg, 1)?;
        let m = test_mean(ctx, &suite, &agent)?;
        tbl.row(vec!["N_RL".into(), n_rl.to_string(), format!("{m:.2}")]);
        eprintln!("[fig6] N_RL={n_rl} -> {m:.2}");
    }
    for &n_cost in n_costs {
        let cfg = TrainCfg { n_cost, ..base.clone() };
        let agent = train_agent(ctx, &suite, cfg, 1)?;
        let m = test_mean(ctx, &suite, &agent)?;
        tbl.row(vec!["N_cost".into(), n_cost.to_string(), format!("{m:.2}")]);
        eprintln!("[fig6] N_cost={n_cost} -> {m:.2}");
    }
    ctx.emit("fig6", &format!("fig6: hyperparameter impact on DLRM-50 (4)\n{}", tbl.render()))
}

/// Fig. 7: cost-net MSE vs number of training samples, and the quality of
/// a policy trained against each (frozen) cost net.
pub fn fig7(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    let pool = if ctx.fast { 1200 } else { 4000 };
    eprintln!("[fig7] collecting {pool} samples ...");
    let (train_all, test_set) = collect_cost_dataset(&suite, pool, 21)?;
    let sizes: &[usize] = if ctx.fast { &[20, 100, 400, 900] } else { &[20, 50, 100, 400, 1000, 3000] };
    let fmask = vec![1.0f32; NUM_FEATURES];
    let steps = if ctx.fast { 400 } else { 1500 };
    let mut tbl = TextTable::new(vec!["n_train", "cost MSE", "policy test_ms"]);
    for &n in sizes {
        let n = n.min(train_all.len());
        let net = fit_cost_net(ctx, &suite, &train_all[..n], steps, &fmask, 31)?;
        let mse = test_mse(ctx, &suite, &net, &test_set)?;
        // train a policy against the frozen cost net (no cost updates)
        let mut rng = Rng::new(40_000);
        let cfg = TrainCfg { n_cost: 0, n_collect: 1, ..ctx.train_cfg() };
        let mut agent = DreamShard::new(&ctx.rt, 4, cfg, &mut rng)?;
        agent.cost = net;
        agent.train(&ctx.rt, &suite.sim, &suite.ds, &suite.train, &mut rng)?;
        let m = test_mean(ctx, &suite, &agent)?;
        tbl.row(vec![n.to_string(), format!("{mse:.3}"), format!("{m:.2}")]);
        eprintln!("[fig7] n={n}: MSE {mse:.3}, policy {m:.2} ms");
    }
    ctx.emit("fig7", &format!(
        "fig7: cost-net accuracy vs data size, and downstream policy quality (DLRM-50 (4))\n{}",
        tbl.render()
    ))
}

/// Fig. 8: training with the estimated MDP vs directly on the simulated
/// hardware (states+rewards from execution), plus inference latency vs
/// number of tables.
pub fn fig8(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    let cfg = ctx.train_cfg();
    let iters = cfg.n_iterations;
    let mut out = String::from(
        "fig8 (left): test cost per iteration — estimated MDP vs real execution\n\
         Hardware budget counts simulated-GPU benchmark runs (PARAM protocol:\n\
         each measured placement state ~= 1.5 s of GPU time, section B.4.2).\n\
         iter\test_mdp_ms\test_wall_s\test_hw_runs\treal_mdp_ms\treal_wall_s\treal_hw_runs\n",
    );
    let mut rows = vec![];
    for real in [false, true] {
        let mut rng = Rng::new(10_000);
        let mut agent = DreamShard::new(&ctx.rt, 4, cfg.clone(), &mut rng)?;
        let t0 = Instant::now();
        let mut series = vec![];
        for it in 0..iters {
            agent.train_iteration(&ctx.rt, &suite.sim, &suite.ds, &suite.train, it, real, &mut rng)?;
            let m = test_mean(ctx, &suite, &agent)?;
            // hardware runs: data collection always hits the hardware;
            // the real-MDP arm additionally measures every step + reward
            let per_iter_hw = if real {
                cfg.n_collect * cfg.prefix_fractions.len()
                    + cfg.n_rl * cfg.n_episode * (50 + 1)
            } else {
                cfg.n_collect * cfg.prefix_fractions.len()
            };
            series.push((m, t0.elapsed().as_secs_f64(), per_iter_hw * (it + 1)));
            eprintln!("[fig8] real={real} iter {}: {m:.2} ms", it + 1);
        }
        rows.push(series);
    }
    for it in 0..iters {
        let (em, ew, eh) = rows[0][it];
        let (rm, rw, rh) = rows[1][it];
        out.push_str(&format!(
            "{}\t{em:.2}\t{ew:.1}\t{eh}\t{rm:.2}\t{rw:.1}\t{rh}\n",
            it + 1
        ));
    }
    // right panel: inference time vs number of tables (argmax placement)
    out.push_str("\nfig8 (right): inference wall time vs number of tables\nn_tables\tplace_ms\n");
    let agent = train_agent(ctx, &suite, ctx.train_cfg(), 0)?;
    let mut dsp = agent_placer(ctx, &agent);
    for &n in &[10usize, 25, 50, 100, 150, 200] {
        let s2 = make_suite(Which::Dlrm, n, 4, 2, 9);
        let t0 = Instant::now();
        let mut reps = 0;
        for task in &s2.test {
            // sequential on purpose: this panel reports per-task latency
            dsp.place(&PlacementRequest::for_runtime(&ctx.rt, &s2.ds, task, &s2.sim)?)?;
            reps += 1;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        out.push_str(&format!("{n}\t{ms:.1}\n"));
        eprintln!("[fig8] inference n={n}: {ms:.1} ms");
    }
    ctx.emit("fig8", &out)
}
