//! Table 2 + Tables 8-10: generalization — apply a DreamShard model
//! trained on a *source* task configuration to a *target* configuration
//! with a different number of tables and/or devices, with no fine-tuning,
//! and compare against a model trained directly on the target.

use crate::util::error::Result;

use super::common::{agent_placer, eval_placer, make_suite, train_agent, Ctx, Suite, Which};
use crate::coordinator::DreamShard;
use crate::placer::RandomPlacer;
use crate::util::table::{ms_pm, TextTable};

/// Evaluate `agent` (trained elsewhere) on `suite`'s test tasks through
/// the facade, which routes each task to a fitting artifact variant (the
/// agent's own when the device count fits, the smallest covering one
/// otherwise) — lane-batched across the suite's tasks.
fn transfer_eval(ctx: &Ctx, agent: &DreamShard, suite: &Suite) -> Result<f64> {
    Ok(eval_placer(ctx, suite, &mut agent_placer(ctx, agent), &suite.test, 1)?.0)
}

pub fn table2(ctx: &Ctx) -> Result<()> {
    // (source, target) pairs from Table 2: table-count transfer (top),
    // device-count transfer (bottom)
    let pairs: &[((usize, usize), (usize, usize))] = &[
        ((20, 4), (100, 4)),
        ((20, 4), (80, 4)),
        ((100, 4), (40, 4)),
        ((100, 4), (20, 4)),
        ((20, 4), (20, 2)),
        ((40, 4), (40, 2)),
        ((20, 2), (20, 4)),
        ((40, 2), (40, 4)),
    ];
    let mut tbl = TextTable::new(vec![
        "Source -> Target", "Random", "Trained-on-target", "Transferred (no fine-tune)",
    ]);
    // cache agents per source config
    let mut agents: std::collections::HashMap<(usize, usize), DreamShard> = Default::default();
    for &((s_t, s_d), (t_t, t_d)) in pairs {
        let src_suite = make_suite(Which::Dlrm, s_t, s_d, ctx.n_tasks(), 7);
        let tgt_suite = make_suite(Which::Dlrm, t_t, t_d, ctx.n_tasks(), 7);
        eprintln!("[table2] DLRM-{s_t} ({s_d}) -> DLRM-{t_t} ({t_d}) ...");
        if !agents.contains_key(&(s_t, s_d)) {
            agents.insert((s_t, s_d), train_agent(ctx, &src_suite, ctx.train_cfg(), 0)?);
        }
        if !agents.contains_key(&(t_t, t_d)) {
            agents.insert((t_t, t_d), train_agent(ctx, &tgt_suite, ctx.train_cfg(), 0)?);
        }
        let transferred = transfer_eval(ctx, &agents[&(s_t, s_d)], &tgt_suite)?;
        let on_target = transfer_eval(ctx, &agents[&(t_t, t_d)], &tgt_suite)?;
        let (rand_m, rand_s) =
            eval_placer(ctx, &tgt_suite, &mut RandomPlacer::new(3), &tgt_suite.test, 5)?;
        tbl.row(vec![
            format!("DLRM-{s_t} ({s_d}) -> DLRM-{t_t} ({t_d})"),
            ms_pm(rand_m, rand_s),
            format!("{on_target:.1}"),
            format!("{transferred:.1}"),
        ]);
    }
    ctx.emit("table2", &format!(
        "table2: generalization across numbers of tables and devices (test-task ms)\n{}",
        tbl.render()
    ))
}

/// Tables 8-10: full source x target generalization matrices.
pub fn table8_10(ctx: &Ctx) -> Result<()> {
    let mut out = String::new();
    // Table 8: table-count matrix at 4 devices
    let sizes4 = if ctx.fast { vec![20, 40, 60] } else { vec![20, 40, 60, 80, 100] };
    out.push_str(&matrix(ctx, "Table 8 (tables x tables, 4 GPUs)", &sizes4, 4, &sizes4, 4)?);
    // Table 9: 4 -> 2 GPUs
    let sizes_s = if ctx.fast { vec![10, 30] } else { vec![10, 20, 30, 40, 50] };
    out.push_str(&matrix(ctx, "Table 9 (4 GPUs -> 2 GPUs)", &sizes_s, 4, &sizes_s, 2)?);
    // Table 10: 2 -> 4 GPUs
    out.push_str(&matrix(ctx, "Table 10 (2 GPUs -> 4 GPUs)", &sizes_s, 2, &sizes_s, 4)?);
    ctx.emit("table8_10", &out)
}

fn matrix(
    ctx: &Ctx,
    title: &str,
    src_sizes: &[usize],
    src_d: usize,
    tgt_sizes: &[usize],
    tgt_d: usize,
) -> Result<String> {
    let mut header = vec!["Source \\ Target".to_string()];
    header.extend(tgt_sizes.iter().map(|t| format!("DLRM-{t} ({tgt_d})")));
    let mut tbl = TextTable::new(header);
    let mut agents = vec![];
    for &s in src_sizes {
        let suite = make_suite(Which::Dlrm, s, src_d, ctx.n_tasks(), 7);
        eprintln!("[{title}] training source DLRM-{s} ({src_d}) ...");
        agents.push(train_agent(ctx, &suite, ctx.train_cfg(), 0)?);
    }
    let tgt_suites: Vec<Suite> =
        tgt_sizes.iter().map(|&t| make_suite(Which::Dlrm, t, tgt_d, ctx.n_tasks(), 7)).collect();
    for (i, &s) in src_sizes.iter().enumerate() {
        let mut row = vec![format!("DLRM-{s} ({src_d})")];
        for suite in &tgt_suites {
            row.push(format!("{:.1}", transfer_eval(ctx, &agents[i], suite)?));
        }
        tbl.row(row);
    }
    // reference row: trained directly on each target
    let mut row = vec!["trained-on-target".to_string()];
    for suite in &tgt_suites {
        let agent = train_agent(ctx, suite, ctx.train_cfg(), 0)?;
        row.push(format!("{:.1}", transfer_eval(ctx, &agent, suite)?));
    }
    tbl.row(row);
    Ok(format!("{title}\n{}\n", tbl.render()))
}
