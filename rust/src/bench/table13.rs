//! Table 13: ultra-scale scalability test — ~1,000 diverse-dim tables
//! placed on a 128-device cluster. The agent is trained at Prod-80 (8)
//! and applied unchanged through the inference-only `d128s16` artifact
//! variant (this *is* the paper's generalization claim at cluster scale).
//! Training throughput improvement is derived from the embedding-cost
//! share of the step (48% compute / 65% comm, section 1).

use crate::util::error::Result;

use super::common::{agent_placer, make_suite, train_agent, Ctx, Which};
use crate::baselines::ALL_EXPERTS;
use crate::placer::{GreedyPlacer, Placer, PlacementRequest, RandomPlacer};
use crate::sim::{SimConfig, Simulator};
use crate::tables::{gen_prod, sample_tasks, split_pools};
use crate::util::table::TextTable;

/// Embedding cost -> end-to-end training-throughput improvement: the
/// embedding stage overlaps the dense stage but dominates it (section
/// A.1), so the step time is ~ embedding cost + non-overlapped overhead
/// (data loading, optimizer, sync) which we put at 35% of the random
/// placement's embedding cost.
fn throughput_gain(rand_ms: f64, ms: f64) -> f64 {
    let overhead = 0.35 * rand_ms;
    (rand_ms + overhead) / (ms + overhead) - 1.0
}

pub fn table13(ctx: &Ctx) -> Result<()> {
    // train at Prod-80 (8)
    let train_suite = make_suite(Which::Prod, 80, 8, ctx.n_tasks(), 7);
    eprintln!("[table13] training on Prod-80 (8) ...");
    let agent = train_agent(ctx, &train_suite, ctx.train_cfg(), 0)?;

    // the production-scale workload: ~1000 tables, 128 devices
    let ds = gen_prod(1024, 77);
    let (pool, _) = split_pools(&ds, 5);
    let n_tables = 960.min(pool.len());
    let task = sample_tasks(&pool, n_tables, 128, 1, 6).remove(0);
    let sim = Simulator::new(SimConfig { mem_cap_gb: 40.0, ..SimConfig::v100() });

    let total_size: f64 = task.table_ids.iter().map(|&i| ds.tables[i].size_gb() as f64).sum();
    eprintln!("[table13] {} tables, {:.1} TB of embedding weights, 128 devices", n_tables, total_size * 3.0 / 1024.0);

    let mut tbl = TextTable::new(vec!["Sharding Algorithm", "Embedding cost (ms)", "Throughput improvement"]);
    // every strategy plans the same request through the Placer facade
    let req = PlacementRequest::for_runtime(&ctx.rt, &ds, &task, &sim)?;
    let rand_ms = {
        let mut random = RandomPlacer::new(99);
        let costs: Vec<f64> = (0..3)
            .map(|_| Ok(random.place(&req)?.eval.latency))
            .collect::<Result<_>>()?;
        crate::util::mean(&costs)
    };
    tbl.row(vec!["Random".into(), format!("{rand_ms:.1}"), "0.0%".into()]);
    for e in ALL_EXPERTS {
        let ms = GreedyPlacer::new(e).place(&req)?.eval.latency;
        tbl.row(vec![
            e.name().into(),
            format!("{ms:.1} ({:+.1}%)", (rand_ms / ms - 1.0) * 100.0),
            format!("{:+.1}%", throughput_gain(rand_ms, ms) * 100.0),
        ]);
    }
    // DreamShard: the facade routes the 128-device task to the
    // inference-only ultra variant automatically
    let mut dsp = agent_placer(ctx, &agent);
    let t0 = std::time::Instant::now();
    let plan = dsp.place(&req)?;
    let plan_s = t0.elapsed().as_secs_f64();
    let ms = plan.eval.latency;
    tbl.row(vec![
        "DreamShard".into(),
        format!("{ms:.1} ({:+.1}%)", (rand_ms / ms - 1.0) * 100.0),
        format!("{:+.1}%", throughput_gain(rand_ms, ms) * 100.0),
    ]);
    ctx.emit("table13", &format!(
        "table13: ultra-scale test — {n_tables} tables ({:.1} TB with optimizer state) on 128 devices\n\
         DreamShard planning time: {plan_s:.1} s (trained at 8 devices, applied at 128 unchanged)\n{}",
        total_size * 3.0 / 1024.0,
        tbl.render()
    ))
}
