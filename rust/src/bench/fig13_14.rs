//! Figs. 13-14: reduction ablation for the cost network — compare
//! sum/mean/max reductions for table representations (Fig. 13) and
//! max/sum/mean for device representations (Fig. 14) by held-out MSE at
//! several training-set sizes, using the offline fitting protocol.

use crate::util::error::Result;

use super::common::{make_suite, Ctx, Which};
use super::costfit::{collect_cost_dataset, fit_cost_net_red, test_mse};
use crate::tables::NUM_FEATURES;
use crate::util::table::TextTable;

pub fn fig13_14(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    let pool = if ctx.fast { 1000 } else { 4000 };
    eprintln!("[fig13_14] collecting {pool} samples ...");
    let (train_all, test_set) = collect_cost_dataset(&suite, pool, 41)?;
    let sizes: &[usize] = if ctx.fast { &[100, 400, 800] } else { &[100, 400, 1000, 3000] };
    let steps = if ctx.fast { 350 } else { 1200 };
    let fmask = vec![1.0f32; NUM_FEATURES];
    // (label, table_red, dev_red); None = the shipped sum+max network
    let combos: &[(&str, Option<(&str, &str)>)] = &[
        ("sum-table / max-dev (DreamShard)", None),
        ("max-table / max-dev", Some(("max", "max"))),
        ("mean-table / max-dev", Some(("mean", "max"))),
        ("sum-table / sum-dev", Some(("sum", "sum"))),
        ("sum-table / mean-dev", Some(("sum", "mean"))),
    ];
    let mut header = vec!["reduction".to_string()];
    header.extend(sizes.iter().map(|s| format!("MSE@{s}")));
    let mut tbl = TextTable::new(header);
    for (label, red) in combos {
        let mut row = vec![label.to_string()];
        for &n in sizes {
            let n = n.min(train_all.len());
            let net = fit_cost_net_red(
                ctx,
                &suite,
                &train_all[..n],
                steps,
                &fmask,
                51,
                red.map(|(a, b)| (a.to_string(), b.to_string())),
            )?;
            let mse = test_mse(ctx, &suite, &net, &test_set)?;
            row.push(format!("{mse:.3}"));
        }
        eprintln!("[fig13_14] {label}: {row:?}");
        tbl.row(row);
    }
    ctx.emit("fig13_14", &format!(
        "fig13_14: cost-network held-out MSE by reduction choice (DLRM-50 (4))\n{}",
        tbl.render()
    ))
}
