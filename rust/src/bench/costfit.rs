//! Offline cost-network fitting protocol shared by Table 12, Fig. 7 and
//! Figs. 13-14: collect a pool of (state, measured cost) samples from
//! random placements, train a cost network supervised, report held-out
//! MSE (sum of cost-feature MSE and overall-cost MSE, as in Eq. 1).

use crate::util::error::Result;

use super::common::{Ctx, Suite};
use crate::baselines::random_placement;
use crate::coordinator::{CostNet, CostSample, ReplayBuffer, Variant};
use crate::mdp::{heuristic_order, PlacementState};
use crate::runtime::TensorF32;
use crate::tables::NUM_FEATURES;
use crate::util::Rng;

/// Generate `n` cost samples from random placements (with prefix states),
/// split 80/20 into train/test. Padded to the standard trainable variant
/// shape (the smallest D >= the suite's device count, S = 48).
pub fn collect_cost_dataset(
    suite: &Suite,
    n: usize,
    seed: u64,
) -> Result<(Vec<CostSample>, Vec<CostSample>)> {
    let mut rng = Rng::new(seed).fork(0xC057);
    let var_d = suite.train[0].n_devices;
    // padded dims must match the artifact variant used by fit_cost_net
    let (d, s) = (var_d.next_power_of_two().max(2), 48);
    assert!(d <= 8, "offline fitting only lowered for the trainable variants");
    let mut samples = vec![];
    let fractions = [0.25f32, 0.5, 0.75, 1.0];
    'outer: loop {
        let task = &suite.train[rng.below(suite.train.len())];
        let placement = random_placement(&suite.ds, task, &suite.sim, &mut rng);
        let order = heuristic_order(&suite.ds, task);
        for &frac in &fractions {
            if samples.len() >= n {
                break 'outer;
            }
            let keep = ((task.n_tables() as f32 * frac).round() as usize).max(1);
            let mut st = PlacementState::new(&suite.ds, task, order.clone(), s);
            for _ in 0..keep {
                let idx = st.current();
                st.apply(placement[idx]);
            }
            let eval = st.evaluate(&suite.sim);
            let mut feats = TensorF32::zeros(&[1, d, s, NUM_FEATURES]);
            let mut mask = TensorF32::zeros(&[1, d, s]);
            let mut dmask = TensorF32::zeros(&[1, d]);
            st.fill_feats(0, d, s, &mut feats, &mut mask, &mut dmask)?;
            let mut q = vec![0.0f32; d * 3];
            for (dev, qd) in eval.q.iter().enumerate() {
                q[dev * 3..dev * 3 + 3].copy_from_slice(qd);
            }
            samples.push(CostSample {
                feats: feats.data,
                mask: mask.data,
                dmask: dmask.data,
                q,
                cost: eval.latency as f32,
            });
        }
    }
    let n_test = samples.len() / 5;
    let test = samples.split_off(samples.len() - n_test);
    Ok((samples, test))
}

/// Supervised-train a cost network for `steps` Adam updates.
pub fn fit_cost_net(
    ctx: &Ctx,
    suite: &Suite,
    train_set: &[CostSample],
    steps: usize,
    fmask: &[f32],
    seed: u64,
) -> Result<CostNet> {
    fit_cost_net_red(ctx, suite, train_set, steps, fmask, seed, None)
}

/// Same, with an explicit reduction variant (Figs. 13-14 ablation).
pub fn fit_cost_net_red(
    ctx: &Ctx,
    suite: &Suite,
    train_set: &[CostSample],
    steps: usize,
    fmask: &[f32],
    seed: u64,
    reduction: Option<(String, String)>,
) -> Result<CostNet> {
    let var = Variant::for_devices(&ctx.rt, suite.train[0].n_devices)?;
    let mut rng = Rng::new(60_000 + seed);
    let mut net = CostNet::new(&ctx.rt, &mut rng)?;
    net.fmask = fmask.to_vec();
    net.reduction = reduction;
    let mut buf = ReplayBuffer::new(train_set.len().max(1));
    for s in train_set {
        buf.push(s.clone());
    }
    for _ in 0..steps {
        let (feats, mask, dmask, q, c) = buf.sample_batch(var.b_cost, var.d, var.s, &mut rng);
        net.train_batch(&ctx.rt, &var, &feats, &mask, &dmask, &q, &c, 5e-4)?;
    }
    Ok(net)
}

/// Held-out MSE (Eq. 1: cost-feature MSE + overall-cost MSE).
pub fn test_mse(ctx: &Ctx, suite: &Suite, net: &CostNet, test_set: &[CostSample]) -> Result<f64> {
    let var = Variant::for_devices(&ctx.rt, suite.train[0].n_devices)?;
    let (e, d, s) = (var.e, var.d, var.s);
    let f = NUM_FEATURES;
    let mut se_q = 0.0f64;
    let mut n_q = 0.0f64;
    let mut se_c = 0.0f64;
    for chunk in test_set.chunks(e) {
        let mut feats = TensorF32::zeros(&[e, d, s, f]);
        let mut mask = TensorF32::zeros(&[e, d, s]);
        let mut dmask = TensorF32::zeros(&[e, d]);
        for (i, sm) in chunk.iter().enumerate() {
            feats.set_row(&[i, 0, 0, 0], &sm.feats);
            mask.set_row(&[i, 0, 0], &sm.mask);
            dmask.set_row(&[i, 0], &sm.dmask);
        }
        let preds = net.predict_tensors(&ctx.rt, &var, &feats, &mask, &dmask, chunk.len())?;
        for (i, sm) in chunk.iter().enumerate() {
            for dev in 0..d {
                if sm.dmask[dev] > 0.0 {
                    for k in 0..3 {
                        let diff = (preds[i].q[dev][k] - sm.q[dev * 3 + k]) as f64;
                        se_q += diff * diff;
                        n_q += 1.0;
                    }
                }
            }
            let dc = (preds[i].cost - sm.cost) as f64;
            se_c += dc * dc;
        }
    }
    Ok(se_q / n_q.max(1.0) + se_c / test_set.len().max(1) as f64)
}
