//! Simulator-characterization experiments: Table 4 (comm vs imbalance),
//! Fig. 10 (kernel time vs hash x dim), Fig. 11 (vs pooling x access
//! ratio), Fig. 12 (fusion speedup scatter), Figs. 15-18 (dataset
//! statistics), and Fig. 1 / Figs. 23-28 (placement traces).

use crate::util::error::Result;

use super::common::{agent_placer, make_suite, Ctx, Which};
use crate::baselines::ALL_EXPERTS;
use crate::placer::{GreedyPlacer, Placer, PlacementPlan, PlacementRequest, RandomPlacer};
use crate::sim::{CommModel, KernelModel, SimConfig, Simulator};
use crate::tables::{gen_dlrm, Table, NUM_BINS};
use crate::util::table::TextTable;
use crate::util::Rng;

pub fn table4(ctx: &Ctx) -> Result<()> {
    let comm = CommModel::new(65_536);
    let rows: &[(&str, [f64; 4])] = &[
        ("Perfectly balanced", [256.0, 256.0, 256.0, 256.0]),
        ("Slightly imbalanced", [192.0, 256.0, 320.0, 384.0]),
        ("Slightly imbalanced", [192.0, 192.0, 320.0, 320.0]),
        ("Slightly imbalanced", [128.0, 192.0, 320.0, 384.0]),
        ("Slightly imbalanced", [128.0, 128.0, 384.0, 384.0]),
        ("Very imbalanced", [64.0, 128.0, 384.0, 448.0]),
        ("Very imbalanced", [64.0, 64.0, 448.0, 448.0]),
        ("Very imbalanced", [64.0, 64.0, 320.0, 576.0]),
        ("Very imbalanced", [64.0, 64.0, 64.0, 832.0]),
    ];
    let mut tbl = TextTable::new(vec![
        "Category", "Dims", "GPU1", "GPU2", "GPU3", "GPU4", "Max cost",
    ]);
    for (cat, dims) in rows {
        let t = comm.all_to_all_ms(dims);
        let max = t.iter().cloned().fold(0.0, f64::max);
        tbl.row(vec![
            cat.to_string(),
            format!("{:?}", dims.map(|d| d as i64)),
            format!("{:.2}", t[0]),
            format!("{:.2}", t[1]),
            format!("{:.2}", t[2]),
            format!("{:.2}", t[3]),
            format!("{max:.2}"),
        ]);
    }
    ctx.emit("table4", &format!(
        "table4: all-to-all time (ms) vs dimension imbalance, 4 GPUs, batch 65536\n{}",
        tbl.render()
    ))
}

fn probe_table(dim: u32, hash: u64, pooling: f32, heat_bin: usize) -> Table {
    let mut bins = [0.0f32; NUM_BINS];
    bins[heat_bin] = 1.0;
    Table { dim, hash_size: hash, pooling, bins }
}

pub fn fig10(ctx: &Ctx) -> Result<()> {
    let k = KernelModel::new(65_536);
    let hashes: Vec<u64> = (0..6).map(|i| 200_000u64 << i).collect();
    let dims: Vec<u32> = (2..=10).map(|p| 1u32 << p).collect();
    let mut out = String::from("fig10: single-table kernel time (fwd+bwd, ms) heatmap\nhash\\dim");
    for d in &dims {
        out.push_str(&format!("\t{d}"));
    }
    out.push('\n');
    for &h in &hashes {
        out.push_str(&format!("{h}"));
        for &d in &dims {
            let t = probe_table(d, h, 32.0, 2);
            out.push_str(&format!("\t{:.2}", k.fwd_ms(&t) + k.bwd_ms(&t)));
        }
        out.push('\n');
    }
    ctx.emit("fig10", &out)
}

pub fn fig11(ctx: &Ctx) -> Result<()> {
    let k = KernelModel::new(65_536);
    let pools: Vec<f32> = (0..=8).map(|p| (1u32 << p) as f32).collect();
    // access "heat" stands in for the paper's accessed-indices ratio:
    // hotter distribution == smaller effective accessed set
    let heats: Vec<usize> = vec![0, 4, 8, 12, 16];
    let mut out =
        String::from("fig11: single-table kernel time (ms) vs pooling factor x access heat\nheat_bin\\pool");
    for p in &pools {
        out.push_str(&format!("\t{p}"));
    }
    out.push('\n');
    for &hb in &heats {
        out.push_str(&format!("bin{hb}"));
        for &p in &pools {
            let t = probe_table(32, 1_000_000, p, hb);
            out.push_str(&format!("\t{:.2}", k.fwd_ms(&t) + k.bwd_ms(&t)));
        }
        out.push('\n');
    }
    ctx.emit("fig11", &out)
}

pub fn fig12(ctx: &Ctx) -> Result<()> {
    let k = KernelModel::new(65_536);
    let ds = gen_dlrm(856, 42);
    let mut rng = Rng::new(12);
    let mut out = String::from("fig12: multi-table fused cost vs sum of single-table costs (10 tables/sample)\nsum_single_ms\tfused_ms\tspeedup\n");
    let mut speedups = vec![];
    for _ in 0..50 {
        let ids = rng.sample_indices(ds.len(), 10);
        let tables: Vec<&Table> = ids.iter().map(|&i| &ds.tables[i]).collect();
        let sum: f64 = tables.iter().map(|t| k.fwd_ms(t) + k.bwd_ms(t)).sum();
        let (f, b) = k.device_ms(&tables);
        let fused = f + b;
        speedups.push(sum / fused);
        out.push_str(&format!("{sum:.2}\t{fused:.2}\t{:.2}\n", sum / fused));
    }
    let (m, s) = crate::util::mean_std(&speedups);
    let lo = speedups.iter().cloned().fold(f64::MAX, f64::min);
    let hi = speedups.iter().cloned().fold(0.0, f64::max);
    out.push_str(&format!("speedup mean {m:.2} ± {s:.2}, range [{lo:.2}, {hi:.2}] (paper: 1x-3x)\n"));
    ctx.emit("fig12", &out)
}

pub fn fig15_18(ctx: &Ctx) -> Result<()> {
    let ds = gen_dlrm(856, 42);
    let mut out = String::new();
    // Fig 15: hash-size histogram (log10 bins)
    let mut hist = [0usize; 8];
    for t in &ds.tables {
        let b = ((t.hash_size as f64).log10().floor() as usize).clamp(3, 7) - 3;
        hist[b] += 1;
    }
    out.push_str("fig15: hash-size distribution (log10 bins 1e3..1e7)\n");
    for (i, c) in hist.iter().take(5).enumerate() {
        out.push_str(&format!("  1e{}..1e{}: {c}\n", i + 3, i + 4));
    }
    // Fig 16: pooling-factor histogram
    let edges = [2.0f32, 5.0, 10.0, 25.0, 50.0, 100.0, 200.1];
    let mut ph = vec![0usize; edges.len()];
    for t in &ds.tables {
        let b = edges.iter().position(|&e| t.pooling < e).unwrap_or(edges.len() - 1);
        ph[b] += 1;
    }
    out.push_str("fig16: pooling-factor distribution (power law; paper avg 15)\n");
    let mut lo = 0.0f32;
    for (i, c) in ph.iter().enumerate() {
        out.push_str(&format!("  [{lo:.0},{:.0}): {c}\n", edges[i]));
        lo = edges[i];
    }
    let avg_pool: f64 = ds.tables.iter().map(|t| t.pooling as f64).sum::<f64>() / ds.len() as f64;
    out.push_str(&format!("  mean pooling factor: {avg_pool:.1}\n"));
    // Fig 17: hash size vs pooling correlation
    let xs: Vec<f64> = ds.tables.iter().map(|t| (t.hash_size as f64).log10()).collect();
    let ys: Vec<f64> = ds.tables.iter().map(|t| (t.pooling as f64).ln()).collect();
    let (mx, sx) = crate::util::mean_std(&xs);
    let (my, sy) = crate::util::mean_std(&ys);
    let corr: f64 = xs
        .iter()
        .zip(ys.iter())
        .map(|(x, y)| (x - mx) * (y - my))
        .sum::<f64>()
        / (xs.len() as f64 * sx * sy);
    out.push_str(&format!(
        "fig17: corr(log hash, log pooling) = {corr:.3} (paper: no clear relationship)\n"
    ));
    // Fig 18: index access-frequency distribution (aggregate bins)
    let mut agg = [0.0f32; NUM_BINS];
    for t in &ds.tables {
        for (i, &b) in t.bins.iter().enumerate() {
            agg[i] += b;
        }
    }
    out.push_str("fig18: aggregate access-frequency bin mass (bin k ~ 2^k accesses)\n  ");
    for (i, a) in agg.iter().enumerate() {
        out.push_str(&format!("b{i}:{:.1} ", a / ds.len() as f32 * 100.0));
    }
    out.push('\n');
    ctx.emit("fig15_18", &out)
}

/// Fig. 1 + Figs. 23-28: trace visualization of random vs best expert vs
/// DreamShard on DLRM-50 (4) tasks.
pub fn fig1(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Dlrm, 50, 4, ctx.n_tasks(), 7);
    eprintln!("[fig1] training DreamShard on DLRM-50 (4) ...");
    let agent = super::common::train_agent(ctx, &suite, ctx.train_cfg(), 0)?;
    let mut out = String::new();
    let mut random = RandomPlacer::new(123);
    let mut dsp = agent_placer(ctx, &agent);
    for (case, task) in suite.test.iter().take(3).enumerate() {
        out.push_str(&format!("=== case {case} ===\n"));
        let req = PlacementRequest::for_runtime(&ctx.rt, &suite.ds, task, &suite.sim)?;
        let plan_rand = random.place(&req)?;
        out.push_str(&suite.sim.render_trace(&plan_rand.eval, "random"));
        let mut best: Option<(&'static str, PlacementPlan)> = None;
        for e in ALL_EXPERTS {
            let plan = GreedyPlacer::new(e).place(&req)?;
            let better =
                best.as_ref().map_or(true, |(_, b)| plan.eval.latency < b.eval.latency);
            if better {
                best = Some((e.name(), plan));
            }
        }
        let (best_name, best_plan) = best.expect("ALL_EXPERTS is non-empty");
        out.push_str(&suite.sim.render_trace(&best_plan.eval, best_name));
        let plan_ds = dsp.place(&req)?;
        out.push_str(&suite.sim.render_trace(&plan_ds.eval, "DreamShard"));
        out.push('\n');
    }
    ctx.emit("fig1", &out)
}

/// Sanity helper used by tests: the simulator under the default config.
pub fn default_sim() -> Simulator {
    Simulator::new(SimConfig::default())
}
