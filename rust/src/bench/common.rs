//! Shared experiment plumbing: suite construction (dataset + disjoint
//! train/test task pools + simulator), agent training, evaluation rows,
//! and CSV/console output helpers.

use crate::util::error::Result;
use std::io::Write;
use std::path::PathBuf;

use crate::baselines::{greedy_placement, random_placement, Expert, ALL_EXPERTS};
use crate::coordinator::{DreamShard, TrainCfg};
use crate::runtime::Runtime;
use crate::sim::{SimConfig, Simulator};
use crate::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools, Dataset, Task};
use crate::util::{mean_std, Rng};

/// Experiment context: runtime + output directory + effort knobs.
pub struct Ctx {
    pub rt: Runtime,
    pub out_dir: PathBuf,
    /// Reduced task counts / training budget (see EXPERIMENTS.md).
    pub fast: bool,
    pub seeds: usize,
}

impl Ctx {
    pub fn new(fast: bool, seeds: usize) -> Result<Self> {
        let rt = Runtime::open_default()?;
        let out_dir = PathBuf::from(
            std::env::var("DREAMSHARD_OUT").unwrap_or_else(|_| "bench_out".into()),
        );
        std::fs::create_dir_all(&out_dir)?;
        Ok(Ctx { rt, out_dir, fast, seeds })
    }

    pub fn n_tasks(&self) -> usize {
        if self.fast {
            10
        } else {
            50
        }
    }

    pub fn train_cfg(&self) -> TrainCfg {
        if self.fast {
            TrainCfg::fast()
        } else {
            TrainCfg::default()
        }
    }

    /// Write an experiment's rendered output both to stdout and a file.
    pub fn emit(&self, name: &str, body: &str) -> Result<()> {
        println!("{body}");
        let path = self.out_dir.join(format!("{name}.txt"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(body.as_bytes())?;
        eprintln!("[saved {}]", path.display());
        Ok(())
    }
}

/// One benchmark suite: `dataset-n_tables (n_devices)`.
pub struct Suite {
    pub name: String,
    pub ds: Dataset,
    pub train: Vec<Task>,
    pub test: Vec<Task>,
    pub sim: Simulator,
}

/// Which dataset a suite draws from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Which {
    Dlrm,
    Prod,
}

pub fn make_suite(which: Which, n_tables: usize, n_devices: usize, n_tasks: usize, seed: u64) -> Suite {
    let (ds, sim_cfg, tag) = match which {
        Which::Dlrm => (gen_dlrm(856, 42), SimConfig::default(), "DLRM"),
        Which::Prod => (gen_prod(856, 42), SimConfig::v100(), "Prod"),
    };
    let (pool_tr, pool_te) = split_pools(&ds, 1000 + seed);
    let train = sample_tasks(&pool_tr, n_tables, n_devices, n_tasks, 2000 + seed);
    let test = sample_tasks(&pool_te, n_tables, n_devices, n_tasks, 3000 + seed);
    Suite {
        name: format!("{tag}-{n_tables} ({n_devices})"),
        ds,
        train,
        test,
        sim: Simulator::new(sim_cfg),
    }
}

/// (mean, std) latency of random placement over tasks (20 draws each).
pub fn eval_random(suite: &Suite, tasks: &[Task], seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed).fork(0xBAD);
    let costs: Vec<f64> = tasks
        .iter()
        .flat_map(|t| {
            (0..5).map(|_| {
                let p = random_placement(&suite.ds, t, &suite.sim, &mut rng);
                suite.sim.evaluate(&suite.ds, t, &p).latency
            }).collect::<Vec<_>>()
        })
        .collect();
    mean_std(&costs)
}

/// (mean, std) latency of one greedy expert over tasks.
pub fn eval_expert(suite: &Suite, tasks: &[Task], e: Expert) -> (f64, f64) {
    let costs: Vec<f64> = tasks
        .iter()
        .map(|t| {
            let p = greedy_placement(&suite.ds, t, &suite.sim, e);
            suite.sim.evaluate(&suite.ds, t, &p).latency
        })
        .collect();
    mean_std(&costs)
}

/// Best expert's mean latency (the paper's "best baseline" column).
pub fn best_expert(suite: &Suite, tasks: &[Task]) -> (Expert, f64) {
    ALL_EXPERTS
        .into_iter()
        .map(|e| (e, eval_expert(suite, tasks, e).0))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap()
}

/// Train one DreamShard agent on a suite (one seed).
pub fn train_agent(ctx: &Ctx, suite: &Suite, cfg: TrainCfg, seed: u64) -> Result<DreamShard> {
    let mut rng = Rng::new(10_000 + seed);
    let mut agent = DreamShard::new(&ctx.rt, suite.train[0].n_devices, cfg, &mut rng)?;
    agent.train(&ctx.rt, &suite.sim, &suite.ds, &suite.train, &mut rng)?;
    Ok(agent)
}

/// (mean, std) latency of an agent's argmax placements over tasks.
pub fn eval_agent(ctx: &Ctx, suite: &Suite, agent: &DreamShard, tasks: &[Task]) -> Result<(f64, f64)> {
    let mut costs = vec![];
    for t in tasks {
        let p = agent.place(&ctx.rt, &suite.sim, &suite.ds, t)?;
        costs.push(suite.sim.evaluate(&suite.ds, t, &p).latency);
    }
    Ok(mean_std(&costs))
}

/// Train `seeds` agents and return per-seed mean test/train latencies.
pub fn seeded_agent_eval(
    ctx: &Ctx,
    suite: &Suite,
    cfg: &TrainCfg,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut train_means = vec![];
    let mut test_means = vec![];
    for seed in 0..ctx.seeds as u64 {
        let agent = train_agent(ctx, suite, cfg.clone(), seed)?;
        train_means.push(eval_agent(ctx, suite, &agent, &suite.train)?.0);
        test_means.push(eval_agent(ctx, suite, &agent, &suite.test)?.0);
    }
    Ok((train_means, test_means))
}
