//! Shared experiment plumbing: suite construction (dataset + disjoint
//! train/test task pools + simulator), agent training, evaluation rows,
//! and CSV/console output helpers.

use crate::util::error::Result;
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;

use crate::baselines::{Expert, ALL_EXPERTS};
use crate::coordinator::{DreamShard, TrainCfg};
use crate::placer::{DreamShardPlacer, GreedyPlacer, Placer, PlacementRequest};
use crate::runtime::Runtime;
use crate::sim::{SimConfig, Simulator};
use crate::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools, Dataset, Task};
use crate::util::{mean_std, Rng};

/// Experiment context: shared runtime + output directory + effort knobs.
pub struct Ctx {
    pub rt: Arc<Runtime>,
    pub out_dir: PathBuf,
    /// Reduced task counts / training budget (see EXPERIMENTS.md).
    pub fast: bool,
    pub seeds: usize,
}

impl Ctx {
    pub fn new(fast: bool, seeds: usize) -> Result<Self> {
        let rt = Arc::new(Runtime::open_default()?);
        let out_dir = PathBuf::from(
            std::env::var("DREAMSHARD_OUT").unwrap_or_else(|_| "bench_out".into()),
        );
        std::fs::create_dir_all(&out_dir)?;
        Ok(Ctx { rt, out_dir, fast, seeds })
    }

    pub fn n_tasks(&self) -> usize {
        if self.fast {
            10
        } else {
            50
        }
    }

    pub fn train_cfg(&self) -> TrainCfg {
        if self.fast {
            TrainCfg::fast()
        } else {
            TrainCfg::default()
        }
    }

    /// Write an experiment's rendered output both to stdout and a file.
    pub fn emit(&self, name: &str, body: &str) -> Result<()> {
        println!("{body}");
        let path = self.out_dir.join(format!("{name}.txt"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(body.as_bytes())?;
        eprintln!("[saved {}]", path.display());
        Ok(())
    }
}

/// Emit one machine-readable benchmark record on its own line. Every
/// bench binary funnels its headline numbers through this so CI (or any
/// log scraper) can `grep ^BENCH_JSON` and parse without touching the
/// human-oriented prose lines. Keys are fixed: `bench` (name),
/// `plans_per_sec` (throughput of whatever unit the bench counts —
/// plans, calls, or evaluations), `backend_calls` (runtime dispatches
/// attributed to the measured section; 0 for pure-CPU benches).
pub fn emit_json(name: &str, plans_per_sec: f64, backend_calls: u64) {
    println!(
        "BENCH_JSON {{\"bench\":\"{name}\",\"plans_per_sec\":{plans_per_sec:.2},\"backend_calls\":{backend_calls}}}"
    );
}

/// One benchmark suite: `dataset-n_tables (n_devices)`.
pub struct Suite {
    pub name: String,
    pub ds: Dataset,
    pub train: Vec<Task>,
    pub test: Vec<Task>,
    pub sim: Simulator,
}

/// Which dataset a suite draws from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Which {
    Dlrm,
    Prod,
}

pub fn make_suite(which: Which, n_tables: usize, n_devices: usize, n_tasks: usize, seed: u64) -> Suite {
    let (ds, sim_cfg, tag) = match which {
        Which::Dlrm => (gen_dlrm(856, 42), SimConfig::default(), "DLRM"),
        Which::Prod => (gen_prod(856, 42), SimConfig::v100(), "Prod"),
    };
    let (pool_tr, pool_te) = split_pools(&ds, 1000 + seed);
    let train = sample_tasks(&pool_tr, n_tables, n_devices, n_tasks, 2000 + seed);
    let test = sample_tasks(&pool_te, n_tables, n_devices, n_tasks, 3000 + seed);
    Suite {
        name: format!("{tag}-{n_tables} ({n_devices})"),
        ds,
        train,
        test,
        sim: Simulator::new(sim_cfg),
    }
}

/// The one generic evaluation loop every strategy shares (the old
/// `eval_random` / `eval_expert` / `eval_agent` trio collapsed): (mean,
/// std) latency of a placer over tasks, `draws` plans per task (`draws >
/// 1` only matters for stochastic placers). All requests flow through a
/// single `place_many`, so batch-capable placers lane-batch the episodes.
pub fn eval_placer(
    ctx: &Ctx,
    suite: &Suite,
    placer: &mut dyn Placer,
    tasks: &[Task],
    draws: usize,
) -> Result<(f64, f64)> {
    let mut reqs = Vec::with_capacity(tasks.len() * draws);
    for t in tasks {
        for _ in 0..draws {
            reqs.push(PlacementRequest::for_runtime(&ctx.rt, &suite.ds, t, &suite.sim)?);
        }
    }
    let plans = placer.place_many(&reqs)?;
    let costs: Vec<f64> = plans.iter().map(|p| p.eval.latency).collect();
    Ok(mean_std(&costs))
}

/// Wrap a trained agent in its facade placer (the tables evaluate agents
/// exclusively through [`eval_placer`]).
pub fn agent_placer(ctx: &Ctx, agent: &DreamShard) -> DreamShardPlacer {
    DreamShardPlacer::from_agent(&ctx.rt, agent)
}

/// Best expert's mean latency (the paper's "best baseline" column).
pub fn best_expert(ctx: &Ctx, suite: &Suite, tasks: &[Task]) -> Result<(Expert, f64)> {
    let mut best: Option<(Expert, f64)> = None;
    for e in ALL_EXPERTS {
        let (m, _) = eval_placer(ctx, suite, &mut GreedyPlacer::new(e), tasks, 1)?;
        if best.map_or(true, |(_, bm)| m < bm) {
            best = Some((e, m));
        }
    }
    Ok(best.expect("ALL_EXPERTS is non-empty"))
}

/// Train one DreamShard agent on a suite (one seed).
pub fn train_agent(ctx: &Ctx, suite: &Suite, cfg: TrainCfg, seed: u64) -> Result<DreamShard> {
    let mut rng = Rng::new(10_000 + seed);
    let mut agent = DreamShard::new(&ctx.rt, suite.train[0].n_devices, cfg, &mut rng)?;
    agent.train(&ctx.rt, &suite.sim, &suite.ds, &suite.train, &mut rng)?;
    Ok(agent)
}

/// Train `seeds` agents and return per-seed mean test/train latencies.
pub fn seeded_agent_eval(
    ctx: &Ctx,
    suite: &Suite,
    cfg: &TrainCfg,
) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut train_means = vec![];
    let mut test_means = vec![];
    for seed in 0..ctx.seeds as u64 {
        let agent = train_agent(ctx, suite, cfg.clone(), seed)?;
        let mut placer = agent_placer(ctx, &agent);
        train_means.push(eval_placer(ctx, suite, &mut placer, &suite.train, 1)?.0);
        test_means.push(eval_placer(ctx, suite, &mut placer, &suite.test, 1)?.0);
    }
    Ok((train_means, test_means))
}
