//! Experiment harness: every table and figure of the paper's evaluation
//! maps to a subcommand here (see DESIGN.md's experiment index).
//!
//! Invoke via the CLI: `dreamshard repro <id> [--fast] [--seeds N]`, or
//! `dreamshard repro all` for the whole battery.

pub mod common;
pub mod costfit;
pub mod fig13_14;
pub mod figs_training;
pub mod simfigs;
pub mod table1;
pub mod table13;
pub mod table2;
pub mod table3;

use crate::bail;
use crate::util::error::Result;
use common::Ctx;

/// All experiment ids, in a sensible execution order (cheap ones first).
pub const ALL_EXPERIMENTS: &[&str] = &[
    "table4", "fig10", "fig11", "fig12", "fig15_18", // simulator analyses (fast)
    "fig1", "fig5", "fig8", // headline dynamics
    "table1", "table13", // headline sweeps
    "table12", "fig13_14", "fig7", "fig6", // cost-net studies
    "table2", "table3", "table8_10", "table6", "table7", // remaining sweeps
];

pub fn run(id: &str, ctx: &Ctx) -> Result<()> {
    match id {
        "table1" => table1::table1(ctx),
        "table2" => table2::table2(ctx),
        "table3" | "table11" => table3::table3(ctx),
        "table4" => simfigs::table4(ctx),
        "table6" => table1::table6(ctx),
        "table7" => table1::table7(ctx),
        "table8_10" => table2::table8_10(ctx),
        "table12" => table3::table12(ctx),
        "table13" => table13::table13(ctx),
        "fig1" => simfigs::fig1(ctx),
        "fig5" => figs_training::fig5(ctx),
        "fig6" => figs_training::fig6(ctx),
        "fig7" => figs_training::fig7(ctx),
        "fig8" => figs_training::fig8(ctx),
        "fig10" => simfigs::fig10(ctx),
        "fig11" => simfigs::fig11(ctx),
        "fig12" => simfigs::fig12(ctx),
        "fig13_14" => fig13_14::fig13_14(ctx),
        "fig15_18" => simfigs::fig15_18(ctx),
        "all" => {
            for id in ALL_EXPERIMENTS {
                eprintln!("==== {id} ====");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment `{other}`; known: {ALL_EXPERIMENTS:?} or `all`"),
    }
}
