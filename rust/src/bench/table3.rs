//! Table 3 / Table 11: ablation study — drop each table-feature group
//! from the state (via the artifacts' fmask input), and drop the cost
//! features from the policy state (qscale = 0).
//!
//! Table 12: cost-network test MSE with each feature removed (Prod data,
//! offline supervised protocol).

use crate::util::error::Result;

use super::common::{agent_placer, eval_placer, make_suite, Ctx, Which};
use super::costfit::{collect_cost_dataset, fit_cost_net, test_mse};
use crate::coordinator::{DreamShard, TrainCfg};
use crate::tables::NUM_FEATURES;
use crate::util::table::TextTable;
use crate::util::{mean_std, Rng};

/// Feature-group -> fmask column ranges (see Table::features layout).
pub const ABLATIONS: &[(&str, std::ops::Range<usize>)] = &[
    ("w/o dim", 0..1),
    ("w/o hash size", 1..2),
    ("w/o pooling factor", 2..3),
    ("w/o table size", 3..4),
    ("w/o distribution", 4..NUM_FEATURES),
];

fn train_ablated(
    ctx: &Ctx,
    suite: &super::common::Suite,
    cfg: &TrainCfg,
    fmask_zero: Option<&std::ops::Range<usize>>,
    no_cost_feats: bool,
    seed: u64,
) -> Result<DreamShard> {
    let mut rng = Rng::new(50_000 + seed);
    let mut agent = DreamShard::new(&ctx.rt, suite.train[0].n_devices, cfg.clone(), &mut rng)?;
    if let Some(range) = fmask_zero {
        for i in range.clone() {
            agent.cost.fmask[i] = 0.0;
            agent.policy.fmask[i] = 0.0;
        }
    }
    if no_cost_feats {
        agent.policy.qscale = vec![0.0; 3];
    }
    agent.train(&ctx.rt, &suite.sim, &suite.ds, &suite.train, &mut rng)?;
    Ok(agent)
}

pub fn table3(ctx: &Ctx) -> Result<()> {
    let configs: &[(usize, usize)] =
        if ctx.fast { &[(50, 4)] } else { &[(20, 4), (50, 4), (80, 4)] };
    let mut tbl = TextTable::new(vec![
        "Task", "Split", "w/o dim", "w/o hash", "w/o pooling", "w/o size", "w/o dist",
        "w/o cost", "DreamShard",
    ]);
    for &(n_tables, n_devices) in configs {
        let suite = make_suite(Which::Dlrm, n_tables, n_devices, ctx.n_tasks(), 7);
        eprintln!("[table3] {} ...", suite.name);
        let mut cols: Vec<(Vec<f64>, Vec<f64>)> = vec![];
        for (name, range) in ABLATIONS {
            eprintln!("  {name}");
            let mut tr = vec![];
            let mut te = vec![];
            for seed in 0..ctx.seeds as u64 {
                let agent = train_ablated(ctx, &suite, &ctx.train_cfg(), Some(range), false, seed)?;
                let mut placer = agent_placer(ctx, &agent);
                tr.push(eval_placer(ctx, &suite, &mut placer, &suite.train, 1)?.0);
                te.push(eval_placer(ctx, &suite, &mut placer, &suite.test, 1)?.0);
            }
            cols.push((tr, te));
        }
        for (name, no_cost) in [("w/o cost", true), ("full", false)] {
            eprintln!("  {name}");
            let mut tr = vec![];
            let mut te = vec![];
            for seed in 0..ctx.seeds as u64 {
                let agent = train_ablated(ctx, &suite, &ctx.train_cfg(), None, no_cost, seed)?;
                let mut placer = agent_placer(ctx, &agent);
                tr.push(eval_placer(ctx, &suite, &mut placer, &suite.train, 1)?.0);
                te.push(eval_placer(ctx, &suite, &mut placer, &suite.test, 1)?.0);
            }
            cols.push((tr, te));
        }
        for (split, pick) in [("Train", 0usize), ("Test", 1usize)] {
            let mut row = vec![suite.name.clone(), split.to_string()];
            for (tr, te) in &cols {
                let (m, s) = mean_std(if pick == 0 { tr } else { te });
                row.push(format!("{m:.1}±{s:.1}"));
            }
            tbl.row(row);
        }
    }
    ctx.emit("table3", &format!(
        "table3/11: ablations (overall cost ms; last column = full DreamShard)\n{}",
        tbl.render()
    ))
}

/// Table 12: cost-network test MSE per removed feature, on Prod tables.
pub fn table12(ctx: &Ctx) -> Result<()> {
    let suite = make_suite(Which::Prod, 40, 4, ctx.n_tasks(), 7);
    let n_data = if ctx.fast { 400 } else { 2000 };
    eprintln!("[table12] collecting {n_data} cost samples ...");
    let (train_set, test_set) = collect_cost_dataset(&suite, n_data, 11)?;
    let mut tbl = TextTable::new(vec!["Features", "Testing MSE"]);
    let steps = if ctx.fast { 400 } else { 2000 };
    let mut rows: Vec<(&str, Option<std::ops::Range<usize>>)> = vec![("All features", None)];
    for (name, r) in ABLATIONS {
        rows.push((name, Some(r.clone())));
    }
    for (name, range) in rows {
        let mut fmask = vec![1.0f32; NUM_FEATURES];
        if let Some(r) = &range {
            for i in r.clone() {
                fmask[i] = 0.0;
            }
        }
        let net = fit_cost_net(ctx, &suite, &train_set, steps, &fmask, 21)?;
        let mse = test_mse(ctx, &suite, &net, &test_set)?;
        tbl.row(vec![name.to_string(), format!("{mse:.3}")]);
        eprintln!("  {name}: {mse:.3}");
    }
    ctx.emit("table12", &format!(
        "table12: cost-network testing MSE with individual features removed (Prod-40 (4))\n{}",
        tbl.render()
    ))
}
