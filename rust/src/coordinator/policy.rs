//! The policy-network wrapper: owns the flat parameter/optimizer vectors
//! and drives `policy_fwd` / `policy_train_*`. Softmax + action sampling
//! happen here in rust (the artifact returns masked logits).

use super::variant::Variant;
use crate::runtime::{to_f32_vec, Runtime, TensorF32, TensorI32};
use crate::tables::NUM_FEATURES;
use crate::util::error::Result;
use crate::util::Rng;

/// One recorded MDP step, padded to a variant's (D, S).
#[derive(Clone, Debug)]
pub struct StepRec {
    /// [D*S*F] padded state features.
    pub feats: Vec<f32>,
    /// [D*S] slot mask.
    pub mask: Vec<f32>,
    /// [D*3] estimated cost features.
    pub q: Vec<f32>,
    /// [F] current-table features.
    pub cur: Vec<f32>,
    /// [D] legal-action mask.
    pub legal: Vec<f32>,
    pub action: usize,
}

/// Policy-network state.
#[derive(Clone)]
pub struct PolicyNet {
    pub phi: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t_step: f32,
    pub fmask: Vec<f32>,
    /// Cost-feature scale (3): zeroed for the "w/o cost" ablation.
    pub qscale: Vec<f32>,
}

impl PolicyNet {
    pub fn new(rt: &Runtime, rng: &mut Rng) -> Result<Self> {
        let phi = rt.init_params("policy", rng)?;
        let n = phi.len();
        Ok(PolicyNet {
            phi,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t_step: 0.0,
            fmask: vec![1.0; NUM_FEATURES],
            qscale: vec![1.0; 3],
        })
    }

    /// Logits for up to `var.e` lanes from pre-built padded tensors.
    #[allow(clippy::too_many_arguments)]
    pub fn logits(
        &self,
        rt: &Runtime,
        var: &Variant,
        feats: &TensorF32,
        mask: &TensorF32,
        q: &TensorF32,
        cur: &TensorF32,
        legal: &TensorF32,
        n: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let (e, d) = (var.e, var.d);
        let out = rt.run_owned(&var.policy_fwd, vec![
            TensorF32::from_vec(self.phi.clone(), &[self.phi.len()]).into_value(),
            feats.value(),
            mask.value(),
            q.value(),
            cur.value(),
            legal.value(),
            TensorF32::from_vec(self.fmask.clone(), &[NUM_FEATURES]).into_value(),
            TensorF32::from_vec(self.qscale.clone(), &[3]).into_value(),
        ])?;
        let flat = to_f32_vec(&out[0], e * d)?;
        Ok((0..n).map(|lane| flat[lane * d..(lane + 1) * d].to_vec()).collect())
    }

    /// REINFORCE update over recorded steps (chunked to artifact capacity).
    /// `adv[i]` is the baseline-subtracted return of step i's episode.
    pub fn train_steps(
        &mut self,
        rt: &Runtime,
        var: &Variant,
        steps: &[StepRec],
        adv: &[f32],
        lr: f32,
    ) -> Result<f32> {
        assert_eq!(steps.len(), adv.len());
        let (d, s) = (var.d, var.s);
        let f = NUM_FEATURES;
        let mut last_loss = 0.0;
        let cap = var.policy_train_for(steps.len()).expect("no policy_train artifact").0;
        for (chunk, adv_chunk) in steps.chunks(cap).zip(adv.chunks(cap)) {
            let (b, name) = var.policy_train_for(chunk.len()).unwrap().clone();
            let mut feats = TensorF32::zeros(&[b, d, s, f]);
            let mut mask = TensorF32::zeros(&[b, d, s]);
            let mut q = TensorF32::zeros(&[b, d, 3]);
            let mut cur = TensorF32::zeros(&[b, f]);
            let mut legal = TensorF32::zeros(&[b, d]);
            let mut action = TensorI32::zeros(&[b]);
            let mut advt = TensorF32::zeros(&[b]);
            let mut smask = TensorF32::zeros(&[b]);
            for (i, st) in chunk.iter().enumerate() {
                feats.set_row(&[i, 0, 0, 0], &st.feats);
                mask.set_row(&[i, 0, 0], &st.mask);
                q.set_row(&[i, 0, 0], &st.q);
                cur.set_row(&[i, 0], &st.cur);
                legal.set_row(&[i, 0], &st.legal);
                action.data[i] = st.action as i32;
                advt.data[i] = adv_chunk[i];
                smask.data[i] = 1.0;
            }
            self.t_step += 1.0;
            let n = self.phi.len();
            let out = rt.run_owned(&name, vec![
                TensorF32::from_vec(std::mem::take(&mut self.phi), &[n]).into_value(),
                TensorF32::from_vec(std::mem::take(&mut self.m), &[n]).into_value(),
                TensorF32::from_vec(std::mem::take(&mut self.v), &[n]).into_value(),
                TensorF32::scalar1(self.t_step).into_value(),
                TensorF32::scalar1(lr).into_value(),
                feats.value(),
                mask.value(),
                q.value(),
                cur.value(),
                legal.value(),
                action.value(),
                advt.value(),
                smask.value(),
                TensorF32::from_vec(self.fmask.clone(), &[NUM_FEATURES]).into_value(),
                TensorF32::from_vec(self.qscale.clone(), &[3]).into_value(),
            ])?;
            self.phi = to_f32_vec(&out[0], n)?;
            self.m = to_f32_vec(&out[1], n)?;
            self.v = to_f32_vec(&out[2], n)?;
            last_loss = to_f32_vec(&out[3], 1)?[0];
        }
        Ok(last_loss)
    }
}

/// Sample an index from masked logits (softmax) or take the argmax.
pub fn select_action(logits: &[f32], legal: &[bool], sample: bool, rng: &mut Rng) -> usize {
    debug_assert_eq!(logits.len() >= legal.len(), true);
    let max = logits
        .iter()
        .zip(legal.iter())
        .filter(|(_, &l)| l)
        .map(|(&x, _)| x)
        .fold(f32::NEG_INFINITY, f32::max);
    if !sample {
        // total_cmp: NaN logits (a diverged network) must not panic here
        return logits
            .iter()
            .take(legal.len())
            .enumerate()
            .filter(|&(i, _)| legal[i])
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
    }
    let probs: Vec<f32> = logits
        .iter()
        .take(legal.len())
        .enumerate()
        .map(|(i, &x)| if legal[i] { (x - max).exp() } else { 0.0 })
        .collect();
    rng.weighted(&probs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_respects_legality() {
        let logits = vec![5.0, 9.0, 1.0];
        let legal = vec![true, false, true];
        let mut rng = Rng::new(0);
        assert_eq!(select_action(&logits, &legal, false, &mut rng), 0);
    }

    #[test]
    fn sampling_never_picks_illegal() {
        let logits = vec![0.0, 100.0, 0.0];
        let legal = vec![true, false, true];
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let a = select_action(&logits, &legal, true, &mut rng);
            assert_ne!(a, 1);
        }
    }

    #[test]
    fn sampling_follows_probabilities() {
        let logits = vec![0.0, 3.0];
        let legal = vec![true, true];
        let mut rng = Rng::new(2);
        let picks1 = (0..2000)
            .filter(|_| select_action(&logits, &legal, true, &mut rng) == 1)
            .count();
        // softmax(0,3) ~ (0.047, 0.953)
        assert!(picks1 > 1800, "{picks1}");
    }
}
