//! Replay buffer of cost data collected from the (simulated) hardware
//! (Algorithm 1, line 7). Each sample is one evaluated placement state:
//! the padded per-device table features plus the measured per-device cost
//! features and overall latency.

use crate::runtime::TensorF32;
use crate::tables::NUM_FEATURES;
use crate::util::Rng;

/// One measured (state, cost) pair, padded to a variant's (D, S).
#[derive(Clone, Debug)]
pub struct CostSample {
    /// [D*S*F]
    pub feats: Vec<f32>,
    /// [D*S]
    pub mask: Vec<f32>,
    /// [D]
    pub dmask: Vec<f32>,
    /// [D*3] measured cost features (fwd comp, bwd comp, bwd comm), ms.
    pub q: Vec<f32>,
    /// Measured overall latency, ms.
    pub cost: f32,
}

/// FIFO-capped replay buffer.
pub struct ReplayBuffer {
    pub samples: Vec<CostSample>,
    pub capacity: usize,
    next: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        ReplayBuffer { samples: Vec::new(), capacity, next: 0 }
    }

    pub fn push(&mut self, s: CostSample) {
        if self.samples.len() < self.capacity {
            self.samples.push(s);
        } else {
            self.samples[self.next] = s;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Build a padded training batch of `b` samples (with replacement).
    /// Returns (feats [B,D,S,F], mask [B,D,S], dmask [B,D], q [B,D,3], c [B]).
    pub fn sample_batch(
        &self,
        b: usize,
        d: usize,
        s: usize,
        rng: &mut Rng,
    ) -> (TensorF32, TensorF32, TensorF32, TensorF32, TensorF32) {
        assert!(!self.is_empty(), "sampling from empty buffer");
        let f = NUM_FEATURES;
        let mut feats = TensorF32::zeros(&[b, d, s, f]);
        let mut mask = TensorF32::zeros(&[b, d, s]);
        let mut dmask = TensorF32::zeros(&[b, d]);
        let mut q = TensorF32::zeros(&[b, d, 3]);
        let mut c = TensorF32::zeros(&[b]);
        for i in 0..b {
            let sm = &self.samples[rng.below(self.samples.len())];
            feats.set_row(&[i, 0, 0, 0], &sm.feats);
            mask.set_row(&[i, 0, 0], &sm.mask);
            dmask.set_row(&[i, 0], &sm.dmask);
            q.set_row(&[i, 0, 0], &sm.q);
            c.data[i] = sm.cost;
        }
        (feats, mask, dmask, q, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(v: f32, d: usize, s: usize) -> CostSample {
        CostSample {
            feats: vec![v; d * s * NUM_FEATURES],
            mask: vec![1.0; d * s],
            dmask: vec![1.0; d],
            q: vec![v; d * 3],
            cost: v,
        }
    }

    #[test]
    fn fifo_eviction() {
        let mut b = ReplayBuffer::new(3);
        for i in 0..5 {
            b.push(sample(i as f32, 2, 4));
        }
        assert_eq!(b.len(), 3);
        let costs: Vec<f32> = b.samples.iter().map(|s| s.cost).collect();
        // 0 and 1 evicted
        assert!(!costs.contains(&0.0) && !costs.contains(&1.0));
    }

    #[test]
    fn batch_shapes() {
        let mut b = ReplayBuffer::new(10);
        b.push(sample(2.5, 4, 8));
        let mut rng = Rng::new(0);
        let (feats, mask, dmask, q, c) = b.sample_batch(6, 4, 8, &mut rng);
        assert_eq!(feats.dims, vec![6, 4, 8, NUM_FEATURES as i64]);
        assert_eq!(mask.dims, vec![6, 4, 8]);
        assert_eq!(dmask.dims, vec![6, 4]);
        assert_eq!(q.dims, vec![6, 4, 3]);
        assert_eq!(c.dims, vec![6]);
        assert!(c.data.iter().all(|&x| x == 2.5));
    }
}
