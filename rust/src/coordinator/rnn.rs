//! RNN-based RL baseline (Mirhoseini et al. 2017, adapted per paper
//! section D.2): a GRU + content-attention controller over the table
//! sequence, trained by the SAME REINFORCE loss but — like the original —
//! with **no cost network**: rewards come from real (simulated) execution,
//! which is what makes it slow and unstable on harder tasks (Table 1).

use crate::err;
use crate::mdp::{heuristic_order, PlacementState};
use crate::runtime::{to_f32_vec, Runtime, TensorF32, TensorI32};
use crate::sim::Simulator;
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// RNN controller state for a fixed device count `D`.
pub struct RnnBaseline {
    pub psi: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t_step: f32,
    pub d: usize,
    pub t_cap: usize,
    pub e_fwd: usize,
    pub e_train: usize,
    pub lr: f32,
}

impl RnnBaseline {
    pub fn new(rt: &Runtime, n_devices: usize, rng: &mut Rng) -> Result<Self> {
        // RNN artifacts exist for exact device counts only (the paper notes
        // the architecture cannot generalize across device counts).
        let d = [2usize, 4, 8]
            .into_iter()
            .find(|&d| d == n_devices)
            .ok_or_else(|| err!("no RNN artifact for {n_devices} devices"))?;
        let psi = rt.init_params(&format!("rnn_d{d}"), rng)?;
        let n = psi.len();
        let t_cap = rt.manifest.consts.get("T_RNN").copied().unwrap_or(256) as usize;
        let e_fwd = rt.manifest.consts.get("E_FWD").copied().unwrap_or(16) as usize;
        let e_train = rt.manifest.consts.get("E_RNN").copied().unwrap_or(10) as usize;
        Ok(RnnBaseline {
            psi,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t_step: 0.0,
            d,
            t_cap,
            e_fwd,
            e_train,
            lr: 5e-4,
        })
    }

    fn fill_feats(&self, ds: &Dataset, task: &Task, order: &[usize], lane: usize,
                  feats: &mut TensorF32, tmask: &mut TensorF32) {
        for (t, &i) in order.iter().enumerate().take(self.t_cap) {
            feats.set_row(&[lane, t, 0], &ds.tables[task.table_ids[i]].features());
            tmask.set(&[lane, t], 1.0);
        }
    }

    /// Per-step logits for up to `e_fwd` lockstep lanes (one forward pass
    /// covers the whole sequence; legality is applied at sampling time and
    /// the recorded masks are replayed in training).
    fn logits(&self, rt: &Runtime, feats: &TensorF32, tmask: &TensorF32) -> Result<Vec<f32>> {
        let legal = TensorF32::ones(&[self.e_fwd, self.t_cap, self.d]);
        let out = rt.run_owned(&format!("rnn_fwd_d{}", self.d), vec![
            TensorF32::from_vec(self.psi.clone(), &[self.psi.len()]).into_value(),
            feats.value(),
            tmask.value(),
            legal.value(),
            TensorF32::ones(&[NUM_FEATURES]).value(),
        ])?;
        to_f32_vec(&out[0], self.e_fwd * self.t_cap * self.d)
    }

    /// Run `n` episodes; returns (placements, real costs, recorded masks
    /// and actions for training).
    #[allow(clippy::type_complexity)]
    #[allow(clippy::too_many_arguments)]
    fn episodes(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
        n: usize,
        sample: bool,
        max_slots: usize,
        rng: &mut Rng,
    ) -> Result<(Vec<Vec<usize>>, Vec<f64>, TensorF32, TensorI32, TensorF32, TensorF32)> {
        let order = heuristic_order(ds, task);
        let m = task.n_tables().min(self.t_cap);
        let mut feats = TensorF32::zeros(&[self.e_fwd, self.t_cap, NUM_FEATURES]);
        let mut tmask = TensorF32::zeros(&[self.e_fwd, self.t_cap]);
        for lane in 0..n {
            self.fill_feats(ds, task, &order, lane, &mut feats, &mut tmask);
        }
        let logits = self.logits(rt, &feats, &tmask)?;

        let mut legal_rec = TensorF32::zeros(&[self.e_train, self.t_cap, self.d]);
        let mut actions = TensorI32::zeros(&[self.e_train, self.t_cap]);
        let mut placements = vec![];
        let mut costs = vec![];
        for lane in 0..n {
            let mut st = PlacementState::new(ds, task, order.clone(), max_slots);
            for t in 0..m {
                let lg = st.legal(sim);
                let base = (lane * self.t_cap + t) * self.d;
                let step_logits = &logits[base..base + self.d];
                // dead end (memory cap + slot cap exhausted everywhere):
                // fall back to the least-loaded device with a free slot
                let a = if lg.iter().any(|&ok| ok) {
                    super::policy::select_action(step_logits, &lg, sample, rng)
                } else {
                    st.fallback_device()
                        .with_context(|| format!("no device can take table {t}"))?
                };
                if lane < self.e_train {
                    for (dev, &ok) in lg.iter().enumerate() {
                        legal_rec.set(&[lane, t, dev], if ok { 1.0 } else { 0.0 });
                    }
                    actions.data[(lane * self.t_cap) + t] = a as i32;
                }
                st.apply(a);
            }
            costs.push(st.evaluate(sim).latency);
            placements.push(st.placement);
        }
        Ok((placements, costs, feats, actions, legal_rec, tmask))
    }

    /// REINFORCE training directly on simulator rewards.
    pub fn train(
        &mut self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        tasks: &[Task],
        n_updates: usize,
        rng: &mut Rng,
    ) -> Result<()> {
        for _ in 0..n_updates {
            let task = &tasks[rng.below(tasks.len())];
            let n = self.e_train;
            let (_p, costs, feats, actions, legal, tmask) =
                self.episodes(rt, sim, ds, task, n, true, usize::MAX, rng)?;
            let returns: Vec<f32> = costs.iter().map(|&c| -(c as f32)).collect();
            let baseline = returns.iter().sum::<f32>() / returns.len() as f32;
            let mut adv = TensorF32::zeros(&[self.e_train]);
            for (i, &r) in returns.iter().enumerate() {
                adv.data[i] = r - baseline;
            }
            // train feats/tmask are the first e_train lanes of the fwd batch
            let mut tf = TensorF32::zeros(&[self.e_train, self.t_cap, NUM_FEATURES]);
            let mut tm = TensorF32::zeros(&[self.e_train, self.t_cap]);
            let lane_f = self.t_cap * NUM_FEATURES;
            tf.data.copy_from_slice(&feats.data[..self.e_train * lane_f]);
            tm.data.copy_from_slice(&tmask.data[..self.e_train * self.t_cap]);
            self.t_step += 1.0;
            let np = self.psi.len();
            let out = rt.run_owned(&format!("rnn_train_d{}", self.d), vec![
                TensorF32::from_vec(std::mem::take(&mut self.psi), &[np]).into_value(),
                TensorF32::from_vec(std::mem::take(&mut self.m), &[np]).into_value(),
                TensorF32::from_vec(std::mem::take(&mut self.v), &[np]).into_value(),
                TensorF32::scalar1(self.t_step).into_value(),
                TensorF32::scalar1(self.lr).into_value(),
                tf.value(),
                tm.value(),
                legal.value(),
                actions.value(),
                adv.value(),
                TensorF32::ones(&[NUM_FEATURES]).value(),
            ])?;
            self.psi = to_f32_vec(&out[0], np)?;
            self.m = to_f32_vec(&out[1], np)?;
            self.v = to_f32_vec(&out[2], np)?;
        }
        Ok(())
    }

    /// Greedy (argmax) placement.
    pub fn place(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
    ) -> Result<Vec<usize>> {
        self.place_with_slots(rt, sim, ds, task, usize::MAX)
    }

    /// Greedy placement under an explicit per-device slot cap (the MDP
    /// legality rule shared by all strategies behind [`crate::placer`]).
    pub fn place_with_slots(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
        max_slots: usize,
    ) -> Result<Vec<usize>> {
        let mut rng = Rng::new(0);
        let (mut p, _c, ..) = self.episodes(rt, sim, ds, task, 1, false, max_slots, &mut rng)?;
        Ok(p.remove(0))
    }
}
