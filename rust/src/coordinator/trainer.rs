//! DreamShard training (Algorithm 1) and inference (Algorithm 2).
//!
//! Each iteration: (1) collect cost data by evaluating policy-generated
//! placements on the simulated cluster, (2) update the cost network on
//! the replay buffer (MSE, Eq. 1), (3) update the policy by REINFORCE
//! against the **estimated** MDP — states and rewards from the cost
//! network, zero simulator/hardware calls (Eq. 2).

use std::time::Instant;

use super::buffer::{CostSample, ReplayBuffer};
use super::costnet::CostNet;
use super::policy::{select_action, PolicyNet, StepRec};
use super::variant::Variant;
use crate::mdp::PlacementState;
use crate::runtime::{Runtime, TensorF32, Ticket};
use crate::sim::Simulator;
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Training hyperparameters (paper defaults, section B.5).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub n_iterations: usize,
    pub n_collect: usize,
    pub n_cost: usize,
    pub n_batch: usize,
    pub n_rl: usize,
    pub n_episode: usize,
    pub lr: f32,
    /// Placement prefixes additionally evaluated per collected placement
    /// (enriches the buffer with partial states at negligible cost).
    pub prefix_fractions: Vec<f32>,
    pub buffer_capacity: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            n_iterations: 10,
            n_collect: 10,
            n_cost: 300,
            n_batch: 64,
            n_rl: 10,
            n_episode: 10,
            lr: 5e-4,
            prefix_fractions: vec![0.25, 0.5, 0.75, 1.0],
            buffer_capacity: 4096,
        }
    }
}

impl TrainCfg {
    /// Reduced budget used by the wide bench sweeps (documented in
    /// EXPERIMENTS.md; Figs. 5/21/22 show returns saturate well before
    /// the paper's full budget).
    pub fn fast() -> Self {
        TrainCfg { n_iterations: 6, n_cost: 120, n_rl: 8, ..Default::default() }
    }
}

/// Per-iteration training statistics.
#[derive(Clone, Debug)]
pub struct IterStat {
    pub iter: usize,
    pub collected_mean_cost: f64,
    pub cost_loss: f32,
    pub policy_loss: f32,
    pub wall_s: f64,
}

/// A generated episode.
#[derive(Clone, Debug)]
pub struct Episode {
    pub placement: Vec<usize>,
    pub steps: Vec<StepRec>,
    /// Estimated (cost-network) overall cost of the final state, ms.
    pub est_cost: f32,
}

/// The trained placement agent.
pub struct DreamShard {
    pub cost: CostNet,
    pub policy: PolicyNet,
    pub var: Variant,
    pub cfg: TrainCfg,
    pub buffer: ReplayBuffer,
    pub log: Vec<IterStat>,
    /// Total parameter updates done / planned (for linear lr decay).
    updates_done: usize,
    updates_total: usize,
}

impl DreamShard {
    pub fn new(rt: &Runtime, n_devices: usize, cfg: TrainCfg, rng: &mut Rng) -> Result<Self> {
        let var = Variant::for_devices(rt, n_devices)?;
        let cost = CostNet::new(rt, &mut rng.fork(1))?;
        let policy = PolicyNet::new(rt, &mut rng.fork(2))?;
        let buffer = ReplayBuffer::new(cfg.buffer_capacity);
        let updates_total = cfg.n_iterations * (cfg.n_cost + cfg.n_rl);
        Ok(DreamShard {
            cost,
            policy,
            var,
            cfg,
            buffer,
            log: vec![],
            updates_done: 0,
            updates_total,
        })
    }

    /// Linearly-decayed learning rate (paper: linear schedule to zero).
    fn lr_now(&self) -> f32 {
        let frac = 1.0 - self.updates_done as f32 / self.updates_total.max(1) as f32;
        self.cfg.lr * frac.max(0.05)
    }

    /// A cheap inference-only copy of this agent: the networks, variant,
    /// and config are cloned (parameter vectors — kilobytes), while the
    /// replay buffer and training log start empty. Planning reads exactly
    /// the cloned state, so the copy's plans are bit-identical to the
    /// original's; only [`DreamShard::train`] would diverge (it needs the
    /// buffer), which is what the copy is *not* for.
    pub fn inference_clone(&self) -> DreamShard {
        DreamShard {
            cost: self.cost.clone(),
            policy: self.policy.clone(),
            var: self.var.clone(),
            cfg: self.cfg.clone(),
            buffer: ReplayBuffer::new(self.cfg.buffer_capacity),
            log: vec![],
            updates_done: self.updates_done,
            updates_total: self.updates_total,
        }
    }

    /// Dispatch one fused estimated-MDP step artifact (cost features +
    /// policy logits for every lane) onto the runtime's worker pool and
    /// return its [`Ticket`]. This is the single definition of the
    /// artifact's 9-input contract, shared by the training episode loop,
    /// the placer facade's lane-batched planning, and the pipelined
    /// serving drain (which fills the next chunk's tensors while this
    /// call executes).
    #[allow(clippy::too_many_arguments)]
    pub fn submit_fused_step(
        &self,
        rt: &Runtime,
        step_name: &str,
        feats: &TensorF32,
        mask: &TensorF32,
        dmask: &TensorF32,
        cur: &TensorF32,
        legal: &TensorF32,
    ) -> Result<Ticket> {
        rt.submit(step_name, vec![
            TensorF32::from_vec(self.cost.theta.clone(), &[self.cost.theta.len()])
                .into_value(),
            TensorF32::from_vec(self.policy.phi.clone(), &[self.policy.phi.len()])
                .into_value(),
            feats.value(),
            mask.value(),
            dmask.value(),
            cur.value(),
            legal.value(),
            TensorF32::from_vec(self.cost.fmask.clone(), &[NUM_FEATURES]).into_value(),
            TensorF32::from_vec(self.policy.qscale.clone(), &[3]).into_value(),
        ])
    }

    /// [`DreamShard::submit_fused_step`], blocking.
    #[allow(clippy::too_many_arguments)]
    pub fn run_fused_step(
        &self,
        rt: &Runtime,
        step_name: &str,
        feats: &TensorF32,
        mask: &TensorF32,
        dmask: &TensorF32,
        cur: &TensorF32,
        legal: &TensorF32,
    ) -> Result<Vec<crate::runtime::Value>> {
        self.submit_fused_step(rt, step_name, feats, mask, dmask, cur, legal)?.wait()
    }

    /// Sort a task's tables descending by predicted single-table cost.
    pub fn order_tables(&self, rt: &Runtime, ds: &Dataset, task: &Task) -> Result<Vec<usize>> {
        Ok(self.order_tables_batch(rt, &[(ds, task)])?.remove(0))
    }

    /// [`DreamShard::order_tables`] for a whole chunk of (dataset, task)
    /// jobs at once: every task's table features are concatenated into one
    /// `[N, F]` `table_cost` pass (split only on the artifact's baked row
    /// cap), instead of one backend call per task. `table_cost` scores
    /// rows independently, so each task's order is bit-identical to its
    /// own [`DreamShard::order_tables`] call — this is the chunk-batched
    /// ordering the serving front end drains queues through.
    pub fn order_tables_batch(
        &self,
        rt: &Runtime,
        jobs: &[(&Dataset, &Task)],
    ) -> Result<Vec<Vec<usize>>> {
        let mut feats: Vec<[f32; NUM_FEATURES]> =
            Vec::with_capacity(jobs.iter().map(|(_, t)| t.n_tables()).sum());
        for (ds, task) in jobs {
            for &tid in &task.table_ids {
                feats.push(ds.tables[tid].features());
            }
        }
        let costs = self.cost.predict_table_costs(rt, &feats)?;
        let mut orders = Vec::with_capacity(jobs.len());
        let mut off = 0;
        for (_, task) in jobs {
            let c = &costs[off..off + task.n_tables()];
            off += task.n_tables();
            let mut order: Vec<usize> = (0..task.n_tables()).collect();
            // total_cmp: an early (or diverged) cost net may emit NaN
            order.sort_by(|&a, &b| c[b].total_cmp(&c[a]));
            orders.push(order);
        }
        Ok(orders)
    }

    /// Run `n` episodes in lockstep lanes against the **estimated** MDP.
    /// The simulator is used only for the memory-legality test, never for
    /// costs. Returns episodes with recorded steps if `record` is set.
    #[allow(clippy::too_many_arguments)]
    pub fn run_episodes(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
        n: usize,
        sample: bool,
        record: bool,
        rng: &mut Rng,
    ) -> Result<Vec<Episode>> {
        self.run_episodes_var(rt, sim, ds, task, n, sample, record, rng, &self.var, false, usize::MAX)
    }

    /// `run_episodes` with an explicit artifact variant (e.g. the ultra
    /// D=128 variant for Table 13), an optional **real-MDP** mode in
    /// which cost features and the reward come from the simulator instead
    /// of the cost network (Fig. 8's w/o-estimation arm), and an episode
    /// slot cap (effective cap = `min(var.s, max_slots)`; pass
    /// `usize::MAX` for the variant's own cap) so the placer facade's
    /// request-level legality holds on this path too.
    #[allow(clippy::too_many_arguments)]
    pub fn run_episodes_var(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
        n: usize,
        sample: bool,
        record: bool,
        rng: &mut Rng,
        var: &Variant,
        real_mdp: bool,
        max_slots: usize,
    ) -> Result<Vec<Episode>> {
        // fused-step artifact sized to the episode count: E=1 for greedy
        // inference, E=16 for lockstep training episodes (§Perf)
        let fused = (!real_mdp).then(|| var.mdp_step_for(n).cloned()).flatten();
        let e = fused.as_ref().map(|(e, _)| *e).unwrap_or(var.e);
        let (d, s) = (var.d, var.s);
        let n = n.min(e);
        let order = self.order_tables(rt, ds, task)?;
        let slot_cap = s.min(max_slots);
        let mut states: Vec<PlacementState> =
            (0..n).map(|_| PlacementState::new(ds, task, order.clone(), slot_cap)).collect();
        let mut episodes: Vec<Episode> = (0..n)
            .map(|_| Episode { placement: vec![], steps: vec![], est_cost: 0.0 })
            .collect();
        let f = NUM_FEATURES;
        let m = task.n_tables();

        for _t in 0..m {
            let mut feats = TensorF32::zeros(&[e, d, s, f]);
            let mut mask = TensorF32::zeros(&[e, d, s]);
            let mut dmask = TensorF32::zeros(&[e, d]);
            for (lane, st) in states.iter().enumerate() {
                st.fill_feats(lane, d, s, &mut feats, &mut mask, &mut dmask)?;
            }
            let mut cur = TensorF32::zeros(&[e, f]);
            let mut legal_t = TensorF32::zeros(&[e, d]);
            let mut legal: Vec<Vec<bool>> = Vec::with_capacity(n);
            for (lane, st) in states.iter().enumerate() {
                cur.set_row(&[lane, 0], &st.current_features());
                let lg = st.legal(sim);
                for (dev, &ok) in lg.iter().enumerate() {
                    legal_t.set(&[lane, dev], if ok { 1.0 } else { 0.0 });
                }
                legal.push(lg);
            }
            // cost features for the augmented state + policy logits: one
            // fused PJRT call on the estimated MDP; separate calls with
            // simulator-measured q on the real MDP (Fig. 8 arm)
            let mut q = TensorF32::zeros(&[e, d, 3]);
            let logits = if let Some((_, step_name)) = &fused {
                let out =
                    self.run_fused_step(rt, step_name, &feats, &mask, &dmask, &cur, &legal_t)?;
                let logits_flat = crate::runtime::to_f32_vec(&out[0], e * d)?;
                q.data = crate::runtime::to_f32_vec(&out[1], e * d * 3)?;
                (0..n).map(|lane| logits_flat[lane * d..(lane + 1) * d].to_vec()).collect()
            } else {
                for (lane, st) in states.iter().enumerate() {
                    let eval = st.evaluate(sim);
                    for (dev, qd) in eval.q.iter().enumerate() {
                        q.set_row(&[lane, dev, 0], qd);
                    }
                }
                self.policy.logits(rt, var, &feats, &mask, &q, &cur, &legal_t, n)?
            };
            for lane in 0..n {
                // dead end (memory cap + slot cap exhausted everywhere):
                // fall back to the least-loaded device with a free slot,
                // and skip recording — the step carries no decision
                let any_legal = legal[lane].iter().any(|&ok| ok);
                let a = if any_legal {
                    select_action(&logits[lane], &legal[lane], sample, rng)
                } else {
                    states[lane]
                        .fallback_device()
                        .with_context(|| format!("lane {lane}: no device can take the table"))?
                };
                if record && any_legal {
                    let base_f = lane * d * s * f;
                    let base_m = lane * d * s;
                    let base_q = lane * d * 3;
                    episodes[lane].steps.push(StepRec {
                        feats: feats.data[base_f..base_f + d * s * f].to_vec(),
                        mask: mask.data[base_m..base_m + d * s].to_vec(),
                        q: q.data[base_q..base_q + d * 3].to_vec(),
                        cur: states[lane].current_features().to_vec(),
                        legal: legal[lane].iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
                        action: a,
                    });
                }
                states[lane].apply(a);
            }
        }

        // final-state cost = episode reward (negated): estimated or real
        if real_mdp {
            for (lane, ep) in episodes.iter_mut().enumerate() {
                ep.placement = states[lane].placement.clone();
                ep.est_cost = states[lane].evaluate(sim).latency as f32;
            }
        } else {
            let refs: Vec<&PlacementState> = states.iter().collect();
            let finals = self.cost.predict_states(rt, var, &refs)?;
            for (lane, ep) in episodes.iter_mut().enumerate() {
                ep.placement = states[lane].placement.clone();
                ep.est_cost = finals[lane].cost;
            }
        }
        Ok(episodes)
    }

    /// Evaluate a placement on the simulator and add its (prefix) states
    /// to the replay buffer.
    fn collect_into_buffer(
        &mut self,
        ds: &Dataset,
        task: &Task,
        order: &[usize],
        placement: &[usize],
        sim: &Simulator,
    ) -> Result<f64> {
        let (d, s) = (self.var.d, self.var.s);
        let f = NUM_FEATURES;
        let mut final_cost = 0.0;
        for &frac in &self.cfg.prefix_fractions.clone() {
            let keep = ((task.n_tables() as f32 * frac).round() as usize).max(1);
            let mut st = PlacementState::new(ds, task, order.to_vec(), s);
            for _ in 0..keep.min(order.len()) {
                let idx = st.current();
                st.apply(placement[idx]);
            }
            let eval = st.evaluate(sim);
            let mut feats = TensorF32::zeros(&[1, d, s, f]);
            let mut mask = TensorF32::zeros(&[1, d, s]);
            let mut dmask = TensorF32::zeros(&[1, d]);
            st.fill_feats(0, d, s, &mut feats, &mut mask, &mut dmask)?;
            let mut q = vec![0.0f32; d * 3];
            for (dev, qd) in eval.q.iter().enumerate() {
                q[dev * 3..dev * 3 + 3].copy_from_slice(qd);
            }
            self.buffer.push(CostSample {
                feats: feats.data,
                mask: mask.data,
                dmask: dmask.data,
                q,
                cost: eval.latency as f32,
            });
            if frac >= 1.0 {
                final_cost = eval.latency;
            }
        }
        Ok(final_cost)
    }

    /// Algorithm 1: full training loop over the given training tasks.
    pub fn train(
        &mut self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        tasks: &[Task],
        rng: &mut Rng,
    ) -> Result<()> {
        for iter in 0..self.cfg.n_iterations {
            self.train_iteration(rt, sim, ds, tasks, iter, false, rng)?;
        }
        Ok(())
    }

    /// One Algorithm-1 iteration (exposed for the per-iteration learning
    /// curves of Figs. 5/8). `real_mdp` switches the policy-update stage
    /// to simulator-backed states/rewards (the w/o-estimation arm).
    pub fn train_iteration(
        &mut self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        tasks: &[Task],
        iter: usize,
        real_mdp: bool,
        rng: &mut Rng,
    ) -> Result<()> {
        {
            let t0 = Instant::now();
            // (1) data collection on the simulated cluster
            let mut collected = vec![];
            for _ in 0..self.cfg.n_collect {
                let task = &tasks[rng.below(tasks.len())];
                let ep = self
                    .run_episodes(rt, sim, ds, task, 1, true, false, rng)?
                    .remove(0);
                let order = self.order_tables(rt, ds, task)?;
                let cost = self.collect_into_buffer(ds, task, &order, &ep.placement, sim)?;
                collected.push(cost);
            }
            // (2) cost-network updates (no simulator)
            let mut cost_loss = 0.0;
            for _ in 0..self.cfg.n_cost {
                let lr = self.lr_now();
                let (feats, mask, dmask, q, c) =
                    self.buffer.sample_batch(self.cfg.n_batch, self.var.d, self.var.s, rng);
                cost_loss =
                    self.cost.train_batch(rt, &self.var, &feats, &mask, &dmask, &q, &c, lr)?;
                self.updates_done += 1;
            }
            // (3) policy updates against the estimated MDP (no simulator)
            let mut policy_loss = 0.0;
            for _ in 0..self.cfg.n_rl {
                let task = &tasks[rng.below(tasks.len())];
                let var = self.var.clone();
                let eps = self.run_episodes_var(
                    rt, sim, ds, task, self.cfg.n_episode, true, true, rng, &var, real_mdp,
                    usize::MAX,
                )?;
                let returns: Vec<f32> = eps.iter().map(|e| -e.est_cost).collect();
                let baseline: f32 = returns.iter().sum::<f32>() / returns.len() as f32;
                let mut steps = vec![];
                let mut adv = vec![];
                for (ep, &ret) in eps.iter().zip(returns.iter()) {
                    for st in &ep.steps {
                        steps.push(st.clone());
                        adv.push(ret - baseline);
                    }
                }
                let lr = self.lr_now();
                policy_loss = self.policy.train_steps(rt, &self.var, &steps, &adv, lr)?;
                self.updates_done += 1;
            }
            self.log.push(IterStat {
                iter,
                collected_mean_cost: crate::util::mean(&collected),
                cost_loss,
                policy_loss,
                wall_s: t0.elapsed().as_secs_f64(),
            });
        }
        Ok(())
    }

    /// Algorithm 2: place a task greedily (argmax), no simulator costs.
    ///
    /// This is the raw single-episode entry point; callers outside the
    /// training loop should prefer the [`crate::placer`] facade
    /// ([`crate::placer::DreamShardPlacer`]), whose `place_many`
    /// additionally lane-batches several tasks per backend call.
    pub fn place(
        &self,
        rt: &Runtime,
        sim: &Simulator,
        ds: &Dataset,
        task: &Task,
    ) -> Result<Vec<usize>> {
        let mut rng = Rng::new(0); // unused by argmax
        let ep = self
            .run_episodes(rt, sim, ds, task, 1, false, false, &mut rng)?
            .remove(0);
        Ok(ep.placement)
    }
}
