//! The cost-network wrapper: owns the flat parameter/optimizer vectors
//! and drives the `cost_fwd` / `cost_train` / `table_cost` artifacts.

use super::variant::Variant;
use crate::err;
use crate::mdp::PlacementState;
use crate::runtime::{to_f32_vec, Runtime, TensorF32};
use crate::tables::NUM_FEATURES;
use crate::util::error::Result;
use crate::util::Rng;

/// Cost-network state: parameters + Adam moments + ablation masks.
#[derive(Clone)]
pub struct CostNet {
    pub theta: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t_step: f32,
    /// Feature mask (21): zeroed columns implement the Table-3 ablations.
    pub fmask: Vec<f32>,
    /// Artifact name prefix override for reduction-ablation variants
    /// (Figures 13-14), e.g. `Some(("mean", "max"))`.
    pub reduction: Option<(String, String)>,
}

/// Prediction for one state: per-device cost features + overall cost (ms).
#[derive(Clone, Debug)]
pub struct CostPrediction {
    pub q: Vec<[f32; 3]>,
    pub cost: f32,
}

impl CostNet {
    pub fn new(rt: &Runtime, rng: &mut Rng) -> Result<Self> {
        let theta = rt.init_params("cost", rng)?;
        let n = theta.len();
        Ok(CostNet {
            theta,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t_step: 0.0,
            fmask: vec![1.0; NUM_FEATURES],
            reduction: None,
        })
    }

    fn fwd_name(&self, var: &Variant) -> String {
        match &self.reduction {
            None => var.cost_fwd.clone(),
            Some((tr, dr)) => format!("cost_fwd_red_{tr}_{dr}_d{}s{}", var.d, var.s),
        }
    }

    fn train_name(&self, var: &Variant) -> Result<String> {
        match &self.reduction {
            None => var
                .cost_train
                .clone()
                .ok_or_else(|| err!("variant d{} has no cost_train artifact", var.d)),
            Some((tr, dr)) => Ok(format!("cost_train_red_{tr}_{dr}_d{}s{}", var.d, var.s)),
        }
    }

    /// Predict cost features + overall cost for up to `var.e` states.
    pub fn predict_states(
        &self,
        rt: &Runtime,
        var: &Variant,
        states: &[&PlacementState],
    ) -> Result<Vec<CostPrediction>> {
        assert!(states.len() <= var.e, "{} states > {} lanes", states.len(), var.e);
        let (e, d, s) = (var.e, var.d, var.s);
        let mut feats = TensorF32::zeros(&[e, d, s, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[e, d, s]);
        let mut dmask = TensorF32::zeros(&[e, d]);
        for (lane, st) in states.iter().enumerate() {
            st.fill_feats(lane, d, s, &mut feats, &mut mask, &mut dmask)?;
        }
        self.predict_tensors(rt, var, &feats, &mask, &dmask, states.len())
    }

    /// Predict from pre-built padded tensors (first `n` lanes meaningful).
    pub fn predict_tensors(
        &self,
        rt: &Runtime,
        var: &Variant,
        feats: &TensorF32,
        mask: &TensorF32,
        dmask: &TensorF32,
        n: usize,
    ) -> Result<Vec<CostPrediction>> {
        let (e, d) = (var.e, var.d);
        let theta = TensorF32::from_vec(self.theta.clone(), &[self.theta.len()]);
        let fmask = TensorF32::from_vec(self.fmask.clone(), &[NUM_FEATURES]);
        let out = rt.run_owned(&self.fwd_name(var), vec![
            theta.value(),
            feats.value(),
            mask.value(),
            dmask.value(),
            fmask.value(),
        ])?;
        let q = to_f32_vec(&out[0], e * d * 3)?;
        let cost = to_f32_vec(&out[1], e)?;
        Ok((0..n)
            .map(|lane| {
                let qd = (0..d)
                    .map(|dev| {
                        let base = (lane * d + dev) * 3;
                        [q[base], q[base + 1], q[base + 2]]
                    })
                    .collect();
                CostPrediction { q: qd, cost: cost[lane] }
            })
            .collect())
    }

    /// Row capacity `N` of the `table_cost` artifact: one backend call
    /// scores up to this many feature rows, so a caller batching `n` rows
    /// pays exactly `ceil(n / cap)` calls (the serving tests pin this).
    pub fn table_cost_cap(rt: &Runtime) -> usize {
        rt.manifest.artifact_meta("table_cost", "N").unwrap_or(256) as usize
    }

    /// Predicted single-table total costs (for episode ordering, §B.4.2).
    /// Rows are scored independently, so callers may concatenate many
    /// tasks' features into one call — the per-row results are identical
    /// to scoring each task separately, only the call count drops.
    pub fn predict_table_costs(&self, rt: &Runtime, feats: &[[f32; NUM_FEATURES]]) -> Result<Vec<f32>> {
        let n_cap = Self::table_cost_cap(rt);
        let mut out = Vec::with_capacity(feats.len());
        let theta = TensorF32::from_vec(self.theta.clone(), &[self.theta.len()]);
        let fmask = TensorF32::from_vec(self.fmask.clone(), &[NUM_FEATURES]);
        for chunk in feats.chunks(n_cap) {
            let mut t = TensorF32::zeros(&[n_cap, NUM_FEATURES]);
            for (i, f) in chunk.iter().enumerate() {
                t.set_row(&[i, 0], f);
            }
            let res =
                rt.run_owned("table_cost", vec![theta.value(), t.value(), fmask.value()])?;
            let v = to_f32_vec(&res[0], n_cap)?;
            out.extend_from_slice(&v[..chunk.len()]);
        }
        Ok(out)
    }

    /// One Adam/MSE update on a padded batch. Returns the loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_batch(
        &mut self,
        rt: &Runtime,
        var: &Variant,
        feats: &TensorF32,
        mask: &TensorF32,
        dmask: &TensorF32,
        q_tgt: &TensorF32,
        c_tgt: &TensorF32,
        lr: f32,
    ) -> Result<f32> {
        self.t_step += 1.0;
        let n = self.theta.len();
        let out = rt.run_owned(&self.train_name(var)?, vec![
            TensorF32::from_vec(std::mem::take(&mut self.theta), &[n]).into_value(),
            TensorF32::from_vec(std::mem::take(&mut self.m), &[n]).into_value(),
            TensorF32::from_vec(std::mem::take(&mut self.v), &[n]).into_value(),
            TensorF32::scalar1(self.t_step).into_value(),
            TensorF32::scalar1(lr).into_value(),
            feats.value(),
            mask.value(),
            dmask.value(),
            q_tgt.value(),
            c_tgt.value(),
            TensorF32::from_vec(self.fmask.clone(), &[NUM_FEATURES]).into_value(),
        ])?;
        self.theta = to_f32_vec(&out[0], n)?;
        self.m = to_f32_vec(&out[1], n)?;
        self.v = to_f32_vec(&out[2], n)?;
        Ok(to_f32_vec(&out[3], 1)?[0])
    }
}
