//! Layer-3 coordination: the DreamShard agent (cost network + policy
//! network + Algorithm-1 trainer), the replay buffer, artifact-variant
//! selection, and the RNN baseline.

mod buffer;
mod costnet;
mod policy;
mod rnn;
mod trainer;
mod variant;

pub use buffer::{CostSample, ReplayBuffer};
pub use costnet::{CostNet, CostPrediction};
pub use policy::{select_action, PolicyNet, StepRec};
pub use rnn::RnnBaseline;
pub use trainer::{DreamShard, Episode, IterStat, TrainCfg};
pub use variant::Variant;
