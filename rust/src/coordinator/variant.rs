//! Artifact-variant selection: the AOT artifacts are lowered for a small
//! set of (device-count D, slots-per-device S) shapes; a task with `n`
//! devices runs on the smallest variant with D >= n (extra devices are
//! masked out — that masking is exactly what makes the networks
//! generalize across device counts).

use crate::err;
use crate::runtime::Runtime;
use crate::util::error::Result;

/// Resolved artifact names + baked dims for one (D, S) variant.
#[derive(Clone, Debug)]
pub struct Variant {
    pub d: usize,
    pub s: usize,
    /// Episode-batch lanes of the forward artifacts.
    pub e: usize,
    pub cost_fwd: String,
    pub policy_fwd: String,
    pub cost_train: Option<String>,
    /// (step capacity B, artifact) sorted ascending by B.
    pub policy_train: Vec<(usize, String)>,
    /// Cost-train batch size.
    pub b_cost: usize,
    /// Fused per-step artifacts: (lane count E, name), ascending by E.
    pub mdp_step: Vec<(usize, String)>,
}

impl Variant {
    /// Pick the smallest lowered variant that fits `n_devices`.
    pub fn for_devices(rt: &Runtime, n_devices: usize) -> Result<Variant> {
        let mut candidates: Vec<(usize, usize)> = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| {
                let rest = k.strip_prefix("cost_fwd_d")?;
                let (d, s) = rest.split_once('s')?;
                Some((d.parse().ok()?, s.parse().ok()?))
            })
            .collect();
        candidates.sort();
        let (d, s) = candidates
            .into_iter()
            .find(|&(d, _)| d >= n_devices)
            .ok_or_else(|| err!("no artifact variant for {n_devices} devices"))?;
        Self::exact(rt, d, s)
    }

    /// Use an exact (D, S) variant.
    pub fn exact(rt: &Runtime, d: usize, s: usize) -> Result<Variant> {
        let cost_fwd = format!("cost_fwd_d{d}s{s}");
        let policy_fwd = format!("policy_fwd_d{d}s{s}");
        if !rt.manifest.artifacts.contains_key(&cost_fwd) {
            return Err(err!("artifact {cost_fwd} missing"));
        }
        let e = rt.manifest.artifact_meta(&cost_fwd, "E").unwrap_or(16) as usize;
        let cost_train_name = format!("cost_train_d{d}s{s}");
        let cost_train = rt
            .manifest
            .artifacts
            .contains_key(&cost_train_name)
            .then_some(cost_train_name.clone());
        let b_cost = rt.manifest.artifact_meta(&cost_train_name, "B").unwrap_or(64) as usize;
        let mut policy_train: Vec<(usize, String)> = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| {
                let rest = k.strip_prefix(&format!("policy_train_d{d}s{s}_b"))?;
                Some((rest.parse().ok()?, k.clone()))
            })
            .collect();
        policy_train.sort();
        let mut mdp_step: Vec<(usize, String)> = rt
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| {
                let rest = k.strip_prefix(&format!("mdp_step_d{d}s{s}_e"))?;
                Some((rest.parse().ok()?, k.clone()))
            })
            .collect();
        mdp_step.sort();
        Ok(Variant { d, s, e, cost_fwd, policy_fwd, cost_train, policy_train, b_cost, mdp_step })
    }

    /// Smallest fused-step artifact with at least `lanes` lanes.
    pub fn mdp_step_for(&self, lanes: usize) -> Option<&(usize, String)> {
        self.mdp_step.iter().find(|(e, _)| *e >= lanes).or(self.mdp_step.last())
    }

    /// Smallest policy-train artifact whose step capacity fits `rows`.
    pub fn policy_train_for(&self, rows: usize) -> Option<&(usize, String)> {
        self.policy_train.iter().find(|(b, _)| *b >= rows).or(self.policy_train.last())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // the reference backend serves the same variant grid the AOT
    // artifacts bake, so these run without `make artifacts`
    #[test]
    fn selects_smallest_fitting() {
        let rt = Runtime::reference();
        assert_eq!(Variant::for_devices(&rt, 2).unwrap().d, 2);
        assert_eq!(Variant::for_devices(&rt, 3).unwrap().d, 4);
        assert_eq!(Variant::for_devices(&rt, 4).unwrap().d, 4);
        assert_eq!(Variant::for_devices(&rt, 8).unwrap().d, 8);
        assert_eq!(Variant::for_devices(&rt, 100).unwrap().d, 128);
        assert!(Variant::for_devices(&rt, 1000).is_err());
    }

    #[test]
    fn ultra_variant_is_inference_only() {
        let rt = Runtime::reference();
        let v = Variant::for_devices(&rt, 128).unwrap();
        assert!(v.cost_train.is_none());
        assert!(v.policy_train.is_empty());
    }

    #[test]
    fn policy_train_capacity_selection() {
        let rt = Runtime::reference();
        let v = Variant::for_devices(&rt, 4).unwrap();
        assert_eq!(v.policy_train_for(100).unwrap().0, 512);
        assert_eq!(v.policy_train_for(513).unwrap().0, 2048);
        // oversized falls back to the largest (caller chunks)
        assert_eq!(v.policy_train_for(10_000).unwrap().0, 2048);
    }

    #[test]
    fn fused_step_selection() {
        let rt = Runtime::reference();
        let v = Variant::for_devices(&rt, 4).unwrap();
        assert_eq!(v.mdp_step_for(1).unwrap().0, 1);
        assert_eq!(v.mdp_step_for(10).unwrap().0, 16);
        // oversized falls back to the largest (caller clamps lanes)
        assert_eq!(v.mdp_step_for(64).unwrap().0, 16);
    }
}
