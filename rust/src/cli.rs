//! Hand-rolled CLI flag parsing for the `dreamshard` binary (the crate is
//! dependency-free by design, so there is no clap). Extracted from
//! `main.rs` so the grammar is unit-testable.
//!
//! Grammar:
//! * `--name value` — a named flag; the value is the next argument unless
//!   that argument itself starts with `--`.
//! * `--switch` — a bare switch (no value follows, or the next argument
//!   is another flag).
//! * anything else — a positional argument, in order.

use std::collections::{HashMap, HashSet};

/// Parsed command-line flags.
#[derive(Debug, Default)]
pub struct Flags {
    pub positional: Vec<String>,
    pub named: HashMap<String, String>,
    pub switches: HashSet<String>,
}

/// Parse arguments (everything after the subcommand) into [`Flags`].
pub fn parse_flags(args: &[String]) -> Flags {
    let mut f = Flags::default();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                f.named.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                f.switches.insert(name.to_string());
                i += 1;
            }
        } else {
            f.positional.push(a.clone());
            i += 1;
        }
    }
    f
}

impl Flags {
    /// Value of `--name` parsed as usize, or `default`.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.named.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Value of `--name` as a string, or `default`.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.named.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Whether `--name` was given at all (as a switch or with a value).
    pub fn has(&self, name: &str) -> bool {
        self.switches.contains(name) || self.named.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Flags {
        parse_flags(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn named_values_and_defaults() {
        let f = parse(&["--tables", "80", "--devices", "8"]);
        assert_eq!(f.get_usize("tables", 50), 80);
        assert_eq!(f.get_usize("devices", 4), 8);
        assert_eq!(f.get_usize("seeds", 3), 3, "absent flag falls back");
        assert_eq!(f.get_usize("tables", 0), 80);
    }

    #[test]
    fn policy_flag_round_trips() {
        let f = parse(&["--policy", "greedy:size-lookup", "--fast"]);
        assert_eq!(f.get_str("policy", "dreamshard"), "greedy:size-lookup");
        assert!(f.has("fast"));
        let g = parse(&["--fast"]);
        assert_eq!(g.get_str("policy", "dreamshard"), "dreamshard");
    }

    #[test]
    fn switches_with_and_without_values() {
        // a flag directly followed by another flag is a switch
        let f = parse(&["--fast", "--seeds", "2", "--prod"]);
        assert!(f.has("fast"));
        assert!(f.has("prod"));
        assert_eq!(f.get_usize("seeds", 3), 2);
        // `has` also sees valued flags
        assert!(f.has("seeds"));
        assert!(!f.has("tables"));
    }

    #[test]
    fn positionals_keep_order_and_mix_with_flags() {
        let f = parse(&["repro", "table1", "--seeds", "2"]);
        assert_eq!(f.positional, vec!["repro".to_string(), "table1".to_string()]);
        assert_eq!(f.get_usize("seeds", 3), 2);
    }

    #[test]
    fn flag_followed_by_bare_word_takes_it_as_value() {
        // the grammar is greedy: `--fast extra` reads as --fast=extra, so
        // switches must come last or be followed by another flag (this is
        // the long-standing CLI behavior, pinned here on purpose)
        let f = parse(&["--fast", "extra"]);
        assert!(f.has("fast"));
        assert_eq!(f.get_str("fast", ""), "extra");
        assert!(f.positional.is_empty());
    }

    #[test]
    fn unparsable_value_falls_back() {
        let f = parse(&["--tables", "many"]);
        assert_eq!(f.get_usize("tables", 50), 50);
        assert_eq!(f.get_str("tables", ""), "many");
    }

    #[test]
    fn empty_args() {
        let f = parse(&[]);
        assert!(f.positional.is_empty());
        assert!(f.named.is_empty());
        assert!(f.switches.is_empty());
    }
}
