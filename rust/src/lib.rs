//! # DreamShard
//!
//! Reproduction of *DreamShard: Generalizable Embedding Table Placement
//! for Recommender Systems* (Zha et al., NeurIPS 2022) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   GPU cluster substrate, the placement MDP, the Algorithm-1 trainer,
//!   greedy expert baselines, the [`placer`] planning facade, the
//!   [`serve`] front end, and the experiment harness.
//! * **Layer 2** (`python/compile/model.py`) — cost / policy / RNN / DLRM
//!   networks in JAX, AOT-lowered to HLO text.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   embedding-bag hot spot and the sum/max reductions.
//!
//! ## Concurrent runtime sessions
//!
//! Everything executes through one shared, thread-safe
//! [`runtime::Runtime`] (`Arc<Runtime>` end-to-end — no borrowed runtime
//! lifetimes anywhere in the planning stack). The runtime owns a small
//! in-crate worker pool: [`runtime::Runtime::submit`] dispatches an
//! artifact execution and returns a [`runtime::Ticket`],
//! [`runtime::Ticket::wait`] joins it, and the blocking
//! [`runtime::Runtime::run`] is exactly `submit(..).wait()` — one
//! dispatch path, one set of lock-free per-artifact call counters
//! ([`runtime::Runtime::run_count`] / [`runtime::Runtime::run_count_for`]),
//! exact under any number of concurrent submitters and unpoisonable by a
//! failed execution. [`runtime::Backend`] is `Send + Sync`; pool size
//! comes from `DREAMSHARD_WORKERS`, [`runtime::Runtime::with_workers`],
//! or the `serve-sim --workers` flag.
//!
//! ## Planning API
//!
//! Every placement strategy sits behind one trait: build a
//! [`placer::PlacementRequest`] (dataset + task + simulator + legality
//! knobs), pick a strategy by name from the registry, and get a
//! [`placer::PlacementPlan`] back:
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, Placer, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(100, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let task = sample_tasks(&pool, 12, 4, 1, 2).remove(0);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim).unwrap();
//! let mut expert = placer::by_name(&rt, "greedy:lookup").unwrap();
//! let plan = expert.place(&req).unwrap();
//! println!("{}: {:.1} ms", plan.strategy, plan.eval.latency);
//! ```
//!
//! Learned strategies (`"dreamshard"`, `"rnn"`) report
//! [`placer::Placer::needs_fit`] and are trained with
//! [`placer::Placer::fit`]. [`placer::Placer::place_many`] plans a batch;
//! the DreamShard implementation fills the backend's episode lanes with
//! different tasks and advances them in lockstep — one fused backend call
//! per MDP step for up to `E` tasks at once, and one concatenated
//! `table_cost` pass ordering every task in a chunk (see
//! [`placer::DreamShardPlacer`]). The same lockstep loop is available as
//! a resumable [`placer::PlanSession`] ([`placer::Placer::open_session`]):
//! each step's CPU feature-fill and fused backend call are driven
//! separately, which is what pipelined callers overlap.
//!
//! Plans age — devices fail, capacity arrives — so every strategy also
//! answers [`placer::Placer::replace`] / [`placer::Placer::replace_many`]:
//! re-plan against a previous [`placer::PlacementPlan`], moving at most
//! what the request's [`placer::MigrationBudget`] allows (forced moves
//! off lost devices are always permitted). The greedy family runs a
//! migration-aware local search that keeps every still-valid assignment;
//! DreamShard re-rolls its MDP warm-started from the prior plan, so only
//! the tables it may move consume fused backend steps (a budget of `K`
//! costs `1 + K` calls per chunk). Either way the returned plan's
//! [`sim::Evaluation`] prices every moved table's weights over the
//! configured copy bandwidth ([`sim::SimConfig::migration_gbps`],
//! [`sim::Simulator::evaluate_migration`]) into `migration_ms`.
//!
//! ## Serving
//!
//! [`serve::PlanService`] turns the facade into a front end for traffic:
//! a bounded FIFO of heterogeneous placement requests (mixed table and
//! device counts), drained in variant-grouped lane-chunks. The default
//! [`serve::PlanService::drain`] is **pipelined**: up to
//! [`serve::ServeConfig::inflight`] chunks stay in flight on the runtime
//! worker pool, and while chunk k's fused call executes, chunk k+1's
//! feature tensors are filled — plans and backend-call budgets are
//! bit-identical to the blocking
//! [`serve::PlanService::drain_blocking`], only the waits overlap
//! (pinned in `tests/serve.rs`). Per-request queue/plan latency and
//! aggregate throughput land in [`serve::ServeStats`]:
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::serve::{PlanService, ServeConfig};
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(60, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let tasks = sample_tasks(&pool, 10, 4, 4, 5);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let mut svc = PlanService::new(
//!     &rt,
//!     placer::by_name(&rt, "greedy:dim").unwrap(),
//!     ServeConfig::default(),
//! );
//! for t in &tasks {
//!     let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
//!     svc.submit(req).unwrap().expect("queue has room");
//! }
//! assert_eq!(svc.drain().unwrap().len(), 4);
//! ```
//!
//! One service is one FIFO; [`serve::ShardedFrontEnd`] serves **many
//! planning streams** at once: it routes every submit to a per-serving-
//! variant (optionally per-tenant) `PlanService` shard, drains each
//! shard on its own thread against the shared `Arc<Runtime>` worker
//! pool — so a 128-device chunk never head-of-line-blocks 8-device
//! traffic — and sheds load at a single global queued-request cap
//! ([`serve::ShardConfig::global_cap`]). Plans and backend-call budgets
//! are bit-identical to draining the same shards sequentially (pinned in
//! `tests/sharded.rs`). The `dreamshard serve-sim` CLI subcommand
//! replays a synthetic open-loop workload
//! ([`serve::synthetic_arrivals`]) against either front end
//! (`--sharded` picks the sharded one), and `benches/serving.rs` reports
//! pipelined vs blocking drains at 1/2/4 workers plus sharded vs
//! single-FIFO throughput on the mixed 2/4/8/128-device workload.
//!
//! The serving layer closes its own loop: [`serve::Controller`] watches
//! the signals each shard already exposes ([`serve::ShardView`]:
//! queue-latency percentiles over a bounded window, queue depths,
//! drain-completion ages — all read off a swappable [`serve::Clock`])
//! and steers the existing knobs toward a
//! [`serve::ControlConfig::target_ms`] tail-latency target: lane-chunk
//! resizing, AIMD admission-cap adaptation, worst-tail-first drain
//! scheduling, SLO-class pressure mode ([`serve::SloClass`]: interactive
//! drains first, batch sheds first), and headroom-sized
//! [`placer::MigrationBudget`]s for rebalances. Under a
//! [`serve::TestClock`] a whole control trajectory is deterministic
//! (`tests/control.rs`); `serve-sim --closed-loop` replays one and
//! prints the static-vs-controlled comparison:
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::serve::{
//!     ControlConfig, Controller, ShardConfig, ShardedFrontEnd, TestClock,
//! };
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(60, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let task = sample_tasks(&pool, 10, 4, 1, 5).remove(0);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let clock = Arc::new(TestClock::new()); // deterministic time
//! let factory = {
//!     let rt = Arc::clone(&rt);
//!     move || placer::by_name(&rt, "greedy:dim")
//! };
//! let mut front =
//!     ShardedFrontEnd::with_clock(&rt, factory, ShardConfig::default(), clock.clone())
//!         .unwrap();
//! let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim).unwrap();
//! front.submit(req).unwrap().expect("under the global cap");
//! clock.advance_ms(5.0);
//!
//! let mut ctl = Controller::new(ControlConfig { target_ms: 50.0, ..Default::default() });
//! let report = ctl.tick(&mut front).unwrap(); // observe -> actuate -> drain
//! assert_eq!(report.planned.len(), 1);
//! assert!(!report.pressure, "5 ms of queueing is far under a 50 ms target");
//! ```
//!
//! Both front ends also serve fleet *changes*:
//! [`serve::PlanService::rebalance`] and
//! [`serve::ShardedFrontEnd::rebalance`] drain batches of
//! [`serve::ReplaceJob`]s (previous plan + new request) through the
//! placer's budgeted `replace_many`, bypassing the submit FIFOs, with
//! moved-table counts and migration cost surfaced in
//! [`serve::ServeStats`] / [`serve::FrontStats`]. `serve-sim
//! --rebalance` and `benches/rebalance.rs` compare that path against
//! re-planning from scratch; `examples/rebalance.rs` is the one-task
//! walkthrough.
//!
//! ## Execution backends
//!
//! Python never runs at placement time: the coordinator drives the
//! networks through the [`runtime::Backend`] seam (`Send + Sync`), which
//! has two implementations:
//!
//! * [`runtime::ReferenceBackend`] (**default**) — a pure-Rust,
//!   dependency-free evaluator of the cost / policy / RNN networks
//!   (forward *and* backward passes, mirroring `python/compile/model.py`
//!   to the operation). `cargo build && cargo test` work from a bare
//!   toolchain: no `make artifacts`, no native libraries.
//! * `XlaBackend` (`--features xla`) — loads the `make artifacts` HLO
//!   text via the PJRT C API and JIT-compiles it (thread-safe executable
//!   cache). Requires a real xla-rs checkout in place of the in-tree
//!   `xla-stub` crate plus its native `libxla_extension`; `make
//!   artifacts` is only ever needed for this backend (and for the DLRM
//!   end-to-end example, whose embedding-bag training step is XLA-only).
//!
//! [`runtime::Runtime::open_default`] picks the backend: an explicitly
//! set `DREAMSHARD_ARTIFACTS` makes the XLA backend mandatory (a build
//! without the feature, or an unopenable directory, is a hard error —
//! never a silent reference-backend substitution); otherwise artifacts
//! present *and* the `xla` feature enabled → XLA, else the reference
//! backend.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod mdp;
pub mod placer;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tables;
pub mod util;

pub use util::error::{Context, Error, Result};
