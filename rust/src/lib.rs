//! # DreamShard
//!
//! Reproduction of *DreamShard: Generalizable Embedding Table Placement
//! for Recommender Systems* (Zha et al., NeurIPS 2022) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   GPU cluster substrate, the placement MDP, the Algorithm-1 trainer,
//!   greedy expert baselines, the [`placer`] planning facade, and the
//!   experiment harness.
//! * **Layer 2** (`python/compile/model.py`) — cost / policy / RNN / DLRM
//!   networks in JAX, AOT-lowered to HLO text.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   embedding-bag hot spot and the sum/max reductions.
//!
//! ## Planning API
//!
//! Every placement strategy sits behind one trait: build a
//! [`placer::PlacementRequest`] (dataset + task + simulator + legality
//! knobs), pick a strategy by name from the registry, and get a
//! [`placer::PlacementPlan`] back:
//!
//! ```
//! use dreamshard::placer::{self, Placer, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Runtime::reference();
//! let ds = gen_dlrm(100, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let task = sample_tasks(&pool, 12, 4, 1, 2).remove(0);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim).unwrap();
//! let mut expert = placer::by_name(&rt, "greedy:lookup").unwrap();
//! let plan = expert.place(&req).unwrap();
//! println!("{}: {:.1} ms", plan.strategy, plan.eval.latency);
//! ```
//!
//! Learned strategies (`"dreamshard"`, `"rnn"`) report
//! [`placer::Placer::needs_fit`] and are trained with
//! [`placer::Placer::fit`]. [`placer::Placer::place_many`] plans a batch;
//! the DreamShard implementation fills the backend's episode lanes with
//! different tasks and advances them in lockstep — one fused backend call
//! per MDP step for up to `E` tasks at once, and one concatenated
//! `table_cost` pass ordering every task in a chunk (see
//! [`placer::DreamShardPlacer`]).
//!
//! ## Serving
//!
//! [`serve::PlanService`] turns the facade into a front end for traffic:
//! a bounded FIFO of heterogeneous placement requests (mixed table and
//! device counts), drained in variant-grouped lane-chunks through one
//! `place_many` call each, with per-request queue/plan latency and
//! aggregate throughput recorded in [`serve::ServeStats`]. The
//! `dreamshard serve-sim` CLI subcommand replays a synthetic open-loop
//! workload ([`serve::synthetic_arrivals`]) against it, and
//! `benches/serving.rs` reports batched-drain vs sequential plans/sec.
//!
//! ## Execution backends
//!
//! Python never runs at placement time: the coordinator drives the
//! networks through the [`runtime::Backend`] seam, which has two
//! implementations:
//!
//! * [`runtime::ReferenceBackend`] (**default**) — a pure-Rust,
//!   dependency-free evaluator of the cost / policy / RNN networks
//!   (forward *and* backward passes, mirroring `python/compile/model.py`
//!   to the operation). `cargo build && cargo test` work from a bare
//!   toolchain: no `make artifacts`, no native libraries.
//! * `XlaBackend` (`--features xla`) — loads the `make artifacts` HLO
//!   text via the PJRT C API and JIT-compiles it. Requires a real xla-rs
//!   checkout in place of the in-tree `xla-stub` crate plus its native
//!   `libxla_extension`; `make artifacts` is only ever needed for this
//!   backend (and for the DLRM end-to-end example, whose embedding-bag
//!   training step is XLA-only).
//!
//! [`runtime::Runtime::open_default`] picks the backend: artifacts present
//! *and* the `xla` feature enabled → XLA; otherwise the reference backend.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod mdp;
pub mod placer;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod tables;
pub mod util;

pub use util::error::{Context, Error, Result};
