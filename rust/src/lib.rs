//! # DreamShard
//!
//! Reproduction of *DreamShard: Generalizable Embedding Table Placement
//! for Recommender Systems* (Zha et al., NeurIPS 2022) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   GPU cluster substrate, the placement MDP, the Algorithm-1 trainer,
//!   greedy expert baselines, and the experiment harness.
//! * **Layer 2** (`python/compile/model.py`) — cost / policy / RNN / DLRM
//!   networks in JAX, AOT-lowered to HLO text.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   embedding-bag hot spot and the sum/max reductions.
//!
//! Python never runs at placement time: the coordinator drives the
//! networks through the [`runtime::Backend`] seam, which has two
//! implementations:
//!
//! * [`runtime::ReferenceBackend`] (**default**) — a pure-Rust,
//!   dependency-free evaluator of the cost / policy / RNN networks
//!   (forward *and* backward passes, mirroring `python/compile/model.py`
//!   to the operation). `cargo build && cargo test` work from a bare
//!   toolchain: no `make artifacts`, no native libraries.
//! * `XlaBackend` (`--features xla`) — loads the `make artifacts` HLO
//!   text via the PJRT C API and JIT-compiles it. Requires a real xla-rs
//!   checkout in place of the in-tree `xla-stub` crate plus its native
//!   `libxla_extension`; `make artifacts` is only ever needed for this
//!   backend (and for the DLRM end-to-end example, whose embedding-bag
//!   training step is XLA-only).
//!
//! [`runtime::Runtime::open_default`] picks the backend: artifacts present
//! *and* the `xla` feature enabled → XLA; otherwise the reference backend.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod mdp;
pub mod runtime;
pub mod sim;
pub mod tables;
pub mod util;

pub use util::error::{Context, Error, Result};
