//! # DreamShard
//!
//! Reproduction of *DreamShard: Generalizable Embedding Table Placement
//! for Recommender Systems* (Zha et al., NeurIPS 2022) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: datasets, the simulated
//!   GPU cluster substrate, the placement MDP, the Algorithm-1 trainer,
//!   greedy expert baselines, and the experiment harness.
//! * **Layer 2** (`python/compile/model.py`) — cost / policy / RNN / DLRM
//!   networks in JAX, AOT-lowered to HLO text.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels for the
//!   embedding-bag hot spot and the sum/max reductions.
//!
//! Python never runs at placement time: `runtime` loads the HLO artifacts
//! via the PJRT C API and the rust coordinator drives them.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod mdp;
pub mod runtime;
pub mod sim;
pub mod tables;
pub mod util;
