//! The trained DreamShard agent behind the [`Placer`] facade, with
//! **lane-batched multi-task planning**: `place_many` fills the backend's
//! `[E, D, S, F]` episode lanes with *different tasks* and advances them
//! in lockstep, one fused `mdp_step` backend call per MDP step — instead
//! of `E` sequential full episodes. Table ordering is chunk-batched the
//! same way: one concatenated `[N, F]` `table_cost` pass scores every
//! task in a chunk (`DreamShard::order_tables_batch`) instead of one
//! backend call per task. Per-lane/per-row network math is independent,
//! so each task's plan is identical to what sequential [`Placer::place`]
//! produces (asserted by `tests/placer_api.rs`); only the wall-clock
//! changes (`benches/placement.rs` reports the throughput gap).
//!
//! The same lockstep loop is also exposed as a resumable
//! [`DreamShardSession`] ([`Placer::open_session`]): each MDP step splits
//! into a CPU fill half and an asynchronous fused-call half
//! ([`crate::runtime::Runtime::submit`]), which is what lets the serving
//! drain fill chunk k+1's tensors while chunk k executes. Both paths run
//! the identical `LaneChunk` state machine, so pipelined plans are
//! bit-identical to blocking ones by construction.

use std::sync::Arc;

use super::{FitRequest, Placer, PlacementPlan, PlacementRequest, PlanSession};
use crate::bail;
use crate::coordinator::{select_action, DreamShard, TrainCfg, Variant};
use crate::mdp::PlacementState;
use crate::runtime::{to_f32_vec, Runtime, TensorF32, Ticket, Value};
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::{Context, Result};
use crate::util::Rng;

const NAME: &str = "dreamshard";

/// The DreamShard agent as a [`Placer`]. Shares the runtime as
/// `Arc<Runtime>` and the agent as `Arc<DreamShard>` (no borrowed
/// lifetimes), so the placer — and any service or session built on it —
/// moves freely across threads.
pub struct DreamShardPlacer {
    rt: Arc<Runtime>,
    agent: Option<Arc<DreamShard>>,
    cfg: TrainCfg,
    seed: u64,
}

impl DreamShardPlacer {
    /// An unfitted agent; [`Placer::place`] before [`Placer::fit`] lazily
    /// initializes random weights (deterministic, useful for benches).
    pub fn untrained(rt: &Arc<Runtime>) -> Self {
        DreamShardPlacer { rt: Arc::clone(rt), agent: None, cfg: TrainCfg::default(), seed: 0 }
    }

    /// Wrap an already-trained agent. The placer snapshots the agent's
    /// inference state ([`DreamShard::inference_clone`]: networks +
    /// variant, kilobytes), which is exactly what planning reads — plans
    /// are bit-identical to running the original agent.
    pub fn from_agent(rt: &Arc<Runtime>, agent: &DreamShard) -> Self {
        DreamShardPlacer {
            rt: Arc::clone(rt),
            agent: Some(Arc::new(agent.inference_clone())),
            cfg: TrainCfg::default(),
            seed: 0,
        }
    }

    /// Configuration for the lazily-created untrained agent (first
    /// placement without a prior [`Placer::fit`]). `fit` itself always
    /// uses [`FitRequest::cfg`].
    pub fn with_cfg(mut self, cfg: TrainCfg) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    fn agent(&self) -> Option<&DreamShard> {
        self.agent.as_deref()
    }

    /// The lazily-created agent, handed back as the `Arc` the caller
    /// plans with — so every `place_many`-family entry point gets its
    /// agent from the one fallible site instead of re-unwrapping the
    /// option it just filled.
    fn ensure_agent(&mut self, n_devices: usize) -> Result<Arc<DreamShard>> {
        match &self.agent {
            Some(agent) => Ok(Arc::clone(agent)),
            None => {
                let mut rng = Rng::new(self.seed).fork(0xD5);
                let agent =
                    Arc::new(DreamShard::new(&self.rt, n_devices, self.cfg.clone(), &mut rng)?);
                self.agent = Some(Arc::clone(&agent));
                Ok(agent)
            }
        }
    }

    /// The artifact variant serving one task: the agent's own (matching
    /// sequential `DreamShard::place` exactly) whenever the task fits its
    /// device capacity, else the smallest variant that does (how Table 13
    /// plans 128 devices with an agent trained at 8).
    fn variant_for(&self, agent: &DreamShard, n_devices: usize) -> Result<Variant> {
        if n_devices <= agent.var.d {
            Ok(agent.var.clone())
        } else {
            Variant::for_devices(&self.rt, n_devices)
        }
    }

    /// Plan one group of requests that share an artifact variant, in
    /// chunks of up to `E` lockstep lanes. Within a chunk every MDP step
    /// costs exactly one fused backend call, shared by all lanes.
    fn plan_batch(
        &self,
        agent: &DreamShard,
        var: &Variant,
        reqs: &[PlacementRequest<'_>],
    ) -> Result<Vec<PlacementPlan>> {
        let Some((lanes, step_name)) = var.mdp_step_for(reqs.len()).cloned() else {
            // no fused artifact lowered for this variant: plan one
            // episode at a time through the classic path (which honors
            // the request's slot cap just like the lane-batched path)
            let mut plans = Vec::with_capacity(reqs.len());
            for r in reqs {
                let mut rng = Rng::new(0); // unused by argmax
                let ep = agent
                    .run_episodes_var(
                        &self.rt, r.sim, r.ds, r.task, 1, false, false, &mut rng, var, false,
                        r.max_slots,
                    )?
                    .remove(0);
                plans.push(PlacementPlan::new(r, ep.placement, NAME));
            }
            return Ok(plans);
        };
        // chunk-batched table ordering: one concatenated [N, F]
        // table_cost pass for the WHOLE group (split only on the
        // artifact's row cap) instead of one backend call per task —
        // hoisted above the lane chunking so the ordering budget is
        // ceil(total_tables / N_cap) however the lanes split
        let jobs: Vec<(&Dataset, &Task)> = reqs.iter().map(|r| (r.ds, r.task)).collect();
        let mut orders = agent.order_tables_batch(&self.rt, &jobs)?.into_iter();
        let mut plans = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(lanes) {
            let chunk_orders: Vec<Vec<usize>> = orders.by_ref().take(chunk.len()).collect();
            let mut lc = LaneChunk::new(var, lanes, chunk, chunk_orders);
            while !lc.done() {
                let (feats, mask, dmask, cur, legal_t) = lc.fill()?;
                // the single fused backend call all lanes share this step
                let out = agent
                    .run_fused_step(&self.rt, &step_name, &feats, &mask, &dmask, &cur, &legal_t)?;
                lc.apply(&out)?;
            }
            plans.extend(lc.into_plans());
        }
        Ok(plans)
    }

    /// Warm-started analogue of [`DreamShardPlacer::plan_batch`]: the
    /// same chunk-batched ordering call and lockstep fused-step loop, but
    /// each lane's state starts from its previous placement with only the
    /// forced + budget-capped discretionary tables left to roll out. A
    /// chunk therefore costs one `table_cost` call plus one fused call
    /// per *remaining* MDP step — at most the cold-start budget, and with
    /// a tight [`super::MigrationBudget`] far below it.
    fn replace_batch(
        &self,
        agent: &DreamShard,
        var: &Variant,
        reqs: &[PlacementRequest<'_>],
        prevs: &[Vec<usize>],
    ) -> Result<Vec<PlacementPlan>> {
        let Some((lanes, step_name)) = var.mdp_step_for(reqs.len()).cloned() else {
            // no fused artifact lowered for this variant: plan from
            // scratch and report the full migration cost (the default
            // `replace` semantics — the budget cannot be honored here)
            let plans = self.plan_batch(agent, var, reqs)?;
            return Ok(plans
                .into_iter()
                .zip(reqs)
                .zip(prevs)
                .map(|((plan, r), prev)| {
                    let eval = r.sim.evaluate_migration(r.ds, r.task, prev, &plan.placement);
                    PlacementPlan { eval, ..plan }
                })
                .collect());
        };
        let jobs: Vec<(&Dataset, &Task)> = reqs.iter().map(|r| (r.ds, r.task)).collect();
        let mut orders = agent.order_tables_batch(&self.rt, &jobs)?.into_iter();
        let mut plans = Vec::with_capacity(reqs.len());
        let mut at = 0;
        for chunk in reqs.chunks(lanes) {
            let chunk_prevs = &prevs[at..at + chunk.len()];
            at += chunk.len();
            let states: Vec<PlacementState<'_>> = chunk
                .iter()
                .zip(chunk_prevs)
                .map(|(r, prev)| {
                    let full = orders
                        .next()
                        .context("order_tables_batch yields one order per request")?;
                    let warm = warm_order(r, prev, &full);
                    Ok(PlacementState::warm_start(
                        r.ds,
                        r.task,
                        warm,
                        var.s.min(r.max_slots),
                        prev.clone(),
                        r.migration.max_moves,
                    ))
                })
                .collect::<Result<_>>()?;
            let mut lc = LaneChunk::from_states(var, lanes, chunk, states);
            while !lc.done() {
                let (feats, mask, dmask, cur, legal_t) = lc.fill()?;
                let out = agent
                    .run_fused_step(&self.rt, &step_name, &feats, &mask, &dmask, &cur, &legal_t)?;
                lc.apply(&out)?;
            }
            plans.extend(lc.into_migration_plans(chunk_prevs));
        }
        Ok(plans)
    }
}

impl Placer for DreamShardPlacer {
    fn name(&self) -> &str {
        NAME
    }

    fn needs_fit(&self) -> bool {
        self.agent.is_none()
    }

    fn fit(&mut self, req: &FitRequest<'_>) -> Result<()> {
        let d = req
            .tasks
            .iter()
            .map(|t| t.n_devices)
            .max()
            .context("dreamshard fit requires at least one task")?;
        let mut rng = Rng::new(req.seed);
        let mut agent = DreamShard::new(&self.rt, d, req.cfg.clone(), &mut rng)?;
        agent.train(&self.rt, req.sim, req.ds, req.tasks, &mut rng)?;
        if req.verbose {
            for st in &agent.log {
                eprintln!(
                    "  iter {}: collected {:.1} ms, cost-loss {:.3}, policy-loss {:.4} ({:.1}s)",
                    st.iter, st.collected_mean_cost, st.cost_loss, st.policy_loss, st.wall_s
                );
            }
        }
        self.agent = Some(Arc::new(agent));
        Ok(())
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let mut plans = self.place_many(std::slice::from_ref(req))?;
        Ok(plans.remove(0))
    }

    /// The variant [`DreamShardPlacer::place_many`] would group this
    /// request under — the agent's own variant whenever the task fits it
    /// (so a scheduler can lane-share mixed device counts), else the
    /// smallest one that serves the task. `None` before the agent exists
    /// (untrained placer prior to its first fit/place).
    fn serving_variant(&self, req: &PlacementRequest<'_>) -> Option<(usize, usize)> {
        let agent = self.agent()?;
        let var = self.variant_for(agent, req.task.n_devices).ok()?;
        Some((var.d, var.s))
    }

    /// Create the lazily-initialized agent (sized to this request's
    /// device count) so [`Placer::serving_variant`] can answer at
    /// routing time instead of only after the first drain engages the
    /// placer — the sharded front end's submit-time mirror of
    /// `PlanService`'s drain-time key refresh.
    fn warm_variant(&mut self, req: &PlacementRequest<'_>) -> Result<()> {
        self.ensure_agent(req.task.n_devices)?;
        Ok(())
    }

    fn place_many(&mut self, reqs: &[PlacementRequest<'_>]) -> Result<Vec<PlacementPlan>> {
        // max() is None exactly when the batch is empty
        let Some(max_dev) = reqs.iter().map(|r| r.task.n_devices).max() else {
            return Ok(vec![]);
        };
        let agent = self.ensure_agent(max_dev)?;
        // group lanes by serving variant: tasks with different device
        // counts share the agent's variant (masking covers the gap), so
        // heterogeneous batches still fill the same lanes
        let mut groups: Vec<(Variant, Vec<usize>)> = vec![];
        for (i, r) in reqs.iter().enumerate() {
            let var = self.variant_for(&agent, r.task.n_devices)?;
            match groups.iter_mut().find(|(v, _)| v.d == var.d && v.s == var.s) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((var, vec![i])),
            }
        }
        let mut plans: Vec<Option<PlacementPlan>> = (0..reqs.len()).map(|_| None).collect();
        for (var, idxs) in &groups {
            let group: Vec<PlacementRequest<'_>> = idxs.iter().map(|&i| reqs[i]).collect();
            let got = self.plan_batch(&agent, var, &group)?;
            for (&i, plan) in idxs.iter().zip(got.into_iter()) {
                plans[i] = Some(plan);
            }
        }
        plans
            .into_iter()
            .map(|p| p.context("every request belongs to exactly one variant group"))
            .collect()
    }

    fn replace(&mut self, prev: &PlacementPlan, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let mut plans =
            self.replace_many(std::slice::from_ref(prev), std::slice::from_ref(req))?;
        Ok(plans.remove(0))
    }

    /// Lane-batched incremental re-planning: requests are grouped by
    /// serving variant exactly like [`Placer::place_many`], then each
    /// group rolls warm-started states ([`PlacementState::warm_start`])
    /// through the same fused-step machinery. With a vacant prev and an
    /// unlimited budget every table is rolled out from scratch and the
    /// result is bit-identical to `place_many` (pinned by
    /// `tests/placer_api.rs`).
    fn replace_many(
        &mut self,
        prevs: &[PlacementPlan],
        reqs: &[PlacementRequest<'_>],
    ) -> Result<Vec<PlacementPlan>> {
        if prevs.len() != reqs.len() {
            bail!("replace_many: {} prev plans for {} requests", prevs.len(), reqs.len());
        }
        let Some(max_dev) = reqs.iter().map(|r| r.task.n_devices).max() else {
            return Ok(vec![]);
        };
        let agent = self.ensure_agent(max_dev)?;
        // normalize prevs: an empty placement means "no prior at all"
        let mut prev_full: Vec<Vec<usize>> = Vec::with_capacity(reqs.len());
        for (p, r) in prevs.iter().zip(reqs) {
            let n = r.task.n_tables();
            if p.placement.is_empty() {
                prev_full.push(vec![usize::MAX; n]);
            } else if p.placement.len() == n {
                prev_full.push(p.placement.clone());
            } else {
                bail!("replace: prev plan covers {} tables but the task has {n}", p.placement.len());
            }
        }
        let mut groups: Vec<(Variant, Vec<usize>)> = vec![];
        for (i, r) in reqs.iter().enumerate() {
            let var = self.variant_for(&agent, r.task.n_devices)?;
            match groups.iter_mut().find(|(v, _)| v.d == var.d && v.s == var.s) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((var, vec![i])),
            }
        }
        let mut plans: Vec<Option<PlacementPlan>> = (0..reqs.len()).map(|_| None).collect();
        for (var, idxs) in &groups {
            let group_reqs: Vec<PlacementRequest<'_>> = idxs.iter().map(|&i| reqs[i]).collect();
            let group_prevs: Vec<Vec<usize>> =
                idxs.iter().map(|&i| prev_full[i].clone()).collect();
            let got = self.replace_batch(&agent, var, &group_reqs, &group_prevs)?;
            for (&i, plan) in idxs.iter().zip(got.into_iter()) {
                plans[i] = Some(plan);
            }
        }
        plans
            .into_iter()
            .map(|p| p.context("every request belongs to exactly one variant group"))
            .collect()
    }

    /// A [`DreamShardSession`] whenever the chunk is what a
    /// variant-grouped serving drain produces: every request served by
    /// the same artifact variant, a fused step artifact lowered for it,
    /// and the chunk fitting that artifact's lanes. Mixed-variant or
    /// oversized chunks (and variants without a fused artifact) decline
    /// with `Ok(None)` so the caller falls back to blocking
    /// [`Placer::place_many`] — same plans, no overlap.
    fn open_session<'a>(
        &mut self,
        reqs: &[PlacementRequest<'a>],
    ) -> Result<Option<Box<dyn PlanSession<'a> + 'a>>> {
        let Some(max_dev) = reqs.iter().map(|r| r.task.n_devices).max() else {
            return Ok(None);
        };
        let agent = self.ensure_agent(max_dev)?;
        let var = self.variant_for(&agent, reqs[0].task.n_devices)?;
        for r in &reqs[1..] {
            let v = self.variant_for(&agent, r.task.n_devices)?;
            if (v.d, v.s) != (var.d, var.s) {
                return Ok(None);
            }
        }
        let Some((lanes, step_name)) = var.mdp_step_for(reqs.len()).cloned() else {
            return Ok(None);
        };
        if reqs.len() > lanes {
            return Ok(None);
        }
        // the chunk-batched ordering pass runs blocking at session open:
        // it is one `table_cost` call per N_cap rows either way, and its
        // output feeds the very first fill
        let jobs: Vec<(&Dataset, &Task)> = reqs.iter().map(|r| (r.ds, r.task)).collect();
        let orders = agent.order_tables_batch(&self.rt, &jobs)?;
        let chunk = LaneChunk::new(&var, lanes, reqs, orders);
        Ok(Some(Box::new(DreamShardSession {
            rt: Arc::clone(&self.rt),
            agent,
            step_name,
            chunk,
        })))
    }
}

/// Lockstep lane state for one chunk of requests sharing a fused-step
/// artifact. Each MDP step splits into [`LaneChunk::fill`] (CPU: build
/// the fused call's input tensors, record per-lane legality) and
/// [`LaneChunk::apply`] (CPU: pick actions from the call's logits and
/// advance the lanes), so the blocking path and the pipelined session
/// drive the *same* state machine — bit-identical plans by construction.
struct LaneChunk<'a> {
    reqs: Vec<PlacementRequest<'a>>,
    states: Vec<PlacementState<'a>>,
    /// Per-lane legal mask of the in-flight step; `None` once a (shorter)
    /// task has finished. Rebuilt by each `fill`, consumed by `apply`.
    legal: Vec<Option<Vec<bool>>>,
    lanes: usize,
    d: usize,
    /// The artifact's baked slot dimension S (tensor shape — per-state
    /// slot *caps* are `min(S, request.max_slots)` and live in the
    /// states).
    s: usize,
    step: usize,
    steps: usize,
    rng: Rng,
}

impl<'a> LaneChunk<'a> {
    fn new(
        var: &Variant,
        lanes: usize,
        reqs: &[PlacementRequest<'a>],
        orders: Vec<Vec<usize>>,
    ) -> Self {
        let s = var.s;
        let states: Vec<PlacementState<'a>> = reqs
            .iter()
            .zip(orders)
            .map(|(r, order)| PlacementState::new(r.ds, r.task, order, s.min(r.max_slots)))
            .collect();
        Self::from_states(var, lanes, reqs, states)
    }

    /// Lockstep over pre-built states — the warm-started `replace` path
    /// hands in states whose orders cover only the unpinned tables, so
    /// the chunk runs `max(order.len())` fused steps instead of
    /// `max(n_tables)` (for cold states the two are equal).
    fn from_states(
        var: &Variant,
        lanes: usize,
        reqs: &[PlacementRequest<'a>],
        states: Vec<PlacementState<'a>>,
    ) -> Self {
        let steps = states.iter().map(|st| st.order.len()).max().unwrap_or(0);
        LaneChunk {
            reqs: reqs.to_vec(),
            states,
            legal: vec![],
            lanes,
            d: var.d,
            s: var.s,
            step: 0,
            steps,
            rng: Rng::new(0), // unused by argmax
        }
    }

    fn done(&self) -> bool {
        self.step >= self.steps
    }

    /// CPU half 1: build the fused step's input tensors from the lanes.
    #[allow(clippy::type_complexity)]
    fn fill(&mut self) -> Result<(TensorF32, TensorF32, TensorF32, TensorF32, TensorF32)> {
        let (lanes, d, s, f) = (self.lanes, self.d, self.s, NUM_FEATURES);
        let mut feats = TensorF32::zeros(&[lanes, d, s, f]);
        let mut mask = TensorF32::zeros(&[lanes, d, s]);
        let mut dmask = TensorF32::zeros(&[lanes, d]);
        let mut cur = TensorF32::zeros(&[lanes, f]);
        let mut legal_t = TensorF32::zeros(&[lanes, d]);
        self.legal.clear();
        for (lane, st) in self.states.iter().enumerate() {
            st.fill_feats(lane, d, s, &mut feats, &mut mask, &mut dmask)?;
            if st.done() {
                self.legal.push(None); // lane logits computed but unused
                continue;
            }
            cur.set_row(&[lane, 0], &st.current_features());
            let lg = st.legal(self.reqs[lane].sim);
            for (dev, &ok) in lg.iter().enumerate() {
                legal_t.set(&[lane, dev], if ok { 1.0 } else { 0.0 });
            }
            self.legal.push(Some(lg));
        }
        Ok((feats, mask, dmask, cur, legal_t))
    }

    /// CPU half 2: pick each live lane's action from the fused call's
    /// logits and advance its MDP state.
    fn apply(&mut self, out: &[Value]) -> Result<()> {
        let (lanes, d) = (self.lanes, self.d);
        let logits = to_f32_vec(&out[0], lanes * d)?;
        for (lane, st) in self.states.iter_mut().enumerate() {
            let Some(lg) = &self.legal[lane] else { continue };
            // dead end (memory + slot caps exhausted everywhere):
            // least-loaded device with a free slot, as in training
            let a = if lg.iter().any(|&ok| ok) {
                select_action(&logits[lane * d..(lane + 1) * d], lg, false, &mut self.rng)
            } else {
                st.fallback_device()
                    .with_context(|| format!("lane {lane}: no device can take the table"))?
            };
            st.apply(a);
        }
        self.step += 1;
        Ok(())
    }

    fn into_plans(self) -> Vec<PlacementPlan> {
        self.states
            .iter()
            .zip(self.reqs.iter())
            .map(|(st, r)| PlacementPlan::new(r, st.placement.clone(), NAME))
            .collect()
    }

    /// Finish a warm-started chunk: each lane evaluated against its
    /// previous placement so the plan carries the migration charge.
    fn into_migration_plans(self, prevs: &[Vec<usize>]) -> Vec<PlacementPlan> {
        self.states
            .iter()
            .zip(self.reqs.iter())
            .zip(prevs)
            .map(|((st, r), prev)| {
                let eval = r.sim.evaluate_migration(r.ds, r.task, prev, &st.placement);
                PlacementPlan { placement: st.placement.clone(), eval, strategy: NAME.to_string() }
            })
            .collect()
    }
}

/// Which tables a warm rollout re-places, in predicted-cost order: every
/// forced table (previous device missing or lost), plus the leading
/// discretionary tables the migration budget could afford if they all
/// moved (a conservative reservation — an unpinned table may still stay
/// put, and the state's own `moves_left` enforces the cap exactly).
/// Everything else is pinned to its previous device without consuming an
/// MDP step — which is what makes `replace` cheaper than `place`.
fn warm_order(req: &PlacementRequest<'_>, prev: &[usize], full_order: &[usize]) -> Vec<usize> {
    let d = req.task.n_devices;
    let budget = req.migration;
    let mut moves = 0usize;
    let mut ms = 0.0f64;
    let mut order = Vec::with_capacity(full_order.len());
    for &i in full_order {
        if prev[i] >= d {
            order.push(i); // forced: rolled out regardless of budget
            continue;
        }
        let t_ms = req.sim.transfer_ms(&req.ds.tables[req.task.table_ids[i]]);
        if moves < budget.max_moves && ms + t_ms <= budget.max_migration_ms {
            moves += 1;
            ms += t_ms;
            order.push(i);
        }
    }
    order
}

/// The DreamShard implementation of [`PlanSession`]: one variant-grouped
/// lane-chunk advanced through [`DreamShard::submit_fused_step`], so the
/// fused call of step t executes on the runtime worker pool while the
/// caller fills other tensors (see
/// [`crate::serve::PlanService::drain`]).
pub struct DreamShardSession<'a> {
    rt: Arc<Runtime>,
    agent: Arc<DreamShard>,
    step_name: String,
    chunk: LaneChunk<'a>,
}

impl<'a> PlanSession<'a> for DreamShardSession<'a> {
    fn submit_step(&mut self) -> Result<Option<Ticket>> {
        if self.chunk.done() {
            return Ok(None);
        }
        let (feats, mask, dmask, cur, legal_t) = self.chunk.fill()?;
        let ticket = self.agent.submit_fused_step(
            &self.rt,
            &self.step_name,
            &feats,
            &mask,
            &dmask,
            &cur,
            &legal_t,
        )?;
        Ok(Some(ticket))
    }

    fn apply_step(&mut self, out: Vec<Value>) -> Result<()> {
        self.chunk.apply(&out)
    }

    fn finish(self: Box<Self>) -> Result<Vec<PlacementPlan>> {
        if !self.chunk.done() {
            bail!(
                "planning session finished early: {}/{} MDP steps applied",
                self.chunk.step,
                self.chunk.steps
            );
        }
        Ok(self.chunk.into_plans())
    }
}
