//! The trained DreamShard agent behind the [`Placer`] facade, with
//! **lane-batched multi-task planning**: `place_many` fills the backend's
//! `[E, D, S, F]` episode lanes with *different tasks* and advances them
//! in lockstep, one fused `mdp_step` backend call per MDP step — instead
//! of `E` sequential full episodes. Table ordering is chunk-batched the
//! same way: one concatenated `[N, F]` `table_cost` pass scores every
//! task in a chunk (`DreamShard::order_tables_batch`) instead of one
//! backend call per task. Per-lane/per-row network math is independent,
//! so each task's plan is identical to what sequential [`Placer::place`]
//! produces (asserted by `tests/placer_api.rs`); only the wall-clock
//! changes (`benches/placement.rs` reports the throughput gap).

use super::{FitRequest, Placer, PlacementPlan, PlacementRequest};
use crate::coordinator::{select_action, DreamShard, TrainCfg, Variant};
use crate::mdp::PlacementState;
use crate::runtime::{to_f32_vec, Runtime, TensorF32};
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::{Context, Result};
use crate::util::Rng;

const NAME: &str = "dreamshard";

/// The DreamShard agent as a [`Placer`]. Holds either a borrowed trained
/// agent ([`DreamShardPlacer::from_agent`]) or an owned one created by
/// [`Placer::fit`] / lazily on first use ([`DreamShardPlacer::untrained`]).
pub struct DreamShardPlacer<'a> {
    rt: &'a Runtime,
    owned: Option<DreamShard>,
    borrowed: Option<&'a DreamShard>,
    cfg: TrainCfg,
    seed: u64,
}

impl<'a> DreamShardPlacer<'a> {
    /// An unfitted agent; [`Placer::place`] before [`Placer::fit`] lazily
    /// initializes random weights (deterministic, useful for benches).
    pub fn untrained(rt: &'a Runtime) -> Self {
        DreamShardPlacer { rt, owned: None, borrowed: None, cfg: TrainCfg::default(), seed: 0 }
    }

    /// Wrap an already-trained agent.
    pub fn from_agent(rt: &'a Runtime, agent: &'a DreamShard) -> Self {
        DreamShardPlacer { rt, owned: None, borrowed: Some(agent), cfg: TrainCfg::default(), seed: 0 }
    }

    /// Configuration for the lazily-created untrained agent (first
    /// placement without a prior [`Placer::fit`]). `fit` itself always
    /// uses [`FitRequest::cfg`].
    pub fn with_cfg(mut self, cfg: TrainCfg) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    fn agent(&self) -> Option<&DreamShard> {
        match self.borrowed {
            Some(a) => Some(a),
            None => self.owned.as_ref(),
        }
    }

    fn ensure_agent(&mut self, n_devices: usize) -> Result<()> {
        if self.agent().is_none() {
            let mut rng = Rng::new(self.seed).fork(0xD5);
            self.owned = Some(DreamShard::new(self.rt, n_devices, self.cfg.clone(), &mut rng)?);
        }
        Ok(())
    }

    /// The artifact variant serving one task: the agent's own (matching
    /// sequential `DreamShard::place` exactly) whenever the task fits its
    /// device capacity, else the smallest variant that does (how Table 13
    /// plans 128 devices with an agent trained at 8).
    fn variant_for(&self, agent: &DreamShard, n_devices: usize) -> Result<Variant> {
        if n_devices <= agent.var.d {
            Ok(agent.var.clone())
        } else {
            Variant::for_devices(self.rt, n_devices)
        }
    }

    /// Plan one group of requests that share an artifact variant, in
    /// chunks of up to `E` lockstep lanes. Within a chunk every MDP step
    /// costs exactly one fused backend call, shared by all lanes.
    fn plan_batch(
        &self,
        agent: &DreamShard,
        var: &Variant,
        reqs: &[&PlacementRequest<'_>],
    ) -> Result<Vec<PlacementPlan>> {
        let (d, s) = (var.d, var.s);
        let f = NUM_FEATURES;
        let Some((lanes, step_name)) = var.mdp_step_for(reqs.len()).cloned() else {
            // no fused artifact lowered for this variant: plan one
            // episode at a time through the classic path (which honors
            // the request's slot cap just like the lane-batched path)
            let mut plans = Vec::with_capacity(reqs.len());
            for &r in reqs {
                let mut rng = Rng::new(0); // unused by argmax
                let ep = agent
                    .run_episodes_var(
                        self.rt, r.sim, r.ds, r.task, 1, false, false, &mut rng, var, false,
                        r.max_slots,
                    )?
                    .remove(0);
                plans.push(PlacementPlan::new(r, ep.placement, NAME));
            }
            return Ok(plans);
        };
        // chunk-batched table ordering: one concatenated [N, F]
        // table_cost pass for the WHOLE group (split only on the
        // artifact's row cap) instead of one backend call per task —
        // hoisted above the lane chunking so the ordering budget is
        // ceil(total_tables / N_cap) however the lanes split
        let jobs: Vec<(&Dataset, &Task)> = reqs.iter().map(|r| (r.ds, r.task)).collect();
        let mut orders = agent.order_tables_batch(self.rt, &jobs)?.into_iter();
        let mut plans = Vec::with_capacity(reqs.len());
        for chunk in reqs.chunks(lanes) {
            let n = chunk.len();
            let mut states: Vec<PlacementState> = Vec::with_capacity(n);
            for &r in chunk {
                let order = orders.next().expect("one order per request");
                states.push(PlacementState::new(r.ds, r.task, order, s.min(r.max_slots)));
            }
            let steps = chunk.iter().map(|r| r.task.n_tables()).max().unwrap_or(0);
            let mut rng = Rng::new(0); // unused by argmax
            for _t in 0..steps {
                let mut feats = TensorF32::zeros(&[lanes, d, s, f]);
                let mut mask = TensorF32::zeros(&[lanes, d, s]);
                let mut dmask = TensorF32::zeros(&[lanes, d]);
                let mut cur = TensorF32::zeros(&[lanes, f]);
                let mut legal_t = TensorF32::zeros(&[lanes, d]);
                // per-lane legal mask; None once a (shorter) task finished
                let mut legal: Vec<Option<Vec<bool>>> = Vec::with_capacity(n);
                for (lane, st) in states.iter().enumerate() {
                    st.fill_feats(lane, d, s, &mut feats, &mut mask, &mut dmask)?;
                    if st.done() {
                        legal.push(None); // lane logits computed but unused
                        continue;
                    }
                    cur.set_row(&[lane, 0], &st.current_features());
                    let lg = st.legal(chunk[lane].sim);
                    for (dev, &ok) in lg.iter().enumerate() {
                        legal_t.set(&[lane, dev], if ok { 1.0 } else { 0.0 });
                    }
                    legal.push(Some(lg));
                }
                // the single fused backend call all lanes share this step
                let out = agent
                    .run_fused_step(self.rt, &step_name, &feats, &mask, &dmask, &cur, &legal_t)?;
                let logits = to_f32_vec(&out[0], lanes * d)?;
                for (lane, st) in states.iter_mut().enumerate() {
                    let Some(lg) = &legal[lane] else { continue };
                    // dead end (memory + slot caps exhausted everywhere):
                    // least-loaded device with a free slot, as in training
                    let a = if lg.iter().any(|&ok| ok) {
                        select_action(&logits[lane * d..(lane + 1) * d], lg, false, &mut rng)
                    } else {
                        st.fallback_device().with_context(|| {
                            format!("lane {lane}: no device can take the table")
                        })?
                    };
                    st.apply(a);
                }
            }
            for (st, &r) in states.iter().zip(chunk.iter()) {
                plans.push(PlacementPlan::new(r, st.placement.clone(), NAME));
            }
        }
        Ok(plans)
    }
}

impl Placer for DreamShardPlacer<'_> {
    fn name(&self) -> &str {
        NAME
    }

    fn needs_fit(&self) -> bool {
        self.agent().is_none()
    }

    fn fit(&mut self, req: &FitRequest<'_>) -> Result<()> {
        let d = req
            .tasks
            .iter()
            .map(|t| t.n_devices)
            .max()
            .context("dreamshard fit requires at least one task")?;
        let mut rng = Rng::new(req.seed);
        let mut agent = DreamShard::new(self.rt, d, req.cfg.clone(), &mut rng)?;
        agent.train(self.rt, req.sim, req.ds, req.tasks, &mut rng)?;
        if req.verbose {
            for st in &agent.log {
                eprintln!(
                    "  iter {}: collected {:.1} ms, cost-loss {:.3}, policy-loss {:.4} ({:.1}s)",
                    st.iter, st.collected_mean_cost, st.cost_loss, st.policy_loss, st.wall_s
                );
            }
        }
        self.borrowed = None;
        self.owned = Some(agent);
        Ok(())
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let mut plans = self.place_many(std::slice::from_ref(req))?;
        Ok(plans.remove(0))
    }

    /// The variant [`DreamShardPlacer::place_many`] would group this
    /// request under — the agent's own variant whenever the task fits it
    /// (so a scheduler can lane-share mixed device counts), else the
    /// smallest one that serves the task. `None` before the agent exists
    /// (untrained placer prior to its first fit/place).
    fn serving_variant(&self, req: &PlacementRequest<'_>) -> Option<(usize, usize)> {
        let agent = self.agent()?;
        let var = self.variant_for(agent, req.task.n_devices).ok()?;
        Some((var.d, var.s))
    }

    fn place_many(&mut self, reqs: &[PlacementRequest<'_>]) -> Result<Vec<PlacementPlan>> {
        if reqs.is_empty() {
            return Ok(vec![]);
        }
        let max_dev = reqs.iter().map(|r| r.task.n_devices).max().unwrap();
        self.ensure_agent(max_dev)?;
        let agent = self.agent().expect("agent ensured above");
        // group lanes by serving variant: tasks with different device
        // counts share the agent's variant (masking covers the gap), so
        // heterogeneous batches still fill the same lanes
        let mut groups: Vec<(Variant, Vec<usize>)> = vec![];
        for (i, r) in reqs.iter().enumerate() {
            let var = self.variant_for(agent, r.task.n_devices)?;
            match groups.iter_mut().find(|(v, _)| v.d == var.d && v.s == var.s) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((var, vec![i])),
            }
        }
        let mut plans: Vec<Option<PlacementPlan>> = (0..reqs.len()).map(|_| None).collect();
        for (var, idxs) in &groups {
            let group: Vec<&PlacementRequest<'_>> = idxs.iter().map(|&i| &reqs[i]).collect();
            let got = self.plan_batch(agent, var, &group)?;
            for (&i, plan) in idxs.iter().zip(got.into_iter()) {
                plans[i] = Some(plan);
            }
        }
        Ok(plans.into_iter().map(|p| p.expect("every request planned")).collect())
    }
}
