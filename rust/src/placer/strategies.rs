//! Non-fused [`Placer`] implementations: the random baseline, the four
//! greedy human experts, and the RNN-based RL baseline.

use std::sync::Arc;

use super::{FitRequest, Placer, PlacementPlan, PlacementRequest};
use crate::bail;
use crate::baselines::{greedy_placement_capped, random_placement_capped, Expert};
use crate::coordinator::RnnBaseline;
use crate::runtime::Runtime;
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Uniform-random legal placement. Stateful: repeated [`Placer::place`]
/// calls on the same request draw different placements from one
/// deterministic stream (seeded at construction).
pub struct RandomPlacer {
    rng: Rng,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> Self {
        RandomPlacer { rng: Rng::new(seed).fork(0xBAD) }
    }
}

impl Placer for RandomPlacer {
    fn name(&self) -> &str {
        "random"
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let p = random_placement_capped(req.ds, req.task, req.sim, &mut self.rng, req.max_slots);
        Ok(PlacementPlan::new(req, p, "random"))
    }
}

/// One greedy human-expert strategy (cost-sort + least-loaded packing).
pub struct GreedyPlacer {
    expert: Expert,
    /// `greedy:<key>` — derived from [`Expert::key`], the single source
    /// of the registry naming.
    name: String,
}

impl GreedyPlacer {
    pub fn new(expert: Expert) -> Self {
        GreedyPlacer { expert, name: format!("greedy:{}", expert.key()) }
    }
}

impl Placer for GreedyPlacer {
    fn name(&self) -> &str {
        &self.name
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let p = greedy_placement_capped(req.ds, req.task, req.sim, self.expert, req.max_slots);
        Ok(PlacementPlan::new(req, p, &self.name))
    }
}

/// The RNN-based RL baseline (Mirhoseini et al. 2017, section D.2) behind
/// the facade. Learned and device-count-specific: [`Placer::fit`] trains
/// a controller for the fit tasks' device count, and planning a task with
/// any other device count fails (the architecture cannot generalize —
/// that limitation is the point of the baseline).
pub struct RnnPlacer {
    rt: Arc<Runtime>,
    model: Option<RnnBaseline>,
    seed: u64,
}

impl RnnPlacer {
    /// An unfitted controller; [`Placer::place`] before [`Placer::fit`]
    /// lazily initializes random weights (useful for smoke tests only).
    pub fn untrained(rt: &Arc<Runtime>) -> Self {
        RnnPlacer { rt: Arc::clone(rt), model: None, seed: 0 }
    }

    /// Wrap an already-trained controller.
    pub fn from_model(rt: &Arc<Runtime>, model: RnnBaseline) -> Self {
        RnnPlacer { rt: Arc::clone(rt), model: Some(model), seed: 0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Placer for RnnPlacer {
    fn name(&self) -> &str {
        "rnn"
    }

    fn needs_fit(&self) -> bool {
        self.model.is_none()
    }

    fn fit(&mut self, req: &FitRequest<'_>) -> Result<()> {
        let d = req
            .tasks
            .iter()
            .map(|t| t.n_devices)
            .max()
            .context("rnn fit requires at least one task")?;
        let mut rng = Rng::new(req.seed);
        let mut model = RnnBaseline::new(&self.rt, d, &mut rng)?;
        // same update budget the paper grants DreamShard's policy stage;
        // one-update steps keep the rng stream identical to a single
        // train(updates) call while allowing progress logging
        let updates = req.cfg.n_iterations * req.cfg.n_rl;
        for u in 0..updates {
            model.train(&self.rt, req.sim, req.ds, req.tasks, 1, &mut rng)?;
            if req.verbose && ((u + 1) % 10 == 0 || u + 1 == updates) {
                eprintln!("  rnn: REINFORCE update {}/{updates}", u + 1);
            }
        }
        self.model = Some(model);
        Ok(())
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        if self.model.is_none() {
            let mut rng = Rng::new(self.seed).fork(0x9A11);
            self.model = Some(RnnBaseline::new(&self.rt, req.task.n_devices, &mut rng)?);
        }
        let model = self.model.as_ref().unwrap();
        if model.d != req.task.n_devices {
            bail!(
                "rnn placer was fitted for {} devices but the task has {} \
                 (the RNN architecture cannot generalize across device counts)",
                model.d,
                req.task.n_devices
            );
        }
        let p = model.place_with_slots(&self.rt, req.sim, req.ds, req.task, req.max_slots)?;
        Ok(PlacementPlan::new(req, p, "rnn"))
    }
}
