//! Non-fused [`Placer`] implementations: the random baseline, the four
//! greedy human experts, and the RNN-based RL baseline.

use std::sync::Arc;

use super::{FitRequest, Placer, PlacementPlan, PlacementRequest};
use crate::bail;
use crate::baselines::{greedy_placement_capped, random_placement_capped, Expert};
use crate::coordinator::RnnBaseline;
use crate::runtime::Runtime;
use crate::tables::Table;
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Uniform-random legal placement. Stateful: repeated [`Placer::place`]
/// calls on the same request draw different placements from one
/// deterministic stream (seeded at construction).
pub struct RandomPlacer {
    rng: Rng,
}

impl RandomPlacer {
    pub fn new(seed: u64) -> Self {
        RandomPlacer { rng: Rng::new(seed).fork(0xBAD) }
    }
}

impl Placer for RandomPlacer {
    fn name(&self) -> &str {
        "random"
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let p = random_placement_capped(req.ds, req.task, req.sim, &mut self.rng, req.max_slots);
        Ok(PlacementPlan::new(req, p, "random"))
    }
}

/// One greedy human-expert strategy (cost-sort + least-loaded packing).
pub struct GreedyPlacer {
    expert: Expert,
    /// `greedy:<key>` — derived from [`Expert::key`], the single source
    /// of the registry naming.
    name: String,
}

impl GreedyPlacer {
    pub fn new(expert: Expert) -> Self {
        GreedyPlacer { expert, name: format!("greedy:{}", expert.key()) }
    }
}

impl Placer for GreedyPlacer {
    fn name(&self) -> &str {
        &self.name
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let p = greedy_placement_capped(req.ds, req.task, req.sim, self.expert, req.max_slots);
        Ok(PlacementPlan::new(req, p, &self.name))
    }

    /// Migration-aware local search: keep every table where it was, evict
    /// only what feasibility demands, re-home the evicted/unplaced tables
    /// greedily, then move the minimum set of further tables that
    /// restores expert-load balance — each discretionary move debited
    /// against [`PlacementRequest::migration`].
    fn replace(&mut self, prev: &PlacementPlan, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        if prev.is_vacant() {
            // no prior constraint: bit-identical to a cold start
            return self.place(req);
        }
        let next = greedy_replace(req, self.expert, &prev.placement)?;
        let eval = req.sim.evaluate_migration(req.ds, req.task, &prev.placement, &next);
        Ok(PlacementPlan { placement: next, eval, strategy: self.name.clone() })
    }
}

/// The greedy family's incremental re-placement. Three budget-exempt
/// phases (keep, evict-to-feasibility, re-home the homeless) and one
/// budgeted phase (balance-restoring single-table moves).
fn greedy_replace(req: &PlacementRequest<'_>, expert: Expert, prev: &[usize]) -> Result<Vec<usize>> {
    let (ds, task) = (req.ds, req.task);
    let n = task.n_tables();
    if prev.len() != n {
        bail!("replace: prev plan covers {} tables but the task has {n}", prev.len());
    }
    let d = task.n_devices;
    let table = |i: usize| -> &Table { &ds.tables[task.table_ids[i]] };
    let costs: Vec<f64> = (0..n).map(|i| expert.cost(table(i))).collect();

    // 1) keep every assignment the perturbed task can still express;
    //    tables on lost devices (or never placed) are forced moves
    let mut next: Vec<usize> = prev.iter().map(|&p| if p < d { p } else { usize::MAX }).collect();
    let mut forced: Vec<bool> = prev.iter().map(|&p| p >= d).collect();
    let mut groups: Vec<Vec<usize>> = vec![vec![]; d];
    for i in 0..n {
        if next[i] != usize::MAX {
            groups[next[i]].push(i);
        }
    }

    // 2) evict until feasible (budget-exempt: the caps leave no choice).
    //    Big anchors stay; the cheapest tables leave first.
    let cap = req.sim.cfg.mem_cap_gb as f64;
    for dev in 0..d {
        let mut kept = std::mem::take(&mut groups[dev]);
        kept.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]));
        let mem = |ix: &[usize]| -> f64 {
            ix.iter().map(|&i| table(i).size_gb() as f64 * 3.0).sum()
        };
        while kept.len() > req.max_slots || mem(&kept) > cap {
            let Some(evicted) = kept.pop() else { break };
            next[evicted] = usize::MAX;
            forced[evicted] = true;
        }
        groups[dev] = kept;
    }

    // 3) re-home the homeless, biggest expert cost first, onto the
    //    lowest-load legal device (the same packing + fallbacks as
    //    `greedy_placement_capped`)
    let mut load: Vec<f64> = groups
        .iter()
        .map(|g| g.iter().map(|&i| costs[i]).sum())
        .collect();
    let mut pending: Vec<usize> = (0..n).filter(|&i| next[i] == usize::MAX).collect();
    pending.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]));
    for i in pending {
        let t = table(i);
        let mut best: Option<usize> = None;
        for dev in 0..d {
            let refs: Vec<&Table> = groups[dev].iter().map(|&j| table(j)).collect();
            if req.device_can_take(&refs, t) && best.map_or(true, |b| load[dev] < load[b]) {
                best = Some(dev);
            }
        }
        let dev = best
            .or_else(|| {
                (0..d)
                    .filter(|&dev| groups[dev].len() < req.max_slots)
                    .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            })
            .or_else(|| (0..d).min_by(|&a, &b| load[a].total_cmp(&load[b])))
            .context("replace: task has no devices to re-home onto")?;
        next[i] = dev;
        groups[dev].push(i);
        load[dev] += costs[i];
    }

    // 4) budgeted local search: shift one table at a time off the
    //    heaviest device onto the lightest legal one, while it strictly
    //    improves the pair's max load. Discretionary moves (a table
    //    leaving its still-valid previous device) debit the budget;
    //    returning one to its previous device refunds it.
    let budget = req.migration;
    let mut disc_count = 0usize;
    let mut disc_ms = 0.0f64;
    for _ in 0..4 * n.max(1) {
        let Some(hi) = (0..d).max_by(|&a, &b| load[a].total_cmp(&load[b])) else { break };
        // heaviest tables first: the biggest single improvement
        let mut cands = groups[hi].clone();
        cands.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]));
        let mut committed = false;
        'cand: for i in cands {
            let t = table(i);
            let dev = match (0..d)
                .filter(|&dev| dev != hi)
                .filter(|&dev| {
                    let refs: Vec<&Table> = groups[dev].iter().map(|&j| table(j)).collect();
                    req.device_can_take(&refs, t)
                })
                .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            {
                Some(dev) => dev,
                None => continue 'cand,
            };
            if load[dev] + costs[i] >= load[hi] {
                continue; // no strict improvement left with this table
            }
            if !forced[i] {
                // budget the end-state deviation from prev, not the hop
                let was = next[i] != prev[i];
                let now = dev != prev[i];
                let count = disc_count + usize::from(now) - usize::from(was);
                let ms = disc_ms
                    + if now { req.sim.transfer_ms(t) } else { 0.0 }
                    - if was { req.sim.transfer_ms(t) } else { 0.0 };
                if count > budget.max_moves || ms > budget.max_migration_ms {
                    continue;
                }
                disc_count = count;
                disc_ms = ms;
            }
            groups[hi].retain(|&j| j != i);
            groups[dev].push(i);
            load[hi] -= costs[i];
            load[dev] += costs[i];
            next[i] = dev;
            committed = true;
            break;
        }
        if !committed {
            break;
        }
    }
    Ok(next)
}

/// The RNN-based RL baseline (Mirhoseini et al. 2017, section D.2) behind
/// the facade. Learned and device-count-specific: [`Placer::fit`] trains
/// a controller for the fit tasks' device count, and planning a task with
/// any other device count fails (the architecture cannot generalize —
/// that limitation is the point of the baseline).
pub struct RnnPlacer {
    rt: Arc<Runtime>,
    model: Option<RnnBaseline>,
    seed: u64,
}

impl RnnPlacer {
    /// An unfitted controller; [`Placer::place`] before [`Placer::fit`]
    /// lazily initializes random weights (useful for smoke tests only).
    pub fn untrained(rt: &Arc<Runtime>) -> Self {
        RnnPlacer { rt: Arc::clone(rt), model: None, seed: 0 }
    }

    /// Wrap an already-trained controller.
    pub fn from_model(rt: &Arc<Runtime>, model: RnnBaseline) -> Self {
        RnnPlacer { rt: Arc::clone(rt), model: Some(model), seed: 0 }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl Placer for RnnPlacer {
    fn name(&self) -> &str {
        "rnn"
    }

    fn needs_fit(&self) -> bool {
        self.model.is_none()
    }

    fn fit(&mut self, req: &FitRequest<'_>) -> Result<()> {
        let d = req
            .tasks
            .iter()
            .map(|t| t.n_devices)
            .max()
            .context("rnn fit requires at least one task")?;
        let mut rng = Rng::new(req.seed);
        let mut model = RnnBaseline::new(&self.rt, d, &mut rng)?;
        // same update budget the paper grants DreamShard's policy stage;
        // one-update steps keep the rng stream identical to a single
        // train(updates) call while allowing progress logging
        let updates = req.cfg.n_iterations * req.cfg.n_rl;
        for u in 0..updates {
            model.train(&self.rt, req.sim, req.ds, req.tasks, 1, &mut rng)?;
            if req.verbose && ((u + 1) % 10 == 0 || u + 1 == updates) {
                eprintln!("  rnn: REINFORCE update {}/{updates}", u + 1);
            }
        }
        self.model = Some(model);
        Ok(())
    }

    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        if self.model.is_none() {
            let mut rng = Rng::new(self.seed).fork(0x9A11);
            self.model = Some(RnnBaseline::new(&self.rt, req.task.n_devices, &mut rng)?);
        }
        let model = self.model.as_ref().context("rnn model is initialized above")?;
        if model.d != req.task.n_devices {
            bail!(
                "rnn placer was fitted for {} devices but the task has {} \
                 (the RNN architecture cannot generalize across device counts)",
                model.d,
                req.task.n_devices
            );
        }
        let p = model.place_with_slots(&self.rt, req.sim, req.ds, req.task, req.max_slots)?;
        Ok(PlacementPlan::new(req, p, "rnn"))
    }
}
