//! The crate's planning facade: **one trait over every placement
//! strategy**, a string registry, lane-batched multi-task planning, and
//! resumable planning sessions for pipelined serving.
//!
//! DreamShard's core claim is a single policy that generalizes across
//! placement tasks; this module gives the crate a matching shape. Every
//! strategy — the four greedy experts, random, the RNN baseline, and the
//! trained DreamShard agent — implements [`Placer`]:
//!
//! * a [`PlacementRequest`] bundles what a task needs planned (dataset +
//!   task + simulator + legality knobs);
//! * [`Placer::place`] returns a [`PlacementPlan`] (device assignment,
//!   its simulated [`Evaluation`], and the strategy name as provenance);
//! * [`Placer::place_many`] plans a batch. The default is a sequential
//!   loop; [`DreamShardPlacer`] overrides it to run up to `E` requests
//!   *concurrently through one fused backend call per MDP step* — the
//!   feature tensors already carry an episode dimension, so a batch of
//!   heterogeneous tasks fills lanes instead of looping whole episodes;
//! * [`Placer::open_session`] opens the same lane-batched planning as a
//!   resumable [`PlanSession`]: the caller drives each MDP step's
//!   CPU feature-fill and asynchronous backend dispatch explicitly, so a
//!   pipelined drain can fill chunk k+1's tensors while chunk k's fused
//!   call executes on the runtime worker pool.
//!
//! Placers share the runtime as `Arc<Runtime>` — no borrowed lifetimes —
//! so they (and the services wrapping them) move freely across threads.
//! Strategies are selected by name through [`by_name`]:
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, Placer, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(100, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let task = sample_tasks(&pool, 10, 4, 1, 2).remove(0);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let mut placer = placer::by_name(&rt, "greedy:dim").unwrap();
//! let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim).unwrap();
//! let plan = placer.place(&req).unwrap();
//! assert_eq!(plan.placement.len(), 10);
//! assert_eq!(plan.strategy, "greedy:dim");
//! ```
//!
//! Learned strategies (`"dreamshard"`, `"rnn"`) come out of the registry
//! untrained: [`Placer::needs_fit`] reports that, and [`Placer::fit`]
//! trains them on a task pool. Non-learned strategies ignore `fit`, which
//! is how the CLI's `place --policy greedy:dim` skips training entirely.

mod dreamshard;
mod strategies;

pub use self::dreamshard::{DreamShardPlacer, DreamShardSession};
pub use self::strategies::{GreedyPlacer, RandomPlacer, RnnPlacer};

use std::sync::Arc;

use crate::baselines::ALL_EXPERTS;
use crate::coordinator::{TrainCfg, Variant};
use crate::err;
use crate::runtime::{Runtime, Ticket, Value};
use crate::sim::{Evaluation, Simulator};
use crate::tables::{Dataset, Table, Task};
use crate::util::error::Result;

/// Everything a strategy needs to plan one task: the dataset the task's
/// table ids index into, the task itself, the simulator that defines
/// memory legality (and evaluates the finished plan), and the legality
/// knobs shared by all strategies.
#[derive(Clone, Copy, Debug)]
pub struct PlacementRequest<'a> {
    pub ds: &'a Dataset,
    pub task: &'a Task,
    pub sim: &'a Simulator,
    /// Per-device slot cap (the MDP's `max_slots` / the artifact's baked
    /// `S`). Every strategy routed through this request obeys it, so a
    /// baseline can no longer emit a placement `fill_feats` would reject.
    pub max_slots: usize,
    /// How much a [`Placer::replace`] answering this request may migrate.
    /// Ignored by [`Placer::place`] (a cold start moves nothing).
    pub migration: MigrationBudget,
}

impl<'a> PlacementRequest<'a> {
    /// A request with no slot cap (memory legality only).
    pub fn new(ds: &'a Dataset, task: &'a Task, sim: &'a Simulator) -> Self {
        PlacementRequest { ds, task, sim, max_slots: usize::MAX, migration: MigrationBudget::unlimited() }
    }

    /// Cap the number of tables any single device may hold.
    pub fn with_max_slots(mut self, max_slots: usize) -> Self {
        self.max_slots = max_slots;
        self
    }

    /// Bound what a [`Placer::replace`] answering this request may move.
    pub fn with_migration(mut self, migration: MigrationBudget) -> Self {
        self.migration = migration;
        self
    }

    /// A request whose slot cap matches the artifact variant that would
    /// serve this task's device count — the cap learned strategies are
    /// subject to anyway, now applied to every strategy uniformly.
    pub fn for_runtime(
        rt: &Runtime,
        ds: &'a Dataset,
        task: &'a Task,
        sim: &'a Simulator,
    ) -> Result<Self> {
        let var = Variant::for_devices(rt, task.n_devices)?;
        Ok(PlacementRequest::new(ds, task, sim).with_max_slots(var.s))
    }

    /// The shared legality check: may `table` join a device currently
    /// holding `group`? (Free slot + memory cap.)
    pub fn device_can_take(&self, group: &[&Table], table: &Table) -> bool {
        group.len() < self.max_slots && self.sim.fits(group, table)
    }
}

/// Cap on what one [`Placer::replace`] call may migrate. The budget
/// bounds *discretionary* moves only: a table whose previous device is
/// gone (or that was never placed) has to land somewhere, and evictions
/// that restore feasibility (memory/slot caps after a perturbation) are
/// likewise exempt — a budget of zero still yields a legal plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MigrationBudget {
    /// Max tables moved off a still-valid previous device.
    pub max_moves: usize,
    /// Max total migration time spent on discretionary moves, in ms.
    pub max_migration_ms: f64,
}

impl MigrationBudget {
    /// No limit on either axis (the [`Default`]).
    pub fn unlimited() -> Self {
        MigrationBudget { max_moves: usize::MAX, max_migration_ms: f64::INFINITY }
    }

    /// Bound the number of moved tables only.
    pub fn moves(max_moves: usize) -> Self {
        MigrationBudget { max_moves, max_migration_ms: f64::INFINITY }
    }

    pub fn is_unlimited(&self) -> bool {
        self.max_moves == usize::MAX && self.max_migration_ms.is_infinite()
    }
}

impl Default for MigrationBudget {
    fn default() -> Self {
        Self::unlimited()
    }
}

/// A finished plan: the device assignment (`placement[i]` is the device
/// of `task.table_ids[i]`), its simulated evaluation, and which strategy
/// produced it.
#[derive(Clone, Debug)]
pub struct PlacementPlan {
    pub placement: Vec<usize>,
    pub eval: Evaluation,
    /// Provenance: the registry name of the producing strategy.
    pub strategy: String,
}

impl PlacementPlan {
    /// Evaluate a complete placement into a plan.
    pub fn new(req: &PlacementRequest<'_>, placement: Vec<usize>, strategy: &str) -> Self {
        let eval = req.sim.evaluate(req.ds, req.task, &placement);
        PlacementPlan { placement, eval, strategy: strategy.to_string() }
    }

    /// Wrap a placement that came from outside the facade (recovered
    /// state, a hand-written assignment) as the `prev` argument of
    /// [`Placer::replace`] — no evaluation attached, none needed.
    pub fn prior(placement: Vec<usize>, strategy: &str) -> Self {
        PlacementPlan { placement, eval: Evaluation::default(), strategy: strategy.to_string() }
    }

    /// The "no prior placement" plan for a task: every table unplaced
    /// (`usize::MAX`). As `prev`, it makes [`Placer::replace`] behave
    /// exactly like [`Placer::place`].
    pub fn no_prior(task: &Task) -> Self {
        Self::prior(vec![usize::MAX; task.n_tables()], "none")
    }

    /// Does this plan place nothing (empty, or every entry unplaced)?
    /// Such a plan as `prev` carries no migration constraint at all.
    pub fn is_vacant(&self) -> bool {
        self.placement.iter().all(|&d| d == usize::MAX)
    }
}

/// Training inputs for learned placers ([`Placer::fit`]).
pub struct FitRequest<'a> {
    pub ds: &'a Dataset,
    pub tasks: &'a [Task],
    pub sim: &'a Simulator,
    pub cfg: TrainCfg,
    pub seed: u64,
    /// Log per-iteration training statistics to stderr.
    pub verbose: bool,
}

/// A resumable lane-chunk planning session ([`Placer::open_session`]):
/// one chunk of requests advanced one fused MDP step at a time, with the
/// CPU half (feature fill, action selection) and the backend half
/// (the fused call, dispatched onto the runtime worker pool) split apart
/// so a caller can overlap them across chunks.
///
/// Protocol: call [`PlanSession::submit_step`]; while its [`Ticket`] is
/// in flight, do other CPU work (fill another chunk's tensors); then
/// [`PlanSession::apply_step`] with the joined outputs; repeat until
/// `submit_step` returns `Ok(None)`, then [`PlanSession::finish`]. The
/// session runs the same MDP with the same artifacts as a blocking
/// [`Placer::place_many`] over the same requests — plans are
/// bit-identical, only the wait is moved.
pub trait PlanSession<'a> {
    /// Fill the next MDP step's feature tensors (CPU) and dispatch the
    /// fused backend call. `Ok(None)` once every lane has finished.
    fn submit_step(&mut self) -> Result<Option<Ticket>>;

    /// Apply the joined outputs of the ticket returned by the matching
    /// [`PlanSession::submit_step`] to the lanes (CPU).
    fn apply_step(&mut self, out: Vec<Value>) -> Result<()>;

    /// Extract the finished plans, in request order. Errors if steps
    /// remain (a ticket was submitted but never applied).
    fn finish(self: Box<Self>) -> Result<Vec<PlacementPlan>>;
}

/// One placement strategy behind a stable task -> plan interface.
///
/// `Send` is a supertrait: placers (and the [`crate::serve::PlanService`]
/// queues wrapping them) move into per-shard drain threads — the
/// [`crate::serve::ShardedFrontEnd`] drains every serving variant's
/// service concurrently against the shared runtime worker pool — so every
/// implementation, including test fixtures, must be transferable across
/// threads. All state a placer holds is either owned plain data or an
/// `Arc` onto the thread-safe runtime/agent, so in practice this costs
/// implementations nothing.
pub trait Placer: Send {
    /// Registry name (`by_name(rt, placer.name())` rebuilds it).
    fn name(&self) -> &str;

    /// Whether this placer still needs [`Placer::fit`] before its plans
    /// are meaningful. Non-learned strategies always return `false`.
    fn needs_fit(&self) -> bool {
        false
    }

    /// Train the underlying model. A no-op for non-learned strategies.
    fn fit(&mut self, _req: &FitRequest<'_>) -> Result<()> {
        Ok(())
    }

    /// Plan one task.
    fn place(&mut self, req: &PlacementRequest<'_>) -> Result<PlacementPlan>;

    /// Plan a batch of tasks. The default loops [`Placer::place`];
    /// batch-capable placers override it (DreamShard lane-batches up to
    /// `E` requests through one backend call per MDP step).
    fn place_many(&mut self, reqs: &[PlacementRequest<'_>]) -> Result<Vec<PlacementPlan>> {
        reqs.iter().map(|r| self.place(r)).collect()
    }

    /// Re-plan `req` against a previous placement. `prev.placement[i]` is
    /// the previous device of `task.table_ids[i]`: `usize::MAX` marks a
    /// table with no prior home, and a device index the task no longer
    /// has (>= `n_devices`, e.g. after a device loss) marks a *forced*
    /// move. The returned plan's evaluation carries the migration charge
    /// ([`Evaluation::migration_ms`] / `moved_tables`).
    ///
    /// The default plans from scratch and reports the full migration
    /// cost — always correct, but oblivious to
    /// [`PlacementRequest::migration`]. Strategies with real incremental
    /// paths override it and honor the budget: the greedy family runs a
    /// migration-aware local search, DreamShard warm-starts its
    /// lane-batched MDP re-rollout. With a vacant `prev`
    /// ([`PlacementPlan::is_vacant`]) every implementation behaves
    /// exactly like [`Placer::place`].
    fn replace(&mut self, prev: &PlacementPlan, req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
        let mut plan = self.place(req)?;
        plan.eval = req.sim.evaluate_migration(req.ds, req.task, &prev.placement, &plan.placement);
        Ok(plan)
    }

    /// Re-plan a batch: `prevs[i]` pairs with `reqs[i]`. The default
    /// loops [`Placer::replace`]; DreamShard overrides it to warm-start
    /// its lane-batched rollout with the same fused-call budget shape as
    /// [`Placer::place_many`].
    fn replace_many(
        &mut self,
        prevs: &[PlacementPlan],
        reqs: &[PlacementRequest<'_>],
    ) -> Result<Vec<PlacementPlan>> {
        if prevs.len() != reqs.len() {
            return Err(err!("replace_many: {} prev plans for {} requests", prevs.len(), reqs.len()));
        }
        prevs.iter().zip(reqs).map(|(p, r)| self.replace(p, r)).collect()
    }

    /// Scheduling hint for batch-capable placers: the artifact variant
    /// `(D, S)` this placer would serve `req` with, when it knows.
    /// `None` (the default) means the scheduler should fall back to the
    /// smallest lowered variant for the request's device count.
    /// DreamShard reports its agent's own variant for any device count
    /// the agent covers, so a serving queue can batch heterogeneous
    /// 2/4/8-device traffic into one lane-chunk instead of splitting it
    /// per device count.
    fn serving_variant(&self, _req: &PlacementRequest<'_>) -> Option<(usize, usize)> {
        None
    }

    /// Routing-time warm-up for lazily-initializing placers: create
    /// whatever state [`Placer::serving_variant`] needs (DreamShard's
    /// agent) so a router can key this request *now*. This mirrors the
    /// drain-time key refresh [`crate::serve::PlanService`] performs —
    /// the service can re-key queued requests after its first drain
    /// engages a lazy placer, but a sharded front end cannot: a
    /// request's key decides which shard's queue it enters, and moving
    /// it between shards later would break per-shard FIFO order. So the
    /// router warms the placer *before* asking for the variant instead
    /// of re-keying after. The default is a no-op: placers with static
    /// variants (or none at all) have nothing to create.
    fn warm_variant(&mut self, _req: &PlacementRequest<'_>) -> Result<()> {
        Ok(())
    }

    /// Open a resumable [`PlanSession`] over one chunk of requests — the
    /// hook pipelined drains overlap chunks through. `Ok(None)` (the
    /// default) means this placer (or this particular request mix) only
    /// supports blocking [`Placer::place_many`], and the caller must fall
    /// back to it; that is never an error. DreamShard returns a session
    /// whenever the chunk shares one artifact variant with a fused step
    /// artifact and fits its lanes — exactly the chunks a variant-grouped
    /// serving drain produces.
    fn open_session<'a>(
        &mut self,
        _reqs: &[PlacementRequest<'a>],
    ) -> Result<Option<Box<dyn PlanSession<'a> + 'a>>> {
        Ok(None)
    }
}

/// Every name [`by_name`] accepts, in display order.
pub const PLACER_NAMES: &[&str] = &[
    "random",
    "greedy:size",
    "greedy:dim",
    "greedy:lookup",
    "greedy:size-lookup",
    "rnn",
    "dreamshard",
];

/// Build a placer from its registry name. Learned strategies come back
/// untrained (see [`Placer::needs_fit`] / [`Placer::fit`]); `rt` is the
/// shared runtime they execute on (learned placers keep an `Arc` clone).
/// Stochastic/lazy-init streams are seeded 0; use [`by_name_seeded`] to
/// control them.
pub fn by_name(rt: &Arc<Runtime>, name: &str) -> Result<Box<dyn Placer>> {
    by_name_seeded(rt, name, 0)
}

/// [`by_name`] with an explicit seed for the strategy's stochastic
/// stream (random draws, lazy weight init).
pub fn by_name_seeded(rt: &Arc<Runtime>, name: &str, seed: u64) -> Result<Box<dyn Placer>> {
    if let Some(key) = name.strip_prefix("greedy:") {
        let expert = ALL_EXPERTS
            .into_iter()
            .find(|e| e.key() == key)
            .ok_or_else(|| unknown_placer(name))?;
        return Ok(Box::new(GreedyPlacer::new(expert)));
    }
    match name {
        "random" => Ok(Box::new(RandomPlacer::new(seed))),
        "rnn" => Ok(Box::new(RnnPlacer::untrained(rt).with_seed(seed))),
        "dreamshard" => Ok(Box::new(DreamShardPlacer::untrained(rt).with_seed(seed))),
        _ => Err(unknown_placer(name)),
    }
}

fn unknown_placer(name: &str) -> crate::util::error::Error {
    err!("unknown placer `{name}`; known: {}", PLACER_NAMES.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;
    use crate::tables::{gen_dlrm, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 20, 4, 1, 3).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn by_name_round_trips_every_listed_placer() {
        let rt = Arc::new(Runtime::reference());
        for name in PLACER_NAMES {
            let p = by_name(&rt, name).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(p.name(), *name);
        }
    }

    #[test]
    fn by_name_rejects_unknown_names() {
        let rt = Arc::new(Runtime::reference());
        for bad in ["", "greedy", "greedy:", "greedy:bogus", "dream-shard", "RANDOM"] {
            let e = by_name(&rt, bad).err().unwrap_or_else(|| panic!("`{bad}` accepted"));
            assert!(e.to_string().contains("unknown placer"), "{bad}: {e}");
        }
    }

    #[test]
    fn learned_placers_need_fit_and_baselines_do_not() {
        let rt = Arc::new(Runtime::reference());
        for name in PLACER_NAMES {
            let p = by_name(&rt, name).unwrap();
            let learned = matches!(*name, "rnn" | "dreamshard");
            assert_eq!(p.needs_fit(), learned, "{name}");
        }
    }

    #[test]
    fn every_baseline_plans_through_the_trait() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::for_runtime(&rt, &ds, &task, &sim).unwrap();
        assert_eq!(req.max_slots, 48, "trainable-variant slot cap");
        for name in PLACER_NAMES {
            let mut p = by_name(&rt, name).unwrap();
            if p.needs_fit() {
                continue; // learned strategies are exercised in tests/placer_api.rs
            }
            let plan = p.place(&req).unwrap();
            assert_eq!(plan.placement.len(), task.n_tables(), "{name}");
            assert!(plan.placement.iter().all(|&d| d < task.n_devices), "{name}");
            assert!(plan.eval.latency > 0.0, "{name}");
            assert_eq!(plan.strategy, *name);
        }
    }

    #[test]
    fn seeded_random_placers_draw_differently() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        let p1 = by_name_seeded(&rt, "random", 1).unwrap().place(&req).unwrap();
        let p2 = by_name_seeded(&rt, "random", 2).unwrap().place(&req).unwrap();
        let p1b = by_name_seeded(&rt, "random", 1).unwrap().place(&req).unwrap();
        assert_eq!(p1.placement, p1b.placement, "same seed replays");
        assert_ne!(p1.placement, p2.placement, "different seeds draw differently");
    }

    #[test]
    fn place_many_default_covers_all_requests() {
        let rt = Arc::new(Runtime::reference());
        let (ds, _, sim) = setup();
        let (pool, _) = split_pools(&ds, 1);
        let tasks = sample_tasks(&pool, 15, 4, 4, 9);
        let reqs: Vec<PlacementRequest> =
            tasks.iter().map(|t| PlacementRequest::new(&ds, t, &sim)).collect();
        let mut p = by_name(&rt, "greedy:lookup").unwrap();
        let plans = p.place_many(&reqs).unwrap();
        assert_eq!(plans.len(), 4);
        for plan in &plans {
            assert_eq!(plan.placement.len(), 15);
        }
    }

    #[test]
    fn default_open_session_declines_gracefully() {
        // non-batch placers have no session path; the serving drain must
        // get a clean None (fall back to blocking), never an error
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        let mut p = by_name(&rt, "greedy:dim").unwrap();
        assert!(p.open_session(&[req]).unwrap().is_none());
    }

    #[test]
    fn warm_variant_lets_a_lazy_placer_name_its_variant() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup(); // 4-device task
        let req = PlacementRequest::new(&ds, &task, &sim);
        let mut p = by_name(&rt, "dreamshard").unwrap();
        assert_eq!(p.serving_variant(&req), None, "lazy agent: no variant before warm-up");
        p.warm_variant(&req).unwrap();
        assert_eq!(p.serving_variant(&req), Some((4, 48)), "warmed agent names its variant");
        // static-variant placers: warm-up is a no-op and never errors
        let mut g = by_name(&rt, "greedy:dim").unwrap();
        g.warm_variant(&req).unwrap();
        assert_eq!(g.serving_variant(&req), None);
    }

    #[test]
    fn migration_budget_defaults_to_unlimited() {
        assert!(MigrationBudget::default().is_unlimited());
        assert!(!MigrationBudget::moves(3).is_unlimited());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        assert!(req.migration.is_unlimited());
        let capped = req.with_migration(MigrationBudget::moves(2));
        assert_eq!(capped.migration.max_moves, 2);
    }

    #[test]
    fn default_replace_reports_full_migration_cost() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        let mut p = by_name(&rt, "random").unwrap();
        // a prior that disagrees with what random will draw almost surely
        let prev = PlacementPlan::prior(vec![0; task.n_tables()], "seed");
        let plan = p.replace(&prev, &req).unwrap();
        let moved =
            plan.placement.iter().zip(&prev.placement).filter(|(a, b)| a != b).count();
        assert_eq!(plan.eval.moved_tables, moved);
        assert!(moved > 0, "random vs all-on-0 should differ");
        assert!(plan.eval.migration_ms > 0.0);
        assert!(plan.eval.total_ms() > plan.eval.latency);
    }

    #[test]
    fn replace_with_no_prior_matches_place() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        for name in ["random", "greedy:dim"] {
            // fresh placers so stochastic streams line up draw-for-draw
            let placed = by_name_seeded(&rt, name, 5).unwrap().place(&req).unwrap();
            let replaced = by_name_seeded(&rt, name, 5)
                .unwrap()
                .replace(&PlacementPlan::no_prior(&task), &req)
                .unwrap();
            assert_eq!(placed.placement, replaced.placement, "{name}");
            assert_eq!(placed.eval.latency, replaced.eval.latency, "{name}");
            assert_eq!(replaced.eval.moved_tables, 0, "{name}");
            assert_eq!(replaced.eval.migration_ms, 0.0, "{name}");
        }
        assert!(PlacementPlan::no_prior(&task).is_vacant());
    }

    #[test]
    fn replace_many_rejects_mismatched_lengths() {
        let rt = Arc::new(Runtime::reference());
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim);
        let mut p = by_name(&rt, "greedy:dim").unwrap();
        let e = p.replace_many(&[], &[req]).err().expect("length mismatch must error");
        assert!(e.to_string().contains("replace_many"));
    }

    #[test]
    fn request_legality_combines_slots_and_memory() {
        let (ds, task, sim) = setup();
        let req = PlacementRequest::new(&ds, &task, &sim).with_max_slots(2);
        let t0 = &ds.tables[task.table_ids[0]];
        let t1 = &ds.tables[task.table_ids[1]];
        assert!(req.device_can_take(&[], t0));
        assert!(req.device_can_take(&[t1], t0));
        assert!(!req.device_can_take(&[t1, t1], t0), "slot cap");
        let uncapped = PlacementRequest::new(&ds, &task, &sim);
        assert!(uncapped.device_can_take(&[t1, t1], t0));
    }
}
