//! DreamShard CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! repro <id|all> [--fast] [--seeds N]   regenerate a paper table/figure
//! train [--tables N] [--devices D] ...  train a policy and report costs
//! place [--tables N] [--policy NAME]    plan one placement and print it
//! serve-sim [--sharded] [flags below]   replay an open-loop serving load
//! placers                               list registered strategies
//! info                                  show artifact/manifest summary
//! ```
//!
//! `place --policy <name>` plans through the placer registry: learned
//! policies (`dreamshard`, `rnn`) are trained first; baselines
//! (`random`, `greedy:dim`, ...) plan immediately with no training.
//!
//! `serve-sim` drives the [`dreamshard::serve::PlanService`] front end
//! with a synthetic open-loop workload (Poisson arrivals, mixed
//! 2/4/8/128-device tasks) and prints a per-variant summary table plus
//! aggregate throughput. Its full flag surface:
//!
//! ```text
//! --requests N     arrivals to replay (64)
//! --devices LIST   comma list of device counts in the mix (2,4,8,128)
//! --min-tables N / --max-tables N   tables per task, uniform (10 / 40)
//! --gap-ms MS      mean exponential inter-arrival gap (5)
//! --policy NAME    placer registry name (dreamshard)
//! --seed N         workload + placer seed (0)
//! --chunk C        lane-chunk size per drain (16)
//! --capacity N     bounded-queue capacity; excess arrivals shed (128)
//! --workers N      runtime execution worker pool size (DREAMSHARD_WORKERS)
//! --sharded        serve through the ShardedFrontEnd: one queue per
//!                  serving variant, each draining on its own thread,
//!                  with per-shard + aggregate tables and a single-FIFO
//!                  throughput comparison (--capacity is the global cap)
//! --rebalance      day-2 scenario: plan the workload, fail one device
//!                  per task, then compare the budgeted incremental
//!                  rebalance (Placer::replace) against re-planning
//!                  from scratch on latency + migration cost
//!                  (--devices defaults to 2,4,8 here)
//! --moves K        discretionary moved-table budget per rebalanced
//!                  plan (4); forced moves off lost devices are exempt
//! --closed-loop    closed-loop mode: arrivals couple to drain
//!                  completions (each gap offsets from the last service
//!                  progress) and the workload replays twice through the
//!                  sharded front end — once with static knobs, once
//!                  steered by the serve::Controller — then prints the
//!                  static-vs-controlled tail-latency/shed comparison
//! --target-ms T    controller queue-latency target, ms (50); the
//!                  controller steers each shard's p95 toward it
//! --slo P          percent of arrivals tagged batch-class (20); under
//!                  pressure the controller drains interactive first
//!                  and sheds/evicts batch first
//! ```
//!
//! Without `--sharded` the run closes with a pipelined-drain vs
//! blocking-drain throughput comparison on the worker pool.
//!
//! (dependency-light by design: flags are parsed by hand, no clap)

use std::sync::Arc;
use std::time::Instant;

use dreamshard::{bail, err, Context, Result};

use dreamshard::bench::{self, common::Ctx};
use dreamshard::cli::parse_flags;
use dreamshard::coordinator::TrainCfg;
use dreamshard::placer::{self, FitRequest, MigrationBudget, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::serve::{
    synthetic_arrivals, Arrival, Clock, ControlConfig, Controller, PlanService, Planned,
    ReplaceJob, ServeConfig, ShardConfig, ShardedFrontEnd, TestClock, WorkloadCfg,
};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools, Dataset, Task};
use dreamshard::util::table::TextTable;

/// serve-sim helper: drain one chunk, stamp each completed request's
/// queue latency on the open-loop virtual clock (drain start minus its
/// arrival time), and advance the clock by the chunk's measured planning
/// wall time — the service is busy for that long on the replayed clock.
fn drain_once(
    svc: &mut PlanService<'_>,
    at_ms_by_ticket: &[f64],
    clock_ms: &mut f64,
    done: &mut Vec<(Planned, f64)>,
) -> Result<()> {
    let drained = svc.drain_chunk()?;
    let wall_ms = drained.first().map(|p| p.plan_ms).unwrap_or(0.0);
    for p in drained {
        let vq = (*clock_ms - at_ms_by_ticket[p.ticket as usize]).max(0.0);
        done.push((p, vq));
    }
    *clock_ms += wall_ms;
    Ok(())
}

/// serve-sim `--closed-loop` outcome of one replay (static or
/// controlled) — the numbers the comparison table prints.
struct LoopOutcome {
    planned: u64,
    shed: u64,
    shed_interactive: u64,
    p95_ms: f64,
    mean_ms: f64,
    ticks: u64,
    final_cap: usize,
    wall_s: f64,
}

/// Replay a closed-loop workload through the sharded front end on a
/// virtual clock ([`TestClock`]): each arrival's gap advances the clock
/// from the last service progress (drain completions advance it by
/// their measured planning wall time), so arrivals throttle with the
/// service instead of piling onto a wall schedule. `controlled` replays
/// through a [`Controller`] tick per arrival burst; static mode drains
/// only when some shard fills a lane-chunk — the hand-tuned baseline
/// the controller is compared against.
#[allow(clippy::too_many_arguments)]
fn replay_closed_loop<'a>(
    rt: &Arc<Runtime>,
    ds: &'a Dataset,
    sim: &'a Simulator,
    arrivals: &'a [Arrival],
    policy: &str,
    seed: u64,
    cfg: ServeConfig,
    capacity: usize,
    target_ms: f64,
    controlled: bool,
) -> Result<LoopOutcome> {
    let clock = Arc::new(TestClock::new());
    let factory = {
        let rt = Arc::clone(rt);
        let policy = policy.to_string();
        move || placer::by_name_seeded(&rt, &policy, seed)
    };
    let mut front = ShardedFrontEnd::with_clock(
        rt,
        factory,
        ShardConfig { per_shard: cfg, global_cap: capacity },
        Arc::clone(&clock) as Arc<dyn Clock>,
    )?;
    let mut ctl = Controller::new(ControlConfig { target_ms, ..Default::default() });
    let wall0 = Instant::now();
    let mut ticks = 0u64;
    // release arrivals in bursts of one control interval each
    const BURST: usize = 8;
    let mut idx = 0usize;
    while idx < arrivals.len() {
        for a in arrivals.iter().skip(idx).take(BURST) {
            // closed-loop coupling: the gap offsets from the clock's
            // current position, which the last drain advanced
            clock.advance_ms(a.at_ms);
            let req = PlacementRequest::for_runtime(rt, ds, &a.task, sim)?;
            front.submit_slo(req, a.class, None)?;
        }
        idx = (idx + BURST).min(arrivals.len());
        let t0 = Instant::now();
        if controlled {
            let report = ctl.tick(&mut front)?;
            ticks = report.tick;
        } else if front.shards().any(|s| s.queued >= s.chunk) {
            front.drain()?;
        }
        // planning occupies the replay clock for its measured wall time
        clock.advance_ms(t0.elapsed().as_secs_f64() * 1e3);
    }
    // flush: keep ticking (aging the idle floor so trickles drain) with
    // a guard against a pathological policy, then a final hard drain
    let mut guard = 0usize;
    while !front.is_empty() {
        let t0 = Instant::now();
        if controlled && guard < 256 {
            clock.advance_ms(ctl.config().max_idle_ms);
            let report = ctl.tick(&mut front)?;
            ticks = report.tick;
            guard += 1;
        } else {
            front.drain()?;
        }
        clock.advance_ms(t0.elapsed().as_secs_f64() * 1e3);
    }
    let fs = front.stats();
    Ok(LoopOutcome {
        planned: fs.aggregate.planned,
        shed: fs.shed_global + fs.aggregate.rejected,
        shed_interactive: (fs.shed_global - fs.shed_global_batch)
            + (fs.aggregate.rejected - fs.aggregate.shed_batch),
        p95_ms: fs.aggregate.p95_queue_ms(),
        mean_ms: fs.aggregate.mean_queue_ms(),
        ticks,
        final_cap: front.global_cap(),
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprintln!("usage: dreamshard <repro|train|place|serve-sim|placers|info> [...]");
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "repro" => {
            let id = flags
                .positional
                .first()
                .cloned()
                .context("usage: dreamshard repro <id|all> [--fast] [--seeds N]")?;
            let fast = flags.has("fast");
            let seeds = flags.get_usize("seeds", if fast { 2 } else { 3 });
            let ctx = Ctx::new(fast, seeds)?;
            bench::run(&id, &ctx)
        }
        "train" | "place" => {
            let n_tables = flags.get_usize("tables", 50);
            let n_devices = flags.get_usize("devices", 4);
            let prod = flags.has("prod");
            let policy = flags.get_str("policy", "dreamshard");
            let rt = Arc::new(Runtime::open_default()?);
            let (ds, sim) = if prod {
                (gen_prod(856, 42), Simulator::new(SimConfig::v100()))
            } else {
                (gen_dlrm(856, 42), Simulator::new(SimConfig::default()))
            };
            let (pool_tr, pool_te) = split_pools(&ds, 1007);
            let train = sample_tasks(&pool_tr, n_tables, n_devices, 20, 2007);
            let test = sample_tasks(&pool_te, n_tables, n_devices, 10, 3007);
            let seed = flags.get_usize("seed", 0) as u64;
            let mut placer = placer::by_name_seeded(&rt, &policy, seed)?;
            // only learned policies train; `place --policy greedy:dim`
            // and friends go straight to planning
            if placer.needs_fit() {
                let cfg = if flags.has("fast") { TrainCfg::fast() } else { TrainCfg::default() };
                eprintln!(
                    "training {policy} on {} tasks of {n_tables} tables x {n_devices} devices ...",
                    train.len()
                );
                placer.fit(&FitRequest {
                    ds: &ds,
                    tasks: &train,
                    sim: &sim,
                    cfg,
                    seed,
                    verbose: true,
                })?;
            } else if cmd == "train" {
                eprintln!("policy `{policy}` has nothing to train; planning directly");
            }
            // one lane-batched pass over all test tasks
            let reqs = test
                .iter()
                .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim))
                .collect::<Result<Vec<_>>>()?;
            let plans = placer.place_many(&reqs)?;
            if cmd == "place" {
                println!("placement: {:?}", plans[0].placement);
            }
            println!(
                "{}",
                sim.render_trace(
                    &plans[0].eval,
                    &format!("{} placement on first test task", plans[0].strategy)
                )
            );
            let costs: Vec<f64> = plans.iter().map(|p| p.eval.latency).collect();
            let mean = dreamshard::util::mean(&costs);
            println!("mean test cost over {} tasks: {mean:.2} ms", test.len());
            Ok(())
        }
        "serve-sim" => {
            let chunk = flags.get_usize("chunk", 16);
            let capacity = flags.get_usize("capacity", 128);
            let seed = flags.get_usize("seed", 0) as u64;
            let policy = flags.get_str("policy", "dreamshard");
            // --workers N resizes the runtime's execution pool (0 =
            // keep the DREAMSHARD_WORKERS / built-in default)
            let workers = flags.get_usize("workers", 0);
            // --devices 2,4,8,128 (device-count-specific placers like
            // `rnn` need a single count here, e.g. --devices 4). The
            // rebalance scenario drops the 128-device lane by default:
            // its serving variant has no fused mdp_step, so `replace`
            // there falls back to scratch planning and would not show
            // the incremental path.
            let rebalance = flags.has("rebalance");
            let device_mix = flags
                .get_str("devices", if rebalance { "2,4,8" } else { "2,4,8,128" })
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| err!("--devices wants a comma list of counts, got `{s}`"))
                })
                .collect::<Result<Vec<usize>>>()?;
            let mut rt = Runtime::open_default()?;
            if workers > 0 {
                rt = rt.with_workers(workers);
            }
            let rt = Arc::new(rt);
            let ds = gen_dlrm(856, 42);
            let (pool, _) = split_pools(&ds, 1007);
            let sim = Simulator::new(SimConfig::default());
            let closed_loop = flags.has("closed-loop");
            let wl = WorkloadCfg {
                n_requests: flags.get_usize("requests", 64),
                device_mix,
                min_tables: flags.get_usize("min-tables", 10),
                max_tables: flags.get_usize("max-tables", 40),
                mean_gap_ms: flags.get_usize("gap-ms", 5) as f64,
                closed_loop,
                batch_pct: flags.get_usize("slo", 20).min(100),
                seed,
            };
            let arrivals = synthetic_arrivals(&pool, &wl);
            let placer = placer::by_name_seeded(&rt, &policy, seed)?;
            if placer.needs_fit() {
                eprintln!(
                    "note: `{policy}` serves with deterministic untrained weights \
                     (serve-sim exercises the serving path; use `train` for plan quality)"
                );
            }
            let cfg = ServeConfig { capacity, chunk, ..ServeConfig::default() };
            if closed_loop {
                // the acceptance run: the same coupled workload replayed
                // twice at equal load — static knobs vs the Controller
                // steering chunk sizes, admission, drain order, and SLO
                // pressure toward --target-ms
                let target_ms = flags.get_usize("target-ms", 50) as f64;
                let run = |controlled: bool| {
                    replay_closed_loop(
                        &rt, &ds, &sim, &arrivals, &policy, seed, cfg, capacity, target_ms,
                        controlled,
                    )
                };
                let fixed = run(false)?;
                let steered = run(true)?;
                println!(
                    "serve-sim --closed-loop: {} arrivals ({}% batch-class), target p95 \
                     {target_ms:.0} ms, policy {policy}, chunk {chunk}, cap {capacity}, \
                     {} runtime workers",
                    arrivals.len(),
                    wl.batch_pct,
                    rt.workers(),
                );
                let mut table = TextTable::new(vec![
                    "mode",
                    "plans",
                    "shed",
                    "shed interactive",
                    "queue p95 ms",
                    "queue mean ms",
                    "final cap",
                    "wall s",
                ]);
                table.row(vec![
                    "static".to_string(),
                    fixed.planned.to_string(),
                    fixed.shed.to_string(),
                    fixed.shed_interactive.to_string(),
                    format!("{:.2}", fixed.p95_ms),
                    format!("{:.2}", fixed.mean_ms),
                    fixed.final_cap.to_string(),
                    format!("{:.2}", fixed.wall_s),
                ]);
                table.row(vec![
                    format!("controlled ({} ticks)", steered.ticks),
                    steered.planned.to_string(),
                    steered.shed.to_string(),
                    steered.shed_interactive.to_string(),
                    format!("{:.2}", steered.p95_ms),
                    format!("{:.2}", steered.mean_ms),
                    steered.final_cap.to_string(),
                    format!("{:.2}", steered.wall_s),
                ]);
                println!("{}", table.render());
                let better_tail = steered.p95_ms <= fixed.p95_ms;
                let fewer_shed = steered.shed_interactive <= fixed.shed_interactive;
                println!(
                    "verdict: controlled p95 {:.2} ms vs static {:.2} ms, interactive shed \
                     {} vs {} -> controller {}",
                    steered.p95_ms,
                    fixed.p95_ms,
                    steered.shed_interactive,
                    fixed.shed_interactive,
                    if better_tail && fewer_shed {
                        "wins on tail latency and interactive shed"
                    } else if better_tail {
                        "wins on tail latency"
                    } else if fewer_shed {
                        "wins on interactive shed"
                    } else {
                        "loses on this replay (timing-sensitive; rerun or raise --requests)"
                    },
                );
                return Ok(());
            }
            if rebalance {
                // day-2 scenario: plan the accepted workload once, fail
                // one device per task, then re-place every live plan two
                // ways — the budgeted incremental rebalance
                // (PlanService::rebalance -> Placer::replace) vs
                // throwing the plans away and planning from scratch.
                // Scratch plans still pay the migration cost of adopting
                // them, so the verdict compares latency + migration.
                let moves = flags.get_usize("moves", 4);
                let mut svc = PlanService::new(&rt, placer, cfg);
                let mut tasks: Vec<Task> = vec![];
                for a in &arrivals {
                    let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                    if svc.submit(req)?.is_some() {
                        tasks.push(a.task.clone());
                    }
                }
                let mut done = svc.drain()?;
                done.sort_by_key(|p| p.ticket); // back to submission order
                // device failure: every task with spare devices loses
                // its highest-indexed one; 2-device tasks keep both, so
                // the mix also exercises pure budget-limited moves
                let perturbed: Vec<Task> = tasks
                    .iter()
                    .map(|t| Task {
                        table_ids: t.table_ids.clone(),
                        n_devices: if t.n_devices > 2 { t.n_devices - 1 } else { t.n_devices },
                    })
                    .collect();
                let budget = MigrationBudget::moves(moves);
                let jobs: Vec<ReplaceJob> = done
                    .iter()
                    .zip(&perturbed)
                    .map(|(p, t)| -> Result<ReplaceJob> {
                        Ok(ReplaceJob {
                            prev: p.plan.clone(),
                            req: PlacementRequest::for_runtime(&rt, &ds, t, &sim)?
                                .with_migration(budget),
                        })
                    })
                    .collect::<Result<_>>()?;
                let n_jobs = jobs.len();
                let t0 = Instant::now();
                let redone = svc.rebalance(jobs)?;
                let rebalance_s = t0.elapsed().as_secs_f64();

                // scratch reference: a fresh placer re-plans the
                // perturbed tasks with no knowledge of the prior plans
                // (agent warm-up untimed, mirroring the service's)
                let scratch_reqs = perturbed
                    .iter()
                    .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim))
                    .collect::<Result<Vec<_>>>()?;
                let mut scratch = placer::by_name_seeded(&rt, &policy, seed)?;
                if let Some(r) = scratch_reqs.iter().max_by_key(|r| r.task.n_devices) {
                    scratch.warm_variant(r)?;
                }
                let t0 = Instant::now();
                let scratch_plans = scratch.place_many(&scratch_reqs)?;
                let scratch_s = t0.elapsed().as_secs_f64();
                let scratch_rows: Vec<(f64, f64, usize)> = scratch_plans
                    .iter()
                    .zip(&done)
                    .zip(&perturbed)
                    .map(|((p, prev), t)| {
                        let e =
                            sim.evaluate_migration(&ds, t, &prev.plan.placement, &p.placement);
                        (e.latency, e.migration_ms, e.moved_tables)
                    })
                    .collect();
                let rebalance_rows: Vec<(f64, f64, usize)> = redone
                    .iter()
                    .map(|p| {
                        (p.plan.eval.latency, p.plan.eval.migration_ms, p.plan.eval.moved_tables)
                    })
                    .collect();

                // (mean latency, total migration, total moved, mean latency+migration)
                let agg = |rows: &[(f64, f64, usize)]| {
                    let n = rows.len().max(1) as f64;
                    let lat = rows.iter().map(|r| r.0).sum::<f64>() / n;
                    let mig: f64 = rows.iter().map(|r| r.1).sum();
                    let moved: usize = rows.iter().map(|r| r.2).sum();
                    let total = rows.iter().map(|r| r.0 + r.1).sum::<f64>() / n;
                    (lat, mig, moved, total)
                };
                let (r_lat, r_mig, r_moved, r_total) = agg(&rebalance_rows);
                let (s_lat, s_mig, s_moved, s_total) = agg(&scratch_rows);

                println!(
                    "serve-sim --rebalance: {} arrivals, {n_jobs} live plans, one failed \
                     device per task, move budget {moves}, policy {policy}, {} runtime workers",
                    arrivals.len(),
                    rt.workers(),
                );
                let mut table = TextTable::new(vec![
                    "approach",
                    "plans",
                    "moved",
                    "migration ms",
                    "latency ms",
                    "total ms",
                    "plans/s",
                ]);
                table.row(vec![
                    format!("rebalance (moves<={moves})"),
                    redone.len().to_string(),
                    r_moved.to_string(),
                    format!("{r_mig:.1}"),
                    format!("{r_lat:.2}"),
                    format!("{r_total:.2}"),
                    format!("{:.1}", redone.len() as f64 / rebalance_s.max(1e-9)),
                ]);
                table.row(vec![
                    "scratch re-plan".to_string(),
                    scratch_plans.len().to_string(),
                    s_moved.to_string(),
                    format!("{s_mig:.1}"),
                    format!("{s_lat:.2}"),
                    format!("{s_total:.2}"),
                    format!("{:.1}", scratch_plans.len() as f64 / scratch_s.max(1e-9)),
                ]);
                println!("{}", table.render());
                println!("service after rebalance: {}", svc.stats().summary());
                println!(
                    "verdict: rebalance {r_total:.2} ms vs scratch {s_total:.2} ms mean \
                     latency+migration per plan ({:.2}x cheaper once migration is paid)",
                    s_total / r_total.max(1e-9),
                );
                return Ok(());
            }
            if flags.has("sharded") {
                // multi-service sharding: one PlanService per serving
                // variant, routed through a single submit API, each shard
                // draining on its own thread against the shared worker
                // pool; --capacity doubles as the global backpressure cap
                let factory = {
                    let rt = Arc::clone(&rt);
                    let policy = policy.clone();
                    move || placer::by_name_seeded(&rt, &policy, seed)
                };
                let mut front = ShardedFrontEnd::new(&rt, factory, ShardConfig {
                    per_shard: cfg,
                    global_cap: capacity,
                })?;
                for a in &arrivals {
                    let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                    front.submit(req)?;
                }
                let accepted = front.queued();
                let t0 = Instant::now();
                let reports = front.try_drain();
                let sharded_s = t0.elapsed().as_secs_f64();

                // (per-shard backend-call counts are omitted: concurrent
                // shard windows observe the shared runtime counter, so
                // only the aggregate total below is exact)
                let mut table = TextTable::new(vec![
                    "shard",
                    "plans",
                    "chunks",
                    "queue ms",
                    "plan ms",
                    "cost ms",
                ]);
                let mut total_plans = 0usize;
                for ((key, drained), sh) in reports.iter().zip(front.shards()) {
                    debug_assert_eq!(key, sh.key);
                    let done = match drained {
                        Ok(done) => done,
                        Err(e) => return Err(e.clone()),
                    };
                    total_plans += done.len();
                    let n = done.len().max(1) as f64;
                    let cost = done.iter().map(|p| p.plan.eval.latency).sum::<f64>() / n;
                    table.row(vec![
                        key.label(),
                        sh.stats.planned.to_string(),
                        sh.stats.chunks.to_string(),
                        format!("{:.2}", sh.stats.mean_queue_ms()),
                        format!("{:.2}", sh.stats.mean_plan_ms()),
                        format!("{cost:.1}"),
                    ]);
                }
                let fs = front.stats();
                println!(
                    "serve-sim --sharded: {} arrivals, {} accepted ({} shed at the global \
                     cap), policy {}, chunk {chunk}, global cap {capacity}, {} runtime workers",
                    arrivals.len(),
                    accepted,
                    fs.shed_global,
                    policy,
                    rt.workers(),
                );
                println!("{}", table.render());
                println!("aggregate: {}", fs.summary());

                // single shared FIFO on the same workload: the 128-device
                // chunks sit ahead of small-device traffic in one queue,
                // which is exactly the head-of-line coupling sharding removes
                let mut placer = placer;
                if let Some(a) = arrivals.first() {
                    // untimed agent warm-up, mirroring the shards (whose
                    // placers were warmed during the untimed submit loop)
                    // so a lazy policy's agent init doesn't land inside
                    // the single-FIFO drain's timed window
                    let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                    placer.warm_variant(&req)?;
                }
                let mut svc = PlanService::new(&rt, placer, cfg);
                for a in &arrivals {
                    let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                    svc.submit(req)?;
                }
                let single_accepted = svc.queued();
                let t0 = Instant::now();
                let single_done = svc.drain()?.len();
                let single_s = t0.elapsed().as_secs_f64();
                debug_assert_eq!(single_done, single_accepted);
                println!(
                    "sharded drain {:.1} plans/s ({total_plans} plans) vs single-FIFO \
                     {:.1} plans/s ({single_done} plans) -> {:.2}x on {} workers",
                    total_plans as f64 / sharded_s.max(1e-9),
                    single_done as f64 / single_s.max(1e-9),
                    (total_plans as f64 / sharded_s.max(1e-9))
                        / (single_done as f64 / single_s.max(1e-9)).max(1e-9),
                    rt.workers(),
                );
                return Ok(());
            }
            let mut svc = PlanService::new(&rt, placer, cfg);

            // open-loop replay on a virtual clock: requests arrive at
            // their schedule times; a drain occupies the service for its
            // measured planning wall time, so a request's queue latency
            // is how long it sat behind earlier traffic on that clock
            let mut clock_ms = 0.0f64;
            let mut at_ms_by_ticket: Vec<f64> = Vec::with_capacity(arrivals.len());
            // (completed request, queue latency on the open-loop clock)
            let mut done: Vec<(Planned, f64)> = Vec::with_capacity(arrivals.len());
            for a in &arrivals {
                clock_ms = clock_ms.max(a.at_ms);
                let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                if svc.submit(req)?.is_none() {
                    continue; // shed by the bounded queue
                }
                at_ms_by_ticket.push(a.at_ms);
                // a full lane-chunk triggers a drain
                while svc.queued() >= chunk {
                    drain_once(&mut svc, &at_ms_by_ticket, &mut clock_ms, &mut done)?;
                }
            }
            while !svc.is_empty() {
                drain_once(&mut svc, &at_ms_by_ticket, &mut clock_ms, &mut done)?;
            }

            // per-serving-variant summary
            let mut keys: Vec<(usize, usize)> = done.iter().map(|(p, _)| p.variant).collect();
            keys.sort_unstable();
            keys.dedup();
            let mut table = TextTable::new(vec![
                "variant",
                "plans",
                "queue ms (clock)",
                "plan ms",
                "cost ms",
            ]);
            for key in keys {
                let group: Vec<&(Planned, f64)> =
                    done.iter().filter(|(p, _)| p.variant == key).collect();
                let n = group.len() as f64;
                let queue = group.iter().map(|(_, vq)| *vq).sum::<f64>() / n;
                let plan = group.iter().map(|(p, _)| p.plan_ms).sum::<f64>() / n;
                let cost = group.iter().map(|(p, _)| p.plan.eval.latency).sum::<f64>() / n;
                table.row(vec![
                    format!("d{}s{}", key.0, key.1),
                    group.len().to_string(),
                    format!("{queue:.2}"),
                    format!("{plan:.2}"),
                    format!("{cost:.1}"),
                ]);
            }
            let span_ms = arrivals.last().map(|a| a.at_ms).unwrap_or(0.0);
            println!(
                "serve-sim: {} arrivals over {span_ms:.0} ms, {} shed, policy {}, \
                 chunk {chunk}, capacity {capacity}, {} runtime workers",
                arrivals.len(),
                svc.stats().rejected,
                svc.placer_name(),
                rt.workers(),
            );
            println!("{}", table.render());
            println!(
                "open-loop makespan {clock_ms:.0} ms (arrival span + planning); \
                 queue ms above are measured on that clock"
            );
            println!("{}", svc.stats().summary());

            // saturated-queue throughput check on the same workload:
            // blocking per-chunk drain vs the pipelined drain that fills
            // chunk k+1's tensors while chunk k executes on the pool
            let timed = |pipelined: bool| -> Result<f64> {
                let placer = placer::by_name_seeded(&rt, &policy, seed)?;
                let mut svc = PlanService::new(&rt, placer, cfg);
                let mut accepted = 0usize;
                for a in &arrivals {
                    let req = PlacementRequest::for_runtime(&rt, &ds, &a.task, &sim)?;
                    if svc.submit(req)?.is_some() {
                        accepted += 1;
                    }
                }
                let t0 = Instant::now();
                let done =
                    if pipelined { svc.drain()? } else { svc.drain_blocking()? };
                let s = t0.elapsed().as_secs_f64();
                debug_assert_eq!(done.len(), accepted);
                Ok(accepted as f64 / s.max(1e-9))
            };
            let blocking_pps = timed(false)?;
            let pipelined_pps = timed(true)?;
            println!(
                "saturated drain: blocking {blocking_pps:.1} plans/s vs pipelined \
                 {pipelined_pps:.1} plans/s ({:.2}x) on {} workers",
                pipelined_pps / blocking_pps.max(1e-9),
                rt.workers(),
            );
            Ok(())
        }
        "placers" => {
            let rt = Arc::new(Runtime::open_default()?);
            for name in placer::PLACER_NAMES {
                let p = placer::by_name(&rt, name)?;
                let kind = if p.needs_fit() { "learned" } else { "heuristic" };
                println!("{name:<20} {kind}");
            }
            Ok(())
        }
        "info" => {
            let rt = Runtime::open_default()?;
            println!("backend: {}", rt.backend_name());
            println!("artifacts: {}", rt.manifest.artifacts.len());
            let mut names: Vec<&String> = rt.manifest.artifacts.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
            for (net, info) in &rt.manifest.params {
                println!("network {net}: {} params in {} segments", info.total, info.segments.len());
            }
            Ok(())
        }
        other => bail!("unknown command `{other}`"),
    }
}
