//! DreamShard CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! repro <id|all> [--fast] [--seeds N]   regenerate a paper table/figure
//! train [--tables N] [--devices D] ...  train a policy and report costs
//! place [--tables N] [--policy NAME]    plan one placement and print it
//! placers                               list registered strategies
//! info                                  show artifact/manifest summary
//! ```
//!
//! `place --policy <name>` plans through the placer registry: learned
//! policies (`dreamshard`, `rnn`) are trained first; baselines
//! (`random`, `greedy:dim`, ...) plan immediately with no training.
//!
//! (dependency-light by design: flags are parsed by hand, no clap)

use dreamshard::{bail, Context, Result};

use dreamshard::bench::{self, common::Ctx};
use dreamshard::cli::parse_flags;
use dreamshard::coordinator::TrainCfg;
use dreamshard::placer::{self, FitRequest, Placer, PlacementRequest};
use dreamshard::runtime::Runtime;
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprintln!("usage: dreamshard <repro|train|place|placers|info> [...]");
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "repro" => {
            let id = flags
                .positional
                .first()
                .cloned()
                .context("usage: dreamshard repro <id|all> [--fast] [--seeds N]")?;
            let fast = flags.has("fast");
            let seeds = flags.get_usize("seeds", if fast { 2 } else { 3 });
            let ctx = Ctx::new(fast, seeds)?;
            bench::run(&id, &ctx)
        }
        "train" | "place" => {
            let n_tables = flags.get_usize("tables", 50);
            let n_devices = flags.get_usize("devices", 4);
            let prod = flags.has("prod");
            let policy = flags.get_str("policy", "dreamshard");
            let rt = Runtime::open_default()?;
            let (ds, sim) = if prod {
                (gen_prod(856, 42), Simulator::new(SimConfig::v100()))
            } else {
                (gen_dlrm(856, 42), Simulator::new(SimConfig::default()))
            };
            let (pool_tr, pool_te) = split_pools(&ds, 1007);
            let train = sample_tasks(&pool_tr, n_tables, n_devices, 20, 2007);
            let test = sample_tasks(&pool_te, n_tables, n_devices, 10, 3007);
            let seed = flags.get_usize("seed", 0) as u64;
            let mut placer = placer::by_name_seeded(&rt, &policy, seed)?;
            // only learned policies train; `place --policy greedy:dim`
            // and friends go straight to planning
            if placer.needs_fit() {
                let cfg = if flags.has("fast") { TrainCfg::fast() } else { TrainCfg::default() };
                eprintln!(
                    "training {policy} on {} tasks of {n_tables} tables x {n_devices} devices ...",
                    train.len()
                );
                placer.fit(&FitRequest {
                    ds: &ds,
                    tasks: &train,
                    sim: &sim,
                    cfg,
                    seed,
                    verbose: true,
                })?;
            } else if cmd == "train" {
                eprintln!("policy `{policy}` has nothing to train; planning directly");
            }
            // one lane-batched pass over all test tasks
            let reqs = test
                .iter()
                .map(|t| PlacementRequest::for_runtime(&rt, &ds, t, &sim))
                .collect::<Result<Vec<_>>>()?;
            let plans = placer.place_many(&reqs)?;
            if cmd == "place" {
                println!("placement: {:?}", plans[0].placement);
            }
            println!(
                "{}",
                sim.render_trace(
                    &plans[0].eval,
                    &format!("{} placement on first test task", plans[0].strategy)
                )
            );
            let costs: Vec<f64> = plans.iter().map(|p| p.eval.latency).collect();
            let mean = dreamshard::util::mean(&costs);
            println!("mean test cost over {} tasks: {mean:.2} ms", test.len());
            Ok(())
        }
        "placers" => {
            let rt = Runtime::open_default()?;
            for name in placer::PLACER_NAMES {
                let p = placer::by_name(&rt, name)?;
                let kind = if p.needs_fit() { "learned" } else { "heuristic" };
                println!("{name:<20} {kind}");
            }
            Ok(())
        }
        "info" => {
            let rt = Runtime::open_default()?;
            println!("backend: {}", rt.backend_name());
            println!("artifacts: {}", rt.manifest.artifacts.len());
            let mut names: Vec<&String> = rt.manifest.artifacts.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
            for (net, info) in &rt.manifest.params {
                println!("network {net}: {} params in {} segments", info.total, info.segments.len());
            }
            Ok(())
        }
        other => bail!("unknown command `{other}`"),
    }
}
