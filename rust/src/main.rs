//! DreamShard CLI — the leader entrypoint.
//!
//! Subcommands:
//!   repro <id|all> [--fast] [--seeds N]   regenerate a paper table/figure
//!   train [--tables N] [--devices D] ...  train an agent and report costs
//!   place [--tables N] [--devices D]      plan one placement and print it
//!   info                                  show artifact/manifest summary
//!
//! (dependency-light by design: flags are parsed by hand, no clap)

use dreamshard::{bail, Context, Result};

use dreamshard::bench::{self, common::Ctx};
use dreamshard::coordinator::{DreamShard, TrainCfg};
use dreamshard::runtime::Runtime;
use dreamshard::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools};
use dreamshard::sim::{SimConfig, Simulator};
use dreamshard::util::Rng;

struct Flags {
    positional: Vec<String>,
    named: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_flags(args: &[String]) -> Flags {
    let mut f = Flags {
        positional: vec![],
        named: Default::default(),
        switches: Default::default(),
    };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                f.named.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                f.switches.insert(name.to_string());
                i += 1;
            }
        } else {
            f.positional.push(a.clone());
            i += 1;
        }
    }
    f
}

impl Flags {
    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.named.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn has(&self, name: &str) -> bool {
        self.switches.contains(name) || self.named.contains_key(name)
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprintln!("usage: dreamshard <repro|train|place|info> [...]");
        std::process::exit(2);
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "repro" => {
            let id = flags
                .positional
                .first()
                .cloned()
                .context("usage: dreamshard repro <id|all> [--fast] [--seeds N]")?;
            let fast = flags.has("fast");
            let seeds = flags.get_usize("seeds", if fast { 2 } else { 3 });
            let ctx = Ctx::new(fast, seeds)?;
            bench::run(&id, &ctx)
        }
        "train" | "place" => {
            let n_tables = flags.get_usize("tables", 50);
            let n_devices = flags.get_usize("devices", 4);
            let prod = flags.has("prod");
            let rt = Runtime::open_default()?;
            let (ds, sim) = if prod {
                (gen_prod(856, 42), Simulator::new(SimConfig::v100()))
            } else {
                (gen_dlrm(856, 42), Simulator::new(SimConfig::default()))
            };
            let (pool_tr, pool_te) = split_pools(&ds, 1007);
            let train = sample_tasks(&pool_tr, n_tables, n_devices, 20, 2007);
            let test = sample_tasks(&pool_te, n_tables, n_devices, 10, 3007);
            let cfg = if flags.has("fast") { TrainCfg::fast() } else { TrainCfg::default() };
            let mut rng = Rng::new(flags.get_usize("seed", 0) as u64);
            let mut agent = DreamShard::new(&rt, n_devices, cfg, &mut rng)?;
            eprintln!("training on {} tasks of {} tables x {} devices ...", train.len(), n_tables, n_devices);
            agent.train(&rt, &sim, &ds, &train, &mut rng)?;
            for st in &agent.log {
                eprintln!(
                    "  iter {}: collected {:.1} ms, cost-loss {:.3}, policy-loss {:.4} ({:.1}s)",
                    st.iter, st.collected_mean_cost, st.cost_loss, st.policy_loss, st.wall_s
                );
            }
            let task = &test[0];
            let p = agent.place(&rt, &sim, &ds, task)?;
            let eval = sim.evaluate(&ds, task, &p);
            if cmd == "place" {
                println!("placement: {p:?}");
            }
            println!("{}", sim.render_trace(&eval, "DreamShard placement on first test task"));
            let mean = dreamshard::coordinator::evaluate_policy(&agent, &rt, &sim, &ds, &test)?;
            println!("mean test cost over {} tasks: {mean:.2} ms", test.len());
            Ok(())
        }
        "info" => {
            let rt = Runtime::open_default()?;
            println!("backend: {}", rt.backend_name());
            println!("artifacts: {}", rt.manifest.artifacts.len());
            let mut names: Vec<&String> = rt.manifest.artifacts.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
            for (net, info) in &rt.manifest.params {
                println!("network {net}: {} params in {} segments", info.total, info.segments.len());
            }
            Ok(())
        }
        other => bail!("unknown command `{other}`"),
    }
}
