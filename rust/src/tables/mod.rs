//! Embedding tables, their 21 features (paper section A.2), the synthetic
//! DLRM / Prod datasets (section C), and placement-task sampling
//! (section E: disjoint train/test table pools, random table subsets).

mod dataset;
mod features;
mod task;

pub use dataset::{gen_dlrm, gen_prod, Dataset};
pub use features::{Table, NUM_BINS, NUM_FEATURES};
pub use task::{sample_tasks, split_pools, Task, TaskSet};
