//! Synthetic dataset generators mirroring the paper's two benchmarks
//! (section C + Figures 15-18):
//!
//! * **DLRM** — 856 tables, fixed dim 16, hash sizes log-normal around
//!   1e6 (tail to 1e7), power-law pooling factors (most < 5, tail to
//!   ~200), diverse access-frequency histograms.
//! * **Prod** — same scale but *diverse dimensions* 4..768 and larger
//!   tables, the property the paper says makes Prod harder (dimension
//!   imbalance hurts communication).

use super::features::{Table, NUM_BINS};
use crate::util::Rng;

/// A named set of embedding tables.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub tables: Vec<Table>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.tables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tables.is_empty()
    }
}

/// Draw an access-frequency histogram. `heat` in [0,1] shifts mass toward
/// hot bins (frequently re-accessed indices), mimicking the long-tailed
/// production reuse patterns of Figure 18.
fn gen_bins(rng: &mut Rng, heat: f64) -> [f32; NUM_BINS] {
    let mut bins = [0.0f32; NUM_BINS];
    // geometric-ish decay away from a heat-dependent center
    let center = heat * (NUM_BINS - 1) as f64 * 0.7;
    let width = 1.5 + 3.0 * rng.f64();
    let mut total = 0.0f32;
    for (k, b) in bins.iter_mut().enumerate() {
        let d = (k as f64 - center) / width;
        let w = (-0.5 * d * d).exp() * (0.05 + rng.f64());
        *b = w as f32;
        total += *b;
    }
    for b in bins.iter_mut() {
        *b /= total;
    }
    bins
}

/// Power-law pooling factor: most tables small, a few up to ~200
/// (Fig. 16; the DLRM dataset's average pooling factor is 15, Table 5).
fn gen_pooling(rng: &mut Rng) -> f32 {
    let p = rng.pareto(2.0, 1.05);
    (p.min(200.0)) as f32
}

/// DLRM synthetic dataset (open-source dlrm_datasets counterpart).
pub fn gen_dlrm(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fork(0xD1A3);
    let tables = (0..n)
        .map(|_| {
            // hash sizes: log-normal centered ~1e6, clipped to [1e3, 2e7]
            let hash = rng.lognormal10(5.9, 0.55).clamp(1e3, 2e7) as u64;
            let heat = rng.f64() * rng.f64(); // mostly cold, some hot
            Table {
                dim: 16, // the public DLRM dataset fixes dim=16 (§C.3)
                hash_size: hash,
                pooling: gen_pooling(&mut rng),
                bins: gen_bins(&mut rng, heat),
            }
        })
        .collect();
    Dataset { name: format!("dlrm{n}"), tables }
}

/// Prod-like dataset: diverse dims 4..768 (the paper's key difference).
pub fn gen_prod(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed).fork(0x940D);
    let dims = [4u32, 8, 16, 32, 48, 64, 96, 128, 192, 256, 384, 512, 768];
    // skew toward mid dims but keep the extremes present
    let dim_w = [4.0f32, 6.0, 10.0, 12.0, 8.0, 10.0, 6.0, 6.0, 3.0, 3.0, 1.5, 1.0, 0.5];
    let tables = (0..n)
        .map(|_| {
            let dim = dims[rng.weighted(&dim_w)];
            let hash = rng.lognormal10(6.1, 0.6).clamp(1e3, 4e7) as u64;
            let heat = rng.f64();
            Table {
                dim,
                hash_size: hash,
                pooling: gen_pooling(&mut rng),
                bins: gen_bins(&mut rng, heat),
            }
        })
        .collect();
    Dataset { name: format!("prod{n}"), tables }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dlrm_shape() {
        let d = gen_dlrm(856, 0);
        assert_eq!(d.len(), 856);
        assert!(d.tables.iter().all(|t| t.dim == 16));
        assert!(d.tables.iter().all(|t| (1_000..=20_000_000).contains(&(t.hash_size as i64))));
        // power-law pooling: majority small, tail exists (Fig. 16)
        let small = d.tables.iter().filter(|t| t.pooling < 5.0).count();
        let big = d.tables.iter().filter(|t| t.pooling > 50.0).count();
        assert!(small > d.len() / 2, "small poolings {small}");
        assert!(big > 0);
    }

    #[test]
    fn prod_dims_diverse() {
        let d = gen_prod(856, 0);
        let mut dims: Vec<u32> = d.tables.iter().map(|t| t.dim).collect();
        dims.sort_unstable();
        dims.dedup();
        assert!(dims.len() >= 8, "expected many distinct dims, got {dims:?}");
        assert!(dims.contains(&4) && *dims.last().unwrap() >= 512);
    }

    #[test]
    fn deterministic() {
        assert_eq!(gen_dlrm(32, 5).tables, gen_dlrm(32, 5).tables);
        assert_ne!(gen_dlrm(32, 5).tables, gen_dlrm(32, 6).tables);
    }

    #[test]
    fn bins_are_distributions() {
        for t in gen_dlrm(64, 1).tables {
            let s: f32 = t.bins.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
            assert!(t.bins.iter().all(|&b| b >= 0.0));
        }
    }
}
