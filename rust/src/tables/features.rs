//! Table features (paper section A.2): dimension, hash size, pooling
//! factor, table size, and a 17-bin index-access-frequency distribution.

/// Number of access-frequency histogram bins: (0,1], (1,2], (2,4], ...,
/// (32768, inf) — powers of two over a 65,536-index batch (section A.2).
pub const NUM_BINS: usize = 17;

/// Total feature dimension fed to the networks: 4 scalars + 17 bins.
pub const NUM_FEATURES: usize = 4 + NUM_BINS;

/// One embedding table and its lookup statistics.
#[derive(Clone, Debug, PartialEq)]
pub struct Table {
    /// Embedding vector dimension (number of columns).
    pub dim: u32,
    /// Number of rows (vocabulary / hash size).
    pub hash_size: u64,
    /// Mean pooling factor: indices fetched per sample.
    pub pooling: f32,
    /// Normalized access-frequency distribution over `NUM_BINS` bins;
    /// sums to 1. Higher-index bins = hotter (more reusable) indices.
    pub bins: [f32; NUM_BINS],
}

impl Table {
    /// Memory footprint in GB (fp16 rows, as in the paper's setup §B.5).
    pub fn size_gb(&self) -> f32 {
        (self.hash_size as f64 * self.dim as f64 * 2.0 / 1e9) as f32
    }

    /// Expected reuse factor in [0, 1]: how much of the lookup traffic
    /// hits frequently-accessed (cacheable) rows. Derived from the bin
    /// histogram: bin k holds indices accessed ~2^(k-1) times, so the
    /// traffic share of bin k is proportional to `bins[k] * 2^(k-1)`.
    pub fn reuse_factor(&self) -> f32 {
        let mut traffic = 0.0f64;
        let mut hot = 0.0f64;
        for (k, &b) in self.bins.iter().enumerate() {
            let freq = 2f64.powi(k as i32);
            let t = b as f64 * freq;
            traffic += t;
            // indices accessed >= 16 times in a batch are effectively
            // cache-resident for the rest of the batch
            if k >= 5 {
                hot += t;
            }
        }
        if traffic <= 0.0 {
            0.0
        } else {
            (hot / traffic) as f32
        }
    }

    /// The normalized 21-feature vector consumed by the cost and policy
    /// networks. Scalars are log/linearly squashed to O(1) ranges so one
    /// network serves tables spanning 4..768 dims and 1e3..1e7 rows:
    ///   f0 = dim/64, f1 = log10(hash)/7, f2 = log2(1+pooling)/8,
    ///   f3 = size_gb, f4.. = bins (already a distribution).
    pub fn features(&self) -> [f32; NUM_FEATURES] {
        let mut f = [0.0f32; NUM_FEATURES];
        f[0] = self.dim as f32 / 64.0;
        f[1] = ((self.hash_size.max(1)) as f32).log10() / 7.0;
        f[2] = (1.0 + self.pooling).log2() / 8.0;
        f[3] = self.size_gb();
        f[4..4 + NUM_BINS].copy_from_slice(&self.bins);
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Table {
        let mut bins = [0.0; NUM_BINS];
        bins[0] = 0.5;
        bins[8] = 0.5;
        Table { dim: 16, hash_size: 1_000_000, pooling: 15.0, bins }
    }

    #[test]
    fn size_gb() {
        // 1e6 rows * 16 dims * 2 bytes = 32 MB
        assert!((t().size_gb() - 0.032).abs() < 1e-6);
    }

    #[test]
    fn features_normalized() {
        let f = t().features();
        assert!((f[0] - 0.25).abs() < 1e-6);
        assert!((f[1] - 6.0 / 7.0).abs() < 1e-6);
        assert!(f[2] > 0.0 && f[2] < 1.0);
        let bin_sum: f32 = f[4..].iter().sum();
        assert!((bin_sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn reuse_monotone_in_hotness() {
        let mut cold = t();
        cold.bins = [0.0; NUM_BINS];
        cold.bins[0] = 1.0;
        let mut hot = t();
        hot.bins = [0.0; NUM_BINS];
        hot.bins[NUM_BINS - 1] = 1.0;
        assert!(cold.reuse_factor() < 0.01);
        assert!(hot.reuse_factor() > 0.99);
        assert!(t().reuse_factor() > cold.reuse_factor());
    }
}
