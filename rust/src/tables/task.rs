//! Placement tasks (paper section 2 + E): a task is a set of tables plus a
//! device count. Train/test tasks are drawn from *disjoint* table pools so
//! every test table is unseen (the GETP generalizability requirement).

use super::dataset::Dataset;
use crate::util::Rng;

/// One placement task `T_i = (E_i, D_i)`: indices into a dataset plus the
/// number of identical devices.
#[derive(Clone, Debug)]
pub struct Task {
    pub table_ids: Vec<usize>,
    pub n_devices: usize,
}

impl Task {
    pub fn n_tables(&self) -> usize {
        self.table_ids.len()
    }
}

/// A train/test suite in the paper's `dataset-num_tables (num_devices)`
/// naming, e.g. DLRM-50 (4).
#[derive(Clone, Debug)]
pub struct TaskSet {
    pub name: String,
    pub train: Vec<Task>,
    pub test: Vec<Task>,
}

/// Split all table ids in half into disjoint train/test pools (section E).
pub fn split_pools(dataset: &Dataset, seed: u64) -> (Vec<usize>, Vec<usize>) {
    let mut ids: Vec<usize> = (0..dataset.len()).collect();
    let mut rng = Rng::new(seed).fork(0x9001);
    rng.shuffle(&mut ids);
    let half = ids.len() / 2;
    let test = ids.split_off(half);
    (ids, test)
}

/// Sample `n_tasks` tasks of `n_tables` tables each from a pool.
pub fn sample_tasks(
    pool: &[usize],
    n_tables: usize,
    n_devices: usize,
    n_tasks: usize,
    seed: u64,
) -> Vec<Task> {
    assert!(n_tables <= pool.len(), "pool of {} too small for {} tables", pool.len(), n_tables);
    let mut rng = Rng::new(seed).fork(0x7A5C);
    (0..n_tasks)
        .map(|_| {
            let picks = rng.sample_indices(pool.len(), n_tables);
            Task { table_ids: picks.into_iter().map(|i| pool[i]).collect(), n_devices }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::gen_dlrm;

    #[test]
    fn pools_disjoint_and_cover() {
        let d = gen_dlrm(100, 0);
        let (tr, te) = split_pools(&d, 1);
        assert_eq!(tr.len(), 50);
        assert_eq!(te.len(), 50);
        let mut all: Vec<usize> = tr.iter().chain(te.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn tasks_sample_from_pool_without_dup() {
        let d = gen_dlrm(100, 0);
        let (tr, _) = split_pools(&d, 1);
        let tasks = sample_tasks(&tr, 20, 4, 10, 2);
        assert_eq!(tasks.len(), 10);
        for t in &tasks {
            assert_eq!(t.n_tables(), 20);
            assert_eq!(t.n_devices, 4);
            let mut ids = t.table_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), 20, "duplicate table in task");
            assert!(ids.iter().all(|i| tr.contains(i)));
        }
    }

    #[test]
    fn deterministic() {
        let d = gen_dlrm(100, 0);
        let (tr, _) = split_pools(&d, 1);
        let a = sample_tasks(&tr, 10, 2, 5, 7);
        let b = sample_tasks(&tr, 10, 2, 5, 7);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.table_ids, y.table_ids);
        }
    }
}
