//! Serving front end: lane-batched planning as a **service**, not a
//! library call.
//!
//! The PR-2 placer facade made every strategy answer one request; this
//! module makes the crate answer *traffic*. [`PlanService`] wraps any
//! [`crate::placer::Placer`] behind a bounded FIFO of heterogeneous
//! placement requests (mixed table counts and device counts):
//!
//! * [`PlanService::submit`] enqueues a request — tagged with the
//!   artifact variant that will serve it, asking the placer first
//!   ([`crate::placer::Placer::serving_variant`]: a DreamShard agent
//!   lane-shares all the device counts it covers under one variant) —
//!   or sheds it when the bounded queue is full: open-loop load
//!   shedding, never unbounded growth;
//! * [`PlanService::drain_chunk`] takes the oldest request's serving
//!   variant, collects up to a lane-chunk of queued requests of that same
//!   variant (FIFO order within the group; younger requests of other
//!   variants stay queued), and plans them through **one**
//!   [`crate::placer::Placer::place_many`] call. For the DreamShard
//!   placer that means one fused `mdp_step` backend call per MDP step
//!   shared by every lane, plus one concatenated `[N, F]` `table_cost`
//!   pass ordering every task in the chunk
//!   ([`crate::coordinator::DreamShard::order_tables_batch`]);
//! * [`PlanService::drain`] **pipelines** those chunks over the placer's
//!   resumable sessions ([`crate::placer::Placer::open_session`]): up to
//!   [`ServeConfig::inflight`] chunks stay in flight on the shared
//!   runtime's worker pool, and while chunk k's fused call executes, the
//!   drain loop fills chunk k+1's feature tensors (double-buffered).
//!   Plans and per-chunk call budgets are bit-identical to the blocking
//!   [`PlanService::drain_blocking`]; only the waits overlap. Chunks the
//!   placer declines a session for fall back to the blocking path;
//! * per-request queue/plan latency and aggregate throughput are recorded
//!   in [`ServeStats`], and drained plans come back as [`Planned`]
//!   (ticket + plan + latency split).
//!
//! The whole loop, compiled (any placer works; a greedy expert keeps the
//! doctest fast):
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::serve::{PlanService, ServeConfig};
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(60, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let tasks = sample_tasks(&pool, 8, 4, 3, 2); // three 4-device tasks
//! let sim = Simulator::new(SimConfig::default());
//!
//! let placer = placer::by_name(&rt, "greedy:size").unwrap();
//! let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
//! for t in &tasks {
//!     let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
//!     svc.submit(req).unwrap().expect("queue has room");
//! }
//! let done = svc.drain().unwrap();
//! assert_eq!(done.len(), 3);
//! assert_eq!(svc.stats().planned, 3);
//! ```
//!
//! One service is still one FIFO, so a slow variant's chunk at the queue
//! head stalls every other variant behind it. [`ShardedFrontEnd`] lifts
//! the same API to *many* planning streams: one `PlanService` per serving
//! variant (per tenant, optionally), a single submit that routes by
//! variant ([`crate::placer::Placer::warm_variant`] +
//! [`crate::placer::Placer::serving_variant`]), per-shard drain threads
//! over the shared runtime worker pool, and a global queued-request cap
//! as the one backpressure knob ([`ShardConfig::global_cap`]). Plans and
//! backend-call budgets are bit-identical to draining the same shards
//! sequentially ([`ShardedFrontEnd::drain_sequential`]).
//!
//! Both layers also answer the day-2 question — the fleet *changed*
//! (a device died, capacity was added) and live plans must follow
//! without paying full re-plan migrations. [`PlanService::rebalance`]
//! takes [`ReplaceJob`]s (previous plan + new request, optionally under
//! a [`crate::placer::MigrationBudget`]) and drains them through the
//! placer's [`crate::placer::Placer::replace_many`] in the same
//! variant-keyed lane chunks a drain uses, bypassing the submit FIFO
//! entirely; [`ShardedFrontEnd::rebalance`] routes jobs per variant and
//! runs the per-shard rebalances concurrently. Moved-table counts and
//! migration cost land in [`ServeStats`] / [`FrontStats`].
//!
//! Workload generation lives in [`synthetic_arrivals`]: arrival
//! schedules (exponential gaps, mixed 2/4/8/128-device tasks) that the
//! `serve-sim` CLI subcommand (`--workers` sizes the runtime pool,
//! `--sharded` serves through the front end), `benches/serving.rs`
//! (pipelined vs blocking drains, sharded vs single-FIFO), and
//! `examples/serve_queue.rs` replay — open-loop (wall schedule) or
//! closed-loop ([`WorkloadCfg::closed_loop`]: each arrival offset from
//! the previous drain completion).
//!
//! Finally, the **closed loop**: nobody should hand-tune chunk sizes and
//! admission caps against live traffic. [`Controller`] watches the
//! per-shard signals the front end already exposes ([`ShardView`]:
//! queue-latency percentiles, queue depths, drain-completion ages) and
//! steers the existing knobs toward a [`ControlConfig`] tail-latency
//! target — resizing lane-chunks, adapting the global admission cap
//! (AIMD), scheduling which shards drain, toggling SLO-class pressure
//! mode ([`SloClass`]: interactive traffic drains first, batch sheds
//! first), and sizing [`crate::placer::MigrationBudget`]s for
//! [`ShardedFrontEnd::rebalance`] to measured headroom. One tick,
//! compiled (the [`TestClock`] keeps it deterministic):
//!
//! ```
//! use std::sync::Arc;
//! use dreamshard::placer::{self, PlacementRequest};
//! use dreamshard::runtime::Runtime;
//! use dreamshard::serve::{
//!     ControlConfig, Controller, ShardConfig, ShardedFrontEnd, TestClock,
//! };
//! use dreamshard::sim::{SimConfig, Simulator};
//! use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
//!
//! let rt = Arc::new(Runtime::reference());
//! let ds = gen_dlrm(60, 0);
//! let (pool, _) = split_pools(&ds, 1);
//! let tasks = sample_tasks(&pool, 8, 4, 3, 2);
//! let sim = Simulator::new(SimConfig::default());
//!
//! let clock = Arc::new(TestClock::new());
//! let factory = {
//!     let rt = Arc::clone(&rt);
//!     move || placer::by_name(&rt, "greedy:size")
//! };
//! let mut front =
//!     ShardedFrontEnd::with_clock(&rt, factory, ShardConfig::default(), clock.clone())
//!         .unwrap();
//! for t in &tasks {
//!     let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
//!     front.submit(req).unwrap().expect("under the global cap");
//! }
//! clock.advance_ms(10.0);
//!
//! let mut ctl = Controller::new(ControlConfig { target_ms: 50.0, ..Default::default() });
//! let report = ctl.tick(&mut front).unwrap(); // observe, actuate, drain
//! assert_eq!(report.planned.len(), 3, "the queued shard was drained");
//! assert!(!report.pressure, "10 ms of queueing is well under a 50 ms target");
//! ```
//!
//! The `serve-sim --closed-loop --target-ms T` CLI mode replays a
//! closed-loop workload through this controller and prints a
//! static-vs-controlled comparison.

mod clock;
mod control;
mod service;
mod sharded;
mod workload;

pub use clock::{system_clock, Clock, SystemClock, TestClock};
pub use control::{ControlConfig, Controller, ShardDecision, TickReport};
pub use service::{PlanService, Planned, ReplaceJob, ServeConfig, ServeStats, SloClass};
pub use sharded::{FrontStats, Routed, ShardConfig, ShardKey, ShardView, ShardedFrontEnd};
pub use workload::{synthetic_arrivals, Arrival, WorkloadCfg};
