//! [`PlanService`]: the bounded planning queue and its variant-grouped,
//! lane-chunked drain loop — blocking per chunk, or pipelined across
//! chunks over the placer's resumable sessions.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::Variant;
use crate::err;
use crate::placer::{Placer, PlacementPlan, PlacementRequest, PlanSession};
use crate::runtime::{Runtime, Ticket};
use crate::util::error::{Error, Result};
use crate::util::{median, percentile};

use super::clock::{system_clock, Clock};

/// Service-level objective class of one request. Classes order by
/// urgency: under pressure ([`PlanService::set_class_order`]) shards
/// drain `Interactive` traffic before `Batch`, and the admission path
/// sheds or evicts `Batch` first — batch replanning can wait out an
/// overload, a user-facing placement cannot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    /// User-facing traffic: drained first under pressure, evicts queued
    /// batch work rather than shed at a full queue. The default class.
    #[default]
    Interactive,
    /// Deferrable replanning traffic: shed or deferred first.
    Batch,
}

impl SloClass {
    /// Short label for tables and logs.
    pub fn label(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }
}

/// Service knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Bounded-queue capacity: submits beyond it are shed
    /// ([`PlanService::submit`] returns `Ok(None)`), never buffered
    /// without limit.
    pub capacity: usize,
    /// Maximum requests drained per [`Placer::place_many`] call — the
    /// lane-chunk size. The DreamShard placer fills up to `E` backend
    /// lanes per chunk, so the artifact's lane count is the natural value.
    pub chunk: usize,
    /// Chunks concurrently in flight during a pipelined
    /// [`PlanService::drain`] (2 = double buffering: chunk k+1's feature
    /// tensors fill while chunk k's fused call executes). 1 disables the
    /// overlap without changing any plan.
    pub inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { capacity: 256, chunk: 16, inflight: 2 }
    }
}

/// One completed request: the plan plus its service-side latency split.
#[derive(Clone, Debug)]
pub struct Planned {
    /// Submission ticket (monotonically increasing per service).
    pub ticket: u64,
    /// Serving-variant key `(D, S)` the scheduler grouped this request by.
    pub variant: (usize, usize),
    /// SLO class the request was submitted under (rebalance re-plans are
    /// [`SloClass::Batch`]: they are deferrable replanning by nature).
    pub class: SloClass,
    pub plan: PlacementPlan,
    /// Time spent queued (submit to drain start), ms.
    pub queue_ms: f64,
    /// Wall time of the chunk this request was planned with, ms —
    /// requests in one chunk complete together, so they share it. In a
    /// pipelined drain this span overlaps other chunks' spans (it is a
    /// latency, not a throughput denominator — that is
    /// [`ServeStats::busy_s`]).
    pub plan_ms: f64,
}

/// Per-request latency samples kept for the median: a bounded window of
/// the most recent requests, so a long-lived service stays O(1) memory
/// no matter how much traffic it serves (means use exact running sums).
const SAMPLE_WINDOW: usize = 1024;

/// Aggregate service counters and latency aggregates.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests shed because the bounded queue was full (including
    /// queued batch requests evicted in favor of interactive traffic —
    /// see `shed_batch`).
    pub rejected: u64,
    /// The [`SloClass::Batch`] share of `rejected`: batch requests shed
    /// at a full queue plus queued batch requests evicted to admit
    /// interactive traffic under pressure
    /// ([`PlanService::evict_newest_batch`]). `rejected - shed_batch` is
    /// therefore the interactive shed count — the number a latency
    /// controller actually answers for.
    pub shed_batch: u64,
    /// Requests planned and returned.
    pub planned: u64,
    /// Chunks drained (one `place_many` call or one planning session
    /// each).
    pub chunks: u64,
    /// Backend executions dispatched while draining (via
    /// [`Runtime::run_count`] deltas).
    pub backend_calls: u64,
    /// Total wall time spent planning, seconds: inside `place_many` for
    /// blocking drains, and the whole pipelined burst (overlap counted
    /// once) for [`PlanService::drain`].
    pub busy_s: f64,
    /// Requests re-planned through [`PlanService::rebalance`] (each also
    /// counted in `planned`).
    pub rebalanced: u64,
    /// Tables that changed device across all rebalanced plans.
    pub moved_tables: u64,
    /// Total migration time charged across all rebalanced plans, ms.
    pub migration_ms: f64,
    queue_ms_sum: f64,
    plan_ms_sum: f64,
    recent_queue_ms: VecDeque<f64>,
}

impl ServeStats {
    /// Push one sample into the bounded median window, evicting the
    /// oldest at capacity (the single definition of the window policy,
    /// shared by [`ServeStats::record`] and [`ServeStats::merge`]).
    fn push_recent(&mut self, queue_ms: f64) {
        if self.recent_queue_ms.len() == SAMPLE_WINDOW {
            self.recent_queue_ms.pop_front();
        }
        self.recent_queue_ms.push_back(queue_ms);
    }

    fn record(&mut self, queue_ms: f64, plan_ms: f64) {
        self.planned += 1;
        self.queue_ms_sum += queue_ms;
        self.plan_ms_sum += plan_ms;
        self.push_recent(queue_ms);
    }

    /// Planning throughput over the time actually spent planning.
    pub fn plans_per_sec(&self) -> f64 {
        if self.busy_s > 0.0 {
            self.planned as f64 / self.busy_s
        } else {
            0.0
        }
    }

    /// Mean queue latency over every planned request, ms.
    pub fn mean_queue_ms(&self) -> f64 {
        if self.planned > 0 {
            self.queue_ms_sum / self.planned as f64
        } else {
            0.0
        }
    }

    /// Mean plan latency over every planned request, ms.
    pub fn mean_plan_ms(&self) -> f64 {
        if self.planned > 0 {
            self.plan_ms_sum / self.planned as f64
        } else {
            0.0
        }
    }

    /// Median queue latency over the most recent requests (bounded
    /// window), ms.
    pub fn median_queue_ms(&self) -> f64 {
        let recent: Vec<f64> = self.recent_queue_ms.iter().copied().collect();
        median(&recent)
    }

    /// Nearest-rank queue-latency percentile (`q` in `[0, 1]`) over the
    /// most recent requests — the same bounded window the median reads,
    /// so a long-lived service stays O(1) memory while still answering
    /// tail-latency questions. 0.0 before anything has been planned.
    /// This is the signal the closed-loop controller
    /// ([`crate::serve::Controller`]) steers against.
    pub fn percentile_queue_ms(&self, q: f64) -> f64 {
        let recent: Vec<f64> = self.recent_queue_ms.iter().copied().collect();
        percentile(&recent, q)
    }

    /// p95 queue latency over the bounded recent window, ms.
    pub fn p95_queue_ms(&self) -> f64 {
        self.percentile_queue_ms(0.95)
    }

    /// p99 queue latency over the bounded recent window, ms.
    pub fn p99_queue_ms(&self) -> f64 {
        self.percentile_queue_ms(0.99)
    }

    /// Samples currently in the bounded latency window (at most the
    /// window size, no matter how much traffic was served or how many
    /// stats were [`ServeStats::merge`]d in).
    pub fn window_len(&self) -> usize {
        self.recent_queue_ms.len()
    }

    /// Fold another service's counters into this one — how the sharded
    /// front end ([`crate::serve::ShardedFrontEnd`]) aggregates per-shard
    /// stats into one view. Counts and latency means stay exact (they are
    /// running sums); the median window concatenates the other service's
    /// most recent samples, still bounded at the per-service window
    /// size. Note that
    /// [`ServeStats::busy_s`] adds up planning time across services, so
    /// an aggregate over concurrently-draining shards can exceed wall
    /// clock — [`ServeStats::plans_per_sec`] on a merged value is
    /// per-shard-thread throughput, not front-end wall-clock throughput.
    pub fn merge(&mut self, other: &ServeStats) {
        self.submitted += other.submitted;
        self.rejected += other.rejected;
        self.shed_batch += other.shed_batch;
        self.planned += other.planned;
        self.chunks += other.chunks;
        self.backend_calls += other.backend_calls;
        self.busy_s += other.busy_s;
        self.rebalanced += other.rebalanced;
        self.moved_tables += other.moved_tables;
        self.migration_ms += other.migration_ms;
        self.queue_ms_sum += other.queue_ms_sum;
        self.plan_ms_sum += other.plan_ms_sum;
        for &q in &other.recent_queue_ms {
            self.push_recent(q);
        }
    }

    /// One-line human summary of the counters and latency aggregates.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} planned / {} accepted ({} shed, {} batch) in {} chunks: {:.1} plans/s, \
             {} backend calls, queue {:.2}/{:.2}/{:.2} ms (mean/median/p95), \
             plan {:.2} ms mean",
            self.planned,
            self.submitted,
            self.rejected,
            self.shed_batch,
            self.chunks,
            self.plans_per_sec(),
            self.backend_calls,
            self.mean_queue_ms(),
            self.median_queue_ms(),
            self.p95_queue_ms(),
            self.mean_plan_ms(),
        );
        if self.rebalanced > 0 {
            s.push_str(&format!(
                ", {} rebalanced ({} tables moved, {:.1} ms migration)",
                self.rebalanced, self.moved_tables, self.migration_ms
            ));
        }
        s
    }
}

/// One rebalance job: a previously served plan plus the perturbed
/// request (device lost/added, load shift) to re-plan it against — the
/// unit [`PlanService::rebalance`] and
/// [`crate::serve::ShardedFrontEnd::rebalance`] consume. The request's
/// [`crate::placer::MigrationBudget`] bounds what the re-plan may move.
pub struct ReplaceJob<'a> {
    pub prev: PlacementPlan,
    pub req: PlacementRequest<'a>,
}

struct Queued<'a> {
    ticket: u64,
    req: PlacementRequest<'a>,
    key: (usize, usize),
    class: SloClass,
    submitted: Instant,
}

/// One chunk being advanced by the pipelined drain: its open session,
/// the queue entries it will answer, and the fused call currently in
/// flight on the runtime worker pool (`None` once all steps applied).
struct InFlight<'a> {
    session: Box<dyn PlanSession<'a> + 'a>,
    picked: Vec<Queued<'a>>,
    key: (usize, usize),
    start: Instant,
    ticket: Option<Ticket>,
}

/// A planning service over any [`Placer`]: bounded FIFO in, lane-batched
/// chunks out. See the [module docs](crate::serve) for the drain policy.
pub struct PlanService<'a> {
    rt: Arc<Runtime>,
    placer: Box<dyn Placer>,
    cfg: ServeConfig,
    /// Time source for queue/plan latencies (the closed-loop seam: a
    /// [`super::TestClock`] makes every latency deterministic).
    clock: Arc<dyn Clock>,
    /// Drain in SLO-class order (interactive before batch) instead of
    /// pure FIFO — the pressure mode a controller toggles.
    class_order: bool,
    queue: VecDeque<Queued<'a>>,
    next_ticket: u64,
    stats: ServeStats,
    /// Some queued keys came from the per-device-count fallback (the
    /// placer could not name its serving variant at submit time), so the
    /// next drain should ask again before grouping.
    fallback_keys: bool,
    /// The placer has been handed at least one chunk (`place_many` or
    /// `open_session`) — i.e. a lazily-initialized placer has had its
    /// chance to create its agent, so re-asking for serving variants can
    /// succeed now. Gates [`PlanService::refresh_keys`] so the pass never
    /// runs — and never wrongly concludes "hopeless" — before that.
    placer_engaged: bool,
    /// A refresh pass after planning had begun got `None` for every
    /// queued request: the placer never names variants (greedy, random,
    /// rnn) — stop asking.
    refresh_hopeless: bool,
}

impl<'a> PlanService<'a> {
    /// Wrap a placer. `rt` must be the same runtime the placer executes
    /// on — it is consulted for scheduling metadata (fallback variant
    /// keys from its manifest) and for the backend-call counters the
    /// stats report; a different handle would mis-key and count nothing.
    pub fn new(rt: &Arc<Runtime>, placer: Box<dyn Placer>, cfg: ServeConfig) -> Self {
        Self::with_clock(rt, placer, cfg, system_clock())
    }

    /// [`PlanService::new`] on an explicit time source — the clock seam
    /// that makes queue/plan latencies (and everything a closed-loop
    /// controller reads off them) deterministic under a
    /// [`super::TestClock`].
    pub fn with_clock(
        rt: &Arc<Runtime>,
        placer: Box<dyn Placer>,
        cfg: ServeConfig,
        clock: Arc<dyn Clock>,
    ) -> Self {
        PlanService {
            rt: Arc::clone(rt),
            placer,
            cfg: ServeConfig {
                capacity: cfg.capacity.max(1),
                chunk: cfg.chunk.max(1),
                inflight: cfg.inflight.max(1),
            },
            clock,
            class_order: false,
            queue: VecDeque::new(),
            next_ticket: 0,
            stats: ServeStats::default(),
            fallback_keys: false,
            placer_engaged: false,
            refresh_hopeless: false,
        }
    }

    /// Registry name of the wrapped strategy.
    pub fn placer_name(&self) -> &str {
        self.placer.name()
    }

    /// Current lane-chunk size ([`ServeConfig::chunk`]).
    pub fn chunk(&self) -> usize {
        self.cfg.chunk
    }

    /// Resize the lane-chunk (clamped to at least 1) — a live actuator:
    /// the next [`PlanService::drain_chunk`] picks up the new size, and
    /// nothing already queued is touched. Larger chunks amortize more
    /// planning per fused backend call (throughput), smaller chunks
    /// complete sooner (latency); the closed-loop controller trades
    /// between them.
    pub fn set_chunk(&mut self, chunk: usize) {
        self.cfg.chunk = chunk.max(1);
    }

    /// Whether drains pick SLO-class order over pure FIFO.
    pub fn class_order(&self) -> bool {
        self.class_order
    }

    /// Toggle class-ordered draining: when on, the oldest request of the
    /// most urgent queued class picks each chunk (interactive before
    /// batch; FIFO *within* a class and variant), and a full queue
    /// prefers evicting queued batch work over shedding an interactive
    /// submit. When off (the default) the queue is one class-blind FIFO
    /// and behavior is bit-identical to a service without SLO classes.
    pub fn set_class_order(&mut self, on: bool) {
        self.class_order = on;
    }

    /// Requests currently queued.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether the next submit would be shed.
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.cfg.capacity
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Enqueue one request. Returns `Ok(Some(ticket))` on acceptance and
    /// `Ok(None)` when the bounded queue is full (the request is counted
    /// as shed — that is load shedding, not an error; a full queue sheds
    /// before any other work or validation). `Err` only when no lowered
    /// artifact variant can serve the request's device count.
    ///
    /// The grouping key prefers [`Placer::serving_variant`] — DreamShard
    /// reports its agent's variant for every device count the agent
    /// covers, so mixed 2/4/8-device traffic shares one lane-chunk —
    /// falling back to the smallest lowered variant for the device count.
    pub fn submit(&mut self, req: PlacementRequest<'a>) -> Result<Option<u64>> {
        self.submit_class(req, SloClass::default())
    }

    /// [`PlanService::submit`] with an explicit [`SloClass`]. Under
    /// class-ordered pressure ([`PlanService::set_class_order`]) a full
    /// queue treats the classes differently: an interactive submit first
    /// tries to evict the youngest queued batch request
    /// ([`PlanService::evict_newest_batch`]) and takes its place; a
    /// batch submit is simply shed (and counted in
    /// [`ServeStats::shed_batch`]).
    pub fn submit_class(
        &mut self,
        req: PlacementRequest<'a>,
        class: SloClass,
    ) -> Result<Option<u64>> {
        if self.is_full() {
            let evicted = class == SloClass::Interactive
                && self.class_order
                && self.evict_newest_batch().is_some();
            if !evicted {
                self.stats.rejected += 1;
                if class == SloClass::Batch {
                    self.stats.shed_batch += 1;
                }
                return Ok(None);
            }
        }
        let key = match self.placer.serving_variant(&req) {
            Some(key) => key,
            None => {
                let var = Variant::for_devices(&self.rt, req.task.n_devices)?;
                self.fallback_keys = true;
                (var.d, var.s)
            }
        };
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let submitted = self.clock.now();
        self.queue.push_back(Queued { ticket, req, key, class, submitted });
        self.stats.submitted += 1;
        Ok(Some(ticket))
    }

    /// Drop the youngest queued [`SloClass::Batch`] request to make room
    /// for interactive traffic, returning its ticket (`None` when no
    /// batch work is queued). The eviction is deferral, not loss, from
    /// the traffic source's point of view: the caller that submitted it
    /// learns nothing here, but the counters do —
    /// [`ServeStats::rejected`] and [`ServeStats::shed_batch`] both
    /// record it, exactly as if the batch request had been shed at
    /// submit time.
    pub fn evict_newest_batch(&mut self) -> Option<u64> {
        let idx = self.queue.iter().rposition(|q| q.class == SloClass::Batch)?;
        let evicted = self.queue.remove(idx)?;
        self.stats.rejected += 1;
        self.stats.shed_batch += 1;
        Some(evicted.ticket)
    }

    /// When the youngest queued batch request was submitted (`None` when
    /// none is queued) — how a front end picks *which* shard's batch
    /// work to evict first.
    pub(super) fn newest_batch_submitted(&self) -> Option<Instant> {
        self.queue
            .iter()
            .filter(|q| q.class == SloClass::Batch)
            .map(|q| q.submitted)
            .max()
    }

    /// Refresh stale grouping keys when they can be stale: some key came
    /// from the submit-time fallback AND the placer has been engaged (a
    /// lazily-initialized placer — an untrained DreamShard — cannot
    /// report its serving variant until its first chunk creates the
    /// agent; after that, fallback-keyed requests re-merge under the
    /// agent's variant here — in a pipelined drain that is already the
    /// *second pick of the first burst*, matching the blocking drain's
    /// grouping). Placers that knew their variants at submit time never
    /// pay this pass, and one all-`None` pass disarms it for placers
    /// that never will.
    fn refresh_keys(&mut self) {
        if !self.fallback_keys || self.refresh_hopeless || !self.placer_engaged {
            return;
        }
        let mut any_known = false;
        let mut all_known = true;
        for q in self.queue.iter_mut() {
            match self.placer.serving_variant(&q.req) {
                Some(k) => {
                    q.key = k;
                    any_known = true;
                }
                None => all_known = false,
            }
        }
        if all_known {
            self.fallback_keys = false;
        }
        if !any_known {
            self.refresh_hopeless = true;
        }
    }

    /// Pop the next lane-chunk: the oldest request picks the serving
    /// variant; up to [`ServeConfig::chunk`] queued requests of that
    /// variant are collected in FIFO order (younger requests of other
    /// variants keep their place in the queue). `None` when the queue is
    /// empty.
    ///
    /// Under class-ordered pressure ([`PlanService::set_class_order`])
    /// the *lead* request is the oldest of the most urgent queued class
    /// instead of the queue head, and the chunk collects only that
    /// class — so interactive traffic drains first even with older batch
    /// work ahead of it, while FIFO order still holds within each
    /// `(class, variant)` stream.
    fn pick_chunk(&mut self) -> Option<((usize, usize), Vec<Queued<'a>>)> {
        if self.queue.is_empty() {
            return None;
        }
        self.refresh_keys();
        // min_by_key returns the first minimum, so ties go to the oldest
        // queued request of the winning class
        let lead = if self.class_order {
            self.queue.iter().min_by_key(|q| q.class)?
        } else {
            self.queue.front()?
        };
        let (key, class) = (lead.key, self.class_order.then_some(lead.class));
        let mut picked: Vec<Queued<'a>> = Vec::new();
        let mut rest: VecDeque<Queued<'a>> = VecDeque::with_capacity(self.queue.len());
        while let Some(q) = self.queue.pop_front() {
            if q.key == key
                && class.map_or(true, |c| q.class == c)
                && picked.len() < self.cfg.chunk
            {
                picked.push(q);
            } else {
                rest.push_back(q);
            }
        }
        self.queue = rest;
        Some((key, picked))
    }

    /// Put a picked chunk back at the head of the queue, original order
    /// intact (a failed drain must not lose requests).
    fn requeue(&mut self, picked: Vec<Queued<'a>>) {
        for q in picked.into_iter().rev() {
            self.queue.push_front(q);
        }
    }

    /// Account a successfully planned chunk and build its [`Planned`]
    /// records. `count_busy` adds the chunk's own wall span to
    /// [`ServeStats::busy_s`] (blocking drains); pipelined drains count
    /// their burst wall once instead, since chunk spans overlap.
    fn finish_chunk(
        &mut self,
        key: (usize, usize),
        picked: Vec<Queued<'a>>,
        plans: Vec<PlacementPlan>,
        start: Instant,
        count_busy: bool,
    ) -> Vec<Planned> {
        let wall_ms = self.clock.now().duration_since(start).as_secs_f64() * 1e3;
        self.stats.chunks += 1;
        if count_busy {
            self.stats.busy_s += wall_ms / 1e3;
        }
        let mut done = Vec::with_capacity(picked.len());
        for (q, plan) in picked.into_iter().zip(plans.into_iter()) {
            let queue_ms = start.duration_since(q.submitted).as_secs_f64() * 1e3;
            self.stats.record(queue_ms, wall_ms);
            done.push(Planned {
                ticket: q.ticket,
                variant: key,
                class: q.class,
                plan,
                queue_ms,
                plan_ms: wall_ms,
            });
        }
        done
    }

    /// Drain one lane-chunk through one blocking
    /// [`Placer::place_many`] call. Returns the completed requests in
    /// submission order; empty when the queue is empty.
    ///
    /// Completion order is FIFO within each variant group as keyed at
    /// drain time. Keys are stable — and the per-group FIFO guarantee
    /// therefore global — once the placer knows its serving variants,
    /// which is always the case for a fitted (or wrapped-agent) placer;
    /// a lazily-initialized one may merge fallback-keyed groups after
    /// its first drain creates the agent.
    pub fn drain_chunk(&mut self) -> Result<Vec<Planned>> {
        let Some((key, picked)) = self.pick_chunk() else {
            return Ok(vec![]);
        };
        let start = self.clock.now();
        let calls_before = self.rt.run_count();
        let reqs: Vec<PlacementRequest<'a>> = picked.iter().map(|q| q.req).collect();
        let result = self.placer.place_many(&reqs);
        self.placer_engaged = true;
        // count backend work whether or not the drain succeeded — a
        // failed chunk still spent real executions
        self.stats.backend_calls += self.rt.run_count() - calls_before;
        let plans: Vec<PlacementPlan> = match result {
            Ok(plans) if plans.len() == reqs.len() => plans,
            result => {
                // a failed — or short: every request must come back, or
                // the zip below would silently drop the tail — drain
                // must not lose requests
                let err = match result {
                    Err(e) => e,
                    Ok(short) => err!(
                        "placer `{}` returned {} plans for {} requests",
                        self.placer.name(),
                        short.len(),
                        reqs.len()
                    ),
                };
                self.requeue(picked);
                return Err(err);
            }
        };
        Ok(self.finish_chunk(key, picked, plans, start, true))
    }

    /// Drain the whole queue, one blocking chunk at a time (the
    /// pre-session behavior; `benches/serving.rs` compares it against the
    /// pipelined [`PlanService::drain`]).
    pub fn drain_blocking(&mut self) -> Result<Vec<Planned>> {
        let mut out = vec![];
        while !self.queue.is_empty() {
            out.extend(self.drain_chunk()?);
        }
        Ok(out)
    }

    /// Drain the whole queue. Chunks whose placer supports resumable
    /// sessions ([`Placer::open_session`]) are **pipelined**: up to
    /// [`ServeConfig::inflight`] chunks stay in flight on the runtime's
    /// worker pool, and while one chunk's fused call executes, the drain
    /// loop fills the next chunk's feature tensors (double-buffered).
    /// Chunk composition, per-chunk backend-call budgets, and every plan
    /// are identical to [`PlanService::drain_blocking`] — the sessions
    /// run the same MDP with the same artifacts; only the waits overlap.
    /// Chunks the placer declines a session for (non-batch placers,
    /// mixed-variant or oversized chunks) fall back to the blocking path
    /// one chunk at a time, preserving drain order.
    ///
    /// On error the failed chunk and every still-in-flight chunk requeue
    /// at the head (original order intact, those requests are never
    /// lost), while chunks the same drain call had already completed are
    /// counted in [`ServeStats`] but their [`Planned`] results are not
    /// returned — the `Err` carries no partial output (exactly the
    /// whole-queue contract [`PlanService::drain_blocking`] has always
    /// had). Callers that need loss-free delivery of every completed
    /// chunk under mid-drain failures should loop
    /// [`PlanService::drain_chunk`] and keep each returned batch.
    pub fn drain(&mut self) -> Result<Vec<Planned>> {
        let mut out = vec![];
        while !self.queue.is_empty() {
            let (mut burst, declined) = self.drain_pipelined_burst()?;
            out.append(&mut burst);
            if declined && !self.queue.is_empty() {
                // the placer declined a session for the chunk now at the
                // head: plan exactly that one blocking, then try
                // pipelining again (later chunks may support sessions)
                out.extend(self.drain_chunk()?);
            }
        }
        Ok(out)
    }

    /// Re-plan a batch of previously served streams against their
    /// perturbed requests, draining [`Placer::replace_many`] calls
    /// instead of `place_many`. Jobs are keyed and variant-grouped like
    /// submits, then chunked like drains, so DreamShard's warm-started
    /// lane batching keeps its per-chunk call budgets. The FIFO is
    /// untouched: rebalance jobs are not new traffic, and any requests
    /// already queued keep their places and their keys.
    ///
    /// Each returned [`Planned`] carries a fresh ticket and `queue_ms` 0
    /// (jobs never queue); moved-table counts and migration cost land in
    /// [`ServeStats`]. On error nothing is returned — the caller still
    /// holds the previous plans, so a retry re-submits the same jobs
    /// (nothing is lost, unlike a drained queue there is no state here
    /// to requeue).
    pub fn rebalance(&mut self, jobs: Vec<ReplaceJob<'a>>) -> Result<Vec<Planned>> {
        let mut keyed: Vec<(ReplaceJob<'a>, (usize, usize))> = Vec::with_capacity(jobs.len());
        for job in jobs {
            let key = match self.placer.serving_variant(&job.req) {
                Some(key) => key,
                None => {
                    let var = Variant::for_devices(&self.rt, job.req.task.n_devices)?;
                    (var.d, var.s)
                }
            };
            keyed.push((job, key));
        }
        let mut out: Vec<Planned> = Vec::with_capacity(keyed.len());
        while !keyed.is_empty() {
            // oldest job picks the variant; same-key jobs fill the chunk
            let key = keyed[0].1;
            let mut chunk: Vec<ReplaceJob<'a>> = Vec::new();
            let mut rest: Vec<(ReplaceJob<'a>, (usize, usize))> = Vec::new();
            for (job, k) in keyed {
                if k == key && chunk.len() < self.cfg.chunk {
                    chunk.push(job);
                } else {
                    rest.push((job, k));
                }
            }
            keyed = rest;
            let start = self.clock.now();
            let calls_before = self.rt.run_count();
            let prevs: Vec<PlacementPlan> = chunk.iter().map(|j| j.prev.clone()).collect();
            let reqs: Vec<PlacementRequest<'a>> = chunk.iter().map(|j| j.req).collect();
            let result = self.placer.replace_many(&prevs, &reqs);
            self.placer_engaged = true;
            self.stats.backend_calls += self.rt.run_count() - calls_before;
            let plans = match result {
                Ok(plans) if plans.len() == reqs.len() => plans,
                Ok(short) => {
                    return Err(err!(
                        "placer `{}` returned {} plans for {} rebalance jobs",
                        self.placer.name(),
                        short.len(),
                        reqs.len()
                    ))
                }
                Err(e) => return Err(e),
            };
            let wall_ms = self.clock.now().duration_since(start).as_secs_f64() * 1e3;
            self.stats.chunks += 1;
            self.stats.busy_s += wall_ms / 1e3;
            for plan in plans {
                self.stats.record(0.0, wall_ms);
                self.stats.rebalanced += 1;
                self.stats.moved_tables += plan.eval.moved_tables as u64;
                self.stats.migration_ms += plan.eval.migration_ms;
                let ticket = self.next_ticket;
                self.next_ticket += 1;
                out.push(Planned {
                    ticket,
                    variant: key,
                    // a rebalance is replanning by definition: batch class
                    class: SloClass::Batch,
                    plan,
                    queue_ms: 0.0,
                    plan_ms: wall_ms,
                });
            }
        }
        Ok(out)
    }

    /// Pipeline chunks through placer sessions until the queue empties or
    /// the placer declines a session (`-> (completed, declined)`).
    fn drain_pipelined_burst(&mut self) -> Result<(Vec<Planned>, bool)> {
        let depth = self.cfg.inflight;
        let burst_start = self.clock.now();
        let calls_before = self.rt.run_count();
        let mut active: VecDeque<InFlight<'a>> = VecDeque::new();
        let mut out: Vec<Planned> = vec![];
        let mut declined = false;
        let mut failure: Option<Error> = None;

        'burst: loop {
            // top up the pipeline: keep `depth` chunks actively stepping
            while !declined
                && active.iter().filter(|c| c.ticket.is_some()).count() < depth
            {
                let Some((key, picked)) = self.pick_chunk() else { break };
                let reqs: Vec<PlacementRequest<'a>> = picked.iter().map(|q| q.req).collect();
                let start = self.clock.now();
                let opened = self.placer.open_session(&reqs);
                self.placer_engaged = true;
                match opened {
                    Ok(Some(mut session)) => match session.submit_step() {
                        Ok(ticket) => {
                            active.push_back(InFlight { session, picked, key, start, ticket });
                        }
                        Err(e) => {
                            self.requeue(picked);
                            failure = Some(e);
                            break 'burst;
                        }
                    },
                    Ok(None) => {
                        // untouched: hand the chunk back for the
                        // blocking fallback once the pipeline empties
                        self.requeue(picked);
                        declined = true;
                    }
                    Err(e) => {
                        self.requeue(picked);
                        failure = Some(e);
                        break 'burst;
                    }
                }
            }
            // emit chunks completed at the pipeline head, preserving pick
            // order (a shorter younger chunk waits for its elders)
            while active.front().map_or(false, |c| c.ticket.is_none()) {
                let Some(InFlight { session, picked, key, start, .. }) = active.pop_front()
                else {
                    break;
                };
                match session.finish() {
                    Ok(plans) if plans.len() == picked.len() => {
                        out.extend(self.finish_chunk(key, picked, plans, start, false));
                    }
                    Ok(short) => {
                        let n = picked.len();
                        self.requeue(picked);
                        failure = Some(err!(
                            "placer `{}` session returned {} plans for {n} requests",
                            self.placer.name(),
                            short.len(),
                        ));
                        break 'burst;
                    }
                    Err(e) => {
                        self.requeue(picked);
                        failure = Some(e);
                        break 'burst;
                    }
                }
            }
            if active.is_empty() {
                if declined || self.queue.is_empty() {
                    break;
                }
                continue;
            }
            // advance every in-flight chunk one MDP step, oldest first:
            // joining chunk i overlaps chunk i+1's already-submitted
            // execution, and chunk i's freshly submitted call executes
            // while chunk i+1 is joined and refilled — the fill/execute
            // overlap the session API exists for
            for c in active.iter_mut() {
                let Some(t) = c.ticket.take() else { continue };
                match t.wait().and_then(|vals| {
                    c.session.apply_step(vals)?;
                    c.session.submit_step()
                }) {
                    Ok(next) => c.ticket = next,
                    Err(e) => {
                        failure = Some(e);
                        break 'burst;
                    }
                }
            }
        }

        // on failure, requeue every in-flight chunk youngest-first so the
        // queue head ends up oldest-first again. Already-dispatched
        // tickets are *joined* (results discarded), not dropped: the
        // pool executes them regardless, and joining first means the
        // backend_calls delta below sees every execution this burst
        // dispatched instead of leaking stray increments into the next
        // drain's delta.
        while let Some(c) = active.pop_back() {
            if let Some(t) = c.ticket {
                let _ = t.wait(); // lint: allow(swallowed-result) — teardown join for the backend_calls delta; failure already recorded
            }
            self.requeue(c.picked);
        }
        self.stats.backend_calls += self.rt.run_count() - calls_before;
        match failure {
            Some(e) => Err(e),
            None => {
                if !out.is_empty() {
                    self.stats.busy_s +=
                        self.clock.now().duration_since(burst_start).as_secs_f64();
                }
                Ok((out, declined))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placer;
    use crate::sim::{SimConfig, Simulator};
    use crate::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};

    fn setup(n_tasks: usize, n_devices: usize) -> (Dataset, Vec<Task>, Simulator) {
        let ds = gen_dlrm(200, 0);
        let (pool, _) = split_pools(&ds, 1);
        let tasks = sample_tasks(&pool, 8, n_devices, n_tasks, 2);
        (ds, tasks, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn bounded_queue_sheds_when_full() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(6, 4);
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig {
            capacity: 4,
            chunk: 16,
            ..ServeConfig::default()
        });
        let mut accepted = 0;
        let mut shed = 0;
        for t in &tasks {
            let req = PlacementRequest::new(&ds, t, &sim);
            match svc.submit(req).unwrap() {
                Some(_) => accepted += 1,
                None => shed += 1,
            }
        }
        assert_eq!((accepted, shed), (4, 2));
        assert!(svc.is_full());
        assert_eq!(svc.stats().submitted, 4);
        assert_eq!(svc.stats().rejected, 2);
        let done = svc.drain().unwrap();
        assert_eq!(done.len(), 4);
        assert!(svc.is_empty());
        assert_eq!(svc.stats().planned, 4);
    }

    #[test]
    fn unservable_device_count_errors_at_submit() {
        let rt = Arc::new(Runtime::reference());
        let (ds, mut tasks, sim) = setup(1, 4);
        tasks[0].n_devices = 1000; // beyond the largest lowered variant
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
        let req = PlacementRequest::new(&ds, &tasks[0], &sim);
        assert!(svc.submit(req).is_err());
        assert_eq!(svc.stats().submitted, 0);
    }

    #[test]
    fn drain_chunk_respects_chunk_size_and_records_latency() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(5, 4);
        let placer = placer::by_name(&rt, "greedy:lookup").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig {
            capacity: 64,
            chunk: 2,
            ..ServeConfig::default()
        });
        for t in &tasks {
            svc.submit(PlacementRequest::new(&ds, t, &sim)).unwrap();
        }
        let first = svc.drain_chunk().unwrap();
        assert_eq!(first.len(), 2, "chunk size caps the drain");
        assert_eq!(svc.queued(), 3);
        assert_eq!(first[0].ticket, 0);
        assert_eq!(first[1].ticket, 1);
        for p in &first {
            assert_eq!(p.variant, (4, 48));
            assert_eq!(p.plan.strategy, "greedy:lookup");
            assert!(p.queue_ms >= 0.0);
            assert!(p.plan_ms >= 0.0);
        }
        let rest = svc.drain().unwrap();
        assert_eq!(rest.len(), 3);
        let stats = svc.stats();
        assert_eq!(stats.chunks, 3); // 2 + 2 + 1
        assert_eq!(stats.planned, 5);
        assert!(stats.mean_queue_ms() >= 0.0);
        assert!(stats.median_queue_ms() >= 0.0);
        assert!(stats.mean_plan_ms() >= 0.0);
        assert!(stats.summary().contains("5 planned"));
    }

    /// A placer whose planning always fails (drain error-path fixture).
    struct FailingPlacer;
    impl Placer for FailingPlacer {
        fn name(&self) -> &str {
            "failing"
        }
        fn place(&mut self, _req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
            Err(crate::err!("backend exploded"))
        }
    }

    #[test]
    fn failed_drain_requeues_the_chunk() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(3, 4);
        let mut svc =
            PlanService::new(&rt, Box::new(FailingPlacer), ServeConfig::default());
        for t in &tasks {
            svc.submit(PlacementRequest::new(&ds, t, &sim)).unwrap();
        }
        let err = svc.drain_chunk().expect_err("failing placer must error");
        assert!(err.to_string().contains("backend exploded"));
        // nothing was lost or double-counted: the chunk is back in the
        // queue, original order intact, and can be retried
        assert_eq!(svc.queued(), 3);
        assert_eq!(svc.stats().planned, 0);
        assert_eq!(svc.stats().chunks, 0);
        let err2 = svc.drain().expect_err("retry fails the same way");
        assert!(err2.to_string().contains("backend exploded"));
        assert_eq!(svc.queued(), 3);
    }

    /// A placer whose batch path drops requests (short-Ok fixture).
    struct ShortPlacer;
    impl Placer for ShortPlacer {
        fn name(&self) -> &str {
            "short"
        }
        fn place(&mut self, _req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
            Err(crate::err!("unused"))
        }
        fn place_many(&mut self, _reqs: &[PlacementRequest<'_>]) -> Result<Vec<PlacementPlan>> {
            Ok(vec![]) // loses every request
        }
    }

    #[test]
    fn short_plan_batches_are_rejected_not_dropped() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(2, 4);
        let mut svc =
            PlanService::new(&rt, Box::new(ShortPlacer), ServeConfig::default());
        for t in &tasks {
            svc.submit(PlacementRequest::new(&ds, t, &sim)).unwrap();
        }
        let err = svc.drain_chunk().expect_err("short batch must be an error");
        assert!(err.to_string().contains("returned 0 plans for 2"), "{err}");
        assert_eq!(svc.queued(), 2, "the chunk went back to the queue");
        assert_eq!(svc.stats().planned, 0);
    }

    #[test]
    fn rebalance_replans_without_touching_the_queue() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 4);
        let placer = placer::by_name(&rt, "greedy:lookup").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
        for t in &tasks {
            svc.submit(PlacementRequest::new(&ds, t, &sim)).unwrap();
        }
        let planned = svc.drain().unwrap();
        assert_eq!(planned.len(), 4);
        // perturb: drop device 3 from every task
        let perturbed: Vec<Task> = tasks
            .iter()
            .map(|t| Task { table_ids: t.table_ids.clone(), n_devices: 3 })
            .collect();
        // leave one request queued to prove rebalance does not drain it
        svc.submit(PlacementRequest::new(&ds, &tasks[0], &sim)).unwrap();
        let jobs: Vec<ReplaceJob> = planned
            .iter()
            .zip(&perturbed)
            .map(|(p, t)| ReplaceJob {
                prev: p.plan.clone(),
                req: PlacementRequest::new(&ds, t, &sim),
            })
            .collect();
        let rebal = svc.rebalance(jobs).unwrap();
        assert_eq!(rebal.len(), 4);
        assert_eq!(svc.queued(), 1, "the queued request must survive a rebalance");
        let stats = svc.stats();
        assert_eq!(stats.rebalanced, 4);
        assert_eq!(stats.planned, 8, "rebalanced plans count as planned");
        assert!(stats.moved_tables > 0, "device loss forces moves");
        assert!(stats.migration_ms > 0.0);
        assert!(stats.summary().contains("rebalanced"), "{}", stats.summary());
        for p in &rebal {
            assert_eq!(p.queue_ms, 0.0);
            assert!(p.plan.placement.iter().all(|&d| d < 3), "lost device still used");
            assert_eq!(p.plan.eval.moved_tables > 0, p.plan.eval.migration_ms > 0.0);
        }
    }

    #[test]
    fn failed_rebalance_is_an_error_not_a_loss() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(1, 4);
        let mut svc = PlanService::new(&rt, Box::new(FailingPlacer), ServeConfig::default());
        let jobs = vec![ReplaceJob {
            prev: PlacementPlan::prior(vec![0; 8], "seed"),
            req: PlacementRequest::new(&ds, &tasks[0], &sim),
        }];
        let err = svc.rebalance(jobs).expect_err("failing placer must error");
        assert!(err.to_string().contains("backend exploded"));
        assert_eq!(svc.stats().rebalanced, 0);
        assert_eq!(svc.stats().planned, 0);
    }

    #[test]
    fn drain_on_empty_queue_is_a_noop() {
        let rt = Arc::new(Runtime::reference());
        let placer = placer::by_name(&rt, "random").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
        assert!(svc.drain_chunk().unwrap().is_empty());
        assert!(svc.drain().unwrap().is_empty());
        assert_eq!(svc.stats().chunks, 0);
    }

    /// A session-capable placer whose session errors mid-chunk, to pin
    /// the pipelined drain's requeue guarantee without involving the
    /// (never-failing) reference backend.
    struct ExplodingSessionPlacer;
    struct ExplodingSession;
    impl<'a> PlanSession<'a> for ExplodingSession {
        fn submit_step(&mut self) -> Result<Option<Ticket>> {
            Err(crate::err!("session exploded"))
        }
        fn apply_step(&mut self, _out: Vec<crate::runtime::Value>) -> Result<()> {
            unreachable!("submit_step never succeeds")
        }
        fn finish(self: Box<Self>) -> Result<Vec<PlacementPlan>> {
            unreachable!("submit_step never succeeds")
        }
    }
    impl Placer for ExplodingSessionPlacer {
        fn name(&self) -> &str {
            "exploding-session"
        }
        fn place(&mut self, _req: &PlacementRequest<'_>) -> Result<PlacementPlan> {
            Err(crate::err!("unused"))
        }
        fn open_session<'b>(
            &mut self,
            _reqs: &[PlacementRequest<'b>],
        ) -> Result<Option<Box<dyn PlanSession<'b> + 'b>>> {
            Ok(Some(Box::new(ExplodingSession)))
        }
    }

    #[test]
    fn stats_percentiles_read_the_bounded_window() {
        let mut stats = ServeStats::default();
        for i in 1..=100 {
            stats.record(i as f64, 0.0);
        }
        assert_eq!(stats.percentile_queue_ms(0.95), 95.0);
        assert_eq!(stats.p95_queue_ms(), 95.0);
        assert_eq!(stats.p99_queue_ms(), 99.0);
        assert_eq!(stats.window_len(), 100);
        assert_eq!(ServeStats::default().p95_queue_ms(), 0.0, "empty window reads 0");
        // the window is bounded: old samples age out, percentiles follow
        for _ in 0..SAMPLE_WINDOW {
            stats.record(1.0, 0.0);
        }
        assert_eq!(stats.window_len(), SAMPLE_WINDOW);
        assert_eq!(stats.p99_queue_ms(), 1.0, "the 1..=100 samples aged out");
    }

    #[test]
    fn merge_of_non_empty_windows_stays_bounded() {
        // regression: merge must fold the other window's samples in while
        // keeping O(1) memory — at most SAMPLE_WINDOW samples retained
        let mut a = ServeStats::default();
        let mut b = ServeStats::default();
        for _ in 0..700 {
            a.record(10.0, 1.0);
            b.record(90.0, 1.0);
        }
        a.merge(&b);
        assert_eq!(a.planned, 1400);
        assert_eq!(a.window_len(), SAMPLE_WINDOW, "window stays bounded across merge");
        // b's 700 samples arrived last, so they dominate the tail: the
        // merged window is 324×10ms then 700×90ms
        assert_eq!(a.p99_queue_ms(), 90.0);
        assert_eq!(a.percentile_queue_ms(0.2), 10.0, "a's newest samples survive too");
        assert!((a.mean_queue_ms() - 50.0).abs() < 1e-9, "means stay exact (running sums)");
        // merging an empty window changes nothing
        let before = a.window_len();
        a.merge(&ServeStats::default());
        assert_eq!(a.window_len(), before);
    }

    #[test]
    fn class_order_drains_interactive_before_batch() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 4);
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
        // batch first, interactive second: FIFO would plan batch first
        svc.submit_class(PlacementRequest::new(&ds, &tasks[0], &sim), SloClass::Batch).unwrap();
        svc.submit_class(PlacementRequest::new(&ds, &tasks[1], &sim), SloClass::Batch).unwrap();
        svc.submit_class(PlacementRequest::new(&ds, &tasks[2], &sim), SloClass::Interactive)
            .unwrap();
        svc.submit_class(PlacementRequest::new(&ds, &tasks[3], &sim), SloClass::Interactive)
            .unwrap();
        svc.set_class_order(true);
        let done = svc.drain_blocking().unwrap();
        let order: Vec<(u64, SloClass)> = done.iter().map(|p| (p.ticket, p.class)).collect();
        assert_eq!(
            order,
            vec![
                (2, SloClass::Interactive),
                (3, SloClass::Interactive),
                (0, SloClass::Batch),
                (1, SloClass::Batch),
            ],
            "interactive drains first, FIFO within each class"
        );
    }

    #[test]
    fn without_class_order_the_queue_is_fifo_regardless_of_class() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(2, 4);
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig::default());
        svc.submit_class(PlacementRequest::new(&ds, &tasks[0], &sim), SloClass::Batch).unwrap();
        svc.submit_class(PlacementRequest::new(&ds, &tasks[1], &sim), SloClass::Interactive)
            .unwrap();
        let done = svc.drain_blocking().unwrap();
        let tickets: Vec<u64> = done.iter().map(|p| p.ticket).collect();
        assert_eq!(tickets, vec![0, 1], "class-blind FIFO is the default");
    }

    #[test]
    fn full_queue_evicts_newest_batch_for_interactive_under_pressure() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 4);
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig {
            capacity: 2,
            ..ServeConfig::default()
        });
        svc.set_class_order(true);
        svc.submit_class(PlacementRequest::new(&ds, &tasks[0], &sim), SloClass::Batch).unwrap();
        svc.submit_class(PlacementRequest::new(&ds, &tasks[1], &sim), SloClass::Batch).unwrap();
        assert!(svc.is_full());
        // a batch submit at a full queue is shed outright
        let shed =
            svc.submit_class(PlacementRequest::new(&ds, &tasks[2], &sim), SloClass::Batch);
        assert_eq!(shed.unwrap(), None);
        assert_eq!((svc.stats().rejected, svc.stats().shed_batch), (1, 1));
        // an interactive submit evicts the *youngest* batch request instead
        let t = svc
            .submit_class(PlacementRequest::new(&ds, &tasks[3], &sim), SloClass::Interactive)
            .unwrap();
        assert_eq!(t, Some(2), "interactive was admitted");
        assert_eq!((svc.stats().rejected, svc.stats().shed_batch), (2, 2));
        assert_eq!(svc.queued(), 2);
        let done = svc.drain_blocking().unwrap();
        let order: Vec<(u64, SloClass)> = done.iter().map(|p| (p.ticket, p.class)).collect();
        assert_eq!(
            order,
            vec![(2, SloClass::Interactive), (0, SloClass::Batch)],
            "ticket 1 (youngest batch) was evicted, ticket 0 survived"
        );
    }

    #[test]
    fn full_queue_of_interactive_sheds_interactive_even_under_pressure() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(3, 4);
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::new(&rt, placer, ServeConfig {
            capacity: 2,
            ..ServeConfig::default()
        });
        svc.set_class_order(true);
        for t in tasks.iter().take(2) {
            svc.submit_class(PlacementRequest::new(&ds, t, &sim), SloClass::Interactive)
                .unwrap();
        }
        // nothing batch to evict: the interactive submit sheds normally
        let shed = svc
            .submit_class(PlacementRequest::new(&ds, &tasks[2], &sim), SloClass::Interactive)
            .unwrap();
        assert_eq!(shed, None);
        assert_eq!((svc.stats().rejected, svc.stats().shed_batch), (1, 0));
    }

    #[test]
    fn test_clock_makes_queue_latency_deterministic() {
        use super::super::clock::TestClock;
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(2, 4);
        let clock = Arc::new(TestClock::new());
        let placer = placer::by_name(&rt, "greedy:dim").unwrap();
        let mut svc = PlanService::with_clock(
            &rt,
            placer,
            ServeConfig::default(),
            Arc::clone(&clock) as Arc<dyn Clock>,
        );
        svc.submit(PlacementRequest::new(&ds, &tasks[0], &sim)).unwrap();
        clock.advance_ms(40.0);
        svc.submit(PlacementRequest::new(&ds, &tasks[1], &sim)).unwrap();
        clock.advance_ms(10.0);
        let done = svc.drain_blocking().unwrap();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].queue_ms, 50.0, "first request queued exactly 50 ms");
        assert_eq!(done[1].queue_ms, 10.0, "second request queued exactly 10 ms");
        assert_eq!(done[0].plan_ms, 0.0, "frozen clock: the drain took zero test-time");
        assert_eq!(svc.stats().p95_queue_ms(), 50.0);
        assert_eq!(svc.stats().median_queue_ms(), 30.0);
    }

    #[test]
    fn failed_pipelined_session_requeues_everything() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(5, 4);
        let mut svc = PlanService::new(&rt, Box::new(ExplodingSessionPlacer), ServeConfig {
            capacity: 64,
            chunk: 2,
            ..ServeConfig::default()
        });
        for t in &tasks {
            svc.submit(PlacementRequest::new(&ds, t, &sim)).unwrap();
        }
        let err = svc.drain().expect_err("exploding session must error");
        assert!(err.to_string().contains("session exploded"), "{err}");
        assert_eq!(svc.queued(), 5, "every request survives the failed drain");
        assert_eq!(svc.stats().planned, 0);
        // order intact: retry pops the same head ticket first
        assert_eq!(svc.queue.front().unwrap().ticket, 0);
    }
}
