//! [`ShardedFrontEnd`]: one runtime, many planning streams.
//!
//! A single [`PlanService`] is one FIFO: a 128-device chunk at the queue
//! head stalls every younger 8-device request behind it (head-of-line
//! blocking), because a drain always takes the *oldest* request's serving
//! variant. The sharded front end removes that coupling: it owns one
//! `PlanService` per serving variant (optionally per tenant), routes
//! every submit to its variant's shard, and drains each shard on its own
//! thread against the shared `Arc<Runtime>` worker pool — so pool
//! capacity, not queue order, is the single backpressure knob. A global
//! cap on aggregate queued requests sheds excess load at the front door
//! before any shard grows unboundedly.
//!
//! Routing asks the placer first ([`Placer::serving_variant`], after a
//! [`Placer::warm_variant`] warm-up so even a lazily-initializing
//! DreamShard agent can answer at submit time), falling back to the
//! smallest lowered artifact variant for the request's device count.
//! Plans are bit-identical to routing the same requests through the same
//! per-variant services *sequentially* ([`ShardedFrontEnd::drain_sequential`]
//! is exactly that reference), and the backend-call budgets match to the
//! call — concurrency moves waits, never work (pinned in
//! `tests/sharded.rs`).

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::Variant;
use crate::err;
use crate::placer::{Placer, PlacementRequest};
use crate::runtime::Runtime;
use crate::tables::Task;
use crate::util::error::Result;

use super::clock::{system_clock, Clock};
use super::{PlanService, Planned, ReplaceJob, ServeConfig, ServeStats, SloClass};

/// Identity of one shard: the serving variant `(D, S)` its requests are
/// planned with, plus an optional tenant label for per-tenant isolation
/// (two tenants submitting the same variant get separate queues, stats,
/// and drain threads).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShardKey {
    pub variant: (usize, usize),
    pub tenant: Option<String>,
}

impl ShardKey {
    /// Human-readable `d{D}s{S}[/tenant]` label for tables and logs.
    pub fn label(&self) -> String {
        match &self.tenant {
            Some(t) => format!("d{}s{}/{t}", self.variant.0, self.variant.1),
            None => format!("d{}s{}", self.variant.0, self.variant.1),
        }
    }
}

/// Front-end knobs: the per-shard service configuration plus the global
/// backpressure cap.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Configuration every shard's [`PlanService`] is created with
    /// (per-shard queue capacity, lane-chunk size, pipeline depth).
    pub per_shard: ServeConfig,
    /// Aggregate queued-request cap across *all* shards: a submit
    /// arriving while the shards already hold `global_cap` queued
    /// requests sheds at the front door ([`ShardedFrontEnd::submit`]
    /// returns `Ok(None)`) before routing, validation, or shard
    /// creation — so at most `global_cap` requests are ever queued.
    /// This is the single backpressure knob a deployment sizes against
    /// its runtime worker pool.
    pub global_cap: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig { per_shard: ServeConfig::default(), global_cap: 1024 }
    }
}

/// Receipt for one accepted submit: which shard took the request and the
/// ticket it holds *within that shard* (tickets are per-service, so the
/// pair is the request's identity — [`Planned::ticket`] from that shard's
/// drain matches it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Routed {
    pub shard: ShardKey,
    pub ticket: u64,
}

/// Read-only view of one shard for monitoring and closed-loop control.
pub struct ShardView<'s> {
    pub key: &'s ShardKey,
    /// Requests currently queued in this shard.
    pub queued: usize,
    /// This shard's current lane-chunk size ([`ServeConfig::chunk`],
    /// possibly resized live via [`ShardedFrontEnd::set_chunk`]).
    pub chunk: usize,
    /// This shard's service counters. `backend_calls` is exact when the
    /// shard drained alone ([`ShardedFrontEnd::drain_sequential`] /
    /// [`ShardedFrontEnd::drain_shard`]); during a concurrent
    /// [`ShardedFrontEnd::try_drain`] its measurement window observes
    /// the shared runtime counter while sibling shards dispatch, so it
    /// is an upper bound there ([`ShardedFrontEnd::stats`] carries the
    /// exact aggregate).
    pub stats: &'s ServeStats,
    /// When this shard's most recent drain completed — the per-shard
    /// drain-completion clock a closed-loop arrival controller couples
    /// to (see the ROADMAP's closed-loop serving item). `None` until the
    /// shard has drained at least once.
    pub last_drain: Option<Instant>,
}

/// Front-end counters plus the merged per-shard stats.
#[derive(Clone, Debug)]
pub struct FrontStats {
    /// Requests accepted and routed into some shard.
    pub routed: u64,
    /// Requests shed by the *global* cap (per-shard queue sheds are in
    /// [`FrontStats::aggregate`]'s `rejected` instead).
    pub shed_global: u64,
    /// The [`SloClass::Batch`] share of `shed_global` — under SLO-aware
    /// admission ([`ShardedFrontEnd::submit_slo`]) batch traffic absorbs
    /// the cap first, so `shed_global - shed_global_batch` is the
    /// interactive loss at the front door.
    pub shed_global_batch: u64,
    /// Shards currently instantiated.
    pub shards: usize,
    /// Every shard's [`ServeStats`] merged ([`ServeStats::merge`]), with
    /// `backend_calls` replaced by the front end's own exact whole-drain
    /// measurement (see [`ShardedFrontEnd::stats`]); note that `busy_s`
    /// sums across concurrently-draining shard threads.
    pub aggregate: ServeStats,
}

impl FrontStats {
    /// One-line human summary of the front door plus the aggregate.
    pub fn summary(&self) -> String {
        format!(
            "{} shards, {} routed, {} shed at the global cap ({} batch); {}",
            self.shards,
            self.routed,
            self.shed_global,
            self.shed_global_batch,
            self.aggregate.summary()
        )
    }
}

struct Shard<'a> {
    key: ShardKey,
    svc: PlanService<'a>,
    last_drain: Option<Instant>,
}

/// A routing layer over per-variant [`PlanService`]s: one submit API in,
/// per-shard drain threads out.
///
/// Each serving variant (optionally each `(variant, tenant)` pair) gets
/// its own bounded [`PlanService`] queue, so a saturated 128-device
/// shard can never head-of-line-block 8-device traffic; all shards
/// drain against the one shared `Arc<Runtime>` worker pool, and
/// aggregate queued requests shed at [`ShardConfig::global_cap`]. Plans
/// and backend-call budgets are bit-identical to draining the same
/// shards sequentially (`tests/sharded.rs` pins both).
///
/// ```
/// use std::sync::Arc;
/// use dreamshard::placer::{self, PlacementRequest};
/// use dreamshard::runtime::Runtime;
/// use dreamshard::serve::{ShardConfig, ShardedFrontEnd};
/// use dreamshard::sim::{SimConfig, Simulator};
/// use dreamshard::tables::{gen_dlrm, sample_tasks, split_pools};
///
/// let rt = Arc::new(Runtime::reference());
/// let ds = gen_dlrm(80, 0);
/// let (pool, _) = split_pools(&ds, 1);
/// let sim = Simulator::new(SimConfig::default());
/// let small = sample_tasks(&pool, 8, 4, 2, 1); // two 4-device tasks
/// let large = sample_tasks(&pool, 8, 128, 2, 2); // two 128-device tasks
///
/// let factory = {
///     let rt = Arc::clone(&rt);
///     move || placer::by_name(&rt, "greedy:size")
/// };
/// let mut front = ShardedFrontEnd::new(&rt, factory, ShardConfig::default()).unwrap();
/// for t in small.iter().chain(&large) {
///     let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
///     front.submit(req).unwrap().expect("under the global cap");
/// }
/// assert_eq!(front.stats().shards, 2); // a d4s48 shard and a d128s16 shard
/// let done = front.drain().unwrap(); // each shard drains on its own thread
/// assert_eq!(done.len(), 4);
/// ```
pub struct ShardedFrontEnd<'a> {
    rt: Arc<Runtime>,
    cfg: ShardConfig,
    /// Routing oracle: a placer from the same factory the shards use, so
    /// route keys agree with the keys each shard's service would compute.
    /// It only ever answers [`Placer::serving_variant`] (after
    /// [`Placer::warm_variant`]) — it never plans.
    router: Box<dyn Placer>,
    factory: Box<dyn FnMut() -> Result<Box<dyn Placer>> + Send + 'a>,
    /// Creation-ordered; every drain API visits shards in this order, so
    /// sequential and concurrent drains aggregate identically.
    shards: Vec<Shard<'a>>,
    /// Time source shared with every shard's service and used for
    /// [`ShardView::last_drain`] stamps — the closed-loop testing seam.
    clock: Arc<dyn Clock>,
    /// Propagated to every shard ([`PlanService::set_class_order`]),
    /// existing and future — the pressure mode the controller toggles.
    class_order: bool,
    routed: u64,
    shed_global: u64,
    shed_global_batch: u64,
    /// Backend executions dispatched by this front end's drains, exact:
    /// measured as a shared-runtime call-count delta around each whole
    /// drain operation. (Per-shard [`ServeStats`] windows overlap during
    /// a concurrent drain — each shard measures deltas of the *shared*
    /// runtime counter — so summing their `backend_calls` would
    /// over-count; this field is the correct total.)
    drained_calls: u64,
}

impl<'a> ShardedFrontEnd<'a> {
    /// Build a front end over `factory`-made placers. The factory is
    /// called once per shard as variants (or tenants) first appear, plus
    /// once up front for the routing oracle; for bit-identical shards
    /// hand it a snapshot source, e.g.
    /// `move || Ok(Box::new(DreamShardPlacer::from_agent(&rt, &agent)))`.
    /// `rt` must be the runtime those placers execute on (it resolves
    /// fallback variant keys and backs every shard's call counters).
    pub fn new<F>(rt: &Arc<Runtime>, factory: F, cfg: ShardConfig) -> Result<Self>
    where
        F: FnMut() -> Result<Box<dyn Placer>> + Send + 'a,
    {
        Self::with_clock(rt, factory, cfg, system_clock())
    }

    /// [`ShardedFrontEnd::new`] on an explicit time source. Every shard's
    /// service shares this clock, and every [`ShardView::last_drain`]
    /// stamp reads it — so under a [`super::TestClock`] the complete
    /// closed-loop signal set (queue latencies, drain-completion ages) is
    /// deterministic.
    pub fn with_clock<F>(
        rt: &Arc<Runtime>,
        mut factory: F,
        cfg: ShardConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Self>
    where
        F: FnMut() -> Result<Box<dyn Placer>> + Send + 'a,
    {
        let router = factory()?;
        Ok(ShardedFrontEnd {
            rt: Arc::clone(rt),
            cfg: ShardConfig { global_cap: cfg.global_cap.max(1), ..cfg },
            router,
            factory: Box::new(factory),
            shards: vec![],
            clock,
            class_order: false,
            routed: 0,
            shed_global: 0,
            shed_global_batch: 0,
            drained_calls: 0,
        })
    }

    /// The front end's time source (the same clock every shard measures
    /// with) — what a controller reads to age [`ShardView::last_drain`].
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Current global admission cap ([`ShardConfig::global_cap`]).
    pub fn global_cap(&self) -> usize {
        self.cfg.global_cap
    }

    /// Retune the global admission cap live (clamped to at least 1) —
    /// the controller's admission actuator. Already-queued requests are
    /// never dropped by a shrink; the new cap only gates future submits.
    pub fn set_global_cap(&mut self, cap: usize) {
        self.cfg.global_cap = cap.max(1);
    }

    /// Whether shards drain in SLO-class order (see
    /// [`PlanService::set_class_order`]).
    pub fn class_order(&self) -> bool {
        self.class_order
    }

    /// Toggle class-ordered draining on every shard, existing and
    /// future — the pressure mode: interactive traffic drains (and is
    /// admitted) ahead of batch. Off by default, where behavior is
    /// bit-identical to a class-blind front end.
    pub fn set_class_order(&mut self, on: bool) {
        self.class_order = on;
        for sh in self.shards.iter_mut() {
            sh.svc.set_class_order(on);
        }
    }

    /// Resize one shard's lane-chunk live (see
    /// [`PlanService::set_chunk`]) — the controller's latency/throughput
    /// actuator. `Err` when no such shard exists.
    pub fn set_chunk(&mut self, key: &ShardKey, chunk: usize) -> Result<()> {
        let sh = self
            .shards
            .iter_mut()
            .find(|s| &s.key == key)
            .ok_or_else(|| err!("no shard {} in this front end", key.label()))?;
        sh.svc.set_chunk(chunk);
        Ok(())
    }

    /// Requests queued across all shards.
    pub fn queued(&self) -> usize {
        self.shards.iter().map(|s| s.svc.queued()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.svc.is_empty())
    }

    /// Whether the next submit would be shed by the global cap.
    pub fn is_full(&self) -> bool {
        self.queued() >= self.cfg.global_cap
    }

    /// Per-shard monitoring views, in shard-creation order.
    pub fn shards(&self) -> impl Iterator<Item = ShardView<'_>> + '_ {
        self.shards.iter().map(|sh| ShardView {
            key: &sh.key,
            queued: sh.svc.queued(),
            chunk: sh.svc.chunk(),
            stats: sh.svc.stats(),
            last_drain: sh.last_drain,
        })
    }

    /// Front-end counters with every shard's stats merged in. The
    /// aggregate's `backend_calls` is the front end's own exact
    /// whole-drain measurement, not the per-shard sum: during a
    /// concurrent drain each shard's [`ServeStats::backend_calls`]
    /// window observes the shared runtime counter while sibling shards
    /// dispatch too, so per-shard values are upper bounds (exact only
    /// when a shard drains alone — [`ShardedFrontEnd::drain_sequential`]
    /// or [`ShardedFrontEnd::drain_shard`]) and their sum over-counts.
    pub fn stats(&self) -> FrontStats {
        let mut aggregate = ServeStats::default();
        for sh in &self.shards {
            aggregate.merge(sh.svc.stats());
        }
        aggregate.backend_calls = self.drained_calls;
        FrontStats {
            routed: self.routed,
            shed_global: self.shed_global,
            shed_global_batch: self.shed_global_batch,
            shards: self.shards.len(),
            aggregate,
        }
    }

    /// Route and enqueue one request (no tenant). `Ok(Some(receipt))` on
    /// acceptance; `Ok(None)` when the global cap — or the routed
    /// shard's own bounded queue — sheds it; `Err` only when no lowered
    /// artifact variant can serve the request's device count.
    pub fn submit(&mut self, req: PlacementRequest<'a>) -> Result<Option<Routed>> {
        self.submit_for(req, None)
    }

    /// [`ShardedFrontEnd::submit`] with per-tenant isolation: requests
    /// with different tenant labels never share a queue, even on the
    /// same serving variant.
    ///
    /// Routing: the global cap sheds first (before any other work or
    /// validation, matching [`PlanService::submit`]'s shed-first
    /// contract); then the router placer is warmed
    /// ([`Placer::warm_variant`]) and asked for the serving variant,
    /// with the smallest lowered variant for the device count as the
    /// fallback; the `(variant, tenant)` shard is created on first use.
    pub fn submit_for(
        &mut self,
        req: PlacementRequest<'a>,
        tenant: Option<&str>,
    ) -> Result<Option<Routed>> {
        self.submit_slo(req, SloClass::default(), tenant)
    }

    /// [`ShardedFrontEnd::submit_for`] with an explicit [`SloClass`] —
    /// the SLO-aware front door. At the global cap the classes part
    /// ways: a batch submit is shed (counted in
    /// [`FrontStats::shed_global_batch`]); an interactive submit under
    /// class-ordered pressure ([`ShardedFrontEnd::set_class_order`])
    /// first tries to evict the youngest queued batch request across
    /// *all* shards ([`ShardedFrontEnd::evict_newest_batch`]) and takes
    /// the freed slot. With class ordering off (the default) every class
    /// sheds alike and behavior matches [`ShardedFrontEnd::submit_for`]
    /// exactly.
    pub fn submit_slo(
        &mut self,
        req: PlacementRequest<'a>,
        class: SloClass,
        tenant: Option<&str>,
    ) -> Result<Option<Routed>> {
        if self.is_full() {
            let evicted = class == SloClass::Interactive
                && self.class_order
                && self.evict_newest_batch().is_some();
            if !evicted {
                self.shed_global += 1;
                if class == SloClass::Batch {
                    self.shed_global_batch += 1;
                }
                return Ok(None);
            }
        }
        let idx = self.route(&req, tenant)?;
        let key = self.shards[idx].key.clone();
        Ok(match self.shards[idx].svc.submit_class(req, class)? {
            Some(ticket) => {
                self.routed += 1;
                Some(Routed { shard: key, ticket })
            }
            // the shard's own bounded queue was full; its ServeStats
            // recorded the shed
            None => None,
        })
    }

    /// Evict the youngest queued [`SloClass::Batch`] request anywhere in
    /// the front end, returning `(shard, ticket)` (`None` when no batch
    /// work is queued). "Youngest" is global — the shard holding the
    /// most recently submitted batch request gives it up — so under
    /// sustained interactive pressure batch work drains out
    /// newest-first, preserving the oldest (closest to service) batch
    /// requests longest. The evicting shard's [`ServeStats`] records the
    /// shed exactly as a submit-time rejection would.
    pub fn evict_newest_batch(&mut self) -> Option<(ShardKey, u64)> {
        let idx = self
            .shards
            .iter()
            .enumerate()
            .filter_map(|(i, sh)| sh.svc.newest_batch_submitted().map(|at| (i, at)))
            .max_by_key(|&(_, at)| at)
            .map(|(i, _)| i)?;
        let sh = &mut self.shards[idx];
        let ticket = sh.svc.evict_newest_batch()?;
        Some((sh.key.clone(), ticket))
    }

    /// Resolve (and create on first use) the shard a request belongs to,
    /// returning its index into `self.shards`. This is the single source
    /// of routing truth shared by [`ShardedFrontEnd::submit_for`] and
    /// [`ShardedFrontEnd::rebalance`].
    fn route(&mut self, req: &PlacementRequest<'a>, tenant: Option<&str>) -> Result<usize> {
        self.router.warm_variant(req)?;
        let variant = match self.router.serving_variant(req) {
            Some(v) => v,
            None => {
                let var = Variant::for_devices(&self.rt, req.task.n_devices)?;
                (var.d, var.s)
            }
        };
        let key = ShardKey { variant, tenant: tenant.map(String::from) };
        match self.shards.iter().position(|s| s.key == key) {
            Some(i) => Ok(i),
            None => {
                let mut placer = (self.factory)()?;
                // warm the new shard's own placer to the *shard key's*
                // device count, not the triggering request's: a lazily-
                // initializing placer creates its agent sized to the
                // variant this shard serves, so the service's internal
                // grouping keys agree with the routing key from the very
                // first submit. (The triggering request can be smaller
                // than the variant the router lane-shares it under —
                // e.g. a tenant shard opened by a 2-device request on a
                // d=8 agent's variant — which without this warm-up would
                // size the shard's lazy agent to d=2 and fracture the
                // shard's chunks by device count.)
                let warm_task =
                    Task { table_ids: req.task.table_ids.clone(), n_devices: variant.0 };
                placer.warm_variant(&PlacementRequest { task: &warm_task, ..*req })?;
                let mut svc = PlanService::with_clock(
                    &self.rt,
                    placer,
                    self.cfg.per_shard,
                    Arc::clone(&self.clock),
                );
                svc.set_class_order(self.class_order);
                self.shards.push(Shard { key, svc, last_drain: None });
                Ok(self.shards.len() - 1)
            }
        }
    }

    /// Drain every shard **concurrently**, one thread per shard, all
    /// executing against the shared runtime worker pool. Returns each
    /// shard's whole-queue [`PlanService::drain`] outcome in
    /// shard-creation order — per-shard failures stay per-shard (a
    /// failing shard requeues its requests exactly as its service's
    /// drain contract says; the other shards' completed plans are still
    /// returned here).
    pub fn try_drain(&mut self) -> Vec<(ShardKey, Result<Vec<Planned>>)> {
        let calls_before = self.rt.run_count();
        let clock = &self.clock;
        // keys are cloned before the scope so a panicking drain thread
        // still yields a keyed per-shard Err instead of poisoning the
        // whole front end
        let keys: Vec<ShardKey> = self.shards.iter().map(|sh| sh.key.clone()).collect();
        let reports: Vec<Result<Vec<Planned>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .map(|sh| {
                    scope.spawn(move || {
                        let drained = sh.svc.drain();
                        // the per-shard drain-completion clock
                        // (ShardView::last_drain): stamped on the drain
                        // thread, so it is the true completion instant —
                        // and only on success, matching drain_sequential
                        // and drain_shard (a failed drain completed
                        // nothing: its requests were requeued)
                        if drained.is_ok() {
                            sh.last_drain = Some(clock.now());
                        }
                        drained
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|_| Err(err!("shard drain thread panicked"))))
                .collect()
        });
        self.drained_calls += self.rt.run_count() - calls_before;
        keys.into_iter().zip(reports).collect()
    }

    /// [`ShardedFrontEnd::try_drain`] flattened: every shard's plans
    /// concatenated in shard-creation order (per-shard order within).
    /// If any shard failed, the first error is returned and the other
    /// shards' results are dropped from the return value — though their
    /// work is still counted in [`ShardedFrontEnd::stats`] and the
    /// failing shard's requests are requeued. Callers needing loss-free
    /// delivery under partial failure should use
    /// [`ShardedFrontEnd::try_drain`] and keep each shard's batch.
    pub fn drain(&mut self) -> Result<Vec<Planned>> {
        let mut out = vec![];
        for (_, drained) in self.try_drain() {
            out.extend(drained?);
        }
        Ok(out)
    }

    /// The bit-identity reference for [`ShardedFrontEnd::drain`]: the
    /// same per-variant services drained one after another on the
    /// calling thread, in the same shard-creation order. Concurrency
    /// moves waits, never work, so `drain` must reproduce this output —
    /// plans and backend-call budgets — exactly (`tests/sharded.rs`).
    pub fn drain_sequential(&mut self) -> Result<Vec<Planned>> {
        let calls_before = self.rt.run_count();
        let mut out = vec![];
        let mut failure = None;
        for sh in self.shards.iter_mut() {
            match sh.svc.drain() {
                Ok(drained) => {
                    sh.last_drain = Some(self.clock.now());
                    out.extend(drained);
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        self.drained_calls += self.rt.run_count() - calls_before;
        match failure {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Drain one shard to empty, leaving every other shard untouched —
    /// how a caller keeps an interactive variant live while a bulk
    /// variant's queue is saturated, without waiting on the full
    /// [`ShardedFrontEnd::drain`].
    pub fn drain_shard(&mut self, key: &ShardKey) -> Result<Vec<Planned>> {
        let sh = self
            .shards
            .iter_mut()
            .find(|s| &s.key == key)
            .ok_or_else(|| err!("no shard {} in this front end", key.label()))?;
        let calls_before = self.rt.run_count();
        let drained = sh.svc.drain();
        self.drained_calls += self.rt.run_count() - calls_before;
        let drained = drained?;
        sh.last_drain = Some(self.clock.now());
        Ok(drained)
    }

    /// Incremental re-placement across shards: route every
    /// [`ReplaceJob`] to its variant's shard (created on first use, same
    /// routing as [`ShardedFrontEnd::submit`]) and run each shard's
    /// [`PlanService::rebalance`] on its own thread against the shared
    /// runtime pool — the rebalance analogue of
    /// [`ShardedFrontEnd::try_drain`]. Queued submits are untouched:
    /// rebalance bypasses every shard's FIFO entirely.
    ///
    /// Returns the re-plans concatenated in shard-creation order
    /// (per-shard job order within). On any shard's failure the first
    /// error is returned; nothing is requeued — the caller still holds
    /// every previous plan, so retrying is its decision. Per-shard
    /// `rebalanced` / `moved_tables` / `migration_ms` counters land in
    /// [`ShardedFrontEnd::stats`]'s aggregate, and the backend calls the
    /// re-plans dispatched are counted in its exact `backend_calls`.
    pub fn rebalance(&mut self, jobs: Vec<ReplaceJob<'a>>) -> Result<Vec<Planned>> {
        // route first — creating shards mutates self.shards, so batching
        // must finish before the scoped borrow of every shard below
        let mut batches: Vec<Vec<ReplaceJob<'a>>> =
            self.shards.iter().map(|_| vec![]).collect();
        for job in jobs {
            let idx = self.route(&job.req, None)?;
            if idx >= batches.len() {
                batches.resize_with(idx + 1, Vec::new);
            }
            batches[idx].push(job);
        }
        let calls_before = self.rt.run_count();
        let reports: Vec<Result<Vec<Planned>>> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .zip(batches)
                .filter(|(_, batch)| !batch.is_empty())
                .map(|(sh, batch)| scope.spawn(move || sh.svc.rebalance(batch)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| Err(err!("shard rebalance thread panicked")))
                })
                .collect()
        });
        self.drained_calls += self.rt.run_count() - calls_before;
        let mut out = vec![];
        for r in reports {
            out.extend(r?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placer;
    use crate::sim::{SimConfig, Simulator};
    use crate::tables::{gen_dlrm, sample_tasks, split_pools, Dataset, Task};

    fn setup(n_devices: usize, n_tasks: usize) -> (Dataset, Vec<Task>, Simulator) {
        let ds = gen_dlrm(200, 0);
        let (pool, _) = split_pools(&ds, 1);
        let tasks = sample_tasks(&pool, 8, n_devices, n_tasks, 2);
        (ds, tasks, Simulator::new(SimConfig::default()))
    }

    fn greedy_front<'a>(rt: &Arc<Runtime>, cfg: ShardConfig) -> ShardedFrontEnd<'a> {
        let rt2 = Arc::clone(rt);
        ShardedFrontEnd::new(rt, move || placer::by_name(&rt2, "greedy:size"), cfg).unwrap()
    }

    #[test]
    fn shard_keys_label_and_compare() {
        let a = ShardKey { variant: (8, 48), tenant: None };
        let b = ShardKey { variant: (8, 48), tenant: Some("acme".into()) };
        assert_eq!(a.label(), "d8s48");
        assert_eq!(b.label(), "d8s48/acme");
        assert_ne!(a, b, "tenant is part of the identity");
    }

    #[test]
    fn unknown_shard_key_is_an_error() {
        let rt = Arc::new(Runtime::reference());
        let mut front = greedy_front(&rt, ShardConfig::default());
        let missing = ShardKey { variant: (8, 48), tenant: None };
        let e = front.drain_shard(&missing).expect_err("no shards exist yet");
        assert!(e.to_string().contains("no shard d8s48"), "{e}");
    }

    #[test]
    fn empty_front_end_drains_to_nothing() {
        let rt = Arc::new(Runtime::reference());
        let mut front = greedy_front(&rt, ShardConfig::default());
        assert!(front.is_empty());
        assert!(front.drain().unwrap().is_empty());
        assert!(front.drain_sequential().unwrap().is_empty());
        assert_eq!(front.stats().shards, 0);
    }

    #[test]
    fn unservable_device_count_errors_at_submit() {
        let rt = Arc::new(Runtime::reference());
        let (ds, mut tasks, sim) = setup(4, 1);
        tasks[0].n_devices = 1000; // beyond the largest lowered variant
        let mut front = greedy_front(&rt, ShardConfig::default());
        let req = PlacementRequest::new(&ds, &tasks[0], &sim);
        assert!(front.submit(req).is_err());
        assert_eq!(front.stats().routed, 0);
        assert_eq!(front.stats().shards, 0, "no shard created for an unroutable request");
    }

    #[test]
    fn rebalance_routes_jobs_across_shards_without_touching_queues() {
        use crate::placer::MigrationBudget;

        let rt = Arc::new(Runtime::reference());
        let (ds, small, sim) = setup(4, 2);
        let (_, large, _) = setup(8, 2);
        let tasks: Vec<Task> = small.into_iter().chain(large).collect();
        let mut front = greedy_front(&rt, ShardConfig::default());
        for t in &tasks {
            let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
            front.submit(req).unwrap().unwrap();
        }
        let done = front.drain().unwrap();
        assert_eq!(done.len(), 4);

        // every task loses its highest device; the incremental re-plans
        // route back to the same two shards (3 -> d4s48, 7 -> d8s48)
        let perturbed: Vec<Task> = tasks
            .iter()
            .map(|t| Task { table_ids: t.table_ids.clone(), n_devices: t.n_devices - 1 })
            .collect();
        // a queued submit must survive the rebalance untouched
        let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[0], &sim).unwrap();
        front.submit(req).unwrap().unwrap();

        let jobs: Vec<ReplaceJob> = done
            .iter()
            .zip(&perturbed)
            .map(|(p, t)| ReplaceJob {
                prev: p.plan.clone(),
                req: PlacementRequest::for_runtime(&rt, &ds, t, &sim)
                    .unwrap()
                    .with_migration(MigrationBudget::moves(4)),
            })
            .collect();
        let redone = front.rebalance(jobs).unwrap();
        assert_eq!(redone.len(), 4, "every job re-planned");
        // shard-creation order = job order here (smalls then larges)
        for (p, t) in redone.iter().zip(&perturbed) {
            assert_eq!(p.plan.placement.len(), t.n_tables());
            assert!(p.plan.placement.iter().all(|&d| d < t.n_devices));
        }
        assert!(
            redone.iter().any(|p| p.plan.eval.moved_tables > 0),
            "losing a device forces moves"
        );

        assert_eq!(front.queued(), 1, "rebalance bypassed the queues");
        let fs = front.stats();
        assert_eq!(fs.shards, 2, "jobs routed to the existing shards");
        assert_eq!(fs.aggregate.rebalanced, 4);
        assert!(fs.aggregate.moved_tables > 0);
        assert!(fs.aggregate.migration_ms > 0.0);
    }

    #[test]
    fn slo_admission_sheds_batch_and_evicts_for_interactive_at_the_cap() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 5);
        let mut front = greedy_front(&rt, ShardConfig { global_cap: 2, ..Default::default() });
        front.set_class_order(true);
        for t in tasks.iter().take(2) {
            let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
            front.submit_slo(req, SloClass::Batch, None).unwrap().unwrap();
        }
        assert!(front.is_full());
        // batch at the cap: shed, and attributed to the batch class
        let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[2], &sim).unwrap();
        assert!(front.submit_slo(req, SloClass::Batch, None).unwrap().is_none());
        // interactive at the cap: the youngest queued batch request
        // (ticket 1) is evicted and the submit is admitted
        let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[3], &sim).unwrap();
        let routed = front.submit_slo(req, SloClass::Interactive, None).unwrap();
        assert!(routed.is_some(), "interactive admitted via eviction");
        let fs = front.stats();
        assert_eq!((fs.shed_global, fs.shed_global_batch), (1, 1));
        assert_eq!(fs.aggregate.shed_batch, 1, "the eviction landed in shard stats");
        assert!(fs.summary().contains("(1 batch)"), "{}", fs.summary());
        // without class ordering, interactive sheds at the cap like anyone
        front.set_class_order(false);
        let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[4], &sim).unwrap();
        assert!(front.submit_slo(req, SloClass::Interactive, None).unwrap().is_none());
        let fs = front.stats();
        assert_eq!((fs.shed_global, fs.shed_global_batch), (2, 1));
    }

    #[test]
    fn live_actuators_resize_cap_and_chunk() {
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 2);
        let mut front = greedy_front(&rt, ShardConfig::default());
        assert_eq!(front.global_cap(), 1024);
        front.set_global_cap(0);
        assert_eq!(front.global_cap(), 1, "cap clamps to at least 1");
        front.set_global_cap(8);
        let req = PlacementRequest::for_runtime(&rt, &ds, &tasks[0], &sim).unwrap();
        let routed = front.submit(req).unwrap().unwrap();
        assert_eq!(front.shards().next().unwrap().chunk, ServeConfig::default().chunk);
        front.set_chunk(&routed.shard, 3).unwrap();
        assert_eq!(front.shards().next().unwrap().chunk, 3);
        let missing = ShardKey { variant: (9, 9), tenant: None };
        assert!(front.set_chunk(&missing, 4).is_err());
    }

    #[test]
    fn test_clock_drives_last_drain_stamps() {
        use super::super::clock::TestClock;
        let rt = Arc::new(Runtime::reference());
        let (ds, tasks, sim) = setup(4, 2);
        let clock = Arc::new(TestClock::new());
        let rt2 = Arc::clone(&rt);
        let mut front = ShardedFrontEnd::with_clock(
            &rt,
            move || placer::by_name(&rt2, "greedy:size"),
            ShardConfig::default(),
            Arc::clone(&clock) as Arc<dyn Clock>,
        )
        .unwrap();
        let t0 = clock.now();
        for t in &tasks {
            let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
            front.submit(req).unwrap().unwrap();
        }
        clock.advance_ms(75.0);
        front.drain().unwrap();
        let view = front.shards().next().unwrap();
        let stamp = view.last_drain.expect("drain stamped the clock");
        assert_eq!(stamp.duration_since(t0).as_millis(), 75, "stamp reads the test clock");
        assert_eq!(view.stats.p95_queue_ms(), 75.0, "queue latency reads the same clock");
    }

    #[test]
    fn stats_merge_per_shard_counters() {
        let rt = Arc::new(Runtime::reference());
        let (ds, small, sim) = setup(4, 3);
        let (_, large, _) = setup(128, 2);
        let mut front = greedy_front(&rt, ShardConfig::default());
        for t in small.iter().chain(&large) {
            let req = PlacementRequest::for_runtime(&rt, &ds, t, &sim).unwrap();
            front.submit(req).unwrap().unwrap();
        }
        assert_eq!(front.queued(), 5);
        let done = front.drain().unwrap();
        assert_eq!(done.len(), 5);
        let fs = front.stats();
        assert_eq!(fs.shards, 2);
        assert_eq!(fs.routed, 5);
        assert_eq!(fs.aggregate.submitted, 5);
        assert_eq!(fs.aggregate.planned, 5);
        assert!(fs.aggregate.mean_queue_ms() >= 0.0);
        assert!(fs.summary().contains("2 shards"), "{}", fs.summary());
        for sh in front.shards() {
            assert!(sh.last_drain.is_some(), "drain stamped the completion clock");
            assert_eq!(sh.queued, 0);
        }
    }
}
