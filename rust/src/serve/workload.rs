//! Synthetic serving workloads: Poisson arrivals of heterogeneous
//! placement tasks (mixed table counts and device counts), replayed by
//! the `serve-sim` CLI subcommand, `benches/serving.rs`, and
//! `examples/serve_queue.rs` — open-loop (wall-clock schedule) or
//! closed-loop (each arrival offset from the previous drain completion,
//! the mode the [`crate::serve::Controller`] steers).

use crate::tables::Task;
use crate::util::Rng;

use super::SloClass;

/// Workload shape knobs.
#[derive(Clone, Debug)]
pub struct WorkloadCfg {
    pub n_requests: usize,
    /// Device counts drawn uniformly per request (each must have a
    /// lowered artifact variant, e.g. 2/4/8/128).
    pub device_mix: Vec<usize>,
    /// Tables per task, drawn uniformly in `[min_tables, max_tables]`.
    pub min_tables: usize,
    pub max_tables: usize,
    /// Mean exponential inter-arrival gap, ms.
    pub mean_gap_ms: f64,
    /// Arrival-clock coupling. Open-loop (`false`, the default):
    /// [`Arrival::at_ms`] is a fixed wall schedule (cumulative gaps since
    /// the workload started), blind to how the service keeps up.
    /// Closed-loop (`true`): `at_ms` is each arrival's *offset from the
    /// last drain completion* ([`crate::serve::ShardView::last_drain`]) —
    /// the replayer releases the next request that many ms after service
    /// progress, so arrivals throttle with the service instead of piling
    /// onto a schedule. The sampled tasks are identical in both modes
    /// (same RNG stream); only the meaning of `at_ms` changes.
    pub closed_loop: bool,
    /// Percent of requests tagged [`SloClass::Batch`] (0-100); drawn from
    /// an independent RNG stream so the task sequence is identical at any
    /// mix.
    pub batch_pct: usize,
    pub seed: u64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        WorkloadCfg {
            n_requests: 64,
            device_mix: vec![2, 4, 8],
            min_tables: 10,
            max_tables: 40,
            mean_gap_ms: 5.0,
            closed_loop: false,
            batch_pct: 0,
            seed: 0,
        }
    }
}

/// One arriving request: the sampled task, its arrival offset, and its
/// SLO class. `at_ms` is ms since the workload started (open-loop) or ms
/// since the previous drain completion (closed-loop) — see
/// [`WorkloadCfg::closed_loop`].
#[derive(Clone, Debug)]
pub struct Arrival {
    pub task: Task,
    pub at_ms: f64,
    pub class: SloClass,
}

/// Generate a deterministic open-loop arrival schedule from a table pool
/// (ids into a dataset, e.g. one side of
/// [`crate::tables::split_pools`]): exponential inter-arrival gaps, table
/// counts uniform in `[min_tables, max_tables]`, device counts uniform
/// over `device_mix`, tables sampled without replacement per task.
pub fn synthetic_arrivals(pool: &[usize], cfg: &WorkloadCfg) -> Vec<Arrival> {
    assert!(!cfg.device_mix.is_empty(), "device_mix must not be empty");
    assert!(
        cfg.min_tables >= 1 && cfg.min_tables <= cfg.max_tables,
        "need 1 <= min_tables <= max_tables"
    );
    assert!(
        cfg.max_tables <= pool.len(),
        "pool of {} too small for {}-table tasks",
        pool.len(),
        cfg.max_tables
    );
    assert!(cfg.batch_pct <= 100, "batch_pct is a percentage (0-100)");
    let mut rng = Rng::new(cfg.seed).fork(0x5E47E);
    // classes come from their own stream so the task sequence is
    // identical at any batch mix (and to pre-SLO workloads)
    let mut class_rng = Rng::new(cfg.seed).fork(0xC1A55);
    let mut clock_ms = 0.0;
    (0..cfg.n_requests)
        .map(|_| {
            // exponential gaps -> Poisson arrival process
            let gap_ms = -cfg.mean_gap_ms * (1.0 - rng.f64()).ln();
            clock_ms += gap_ms;
            let n_tables = cfg.min_tables + rng.below(cfg.max_tables - cfg.min_tables + 1);
            let n_devices = cfg.device_mix[rng.below(cfg.device_mix.len())];
            let picks = rng.sample_indices(pool.len(), n_tables);
            let class = if class_rng.below(100) < cfg.batch_pct {
                SloClass::Batch
            } else {
                SloClass::Interactive
            };
            Arrival {
                task: Task {
                    table_ids: picks.into_iter().map(|i| pool[i]).collect(),
                    n_devices,
                },
                // closed-loop: the raw gap, to be offset from the last
                // drain completion by the replayer; open-loop: the
                // cumulative wall schedule
                at_ms: if cfg.closed_loop { gap_ms } else { clock_ms },
                class,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{gen_dlrm, split_pools};

    fn cfg() -> WorkloadCfg {
        WorkloadCfg {
            n_requests: 50,
            device_mix: vec![2, 4, 8, 128],
            min_tables: 5,
            max_tables: 20,
            mean_gap_ms: 3.0,
            seed: 9,
            ..WorkloadCfg::default()
        }
    }

    #[test]
    fn arrivals_match_the_requested_shape() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let arrivals = synthetic_arrivals(&pool, &cfg());
        assert_eq!(arrivals.len(), 50);
        let mut last = 0.0;
        let mut mixes = std::collections::HashSet::new();
        for a in &arrivals {
            assert!(a.at_ms >= last, "arrival clock must be nondecreasing");
            last = a.at_ms;
            assert!((5..=20).contains(&a.task.n_tables()));
            assert!([2, 4, 8, 128].contains(&a.task.n_devices));
            mixes.insert(a.task.n_devices);
            let mut ids = a.task.table_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), a.task.n_tables(), "duplicate table in task");
            assert!(a.task.table_ids.iter().all(|id| pool.contains(id)));
        }
        assert!(mixes.len() >= 2, "50 draws should hit several device counts");
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let a = synthetic_arrivals(&pool, &cfg());
        let b = synthetic_arrivals(&pool, &cfg());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.task.table_ids, y.task.table_ids);
            assert_eq!(x.task.n_devices, y.task.n_devices);
            assert_eq!(x.at_ms, y.at_ms);
        }
        let other = synthetic_arrivals(&pool, &WorkloadCfg { seed: 10, ..cfg() });
        assert!(
            a.iter().zip(other.iter()).any(|(x, y)| x.task.table_ids != y.task.table_ids),
            "different seeds should draw different workloads"
        );
    }

    #[test]
    fn closed_loop_keeps_the_task_stream_and_reinterprets_at_ms() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let open = synthetic_arrivals(&pool, &cfg());
        let closed = synthetic_arrivals(&pool, &WorkloadCfg { closed_loop: true, ..cfg() });
        let mut cumulative = 0.0;
        for (o, c) in open.iter().zip(closed.iter()) {
            assert_eq!(o.task.table_ids, c.task.table_ids, "identical tasks in both modes");
            assert_eq!(o.task.n_devices, c.task.n_devices);
            assert!(c.at_ms > 0.0, "closed-loop at_ms is a per-arrival gap");
            cumulative += c.at_ms;
            assert!(
                (o.at_ms - cumulative).abs() < 1e-9,
                "closed-loop gaps cumulate to the open-loop schedule"
            );
        }
    }

    #[test]
    fn batch_pct_tags_classes_without_perturbing_tasks() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let plain = synthetic_arrivals(&pool, &cfg());
        assert!(
            plain.iter().all(|a| a.class == SloClass::Interactive),
            "batch_pct 0 tags nothing"
        );
        let mixed = synthetic_arrivals(&pool, &WorkloadCfg { batch_pct: 40, ..cfg() });
        let n_batch = mixed.iter().filter(|a| a.class == SloClass::Batch).count();
        assert!((1..mixed.len()).contains(&n_batch), "40% of 50 draws hits both classes");
        for (p, m) in plain.iter().zip(mixed.iter()) {
            assert_eq!(p.task.table_ids, m.task.table_ids, "class stream is independent");
            assert_eq!(p.at_ms, m.at_ms);
        }
        // the class sequence is part of the fixed-seed determinism
        let again = synthetic_arrivals(&pool, &WorkloadCfg { batch_pct: 40, ..cfg() });
        for (a, b) in mixed.iter().zip(again.iter()) {
            assert_eq!(a.class, b.class);
        }
    }
}
