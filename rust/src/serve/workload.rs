//! Synthetic open-loop serving workloads: Poisson arrivals of
//! heterogeneous placement tasks (mixed table counts and device counts),
//! replayed by the `serve-sim` CLI subcommand, `benches/serving.rs`, and
//! `examples/serve_queue.rs`.

use crate::tables::Task;
use crate::util::Rng;

/// Workload shape knobs.
#[derive(Clone, Debug)]
pub struct WorkloadCfg {
    pub n_requests: usize,
    /// Device counts drawn uniformly per request (each must have a
    /// lowered artifact variant, e.g. 2/4/8/128).
    pub device_mix: Vec<usize>,
    /// Tables per task, drawn uniformly in `[min_tables, max_tables]`.
    pub min_tables: usize,
    pub max_tables: usize,
    /// Mean exponential inter-arrival gap, ms (open-loop arrival clock).
    pub mean_gap_ms: f64,
    pub seed: u64,
}

impl Default for WorkloadCfg {
    fn default() -> Self {
        WorkloadCfg {
            n_requests: 64,
            device_mix: vec![2, 4, 8],
            min_tables: 10,
            max_tables: 40,
            mean_gap_ms: 5.0,
            seed: 0,
        }
    }
}

/// One arriving request: the sampled task plus its arrival time on the
/// open-loop clock (ms since the workload started).
#[derive(Clone, Debug)]
pub struct Arrival {
    pub task: Task,
    pub at_ms: f64,
}

/// Generate a deterministic open-loop arrival schedule from a table pool
/// (ids into a dataset, e.g. one side of
/// [`crate::tables::split_pools`]): exponential inter-arrival gaps, table
/// counts uniform in `[min_tables, max_tables]`, device counts uniform
/// over `device_mix`, tables sampled without replacement per task.
pub fn synthetic_arrivals(pool: &[usize], cfg: &WorkloadCfg) -> Vec<Arrival> {
    assert!(!cfg.device_mix.is_empty(), "device_mix must not be empty");
    assert!(
        cfg.min_tables >= 1 && cfg.min_tables <= cfg.max_tables,
        "need 1 <= min_tables <= max_tables"
    );
    assert!(
        cfg.max_tables <= pool.len(),
        "pool of {} too small for {}-table tasks",
        pool.len(),
        cfg.max_tables
    );
    let mut rng = Rng::new(cfg.seed).fork(0x5E47E);
    let mut clock_ms = 0.0;
    (0..cfg.n_requests)
        .map(|_| {
            // exponential gaps -> Poisson arrival process
            clock_ms += -cfg.mean_gap_ms * (1.0 - rng.f64()).ln();
            let n_tables = cfg.min_tables + rng.below(cfg.max_tables - cfg.min_tables + 1);
            let n_devices = cfg.device_mix[rng.below(cfg.device_mix.len())];
            let picks = rng.sample_indices(pool.len(), n_tables);
            Arrival {
                task: Task {
                    table_ids: picks.into_iter().map(|i| pool[i]).collect(),
                    n_devices,
                },
                at_ms: clock_ms,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{gen_dlrm, split_pools};

    fn cfg() -> WorkloadCfg {
        WorkloadCfg {
            n_requests: 50,
            device_mix: vec![2, 4, 8, 128],
            min_tables: 5,
            max_tables: 20,
            mean_gap_ms: 3.0,
            seed: 9,
        }
    }

    #[test]
    fn arrivals_match_the_requested_shape() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let arrivals = synthetic_arrivals(&pool, &cfg());
        assert_eq!(arrivals.len(), 50);
        let mut last = 0.0;
        let mut mixes = std::collections::HashSet::new();
        for a in &arrivals {
            assert!(a.at_ms >= last, "arrival clock must be nondecreasing");
            last = a.at_ms;
            assert!((5..=20).contains(&a.task.n_tables()));
            assert!([2, 4, 8, 128].contains(&a.task.n_devices));
            mixes.insert(a.task.n_devices);
            let mut ids = a.task.table_ids.clone();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), a.task.n_tables(), "duplicate table in task");
            assert!(a.task.table_ids.iter().all(|id| pool.contains(id)));
        }
        assert!(mixes.len() >= 2, "50 draws should hit several device counts");
    }

    #[test]
    fn deterministic_per_seed() {
        let ds = gen_dlrm(120, 0);
        let (pool, _) = split_pools(&ds, 1);
        let a = synthetic_arrivals(&pool, &cfg());
        let b = synthetic_arrivals(&pool, &cfg());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.task.table_ids, y.task.table_ids);
            assert_eq!(x.task.n_devices, y.task.n_devices);
            assert_eq!(x.at_ms, y.at_ms);
        }
        let other = synthetic_arrivals(&pool, &WorkloadCfg { seed: 10, ..cfg() });
        assert!(
            a.iter().zip(other.iter()).any(|(x, y)| x.task.table_ids != y.task.table_ids),
            "different seeds should draw different workloads"
        );
    }
}
