//! [`Controller`]: the closed loop over the sharded front end.
//!
//! Everything below observes signals the front end already exposes and
//! actuates knobs that already exist — the controller adds no new
//! mechanism to the serving stack, only the policy that connects
//! measurement to actuation:
//!
//! * **observe** — per-shard queue-latency percentiles
//!   ([`ServeStats::percentile_queue_ms`] on the bounded window), queue
//!   depths ([`ShardView::queued`]), and drain-completion ages
//!   ([`ShardView::last_drain`] against the front end's [`Clock`]);
//! * **compare** — against the [`ControlConfig::target_ms`] tail-latency
//!   target, with hysteresis (`pressure_enter` / `pressure_exit`) so the
//!   loop does not chatter around the threshold;
//! * **actuate** — resize per-shard lane-chunks
//!   ([`ShardedFrontEnd::set_chunk`]: bigger chunks amortize more
//!   planning per fused backend call when a shard falls behind, smaller
//!   chunks complete sooner when it is comfortably ahead), adapt the
//!   global admission cap AIMD-style
//!   ([`ShardedFrontEnd::set_global_cap`]: multiplicative decrease under
//!   pressure, additive recovery when healthy), toggle SLO-class
//!   pressure mode ([`ShardedFrontEnd::set_class_order`]: interactive
//!   drains first, batch sheds first), and schedule which shards drain
//!   this tick ([`ShardedFrontEnd::drain_shard`], worst tail first);
//! * **rebalance** — size a [`MigrationBudget`] to measured headroom
//!   ([`Controller::migration_budget`]): the farther under target the
//!   fleet is, the more tables a re-plan may move.
//!
//! Every decision reads the front end's clock, so under a
//! [`super::TestClock`] a whole control trajectory — overload, pressure
//! entry, convergence back under target — is a deterministic unit test
//! (`tests/control.rs`), not a timing race.

use crate::placer::MigrationBudget;
use crate::util::error::Result;

use super::clock::Clock;
use super::{Planned, ReplaceJob, ServeStats, ShardKey, ShardView, ShardedFrontEnd};

/// Closed-loop policy knobs. The defaults steer toward a 50 ms queue
/// p95; deployments mostly only change [`ControlConfig::target_ms`].
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// Queue-latency target, ms: the controller steers every shard's
    /// tail percentile toward (and under) this.
    pub target_ms: f64,
    /// Which tail to target (`0.95` = p95), evaluated per shard on the
    /// bounded recent window ([`ServeStats::percentile_queue_ms`]).
    pub percentile: f64,
    /// Lane-chunk resize bounds ([`ShardedFrontEnd::set_chunk`]).
    pub min_chunk: usize,
    pub max_chunk: usize,
    /// Global admission-cap bounds ([`ShardedFrontEnd::set_global_cap`]).
    pub min_cap: usize,
    pub max_cap: usize,
    /// Enter pressure mode when the worst shard's tail exceeds
    /// `target_ms * pressure_enter`; leave it only when the worst tail
    /// falls below `target_ms * pressure_exit`. `exit < enter` is the
    /// hysteresis band that keeps the mode from chattering.
    pub pressure_enter: f64,
    pub pressure_exit: f64,
    /// How many shards [`Controller::tick`] drains per tick (worst tail
    /// first) — bounds per-tick work so one tick never becomes a full
    /// front-end drain under wide fan-out.
    pub drains_per_tick: usize,
    /// Drain a queued shard regardless of other signals once its last
    /// drain completion is this old, ms (freshness floor: a trickle of
    /// requests on a quiet shard must not wait forever).
    pub max_idle_ms: f64,
    /// Migration-budget ceiling: at full headroom (worst tail at 0)
    /// [`Controller::migration_budget`] grants this many moves per
    /// re-planned stream; at or above target it grants none.
    pub max_moves: usize,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            target_ms: 50.0,
            percentile: 0.95,
            min_chunk: 1,
            max_chunk: 64,
            min_cap: 16,
            max_cap: 1024,
            pressure_enter: 1.0,
            pressure_exit: 0.5,
            drains_per_tick: 2,
            max_idle_ms: 100.0,
            max_moves: 16,
        }
    }
}

/// What [`Controller::tick`] observed and decided for one shard.
#[derive(Clone, Debug)]
pub struct ShardDecision {
    pub key: ShardKey,
    /// The shard's tail queue latency this tick, ms
    /// ([`ControlConfig::percentile`] over the bounded window).
    pub p_queue_ms: f64,
    /// Requests queued when the tick observed the shard.
    pub queued: usize,
    /// Lane-chunk size after this tick's resize (if any).
    pub chunk: usize,
    /// Whether this tick drained the shard.
    pub drained: bool,
}

/// One tick's full observation/actuation record — what a dashboard (or
/// the `serve-sim --closed-loop` replay) prints per control interval.
#[derive(Clone, Debug)]
pub struct TickReport {
    /// Monotonic tick counter (1-based: set before observation).
    pub tick: u64,
    /// Worst per-shard tail queue latency observed this tick, ms.
    pub worst_p_ms: f64,
    /// Pressure mode after this tick's hysteresis update.
    pub pressure: bool,
    /// Global admission cap after this tick's AIMD update.
    pub global_cap: usize,
    /// Per-shard observations and decisions, in shard-creation order.
    pub shards: Vec<ShardDecision>,
    /// Everything the tick's scheduled drains planned.
    pub planned: Vec<Planned>,
}

impl TickReport {
    /// One-line human summary for logs.
    pub fn summary(&self) -> String {
        let drained: Vec<String> = self
            .shards
            .iter()
            .filter(|d| d.drained)
            .map(|d| d.key.label())
            .collect();
        format!(
            "tick {}: worst p{:.0} ms, pressure {}, cap {}, {} planned (drained: {})",
            self.tick,
            self.worst_p_ms,
            if self.pressure { "ON" } else { "off" },
            self.global_cap,
            self.planned.len(),
            if drained.is_empty() { "-".into() } else { drained.join(", ") },
        )
    }
}

/// Per-shard signals one tick reads, captured before any actuation (the
/// observation and the mutation phases must not interleave: decisions
/// within a tick are all made against the same snapshot).
struct Observed {
    key: ShardKey,
    p_queue_ms: f64,
    queued: usize,
    chunk: usize,
    idle_ms: f64,
}

/// The closed-loop serving controller. Pure policy over
/// [`ShardedFrontEnd`]'s observation and actuation surface; owns no
/// threads and keeps almost no state (a tick counter and the pressure
/// latch), so a caller ticks it from whatever cadence it likes — a
/// replay loop, a timer thread, a test.
pub struct Controller {
    cfg: ControlConfig,
    ticks: u64,
    pressure: bool,
}

impl Controller {
    pub fn new(cfg: ControlConfig) -> Self {
        let cfg = ControlConfig {
            target_ms: cfg.target_ms.max(f64::MIN_POSITIVE),
            percentile: cfg.percentile.clamp(0.0, 1.0),
            min_chunk: cfg.min_chunk.max(1),
            max_chunk: cfg.max_chunk.max(cfg.min_chunk.max(1)),
            min_cap: cfg.min_cap.max(1),
            max_cap: cfg.max_cap.max(cfg.min_cap.max(1)),
            drains_per_tick: cfg.drains_per_tick.max(1),
            ..cfg
        };
        Controller { cfg, ticks: 0, pressure: false }
    }

    pub fn config(&self) -> &ControlConfig {
        &self.cfg
    }

    /// Whether the loop is currently in pressure mode (worst tail above
    /// target, not yet recovered below the exit threshold).
    pub fn pressure(&self) -> bool {
        self.pressure
    }

    /// Ticks run so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// The shard's tail latency signal: the configured percentile when
    /// the window has samples, else 0 (a never-drained shard has no
    /// latency evidence yet — its `queued`/idle signals drive instead).
    fn tail_ms(&self, stats: &ServeStats) -> f64 {
        if stats.window_len() == 0 {
            0.0
        } else {
            stats.percentile_queue_ms(self.cfg.percentile)
        }
    }

    /// Run one control interval: observe every shard, update the
    /// pressure latch and admission cap, resize lane-chunks, then drain
    /// up to [`ControlConfig::drains_per_tick`] shards (worst tail
    /// first). Returns the full [`TickReport`]; its `planned` carries
    /// whatever the scheduled drains completed. Errors are the drained
    /// shards' errors (an observation/actuation pass itself cannot
    /// fail).
    pub fn tick<'a>(&mut self, front: &mut ShardedFrontEnd<'a>) -> Result<TickReport> {
        self.ticks += 1;
        let cfg = self.cfg.clone();
        let now = front.clock().now();

        // -------- observe (immutable snapshot) --------
        let observed: Vec<Observed> = front
            .shards()
            .map(|v: ShardView<'_>| Observed {
                key: v.key.clone(),
                p_queue_ms: self.tail_ms(v.stats),
                queued: v.queued,
                chunk: v.chunk,
                idle_ms: v
                    .last_drain
                    // never-drained shards read as infinitely idle, so
                    // the freshness floor fires on the first tick
                    .map_or(f64::INFINITY, |at| {
                        now.duration_since(at).as_secs_f64() * 1e3
                    }),
            })
            .collect();
        let worst_p_ms =
            observed.iter().map(|o| o.p_queue_ms).fold(0.0, f64::max);

        // -------- pressure latch (hysteresis) --------
        if worst_p_ms > cfg.target_ms * cfg.pressure_enter {
            self.pressure = true;
        } else if worst_p_ms < cfg.target_ms * cfg.pressure_exit {
            self.pressure = false;
        }
        front.set_class_order(self.pressure);

        // -------- admission cap (AIMD) --------
        let cap = front.global_cap();
        let cap = if self.pressure {
            // multiplicative decrease: shed harder while over target
            (cap * 3 / 4).max(cfg.min_cap)
        } else {
            // additive recovery toward the ceiling
            (cap + (cfg.max_cap / 8).max(1)).min(cfg.max_cap)
        };
        front.set_global_cap(cap);

        // -------- per-shard chunk resize --------
        let mut decisions: Vec<ShardDecision> = Vec::with_capacity(observed.len());
        for o in &observed {
            let chunk = if o.p_queue_ms > cfg.target_ms {
                // behind target: bigger chunks amortize more planning
                // per fused backend call, raising drain throughput
                (o.chunk * 2).min(cfg.max_chunk)
            } else if o.p_queue_ms < cfg.target_ms * 0.5 && o.queued <= o.chunk / 2 {
                // comfortably ahead with a shallow queue: smaller chunks
                // complete sooner, trading spare throughput for latency
                (o.chunk / 2).max(cfg.min_chunk)
            } else {
                o.chunk
            };
            if chunk != o.chunk {
                front.set_chunk(&o.key, chunk)?;
            }
            decisions.push(ShardDecision {
                key: o.key.clone(),
                p_queue_ms: o.p_queue_ms,
                queued: o.queued,
                chunk,
                drained: false,
            });
        }

        // -------- drain scheduling --------
        // candidates: queued work that is worth a drain now — a full
        // chunk to batch, a stale shard past the freshness floor, or a
        // shard already over target (always drain everything under
        // pressure: the backlog *is* the latency)
        let mut candidates: Vec<usize> = observed
            .iter()
            .enumerate()
            .filter(|(i, o)| {
                o.queued > 0
                    && (o.queued >= decisions[*i].chunk
                        || o.idle_ms >= cfg.max_idle_ms
                        || o.p_queue_ms > cfg.target_ms
                        || self.pressure)
            })
            .map(|(i, _)| i)
            .collect();
        candidates.sort_by(|&a, &b| {
            observed[b]
                .p_queue_ms
                .total_cmp(&observed[a].p_queue_ms)
                .then(observed[b].queued.cmp(&observed[a].queued))
        });
        let mut planned: Vec<Planned> = vec![];
        for &i in candidates.iter().take(cfg.drains_per_tick) {
            planned.extend(front.drain_shard(&observed[i].key)?);
            decisions[i].drained = true;
        }

        Ok(TickReport {
            tick: self.ticks,
            worst_p_ms,
            pressure: self.pressure,
            global_cap: cap,
            shards: decisions,
            planned,
        })
    }

    /// Fraction of the latency target currently unused, in `[0, 1]`:
    /// 1 when the worst shard's tail is 0, 0 when it is at or over
    /// target.
    pub fn headroom(&self, front: &ShardedFrontEnd<'_>) -> f64 {
        let worst = front
            .shards()
            .map(|v| self.tail_ms(v.stats))
            .fold(0.0, f64::max);
        ((self.cfg.target_ms - worst) / self.cfg.target_ms).clamp(0.0, 1.0)
    }

    /// Size a migration budget to measured headroom: at full headroom a
    /// re-plan may move up to [`ControlConfig::max_moves`] tables per
    /// stream; at zero headroom none (forced moves — a vanished device —
    /// are always exempt, see [`MigrationBudget`]). This is the knob the
    /// ROADMAP asked the closed loop to own: migration work rides in
    /// whatever latency slack the fleet actually has.
    pub fn migration_budget(&self, front: &ShardedFrontEnd<'_>) -> MigrationBudget {
        let moves = (self.headroom(front) * self.cfg.max_moves as f64).round() as usize;
        MigrationBudget::moves(moves)
    }

    /// [`ShardedFrontEnd::rebalance`] under a controller-sized budget:
    /// every job's request gets [`Controller::migration_budget`] before
    /// the re-plans fan out. Call it when the fleet changed; the budget
    /// makes the migration cost proportional to available headroom.
    pub fn rebalance<'a>(
        &mut self,
        front: &mut ShardedFrontEnd<'a>,
        jobs: Vec<ReplaceJob<'a>>,
    ) -> Result<Vec<Planned>> {
        let budget = self.migration_budget(front);
        let jobs: Vec<ReplaceJob<'a>> = jobs
            .into_iter()
            .map(|j| ReplaceJob { prev: j.prev, req: j.req.with_migration(budget) })
            .collect();
        front.rebalance(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_sanitizes_degenerate_bounds() {
        let ctl = Controller::new(ControlConfig {
            target_ms: 0.0,
            min_chunk: 0,
            max_chunk: 0,
            min_cap: 0,
            max_cap: 0,
            drains_per_tick: 0,
            ..Default::default()
        });
        let cfg = ctl.config();
        assert!(cfg.target_ms > 0.0);
        assert_eq!((cfg.min_chunk, cfg.max_chunk), (1, 1));
        assert_eq!((cfg.min_cap, cfg.max_cap), (1, 1));
        assert_eq!(cfg.drains_per_tick, 1);
        assert!(!ctl.pressure());
        assert_eq!(ctl.ticks(), 0);
    }

    #[test]
    fn defaults_form_a_valid_hysteresis_band() {
        let cfg = ControlConfig::default();
        assert!(cfg.pressure_exit < cfg.pressure_enter);
        assert!(cfg.min_chunk <= cfg.max_chunk);
        assert!(cfg.min_cap <= cfg.max_cap);
    }
}
