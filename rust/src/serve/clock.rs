//! The serving stack's time source, as a seam.
//!
//! Every latency the serving layer measures — queue wait, chunk wall
//! time, the per-shard drain-completion clock
//! ([`crate::serve::ShardView::last_drain`]) — and every decision the
//! closed-loop [`crate::serve::Controller`] makes off those measurements
//! flows through one [`Clock`]. Production uses [`SystemClock`]
//! (`Instant::now()`); tests use [`TestClock`], which only moves when the
//! test calls [`TestClock::advance_ms`] — so "a shard sat queued for
//! 400 ms" is two method calls, not a real sleep, and controller
//! convergence is a deterministic assertion instead of a timing race.
//!
//! The clock hands out real `Instant`s (a fixed base plus the advanced
//! offset) rather than raw floats, so the rest of the serving code keeps
//! ordinary `Instant`/`Duration` arithmetic and nothing downstream can
//! tell the difference.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic time source. `Send + Sync` because drain threads stamp
/// completion times concurrently ([`crate::serve::ShardedFrontEnd`]
/// drains every shard on its own thread).
pub trait Clock: Send + Sync {
    /// The current instant on this clock. Must be monotonic:
    /// successive calls never go backwards.
    fn now(&self) -> Instant;
}

/// The real wall clock: [`Instant::now`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// A deterministic test clock: frozen at construction, moved only by
/// explicit [`TestClock::advance_ms`] calls. Share it (`Arc`) between
/// the service under test and the test body:
///
/// ```
/// use std::sync::Arc;
/// use dreamshard::serve::{Clock, TestClock};
///
/// let clock = Arc::new(TestClock::new());
/// let t0 = clock.now();
/// clock.advance_ms(250.0);
/// assert_eq!(clock.now().duration_since(t0).as_millis(), 250);
/// ```
#[derive(Debug)]
pub struct TestClock {
    base: Instant,
    /// Offset since `base`, in microseconds (atomic so drain threads and
    /// the test body can share the clock without locks).
    offset_us: AtomicU64,
}

impl TestClock {
    pub fn new() -> Self {
        TestClock { base: Instant::now(), offset_us: AtomicU64::new(0) }
    }

    /// Move the clock forward. Negative or non-finite advances are
    /// rejected — the clock, like the trait, is monotonic.
    pub fn advance_ms(&self, ms: f64) {
        assert!(ms.is_finite() && ms >= 0.0, "TestClock::advance_ms({ms}): clock is monotonic");
        self.offset_us.fetch_add((ms * 1e3) as u64, Ordering::SeqCst);
    }

    /// Milliseconds advanced since construction.
    pub fn elapsed_ms(&self) -> f64 {
        self.offset_us.load(Ordering::SeqCst) as f64 / 1e3
    }
}

impl Default for TestClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for TestClock {
    fn now(&self) -> Instant {
        self.base + Duration::from_micros(self.offset_us.load(Ordering::SeqCst))
    }
}

/// The default clock services are built with ([`SystemClock`]).
pub fn system_clock() -> Arc<dyn Clock> {
    Arc::new(SystemClock)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock;
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn test_clock_only_moves_when_advanced() {
        let c = TestClock::new();
        let a = c.now();
        assert_eq!(c.now(), a, "frozen until advanced");
        c.advance_ms(1.5);
        assert_eq!(c.now().duration_since(a).as_micros(), 1500);
        assert_eq!(c.elapsed_ms(), 1.5);
        c.advance_ms(0.0); // a no-op advance is legal
        assert_eq!(c.elapsed_ms(), 1.5);
    }

    #[test]
    #[should_panic(expected = "monotonic")]
    fn test_clock_rejects_backward_advance() {
        TestClock::new().advance_ms(-1.0);
    }

    #[test]
    fn test_clock_is_shareable_across_threads() {
        let c = Arc::new(TestClock::new());
        let t0 = c.now();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = Arc::clone(&c);
                s.spawn(move || c.advance_ms(10.0));
            }
        });
        assert_eq!(c.now().duration_since(t0).as_millis(), 40);
    }
}
