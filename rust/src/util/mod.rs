//! Small self-contained utilities: deterministic RNG, stats, text tables.
//!
//! The build is fully offline with a minimal dependency closure, so the
//! RNG (SplitMix64) and helpers live here instead of pulling `rand`.

pub mod error;
pub mod rng;
pub mod stats;
pub mod table;

pub use error::{Context, Error, Result};
pub use rng::Rng;
pub use stats::{mean, mean_std, median, percentile};
