//! Minimal std-only error plumbing (the crate builds with zero external
//! dependencies, so there is no `anyhow`).
//!
//! [`Error`] is a message-carrying error, [`Result`] defaults its error
//! type to it, [`Context`] adds `.context(..)` / `.with_context(..)` on
//! `Result` and `Option`, and the [`err!`](crate::err) / [`bail!`](crate::bail)
//! macros build/return formatted errors.

use std::fmt;

/// A simple message-carrying error. Context wraps prepend `"<ctx>: "`.
#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: Into<String>>(m: M) -> Error {
        Error { msg: m.into() }
    }

    /// Prepend a context layer: `"<ctx>: <self>"`.
    pub fn wrap<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! err {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($e:expr) => {
        $crate::util::error::Error::msg(format!("{}", $e))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::err!($($t)*))
    };
}

/// Return early with a formatted [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::err!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(crate::err!("base {}", 42))
    }

    #[test]
    fn formats_and_wraps() {
        let e = fails().with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: base 42");
        let e2 = e.wrap("top");
        assert_eq!(e2.to_string(), "top: outer: base 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                crate::bail!("three is right out");
            }
            Ok(x)
        }
        assert!(f(11).is_err());
        assert!(f(3).is_err());
        assert_eq!(f(5).unwrap(), 5);
    }
}
