//! Minimal fixed-width text-table printer for the bench harness, so the
//! reproduced paper tables read like the originals on a terminal.

pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable { header: header.into_iter().map(Into::into).collect(), rows: vec![] }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        self.rows.push(cells.into_iter().map(Into::into).collect());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                let w = widths.get(i).copied().unwrap_or(c.len());
                line.push_str(&format!("{:<w$}  ", c, w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// `12.3±0.4 (+19.0%)` formatting used throughout the paper's tables.
pub fn ms_pm(mean: f64, std: f64) -> String {
    format!("{mean:.1}±{std:.1}")
}

pub fn speedup_vs(random: f64, x: f64) -> String {
    if x <= 0.0 {
        return "-".into();
    }
    format!("{:+.1}%", (random - x) / x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders() {
        let mut t = TextTable::new(vec!["a", "bb"]);
        t.row(vec!["1", "2"]);
        let s = t.render();
        assert!(s.contains("a"));
        assert!(s.contains("bb"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn formatting() {
        assert_eq!(ms_pm(12.34, 0.41), "12.3±0.4");
        assert_eq!(speedup_vs(24.0, 20.0), "+20.0%");
    }
}
