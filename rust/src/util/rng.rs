//! Deterministic SplitMix64 RNG.
//!
//! Every stochastic component in the library (dataset generation, task
//! sampling, policy sampling, simulator measurement noise, parameter init)
//! takes an explicit seed, so whole experiments replay bit-identically.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and trivially
/// forkable (`fork` derives an independent stream from a label).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream for a sub-component.
    pub fn fork(&self, label: u64) -> Rng {
        let mut r = Rng::new(self.state ^ label.wrapping_mul(0xBF58476D1CE4E5B9));
        r.next_u64(); // decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n), exactly unbiased via Lemire's
    /// multiply-shift rejection method (the old `next_u64() % n` carried a
    /// modulo bias of up to n/2^64 toward small residues). Draws one extra
    /// `next_u64` only in the rare rejection case, so the stream stays
    /// deterministic per seed — but it is a *different* stream than the
    /// modulo version produced.
    pub fn below(&mut self, n: usize) -> usize {
        // hard assert: the old `% n` panicked on n = 0 in every build
        // profile; Lemire's guard would instead silently return 0, so
        // keep the fault at the call site
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut m = (self.next_u64() as u128) * (n as u128);
        if (m as u64) < n {
            // threshold = 2^64 mod n; reject the low fringe that maps
            // unevenly onto [0, n)
            let t = n.wrapping_neg() % n;
            while (m as u64) < t {
                m = (self.next_u64() as u128) * (n as u128);
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log10-space mean and std.
    pub fn lognormal10(&mut self, mu10: f64, sigma10: f64) -> f64 {
        10f64.powf(mu10 + sigma10 * self.normal())
    }

    /// Pareto (power-law) sample with minimum `xm` and exponent `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / self.f64().max(1e-12).powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices out of `n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f32]) -> usize {
        let total: f64 = w.iter().map(|&x| x.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(w.len());
        }
        let mut r = self.f64() * total;
        for (i, &x) in w.iter().enumerate() {
            r -= x.max(0.0) as f64;
            if r <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn below_in_range_and_roughly_uniform() {
        let mut r = Rng::new(11);
        for n in [1usize, 2, 3, 7, 10, 1000] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
        // Lemire rejection removes the modulo bias; each residue of a
        // non-power-of-two n should land near 1/n
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3)] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "bucket count {c} far from 10k");
        }
    }

    #[test]
    fn fork_decorrelates() {
        let base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(4);
        let w = [0.0f32, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(r.weighted(&w), 2);
        }
    }
}
