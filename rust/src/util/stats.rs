//! Mean / standard-deviation helpers used by the bench harness when
//! aggregating repeated runs (the paper reports mean ± std over 5 seeds).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// (mean, population std); (0, 0) for an empty slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = mean(xs);
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

/// Median (sorts a copy); 0.0 for an empty slice. NaN inputs sort to the
/// ends (`total_cmp`) instead of panicking the sort.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        0.5 * (s[n / 2 - 1] + s[n / 2])
    }
}

/// Nearest-rank percentile: the smallest sample such that at least
/// `q` (in `[0, 1]`) of the data is `<=` it. Sorts a copy; 0.0 for an
/// empty slice; NaN inputs sort to the ends (`total_cmp`) instead of
/// panicking the sort. `q` outside `[0, 1]` clamps to the extremes, so
/// `percentile(xs, 1.0)` is the max and `percentile(xs, 0.0)` the min.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = (q * s.len() as f64).ceil() as usize;
    s[rank.clamp(1, s.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_survives_nan_input() {
        // regression: partial_cmp().unwrap() panicked on any NaN sample
        let m = median(&[3.0, f64::NAN, 1.0]);
        // positive NaN totally-orders after +inf, so the finite values
        // stay in front and the middle element is the larger finite one
        assert_eq!(m, 3.0);
        assert!(median(&[f64::NAN]).is_nan());
    }

    #[test]
    fn empty() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
        assert_eq!(median(&[]), 0.0);
        assert_eq!(percentile(&[], 0.95), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 0.5), 50.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0, "q=0 clamps to the min");
        assert_eq!(percentile(&xs, 2.0), 100.0, "q>1 clamps to the max");
        // order-independent: percentile sorts its own copy
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 0.95), 9.0);
        assert_eq!(percentile(&[9.0, 1.0, 5.0], 0.34), 5.0);
        // a single sample answers every quantile
        assert_eq!(percentile(&[7.0], 0.01), 7.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }
}
