//! PJRT/XLA backend (`--features xla`): load AOT HLO-text artifacts
//! written by `make artifacts` and execute them through xla-rs.
//!
//! This module is the only place in the crate that mentions `xla::*`. The
//! workspace ships a compile-only `xla-stub` crate in its place so the
//! feature type-checks offline; constructing the backend against the stub
//! fails with a pointer at the real dependency.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use super::manifest::Manifest;
use super::tensor::{TensorF32, TensorI32, Value};
use super::Backend;
use crate::err;
use crate::util::error::Result;

/// Lazily-compiling PJRT executor over an artifact directory.
pub struct XlaBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// artifact name -> HLO text file (from the manifest).
    files: HashMap<String, String>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl XlaBackend {
    pub fn new(dir: PathBuf, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        let files = manifest
            .artifacts
            .iter()
            .map(|(name, art)| (name.clone(), art.file.clone()))
            .collect();
        Ok(XlaBackend { client, dir, files, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let file = self.files.get(name).ok_or_else(|| err!("artifact {name} not in manifest"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| err!("compile {name}: {e:?}"))?;
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Number of artifacts compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

fn to_literal(v: &Value) -> Result<xla::Literal> {
    let (lit, dims) = match v {
        Value::F32(TensorF32 { dims, data }) => (xla::Literal::vec1(data), dims),
        Value::I32(TensorI32 { dims, data }) => (xla::Literal::vec1(data), dims),
    };
    lit.reshape(dims).map_err(|e| err!("reshape literal to {dims:?}: {e:?}"))
}

/// All artifact outputs are f32 in this crate's lowering.
fn from_literal(lit: &xla::Literal) -> Result<Value> {
    let data = lit.to_vec::<f32>().map_err(|e| err!("literal to f32: {e:?}"))?;
    let n = data.len();
    Ok(Value::F32(TensorF32::from_vec(data, &[n])))
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Execute an artifact: literals in, tuple-decomposed literals out
    /// (everything is lowered with `return_tuple=True`).
    fn execute(&self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let exe = self.executable(artifact)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let out = exe.execute(&literals).map_err(|e| err!("execute {artifact}: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| err!("fetch {artifact}: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| err!("untuple {artifact}: {e:?}"))?;
        tuple.iter().map(from_literal).collect()
    }
}
