//! PJRT/XLA backend (`--features xla`): load AOT HLO-text artifacts
//! written by `make artifacts` and execute them through xla-rs.
//!
//! This module is the only place in the crate that mentions `xla::*`. The
//! workspace ships a compile-only `xla-stub` crate in its place so the
//! feature type-checks offline; constructing the backend against the stub
//! fails with a pointer at the real dependency.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::manifest::Manifest;
use super::tensor::{TensorF32, TensorI32, Value};
use super::Backend;
use crate::err;
use crate::util::error::Result;

/// Every xla-rs handle the backend owns, behind one lock: the client and
/// the compiled-executable cache. All compilation *and* execution happen
/// under this mutex — xla-rs wrapper types use non-atomic internal
/// sharing (the pre-concurrency design here held them in `Rc`/`RefCell`
/// for a reason), so concurrent `execute` calls are serialized rather
/// than trusted to be thread-safe. Lifting this to true parallel
/// dispatch requires auditing the real xla-rs crate's handle sharing,
/// not just the PJRT C API underneath it.
struct XlaState {
    client: xla::PjRtClient,
    cache: HashMap<String, Arc<xla::PjRtLoadedExecutable>>,
}

/// Lazily-compiling PJRT executor over an artifact directory. Satisfies
/// the `Backend: Send + Sync` contract by serializing all xla-handle
/// access behind `XlaState`'s mutex (executions do not overlap on this
/// backend; the reference backend is the parallel one).
pub struct XlaBackend {
    state: Mutex<XlaState>,
    dir: PathBuf,
    /// artifact name -> HLO text file (from the manifest).
    files: HashMap<String, String>,
}

// SAFETY: every xla-rs handle lives inside `state: Mutex<XlaState>` and
// is only touched while that lock is held, so cross-thread use is fully
// serialized (the mutex provides the happens-before edges); the impls
// additionally assert that the handles may *move* between threads
// while externally synchronized, which holds for PJRT's C-API objects
// (they are plain heap pointers with no thread affinity).
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    pub fn new(dir: PathBuf, manifest: &Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        let files = manifest
            .artifacts
            .iter()
            .map(|(name, art)| (name.clone(), art.file.clone()))
            .collect();
        Ok(XlaBackend {
            state: Mutex::new(XlaState { client, cache: HashMap::new() }),
            dir,
            files,
        })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    /// Called with the state lock held; keeping the compile under the
    /// lock also means concurrent first calls never duplicate JIT work.
    fn executable(
        &self,
        state: &mut XlaState,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = state.cache.get(name) {
            return Ok(Arc::clone(e));
        }
        let file = self.files.get(name).ok_or_else(|| err!("artifact {name} not in manifest"))?;
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = state.client.compile(&comp).map_err(|e| err!("compile {name}: {e:?}"))?;
        let rc = Arc::new(exe);
        state.cache.insert(name.to_string(), Arc::clone(&rc));
        Ok(rc)
    }

    /// Number of artifacts compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).cache.len()
    }
}

fn to_literal(v: &Value) -> Result<xla::Literal> {
    let (lit, dims) = match v {
        Value::F32(TensorF32 { dims, data }) => (xla::Literal::vec1(data), dims),
        Value::I32(TensorI32 { dims, data }) => (xla::Literal::vec1(data), dims),
    };
    lit.reshape(dims).map_err(|e| err!("reshape literal to {dims:?}: {e:?}"))
}

/// All artifact outputs are f32 in this crate's lowering.
fn from_literal(lit: &xla::Literal) -> Result<Value> {
    let data = lit.to_vec::<f32>().map_err(|e| err!("literal to f32: {e:?}"))?;
    let n = data.len();
    Ok(Value::F32(TensorF32::from_vec(data, &[n])))
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// Execute an artifact: literals in, tuple-decomposed literals out
    /// (everything is lowered with `return_tuple=True`). Serialized
    /// under the state lock (see `XlaState`).
    fn execute(&self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let mut state = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let exe = self.executable(&mut state, artifact)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let out = exe.execute(&literals).map_err(|e| err!("execute {artifact}: {e:?}"))?; // lint: allow(lock-order) — exe is an xla::PjRtLoadedExecutable, not this backend; name-based over-approximation
        let lit = out[0][0].to_literal_sync().map_err(|e| err!("fetch {artifact}: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| err!("untuple {artifact}: {e:?}"))?;
        tuple.iter().map(from_literal).collect()
    }
}
