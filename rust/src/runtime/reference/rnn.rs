//! Reference RNN baseline controller (model.py `rnn_logits` /
//! `rnn_train_step`): shared table-MLP representations, a GRU scanned
//! over the table sequence, dot-product content attention over the
//! sequence, a per-step device head — and full backpropagation through
//! time for the REINFORCE update.
//!
//! Entry points acquire the thread-local [`Scratch`] pool once per call;
//! the GRU scan and the BPTT loop draw every per-step buffer from it, so
//! repeated dispatches (and long sequences) stop churning the allocator.

use super::math::{
    linear_bwd_s, linear_fwd_s, mlp2_bwd, mlp2_fwd, reinforce_loss_grad, with_scratch, Lin,
    Mlp2Cache, Scratch,
};
use super::spec::{rnn_spec, Spec, ENTROPY_W, F, L};

/// Per-step GRU activations kept for BPTT.
struct GruStep {
    /// Input rows x_t [e, L] (gathered table reps).
    x: Vec<f32>,
    /// Previous hidden state [e, L].
    h_prev: Vec<f32>,
    z: Vec<f32>,
    r: Vec<f32>,
    n: Vec<f32>,
    /// r ⊙ h_prev (input of the hn linear) [e, L].
    rh: Vec<f32>,
}

impl GruStep {
    fn recycle(self, scr: &mut Scratch) {
        scr.give(self.x);
        scr.give(self.h_prev);
        scr.give(self.z);
        scr.give(self.r);
        scr.give(self.n);
        scr.give(self.rh);
    }
}

struct Caches {
    tbl: Mlp2Cache,
    steps: Vec<GruStep>,
    /// Hidden states [e, t_eff, L].
    hs: Vec<f32>,
    /// Attention weights [e, t_eff, t_eff].
    att: Vec<f32>,
    /// Head input rows [hs ; ctx] [e * t_eff, 2L].
    xcat: Vec<f32>,
}

impl Caches {
    fn recycle(self, scr: &mut Scratch) {
        self.tbl.recycle(scr);
        for st in self.steps {
            st.recycle(scr);
        }
        scr.give(self.hs);
        scr.give(self.att);
        scr.give(self.xcat);
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn gru_linear2(
    psi: &[f32],
    lx: Lin,
    lh: Lin,
    x: &[f32],
    h: &[f32],
    e: usize,
    scr: &mut Scratch,
) -> Vec<f32> {
    let mut a = linear_fwd_s(psi, lx, x, e, false, scr);
    let b = linear_fwd_s(psi, lh, h, e, false, scr);
    for (av, &bv) in a.iter_mut().zip(b.iter()) {
        *av += bv;
    }
    scr.give(b);
    a
}

/// Forward over `e` lanes and `t_eff` real steps. Returns the logits of
/// the computed region [e, t_eff, d] plus everything backward needs.
#[allow(clippy::too_many_arguments)]
fn forward_inner(
    spec: &Spec,
    psi: &[f32],
    feats: &[f32],
    tmask: &[f32],
    legal: &[f32],
    fmask: &[f32],
    e: usize,
    t_cap: usize,
    d: usize,
    t_eff: usize,
    scr: &mut Scratch,
) -> (Vec<f32>, Caches) {
    // table reps over the trimmed [e, t_eff, F] grid
    let rows = e * t_eff;
    let mut x = scr.take(rows * F);
    for lane in 0..e {
        for t in 0..t_eff {
            let src = (lane * t_cap + t) * F;
            let dst = (lane * t_eff + t) * F;
            for (i, &fm) in fmask.iter().enumerate() {
                x[dst + i] = feats[src + i] * fm;
            }
        }
    }
    let (reps, tbl) = mlp2_fwd(psi, spec.lin("tbl1"), spec.lin("tbl2"), x, rows, scr);

    // GRU scan
    let (lxz, lhz) = (spec.lin("gru_xz"), spec.lin("gru_hz"));
    let (lxr, lhr) = (spec.lin("gru_xr"), spec.lin("gru_hr"));
    let (lxn, lhn) = (spec.lin("gru_xn"), spec.lin("gru_hn"));
    let mut h = scr.take(e * L);
    let mut steps = Vec::with_capacity(t_eff);
    let mut hs = scr.take(e * t_eff * L);
    for t in 0..t_eff {
        let mut xt = scr.take(e * L);
        for lane in 0..e {
            let src = (lane * t_eff + t) * L;
            xt[lane * L..(lane + 1) * L].copy_from_slice(&reps[src..src + L]);
        }
        let mut z = gru_linear2(psi, lxz, lhz, &xt, &h, e, scr);
        let mut r = gru_linear2(psi, lxr, lhr, &xt, &h, e, scr);
        for v in z.iter_mut() {
            *v = sigmoid(*v);
        }
        for v in r.iter_mut() {
            *v = sigmoid(*v);
        }
        let mut rh = scr.take(e * L);
        for i in 0..e * L {
            rh[i] = r[i] * h[i];
        }
        let mut n = gru_linear2(psi, lxn, lhn, &xt, &rh, e, scr);
        for v in n.iter_mut() {
            *v = v.tanh();
        }
        let mut h_prev = scr.take(e * L);
        h_prev.copy_from_slice(&h);
        for i in 0..e * L {
            h[i] = (1.0 - z[i]) * h_prev[i] + z[i] * n[i];
        }
        for lane in 0..e {
            let dst = (lane * t_eff + t) * L;
            hs[dst..dst + L].copy_from_slice(&h[lane * L..(lane + 1) * L]);
        }
        steps.push(GruStep { x: xt, h_prev, z, r, n, rh });
    }
    scr.give(reps);
    scr.give(h);

    // content attention per lane: softmax(hs hs^T / sqrt(L)) over keys
    let scale = 1.0 / (L as f32).sqrt();
    let mut att = scr.take(e * t_eff * t_eff);
    let mut ctx = scr.take(e * t_eff * L);
    for lane in 0..e {
        for t in 0..t_eff {
            let qrow = &hs[(lane * t_eff + t) * L..(lane * t_eff + t + 1) * L];
            let arow = &mut att[(lane * t_eff + t) * t_eff..(lane * t_eff + t + 1) * t_eff];
            let mut amax = f32::NEG_INFINITY;
            for u in 0..t_eff {
                let krow = &hs[(lane * t_eff + u) * L..(lane * t_eff + u + 1) * L];
                let mut dot = 0.0f32;
                for (a, b) in qrow.iter().zip(krow.iter()) {
                    dot += a * b;
                }
                let v = if tmask[lane * t_cap + u] > 0.0 { dot * scale } else { -1e9 };
                arow[u] = v;
                amax = amax.max(v);
            }
            let mut sum = 0.0f32;
            for v in arow.iter_mut() {
                *v = (*v - amax).exp();
                sum += *v;
            }
            for v in arow.iter_mut() {
                *v /= sum;
            }
            let crow_off = (lane * t_eff + t) * L;
            for u in 0..t_eff {
                let w = arow[u];
                if w != 0.0 {
                    let krow = &hs[(lane * t_eff + u) * L..(lane * t_eff + u + 1) * L];
                    for ch in 0..L {
                        ctx[crow_off + ch] += w * krow[ch];
                    }
                }
            }
        }
    }

    // head over [hs ; ctx]
    let mut xcat = scr.take(rows * 2 * L);
    for rowi in 0..rows {
        xcat[rowi * 2 * L..rowi * 2 * L + L].copy_from_slice(&hs[rowi * L..(rowi + 1) * L]);
        xcat[rowi * 2 * L + L..(rowi + 1) * 2 * L]
            .copy_from_slice(&ctx[rowi * L..(rowi + 1) * L]);
    }
    scr.give(ctx);
    let score = linear_fwd_s(psi, spec.lin("head"), &xcat, rows, false, scr);
    let mut logits = scr.take(rows * d);
    for lane in 0..e {
        for t in 0..t_eff {
            for j in 0..d {
                let li = (lane * t_eff + t) * d + j;
                logits[li] = if legal[(lane * t_cap + t) * d + j] > 0.0 {
                    score[li]
                } else {
                    -1e9
                };
            }
        }
    }
    scr.give(score);
    (logits, Caches { tbl, steps, hs, att, xcat })
}

/// Effective sequence length: last step any lane still masks in, +1.
pub fn effective_t(tmask: &[f32], e: usize, t_cap: usize) -> usize {
    let mut t_eff = 0;
    for lane in 0..e {
        for t in (t_eff..t_cap).rev() {
            if tmask[lane * t_cap + t] > 0.0 {
                t_eff = t + 1;
                break;
            }
        }
    }
    t_eff
}

/// Full-size per-step logits [e, t_cap, d] (entries beyond the effective
/// sequence are 0 — callers never index them).
pub fn rnn_forward(
    psi: &[f32],
    feats: &[f32],
    tmask: &[f32],
    legal: &[f32],
    fmask: &[f32],
    e: usize,
    t_cap: usize,
    d: usize,
) -> Vec<f32> {
    let spec = rnn_spec(d);
    let t_eff = effective_t(tmask, e, t_cap);
    let mut out = vec![0.0f32; e * t_cap * d];
    if t_eff == 0 {
        return out;
    }
    with_scratch(|scr| {
        let (logits, caches) =
            forward_inner(&spec, psi, feats, tmask, legal, fmask, e, t_cap, d, t_eff, scr);
        for lane in 0..e {
            for t in 0..t_eff {
                let src = (lane * t_eff + t) * d;
                let dst = (lane * t_cap + t) * d;
                out[dst..dst + d].copy_from_slice(&logits[src..src + d]);
            }
        }
        scr.give(logits);
        caches.recycle(scr);
    });
    out
}

/// REINFORCE loss over the whole sequence batch + full parameter
/// gradient (BPTT through the GRU and the attention).
#[allow(clippy::too_many_arguments)]
pub fn rnn_loss_grad(
    psi: &[f32],
    feats: &[f32],
    tmask: &[f32],
    legal: &[f32],
    action: &[i32],
    adv: &[f32],
    fmask: &[f32],
    e: usize,
    t_cap: usize,
    d: usize,
) -> (f32, Vec<f32>) {
    let spec = rnn_spec(d);
    let t_eff = effective_t(tmask, e, t_cap);
    if t_eff == 0 {
        return (0.0, vec![0.0f32; spec.total]);
    }
    with_scratch(|scr| {
        let (logits, caches) =
            forward_inner(&spec, psi, feats, tmask, legal, fmask, e, t_cap, d, t_eff, scr);
        let rows = e * t_eff;

        // flatten the per-(lane, step) loss inputs to the trimmed region
        let mut legal_f = scr.take(rows * d);
        let mut action_f = vec![0i32; rows];
        let mut adv_f = scr.take(rows);
        let mut smask_f = scr.take(rows);
        for lane in 0..e {
            for t in 0..t_eff {
                let rowi = lane * t_eff + t;
                legal_f[rowi * d..(rowi + 1) * d]
                    .copy_from_slice(&legal[(lane * t_cap + t) * d..(lane * t_cap + t + 1) * d]);
                action_f[rowi] = action[lane * t_cap + t];
                adv_f[rowi] = adv[lane];
                smask_f[rowi] = tmask[lane * t_cap + t];
            }
        }
        let (loss, dlogits) = reinforce_loss_grad(
            &logits, &legal_f, &action_f, &adv_f, &smask_f, rows, d, ENTROPY_W,
        );
        scr.give(logits);
        scr.give(legal_f);
        scr.give(adv_f);
        scr.give(smask_f);

        let mut grad = vec![0.0f32; spec.total];
        // head -> [dhs ; dctx]
        let dxcat =
            linear_bwd_s(psi, &mut grad, spec.lin("head"), &caches.xcat, &dlogits, rows, true, scr);
        let mut dhs = scr.take(rows * L);
        let mut dctx = scr.take(rows * L);
        for rowi in 0..rows {
            dhs[rowi * L..(rowi + 1) * L].copy_from_slice(&dxcat[rowi * 2 * L..rowi * 2 * L + L]);
            dctx[rowi * L..(rowi + 1) * L]
                .copy_from_slice(&dxcat[rowi * 2 * L + L..(rowi + 1) * 2 * L]);
        }
        scr.give(dxcat);

        // attention backward: ctx = A hs, A = softmax(hs hs^T * scale, keys masked)
        let scale = 1.0 / (L as f32).sqrt();
        let mut da = scr.take(t_eff);
        let mut dq = scr.take(L);
        for lane in 0..e {
            let base = lane * t_eff;
            for t in 0..t_eff {
                let arow = &caches.att[(base + t) * t_eff..(base + t + 1) * t_eff];
                let dcrow = &dctx[(base + t) * L..(base + t + 1) * L];
                // dA[t,u] = dctx[t] . hs[u]; dhs[u] += A[t,u] * dctx[t]
                let mut dot_sum = 0.0f32; // sum_u A[t,u] dA[t,u]
                for u in 0..t_eff {
                    let a = arow[u];
                    let krow = &caches.hs[(base + u) * L..(base + u + 1) * L];
                    let mut dot = 0.0f32;
                    for ch in 0..L {
                        dot += dcrow[ch] * krow[ch];
                    }
                    da[u] = dot;
                    dot_sum += a * dot;
                    if a != 0.0 {
                        let dk = &mut dhs[(base + u) * L..(base + u + 1) * L];
                        for ch in 0..L {
                            dk[ch] += a * dcrow[ch];
                        }
                    }
                }
                // softmax backward, then the bilinear hs hs^T term
                let qrow = &caches.hs[(base + t) * L..(base + t + 1) * L];
                dq.fill(0.0);
                for u in 0..t_eff {
                    let datt = arow[u] * (da[u] - dot_sum);
                    if datt != 0.0 {
                        let krow = &caches.hs[(base + u) * L..(base + u + 1) * L];
                        let dk = &mut dhs[(base + u) * L..(base + u + 1) * L];
                        for ch in 0..L {
                            dq[ch] += datt * krow[ch] * scale;
                            dk[ch] += datt * qrow[ch] * scale;
                        }
                    }
                }
                let dqr = &mut dhs[(base + t) * L..(base + t + 1) * L];
                for ch in 0..L {
                    dqr[ch] += dq[ch];
                }
            }
        }
        scr.give(da);
        scr.give(dq);
        scr.give(dctx);

        // BPTT through the GRU
        let (lxz, lhz) = (spec.lin("gru_xz"), spec.lin("gru_hz"));
        let (lxr, lhr) = (spec.lin("gru_xr"), spec.lin("gru_hr"));
        let (lxn, lhn) = (spec.lin("gru_xn"), spec.lin("gru_hn"));
        let mut dreps = scr.take(rows * L);
        let mut carry = scr.take(e * L);
        for t in (0..t_eff).rev() {
            let st = &caches.steps[t];
            // total gradient on h_t
            let mut dht = scr.take(e * L);
            dht.copy_from_slice(&carry);
            for lane in 0..e {
                let src = (lane * t_eff + t) * L;
                for ch in 0..L {
                    dht[lane * L + ch] += dhs[src + ch];
                }
            }
            let el = e * L;
            let mut dz = scr.take(el);
            let mut dn = scr.take(el);
            let mut new_carry = scr.take(el);
            for i in 0..el {
                dz[i] = dht[i] * (st.n[i] - st.h_prev[i]);
                dn[i] = dht[i] * st.z[i];
                new_carry[i] = dht[i] * (1.0 - st.z[i]);
            }
            // n = tanh(a_n)
            let mut da_n = scr.take(el);
            for i in 0..el {
                da_n[i] = dn[i] * (1.0 - st.n[i] * st.n[i]);
            }
            let dxt_n = linear_bwd_s(psi, &mut grad, lxn, &st.x, &da_n, e, true, scr);
            let drh = linear_bwd_s(psi, &mut grad, lhn, &st.rh, &da_n, e, true, scr);
            let mut dr = scr.take(el);
            for i in 0..el {
                dr[i] = drh[i] * st.h_prev[i];
                new_carry[i] += drh[i] * st.r[i];
            }
            // z = sigmoid(a_z), r = sigmoid(a_r)
            let mut da_z = scr.take(el);
            let mut da_r = scr.take(el);
            for i in 0..el {
                da_z[i] = dz[i] * st.z[i] * (1.0 - st.z[i]);
                da_r[i] = dr[i] * st.r[i] * (1.0 - st.r[i]);
            }
            let dxt_z = linear_bwd_s(psi, &mut grad, lxz, &st.x, &da_z, e, true, scr);
            let dh_z = linear_bwd_s(psi, &mut grad, lhz, &st.h_prev, &da_z, e, true, scr);
            let dxt_r = linear_bwd_s(psi, &mut grad, lxr, &st.x, &da_r, e, true, scr);
            let dh_r = linear_bwd_s(psi, &mut grad, lhr, &st.h_prev, &da_r, e, true, scr);
            for i in 0..el {
                new_carry[i] += dh_z[i] + dh_r[i];
            }
            scr.give(std::mem::replace(&mut carry, new_carry));
            for lane in 0..e {
                let dst = (lane * t_eff + t) * L;
                for ch in 0..L {
                    dreps[dst + ch] +=
                        dxt_n[lane * L + ch] + dxt_z[lane * L + ch] + dxt_r[lane * L + ch];
                }
            }
            scr.give(dht);
            scr.give(dz);
            scr.give(dn);
            scr.give(da_n);
            scr.give(dxt_n);
            scr.give(drh);
            scr.give(dr);
            scr.give(da_z);
            scr.give(da_r);
            scr.give(dxt_z);
            scr.give(dh_z);
            scr.give(dxt_r);
            scr.give(dh_r);
        }
        scr.give(carry);
        scr.give(dhs);
        mlp2_bwd(psi, &mut grad, spec.lin("tbl1"), spec.lin("tbl2"), &caches.tbl, &dreps, false, scr);
        scr.give(dreps);
        caches.recycle(scr);
        (loss, grad)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::math::{fd_check, rand_vec};
    use crate::util::Rng;

    #[test]
    fn forward_trims_and_masks() {
        let mut rng = Rng::new(31);
        let d = 2;
        let spec = rnn_spec(d);
        let psi = rand_vec(spec.total, 0.1, &mut rng);
        let (e, t_cap) = (2usize, 4usize);
        let feats: Vec<f32> =
            rand_vec(e * t_cap * F, 1.0, &mut rng).iter().map(|v| v.abs()).collect();
        let mut tmask = vec![0.0f32; e * t_cap];
        tmask[0] = 1.0;
        tmask[1] = 1.0;
        tmask[t_cap] = 1.0; // lane 1: one table
        let legal = vec![1.0f32; e * t_cap * d];
        let fmask = vec![1.0f32; F];
        let logits = rnn_forward(&psi, &feats, &tmask, &legal, &fmask, e, t_cap, d);
        assert_eq!(logits.len(), e * t_cap * d);
        assert_eq!(effective_t(&tmask, e, t_cap), 2);
        // steps beyond the effective length stay zero
        assert!(logits[(2 * d)..(t_cap * d)].iter().all(|&v| v == 0.0));
        assert!(logits[..2 * d].iter().all(|v| v.is_finite() && v.abs() < 1e6));
        // deterministic
        let logits2 = rnn_forward(&psi, &feats, &tmask, &legal, &fmask, e, t_cap, d);
        assert_eq!(logits, logits2);
    }

    #[test]
    fn rnn_gradcheck() {
        let mut rng = Rng::new(32);
        let d = 2;
        let spec = rnn_spec(d);
        let psi = rand_vec(spec.total, 0.15, &mut rng);
        let (e, t_cap) = (2usize, 3usize);
        let feats: Vec<f32> =
            rand_vec(e * t_cap * F, 1.0, &mut rng).iter().map(|v| v.abs()).collect();
        let mut tmask = vec![1.0f32; e * t_cap];
        tmask[e * t_cap - 1] = 0.0; // ragged tail on the last lane
        let mut legal = vec![1.0f32; e * t_cap * d];
        legal[0] = 0.0;
        let action = vec![1i32, 0, 1, 0, 1, 0];
        let adv = vec![0.9f32, -0.6];
        let fmask = vec![1.0f32; F];
        let loss = |p: &[f32]| -> f32 {
            rnn_loss_grad(p, &feats, &tmask, &legal, &action, &adv, &fmask, e, t_cap, d).0
        };
        let (_, grad) =
            rnn_loss_grad(&psi, &feats, &tmask, &legal, &action, &adv, &fmask, e, t_cap, d);
        fd_check(loss, &psi, &grad, 40, 99);
    }
}
