//! Pure-Rust reference backend: evaluates every artifact the coordinator
//! uses — cost / policy / RNN forward passes, their Adam training steps,
//! and the fused MDP step — natively, mirroring `python/compile/model.py`
//! to the operation. No artifacts directory, no native libraries.
//!
//! The backend synthesizes its own [`Manifest`]
//! ([`reference_manifest`]): the same flat-parameter layouts
//! (`spec`), the same artifact-name grid the AOT pipeline bakes
//! (`cost_fwd_d4s48`, `policy_train_d4s48_b512`, ...), and the same shape
//! metadata. Because execution here is shape-polymorphic (dims are read
//! from the inputs), the baked `E`/`S`/`B` capacities only drive the
//! coordinator's padding; padded lanes/rows are trimmed before compute,
//! so e.g. a 60-step REINFORCE update pays for 60 rows, not 512.
//!
//! The XLA-only `dlrm_train` artifact (embedding-bag training of the
//! DLRM example) is intentionally *not* implemented: it needs the Pallas
//! kernels and is the one workload that genuinely requires
//! `make artifacts` + `--features xla`.
//!
//! # Kernels, scratch, and the intra-op split
//!
//! The numeric core ([`math`]) uses cache-blocked dense kernels and a
//! per-thread scratch-buffer pool; both are **bit-identical** to the
//! original naive loops (the blocking never reorders the operand
//! sequence feeding any single output element — see the [`math`] module
//! docs). On top of that, the one shape that dominates serving — the
//! chunk-concatenated `[N, F]` `table_cost` batch — is row-split across
//! intra-op helper threads when `N >=` [`INTRA_OP_MIN_ROWS`] and the
//! backend was built with [`ReferenceBackend::with_intra_op`]` > 1`
//! ([`Runtime::reference`](crate::runtime::Runtime::reference) passes
//! the `DREAMSHARD_WORKERS` pool width). The split happens *inside* the
//! backend with `std::thread::scope` — never a nested `submit` onto the
//! session pool, which preserves the no-nested-dispatch contract (pool
//! workers stay leaf executors, so a 1-worker pool cannot deadlock) and
//! keeps the per-artifact call counters counting one logical call. Rows
//! of `table_cost` are strictly independent (see
//! `cost::table_cost_forward`), so the split is bit-identical to the
//! serial pass at every width; `rust/tests/kernels.rs` pins that, the
//! budget invariant, and the panic-in-helper path.

mod cost;
pub mod math;
mod policy;
mod rnn;
mod spec;

use std::collections::HashMap;

use super::manifest::{Artifact, Manifest};
use super::tensor::{TensorF32, TensorI32, Value};
use super::Backend;
use crate::bail;
use crate::util::error::{Context, Result};

pub use math::Red;

/// Minimum `[N, F]` row count before `table_cost` is worth row-splitting
/// across intra-op helper threads: below this the per-thread spawn/join
/// overhead outweighs the kernel work.
pub const INTRA_OP_MIN_ROWS: usize = 64;

/// The dependency-free reference backend.
///
/// Stateless apart from one knob: `intra_op`, the number of threads a
/// single large `table_cost` execution may fan out across (see the
/// module docs). [`ReferenceBackend::new`] gives a strictly serial
/// backend; [`Runtime::reference`](crate::runtime::Runtime::reference)
/// constructs it with the `DREAMSHARD_WORKERS` pool width.
#[derive(Clone, Copy, Debug)]
pub struct ReferenceBackend {
    intra_op: usize,
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ReferenceBackend {
    /// Serial backend: no intra-op splitting.
    pub fn new() -> Self {
        ReferenceBackend { intra_op: 1 }
    }

    /// Backend whose large `table_cost` batches row-split across up to
    /// `threads` scoped helper threads (values < 1 behave as 1).
    pub fn with_intra_op(threads: usize) -> Self {
        ReferenceBackend { intra_op: threads.max(1) }
    }

    /// The configured intra-op split width.
    pub fn intra_op(&self) -> usize {
        self.intra_op.max(1)
    }
}

// ---------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------

/// (D, S, trainable, lanes) variant grid — matches what `make artifacts`
/// lowers: three trainable variants plus the inference-only ultra one.
const VARIANTS: [(usize, usize, bool, usize); 4] =
    [(2, 48, true, 16), (4, 48, true, 16), (8, 48, true, 16), (128, 16, false, 4)];

fn artifact(meta: &[(&str, String)]) -> Artifact {
    Artifact {
        file: "<builtin>".to_string(),
        meta: meta.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    }
}

/// The manifest the reference backend serves (no files behind it).
pub fn reference_manifest() -> Manifest {
    let mut m = Manifest::default();
    for (k, v) in [("F", spec::F as i64), ("T_RNN", 256), ("E_FWD", 16), ("E_RNN", 10)] {
        m.consts.insert(k.to_string(), v);
    }
    m.params.insert("cost".into(), spec::cost_spec().param_info());
    m.params.insert("policy".into(), spec::policy_spec().param_info());
    for d in [2usize, 4, 8] {
        m.params.insert(format!("rnn_d{d}"), spec::rnn_spec(d).param_info());
    }
    let mut add = |name: String, meta: &[(&str, String)]| {
        m.artifacts.insert(name, artifact(meta));
    };
    for (d, s, trainable, e) in VARIANTS {
        let dims =
            [("E", e.to_string()), ("D", d.to_string()), ("S", s.to_string())];
        add(format!("cost_fwd_d{d}s{s}"), &dims);
        add(format!("policy_fwd_d{d}s{s}"), &dims);
        add(format!("mdp_step_d{d}s{s}_e1"), &[("E", "1".into())]);
        add(format!("mdp_step_d{d}s{s}_e16"), &[("E", "16".into())]);
        if trainable {
            add(format!("cost_train_d{d}s{s}"), &[("B", "64".into())]);
            for b in [512usize, 2048] {
                add(format!("policy_train_d{d}s{s}_b{b}"), &[("B", b.to_string())]);
            }
        }
    }
    // reduction-ablation variants (Figs. 13-14) on the standard 4-device shape
    for tr in ["sum", "mean", "max"] {
        for dr in ["sum", "mean", "max"] {
            if (tr, dr) == ("sum", "max") {
                continue; // that's the shipped default network
            }
            add(format!("cost_fwd_red_{tr}_{dr}_d4s48"), &[("E", "16".into())]);
            add(format!("cost_train_red_{tr}_{dr}_d4s48"), &[("B", "64".into())]);
        }
    }
    add("table_cost".to_string(), &[("N", "256".into())]);
    for d in [2usize, 4, 8] {
        add(format!("rnn_fwd_d{d}"), &[]);
        add(format!("rnn_train_d{d}"), &[]);
    }
    m
}

// ---------------------------------------------------------------------
// dispatch helpers
// ---------------------------------------------------------------------

fn f32_in<'a>(inputs: &'a [Value], i: usize, what: &str) -> Result<&'a TensorF32> {
    inputs
        .get(i)
        .with_context(|| format!("missing input {i} ({what})"))?
        .f32s()
        .with_context(|| format!("input {i} ({what})"))
}

fn i32_in<'a>(inputs: &'a [Value], i: usize, what: &str) -> Result<&'a TensorI32> {
    inputs
        .get(i)
        .with_context(|| format!("missing input {i} ({what})"))?
        .i32s()
        .with_context(|| format!("input {i} ({what})"))
}

fn scalar(inputs: &[Value], i: usize, what: &str) -> Result<f32> {
    let t = f32_in(inputs, i, what)?;
    t.data.first().copied().with_context(|| format!("input {i} ({what}) is empty"))
}

fn out_f32(data: Vec<f32>, dims: &[usize]) -> Value {
    Value::F32(TensorF32::from_vec(data, dims))
}

fn out_scalar1(x: f32) -> Value {
    Value::F32(TensorF32::scalar1(x))
}

/// Number of leading rows to keep: the last row (of `rows` rows of
/// `stride` elements) containing any nonzero, plus one.
fn active_rows(data: &[f32], rows: usize, stride: usize) -> usize {
    for r in (0..rows).rev() {
        if data[r * stride..(r + 1) * stride].iter().any(|&v| v != 0.0) {
            return r + 1;
        }
    }
    0
}

/// Dims of a rank-4 `[E, D, S, F]` tensor.
fn dims4(t: &TensorF32, what: &str) -> Result<(usize, usize, usize, usize)> {
    if t.dims.len() != 4 {
        bail!("{what}: expected rank-4 tensor, got dims {:?}", t.dims);
    }
    Ok((t.dims[0] as usize, t.dims[1] as usize, t.dims[2] as usize, t.dims[3] as usize))
}

fn parse_red_pair(rest: &str) -> Result<(Red, Red)> {
    let mut it = rest.split('_');
    let tr = math::parse_red(it.next().unwrap_or(""))?;
    let dr = math::parse_red(it.next().unwrap_or(""))?;
    Ok((tr, dr))
}

// ---------------------------------------------------------------------
// artifact implementations
// ---------------------------------------------------------------------

fn run_cost_fwd(inputs: &[Value], tr: Red, dr: Red) -> Result<Vec<Value>> {
    let feats = f32_in(inputs, 1, "feats")?;
    let mask = f32_in(inputs, 2, "mask")?;
    let dmask = f32_in(inputs, 3, "dmask")?;
    let fmask = f32_in(inputs, 4, "fmask")?;
    let theta = f32_in(inputs, 0, "theta")?;
    let (e, d, s, f) = dims4(feats, "cost_fwd feats")?;
    if f != spec::F {
        bail!("cost_fwd: feature dim {f} != {}", spec::F);
    }
    let e_eff = active_rows(&dmask.data, e, d);
    let mut q = vec![0.0f32; e * d * 3];
    let mut cost = vec![0.0f32; e];
    if e_eff > 0 {
        let out = cost::cost_forward(
            &theta.data,
            &feats.data[..e_eff * d * s * f],
            &mask.data[..e_eff * d * s],
            &dmask.data[..e_eff * d],
            &fmask.data,
            e_eff,
            d,
            s,
            tr,
            dr,
        );
        q[..e_eff * d * 3].copy_from_slice(&out.q);
        cost[..e_eff].copy_from_slice(&out.cost);
    }
    Ok(vec![out_f32(q, &[e, d, 3]), out_f32(cost, &[e])])
}

fn run_cost_train(inputs: &[Value], tr: Red, dr: Red) -> Result<Vec<Value>> {
    let t = scalar(inputs, 3, "t_step")?;
    let lr = scalar(inputs, 4, "lr")?;
    let feats = f32_in(inputs, 5, "feats")?;
    let mask = f32_in(inputs, 6, "mask")?;
    let dmask = f32_in(inputs, 7, "dmask")?;
    let q_tgt = f32_in(inputs, 8, "q_tgt")?;
    let c_tgt = f32_in(inputs, 9, "c_tgt")?;
    let fmask = f32_in(inputs, 10, "fmask")?;
    let (b, d, s, f) = dims4(feats, "cost_train feats")?;
    if f != spec::F {
        bail!("cost_train: feature dim {f} != {}", spec::F);
    }
    let mut theta = f32_in(inputs, 0, "theta")?.data.clone();
    let mut m = f32_in(inputs, 1, "m")?.data.clone();
    let mut v = f32_in(inputs, 2, "v")?.data.clone();
    let (loss, grad) = cost::cost_loss_grad(
        &theta, &feats.data, &mask.data, &dmask.data, &q_tgt.data, &c_tgt.data, &fmask.data, b,
        d, s, tr, dr,
    );
    math::adam(&mut theta, &mut m, &mut v, &grad, t, lr);
    let n = theta.len();
    Ok(vec![
        out_f32(theta, &[n]),
        out_f32(m, &[n]),
        out_f32(v, &[n]),
        out_scalar1(loss),
    ])
}

fn run_policy_fwd(inputs: &[Value]) -> Result<Vec<Value>> {
    let phi = f32_in(inputs, 0, "phi")?;
    let feats = f32_in(inputs, 1, "feats")?;
    let mask = f32_in(inputs, 2, "mask")?;
    let q = f32_in(inputs, 3, "q")?;
    let cur = f32_in(inputs, 4, "cur")?;
    let legal = f32_in(inputs, 5, "legal")?;
    let fmask = f32_in(inputs, 6, "fmask")?;
    let qscale = f32_in(inputs, 7, "qscale")?;
    let (e, d, s, f) = dims4(feats, "policy_fwd feats")?;
    if f != spec::F {
        bail!("policy_fwd: feature dim {f} != {}", spec::F);
    }
    // no lane trimming here: unlike the fused mdp_step (which trims by
    // dmask), this entry point has no reliable active-lane signal and is
    // off the hot path (real-MDP arm + micro-benches only)
    let logits = policy::policy_forward(
        &phi.data, &feats.data, &mask.data, &q.data, &cur.data, &legal.data, &fmask.data,
        &qscale.data, e, d, s,
    );
    Ok(vec![out_f32(logits, &[e, d])])
}

fn run_policy_train(inputs: &[Value]) -> Result<Vec<Value>> {
    let t = scalar(inputs, 3, "t_step")?;
    let lr = scalar(inputs, 4, "lr")?;
    let feats = f32_in(inputs, 5, "feats")?;
    let mask = f32_in(inputs, 6, "mask")?;
    let q = f32_in(inputs, 7, "q")?;
    let cur = f32_in(inputs, 8, "cur")?;
    let legal = f32_in(inputs, 9, "legal")?;
    let action = i32_in(inputs, 10, "action")?;
    let adv = f32_in(inputs, 11, "adv")?;
    let smask = f32_in(inputs, 12, "smask")?;
    let fmask = f32_in(inputs, 13, "fmask")?;
    let qscale = f32_in(inputs, 14, "qscale")?;
    let (b, d, s, f) = dims4(feats, "policy_train feats")?;
    if f != spec::F {
        bail!("policy_train: feature dim {f} != {}", spec::F);
    }
    let mut phi = f32_in(inputs, 0, "phi")?.data.clone();
    let mut m = f32_in(inputs, 1, "m")?.data.clone();
    let mut v = f32_in(inputs, 2, "v")?.data.clone();
    // padded rows have smask = 0 and contribute neither loss nor gradient
    let b_eff = active_rows(&smask.data, b, 1);
    let mut loss = 0.0;
    if b_eff > 0 {
        let (l, grad) = policy::policy_loss_grad(
            &phi,
            &feats.data[..b_eff * d * s * f],
            &mask.data[..b_eff * d * s],
            &q.data[..b_eff * d * 3],
            &cur.data[..b_eff * f],
            &legal.data[..b_eff * d],
            &action.data[..b_eff],
            &adv.data[..b_eff],
            &smask.data[..b_eff],
            &fmask.data,
            &qscale.data,
            b_eff,
            d,
            s,
        );
        loss = l;
        math::adam(&mut phi, &mut m, &mut v, &grad, t, lr);
    }
    let n = phi.len();
    Ok(vec![out_f32(phi, &[n]), out_f32(m, &[n]), out_f32(v, &[n]), out_scalar1(loss)])
}

fn run_mdp_step(inputs: &[Value]) -> Result<Vec<Value>> {
    let theta = f32_in(inputs, 0, "theta")?;
    let phi = f32_in(inputs, 1, "phi")?;
    let feats = f32_in(inputs, 2, "feats")?;
    let mask = f32_in(inputs, 3, "mask")?;
    let dmask = f32_in(inputs, 4, "dmask")?;
    let cur = f32_in(inputs, 5, "cur")?;
    let legal = f32_in(inputs, 6, "legal")?;
    let fmask = f32_in(inputs, 7, "fmask")?;
    let qscale = f32_in(inputs, 8, "qscale")?;
    let (e, d, s, f) = dims4(feats, "mdp_step feats")?;
    if f != spec::F {
        bail!("mdp_step: feature dim {f} != {}", spec::F);
    }
    let e_eff = active_rows(&dmask.data, e, d);
    let mut logits = vec![0.0f32; e * d];
    let mut q = vec![0.0f32; e * d * 3];
    let mut cost = vec![0.0f32; e];
    if e_eff > 0 {
        let c = cost::cost_forward(
            &theta.data,
            &feats.data[..e_eff * d * s * f],
            &mask.data[..e_eff * d * s],
            &dmask.data[..e_eff * d],
            &fmask.data,
            e_eff,
            d,
            s,
            Red::Sum,
            Red::Max,
        );
        let lg = policy::policy_forward(
            &phi.data,
            &feats.data[..e_eff * d * s * f],
            &mask.data[..e_eff * d * s],
            &c.q,
            &cur.data[..e_eff * f],
            &legal.data[..e_eff * d],
            &fmask.data,
            &qscale.data,
            e_eff,
            d,
            s,
        );
        logits[..e_eff * d].copy_from_slice(&lg);
        q[..e_eff * d * 3].copy_from_slice(&c.q);
        cost[..e_eff].copy_from_slice(&c.cost);
    }
    Ok(vec![out_f32(logits, &[e, d]), out_f32(q, &[e, d, 3]), out_f32(cost, &[e])])
}

fn run_table_cost(inputs: &[Value], intra_op: usize) -> Result<Vec<Value>> {
    let theta = f32_in(inputs, 0, "theta")?;
    let feats = f32_in(inputs, 1, "feats")?;
    let fmask = f32_in(inputs, 2, "fmask")?;
    if feats.dims.len() != 2 {
        bail!("table_cost: expected [N, F] feats, got {:?}", feats.dims);
    }
    let (n, f) = (feats.dims[0] as usize, feats.dims[1] as usize);
    if f != spec::F {
        bail!("table_cost: feature dim {f} != {}", spec::F);
    }
    // score every row, exactly as the AOT artifact computes. Unlike the
    // mask-driven lane trims above, trimming trailing zero FEATURE rows
    // here would be a content-based guess that makes a row's score
    // depend on what happens to follow it — concatenated multi-task
    // ordering batches require strict per-row independence.
    let total = table_cost_split(&theta.data, &feats.data, &fmask.data, n, intra_op);
    Ok(vec![out_f32(total, &[n])])
}

/// Row-split `table_cost` driver: one large `[N, F]` batch is chunked
/// across `intra_op` scoped helper threads (plus the dispatching worker
/// itself, which computes the first chunk inline). Because each output
/// row depends only on its own feature row, every width produces
/// bit-identical results to the serial pass.
///
/// This deliberately does NOT `submit` onto the session worker pool:
/// workers are leaf executors, and nesting a dispatch inside a dispatch
/// would deadlock a 1-worker pool. `std::thread::scope` also gives the
/// panic semantics the pool relies on — a panicking helper's payload is
/// re-raised here exactly once, unwinds through the one logical
/// `execute` call, and is caught by the worker's `catch_unwind`, so the
/// caller sees a single `Err` and the pool and call counters survive.
fn table_cost_split(
    theta: &[f32],
    feats: &[f32],
    fmask: &[f32],
    n: usize,
    intra_op: usize,
) -> Vec<f32> {
    if intra_op <= 1 || n < INTRA_OP_MIN_ROWS {
        return cost::table_cost_forward(theta, feats, fmask, n);
    }
    let chunk = n.div_ceil(intra_op);
    let mut total = vec![0.0f32; n];
    std::thread::scope(|scope| {
        let mut shards = total.chunks_mut(chunk);
        let first = shards.next();
        let mut handles = Vec::new();
        for (ci, out) in shards.enumerate() {
            let lo = (ci + 1) * chunk;
            let rows = out.len();
            let fpart = &feats[lo * spec::F..(lo + rows) * spec::F];
            handles.push(scope.spawn(move || {
                cost::table_cost_forward_into(theta, fpart, fmask, rows, out);
            }));
        }
        if let Some(out) = first {
            let rows = out.len();
            cost::table_cost_forward_into(theta, &feats[..rows * spec::F], fmask, rows, out);
        }
        for h in handles {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
    });
    total
}

fn run_rnn_fwd(inputs: &[Value]) -> Result<Vec<Value>> {
    let psi = f32_in(inputs, 0, "psi")?;
    let feats = f32_in(inputs, 1, "feats")?;
    let tmask = f32_in(inputs, 2, "tmask")?;
    let legal = f32_in(inputs, 3, "legal")?;
    let fmask = f32_in(inputs, 4, "fmask")?;
    if legal.dims.len() != 3 {
        bail!("rnn_fwd: expected [E, T, D] legal, got {:?}", legal.dims);
    }
    let (e, t_cap, d) =
        (legal.dims[0] as usize, legal.dims[1] as usize, legal.dims[2] as usize);
    let logits =
        rnn::rnn_forward(&psi.data, &feats.data, &tmask.data, &legal.data, &fmask.data, e, t_cap, d);
    Ok(vec![out_f32(logits, &[e, t_cap, d])])
}

fn run_rnn_train(inputs: &[Value]) -> Result<Vec<Value>> {
    let t = scalar(inputs, 3, "t_step")?;
    let lr = scalar(inputs, 4, "lr")?;
    let feats = f32_in(inputs, 5, "feats")?;
    let tmask = f32_in(inputs, 6, "tmask")?;
    let legal = f32_in(inputs, 7, "legal")?;
    let action = i32_in(inputs, 8, "action")?;
    let adv = f32_in(inputs, 9, "adv")?;
    let fmask = f32_in(inputs, 10, "fmask")?;
    if legal.dims.len() != 3 {
        bail!("rnn_train: expected [E, T, D] legal, got {:?}", legal.dims);
    }
    let (e, t_cap, d) =
        (legal.dims[0] as usize, legal.dims[1] as usize, legal.dims[2] as usize);
    let mut psi = f32_in(inputs, 0, "psi")?.data.clone();
    let mut m = f32_in(inputs, 1, "m")?.data.clone();
    let mut v = f32_in(inputs, 2, "v")?.data.clone();
    let (loss, grad) = rnn::rnn_loss_grad(
        &psi,
        &feats.data,
        &tmask.data,
        &legal.data,
        &action.data,
        &adv.data,
        &fmask.data,
        e,
        t_cap,
        d,
    );
    math::adam(&mut psi, &mut m, &mut v, &grad, t, lr);
    let n = psi.len();
    Ok(vec![out_f32(psi, &[n]), out_f32(m, &[n]), out_f32(v, &[n]), out_scalar1(loss)])
}

impl Backend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn execute(&self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        if artifact == "table_cost" {
            return run_table_cost(inputs, self.intra_op);
        }
        if let Some(rest) = artifact.strip_prefix("cost_fwd_red_") {
            let (tr, dr) = parse_red_pair(rest)?;
            return run_cost_fwd(inputs, tr, dr);
        }
        if let Some(rest) = artifact.strip_prefix("cost_train_red_") {
            let (tr, dr) = parse_red_pair(rest)?;
            return run_cost_train(inputs, tr, dr);
        }
        if artifact.starts_with("cost_fwd_d") {
            return run_cost_fwd(inputs, Red::Sum, Red::Max);
        }
        if artifact.starts_with("cost_train_d") {
            return run_cost_train(inputs, Red::Sum, Red::Max);
        }
        if artifact.starts_with("policy_fwd_d") {
            return run_policy_fwd(inputs);
        }
        if artifact.starts_with("policy_train_d") {
            return run_policy_train(inputs);
        }
        if artifact.starts_with("mdp_step_d") {
            return run_mdp_step(inputs);
        }
        if artifact.starts_with("rnn_fwd_d") {
            return run_rnn_fwd(inputs);
        }
        if artifact.starts_with("rnn_train_d") {
            return run_rnn_train(inputs);
        }
        bail!(
            "artifact {artifact} is not implemented by the reference backend \
             (XLA-only; build with --features xla and run `make artifacts`)"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_grid_is_complete() {
        let m = reference_manifest();
        for (d, s, trainable, _) in VARIANTS {
            assert!(m.artifacts.contains_key(&format!("cost_fwd_d{d}s{s}")));
            assert!(m.artifacts.contains_key(&format!("policy_fwd_d{d}s{s}")));
            assert!(m.artifacts.contains_key(&format!("mdp_step_d{d}s{s}_e16")));
            assert_eq!(
                m.artifacts.contains_key(&format!("cost_train_d{d}s{s}")),
                trainable
            );
        }
        assert!(m.artifacts.contains_key("cost_fwd_red_mean_max_d4s48"));
        assert!(!m.artifacts.contains_key("cost_fwd_red_sum_max_d4s48"));
        for d in [2, 4, 8] {
            assert!(m.params.contains_key(&format!("rnn_d{d}")));
            assert!(m.artifacts.contains_key(&format!("rnn_train_d{d}")));
        }
        // parameter layouts cover their totals contiguously
        for info in m.params.values() {
            let covered: usize = info.segments.iter().map(|s| s.len).sum();
            assert_eq!(covered, info.total);
        }
    }

    #[test]
    fn active_rows_trims_trailing_zeros() {
        let data = vec![1.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(active_rows(&data, 4, 2), 2);
        assert_eq!(active_rows(&[0.0; 6], 3, 2), 0);
        assert_eq!(active_rows(&data, 2, 4), 1);
    }
}
