//! Reference policy network (model.py `policy_logits` /
//! `policy_train_step`): shared table-MLP device representations (sum
//! reduction), a cost-feature MLP over the estimated-MDP `q`, the
//! current-table representation, and a linear head over the
//! concatenation — plus the REINFORCE training step (Eq. 2).
//!
//! Entry points acquire the thread-local [`Scratch`] pool once per call
//! and recycle every intermediate on return (see `math.rs` module docs).

use super::math::{
    linear_bwd_s, linear_fwd_s, masked_reduce, masked_reduce_bwd, mlp2_bwd, mlp2_fwd,
    reinforce_loss_grad, with_scratch, Mlp2Cache, Red, RedCache, Scratch,
};
use super::spec::{policy_spec, Spec, ENTROPY_W, F, L};

struct Caches {
    tbl: Mlp2Cache,
    red: RedCache,
    cost: Mlp2Cache,
    cur: Mlp2Cache,
    /// Concatenated head input rows [e*d, 3L].
    x: Vec<f32>,
}

impl Caches {
    fn recycle(self, scr: &mut Scratch) {
        self.tbl.recycle(scr);
        self.red.recycle(scr);
        self.cost.recycle(scr);
        self.cur.recycle(scr);
        scr.give(self.x);
    }
}

#[allow(clippy::too_many_arguments)]
fn forward_inner(
    spec: &Spec,
    phi: &[f32],
    feats: &[f32],
    mask: &[f32],
    q: &[f32],
    cur: &[f32],
    legal: &[f32],
    fmask: &[f32],
    qscale: &[f32],
    e: usize,
    d: usize,
    s: usize,
    scr: &mut Scratch,
) -> (Vec<f32>, Caches) {
    let rows = e * d * s;
    let mut x = scr.take(rows * F);
    for r in 0..rows {
        for (i, &fm) in fmask.iter().enumerate() {
            x[r * F + i] = feats[r * F + i] * fm;
        }
    }
    let (h, tbl) = mlp2_fwd(phi, spec.lin("tbl1"), spec.lin("tbl2"), x, rows, scr);
    let (hdev, red) = masked_reduce(&h, mask, e * d, s, L, Red::Sum, scr);
    scr.give(h);

    let mut qx = scr.take(e * d * 3);
    for ed in 0..e * d {
        for k in 0..3 {
            qx[ed * 3 + k] = q[ed * 3 + k] * qscale[k];
        }
    }
    let (hq, cost) = mlp2_fwd(phi, spec.lin("cost1"), spec.lin("cost2"), qx, e * d, scr);

    let mut xc = scr.take(e * F);
    for r in 0..e {
        for (i, &fm) in fmask.iter().enumerate() {
            xc[r * F + i] = cur[r * F + i] * fm;
        }
    }
    let (hcur, curc) = mlp2_fwd(phi, spec.lin("tbl1"), spec.lin("tbl2"), xc, e, scr);

    // head input rows: [hdev[ed] ; hq[ed] ; hcur[e]] -> [e*d, 3L]
    let mut xh = scr.take(e * d * 3 * L);
    for lane in 0..e {
        for dev in 0..d {
            let ed = lane * d + dev;
            let row = &mut xh[ed * 3 * L..(ed + 1) * 3 * L];
            row[..L].copy_from_slice(&hdev[ed * L..(ed + 1) * L]);
            row[L..2 * L].copy_from_slice(&hq[ed * L..(ed + 1) * L]);
            row[2 * L..].copy_from_slice(&hcur[lane * L..(lane + 1) * L]);
        }
    }
    scr.give(hdev);
    scr.give(hq);
    scr.give(hcur);
    let score = linear_fwd_s(phi, spec.lin("head"), &xh, e * d, false, scr);
    let mut logits = vec![0.0f32; e * d];
    for ed in 0..e * d {
        logits[ed] = if legal[ed] > 0.0 { score[ed] } else { -1e9 };
    }
    scr.give(score);
    (logits, Caches { tbl, red, cost, cur: curc, x: xh })
}

/// Device logits for the table currently being placed ([e*d]).
#[allow(clippy::too_many_arguments)]
pub fn policy_forward(
    phi: &[f32],
    feats: &[f32],
    mask: &[f32],
    q: &[f32],
    cur: &[f32],
    legal: &[f32],
    fmask: &[f32],
    qscale: &[f32],
    e: usize,
    d: usize,
    s: usize,
) -> Vec<f32> {
    let spec = policy_spec();
    with_scratch(|scr| {
        let (logits, caches) =
            forward_inner(&spec, phi, feats, mask, q, cur, legal, fmask, qscale, e, d, s, scr);
        caches.recycle(scr);
        logits
    })
}

/// REINFORCE loss and full parameter gradient over `b` recorded steps.
#[allow(clippy::too_many_arguments)]
pub fn policy_loss_grad(
    phi: &[f32],
    feats: &[f32],
    mask: &[f32],
    q: &[f32],
    cur: &[f32],
    legal: &[f32],
    action: &[i32],
    adv: &[f32],
    smask: &[f32],
    fmask: &[f32],
    qscale: &[f32],
    b: usize,
    d: usize,
    s: usize,
) -> (f32, Vec<f32>) {
    let spec = policy_spec();
    with_scratch(|scr| {
        let (logits, caches) =
            forward_inner(&spec, phi, feats, mask, q, cur, legal, fmask, qscale, b, d, s, scr);
        let (loss, dlogits) =
            reinforce_loss_grad(&logits, legal, action, adv, smask, b, d, ENTROPY_W);

        let mut grad = vec![0.0f32; spec.total];
        // linear head: dy [b*d, 1] -> dx [b*d, 3L]
        let dx = linear_bwd_s(phi, &mut grad, spec.lin("head"), &caches.x, &dlogits, b * d, true, scr);
        let mut dhdev = scr.take(b * d * L);
        let mut dhq = scr.take(b * d * L);
        let mut dhcur = scr.take(b * L);
        for lane in 0..b {
            for dev in 0..d {
                let ed = lane * d + dev;
                let row = &dx[ed * 3 * L..(ed + 1) * 3 * L];
                dhdev[ed * L..(ed + 1) * L].copy_from_slice(&row[..L]);
                dhq[ed * L..(ed + 1) * L].copy_from_slice(&row[L..2 * L]);
                for ch in 0..L {
                    dhcur[lane * L + ch] += row[2 * L + ch]; // broadcast over devices
                }
            }
        }
        scr.give(dx);
        mlp2_bwd(phi, &mut grad, spec.lin("cost1"), spec.lin("cost2"), &caches.cost, &dhq, false, scr);
        mlp2_bwd(phi, &mut grad, spec.lin("tbl1"), spec.lin("tbl2"), &caches.cur, &dhcur, false, scr);
        let dh = masked_reduce_bwd(&dhdev, mask, b * d, s, L, Red::Sum, &caches.red, scr);
        mlp2_bwd(phi, &mut grad, spec.lin("tbl1"), spec.lin("tbl2"), &caches.tbl, &dh, false, scr);
        scr.give(dh);
        scr.give(dhdev);
        scr.give(dhq);
        scr.give(dhcur);
        caches.recycle(scr);
        (loss, grad)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::math::{fd_check, rand_vec};
    use crate::util::Rng;

    #[allow(clippy::type_complexity)]
    fn tiny(
        rng: &mut Rng,
        b: usize,
        d: usize,
        s: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let feats: Vec<f32> = rand_vec(b * d * s * F, 1.0, rng).iter().map(|v| v.abs()).collect();
        let mut mask = vec![0.0f32; b * d * s];
        for step in 0..b {
            for dev in 0..d {
                for slot in 0..=(dev % s.max(1)) {
                    mask[(step * d + dev) * s + slot] = 1.0;
                }
            }
        }
        let q = rand_vec(b * d * 3, 1.0, rng);
        let cur: Vec<f32> = rand_vec(b * F, 1.0, rng).iter().map(|v| v.abs()).collect();
        let mut legal = vec![1.0f32; b * d];
        legal[0] = 0.0; // one illegal device in step 0
        let fmask = vec![1.0f32; F];
        let qscale = vec![1.0f32; 3];
        (feats, mask, q, cur, legal, fmask, qscale)
    }

    #[test]
    fn logits_respect_legality() {
        let mut rng = Rng::new(21);
        let spec = policy_spec();
        let phi = rand_vec(spec.total, 0.1, &mut rng);
        let (b, d, s) = (2usize, 3usize, 2usize);
        let (feats, mask, q, cur, legal, fmask, qscale) = tiny(&mut rng, b, d, s);
        let logits =
            policy_forward(&phi, &feats, &mask, &q, &cur, &legal, &fmask, &qscale, b, d, s);
        assert_eq!(logits.len(), b * d);
        assert_eq!(logits[0], -1e9);
        assert!(logits[1].is_finite() && logits[1].abs() < 1e6);
    }

    #[test]
    fn policy_gradcheck() {
        let mut rng = Rng::new(22);
        let spec = policy_spec();
        let phi = rand_vec(spec.total, 0.15, &mut rng);
        let (b, d, s) = (3usize, 2usize, 2usize);
        let (feats, mask, q, cur, legal, fmask, qscale) = tiny(&mut rng, b, d, s);
        let action = vec![1i32, 0, 1];
        let adv = vec![0.8f32, -0.3, 1.1];
        let smask = vec![1.0f32, 1.0, 0.0];
        let loss = |ph: &[f32]| -> f32 {
            policy_loss_grad(
                ph, &feats, &mask, &q, &cur, &legal, &action, &adv, &smask, &fmask, &qscale, b,
                d, s,
            )
            .0
        };
        let (_, grad) = policy_loss_grad(
            &phi, &feats, &mask, &q, &cur, &legal, &action, &adv, &smask, &fmask, &qscale, b, d,
            s,
        );
        fd_check(loss, &phi, &grad, 30, 88);
    }
}
