//! Flat-parameter layouts of the three networks, mirroring
//! `python/compile/params.py` + `model.py` **exactly** (same entry order,
//! offsets, and PyTorch-Linear init bounds), so parameters initialized by
//! either backend are interchangeable.

use crate::runtime::manifest::{ParamInfo, Segment};

/// Table-feature count (paper section A.2). Equals `tables::NUM_FEATURES`.
pub const F: usize = 21;
/// Latent dim.
pub const L: usize = 32;
/// Shared table-MLP hidden width.
pub const H_TBL: usize = 128;
/// Prediction-head hidden width.
pub const H_HEAD: usize = 64;
/// Policy cost-feature MLP hidden width.
pub const H_COST: usize = 64;
/// Entropy-bonus weight in the REINFORCE loss (Eq. 2).
pub const ENTROPY_W: f32 = 0.001;

/// One dense layer's location inside the flat parameter vector:
/// weight `[n_in, n_out]` (row-major) at `w`, bias `[n_out]` at `b`.
#[derive(Clone, Copy, Debug)]
pub struct Lin {
    pub w: usize,
    pub b: usize,
    pub n_in: usize,
    pub n_out: usize,
}

/// Ordered list of named segments living inside one flat vector.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    /// (name, offset, len, init bound).
    pub segs: Vec<(String, usize, usize, f32)>,
    pub total: usize,
}

impl Spec {
    fn add(&mut self, name: String, len: usize, fan_in: usize) {
        // PyTorch nn.Linear default: U(-1/sqrt(fan_in), 1/sqrt(fan_in))
        // for both weight and bias.
        let bound = 1.0 / (fan_in as f32).sqrt();
        self.segs.push((name, self.total, len, bound));
        self.total += len;
    }

    /// Register a dense layer's weight `[n_in, n_out]` and bias `[n_out]`.
    fn linear(&mut self, name: &str, n_in: usize, n_out: usize) {
        self.add(format!("{name}.w"), n_in * n_out, n_in);
        self.add(format!("{name}.b"), n_out, n_in);
    }

    /// Locate a dense layer registered with [`Spec::linear`].
    pub fn lin(&self, name: &str) -> Lin {
        let wname = format!("{name}.w");
        let wi = self
            .segs
            .iter()
            .position(|(n, ..)| *n == wname)
            // lint: allow(panic-policy) — layer names are compile-time constants in the reference-backend builders; a miss is a construction bug caught by every test, not a runtime condition
            .unwrap_or_else(|| panic!("no layer {name} in spec"));
        let (_, w_off, w_len, _) = &self.segs[wi];
        let (_, b_off, b_len, _) = &self.segs[wi + 1];
        Lin { w: *w_off, b: *b_off, n_in: w_len / b_len, n_out: *b_len }
    }

    /// Manifest record of this layout.
    pub fn param_info(&self) -> ParamInfo {
        ParamInfo {
            total: self.total,
            segments: self
                .segs
                .iter()
                .map(|(name, offset, len, bound)| Segment {
                    name: name.clone(),
                    offset: *offset,
                    len: *len,
                    bound: *bound,
                })
                .collect(),
        }
    }
}

/// Cost network (paper section 3.2 / B.1).
pub fn cost_spec() -> Spec {
    let mut s = Spec::default();
    s.linear("tbl1", F, H_TBL);
    s.linear("tbl2", H_TBL, L);
    for head in ["fwd", "bwd", "comm"] {
        s.linear(&format!("{head}1"), L, H_HEAD);
        s.linear(&format!("{head}2"), H_HEAD, 1);
    }
    s.linear("ovr1", L, H_HEAD);
    s.linear("ovr2", H_HEAD, 1);
    s
}

/// Policy network (paper section 3.3 / B.2).
pub fn policy_spec() -> Spec {
    let mut s = Spec::default();
    s.linear("tbl1", F, H_TBL);
    s.linear("tbl2", H_TBL, L);
    s.linear("cost1", 3, H_COST);
    s.linear("cost2", H_COST, L);
    // Head input: [device rep ; cost rep ; current-table rep].
    s.linear("head", 3 * L, 1);
    s
}

/// RNN baseline controller (section D.2); artifacts are per device count.
pub fn rnn_spec(n_devices: usize) -> Spec {
    let mut s = Spec::default();
    s.linear("tbl1", F, H_TBL);
    s.linear("tbl2", H_TBL, L);
    for gate in ["z", "r", "n"] {
        s.linear(&format!("gru_x{gate}"), L, L);
        s.linear(&format!("gru_h{gate}"), L, L);
    }
    s.linear("head", 2 * L, n_devices);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_layout_matches_python() {
        let s = cost_spec();
        // tbl1.w starts at 0; tbl1.b right after; total covers all segs
        assert_eq!(s.segs[0], ("tbl1.w".into(), 0, F * H_TBL, 1.0 / (F as f32).sqrt()));
        assert_eq!(s.segs[1].1, F * H_TBL);
        let covered: usize = s.segs.iter().map(|(_, _, l, _)| *l).sum();
        assert_eq!(covered, s.total);
        // 2 tbl layers + 3 heads x 2 + 2 ovr = 10 linears = 20 segments
        assert_eq!(s.segs.len(), 20);
        let expected = (F * H_TBL + H_TBL)
            + (H_TBL * L + L)
            + 4 * ((L * H_HEAD + H_HEAD) + (H_HEAD + 1));
        assert_eq!(s.total, expected);
    }

    #[test]
    fn lin_lookup() {
        let s = policy_spec();
        let head = s.lin("head");
        assert_eq!(head.n_in, 3 * L);
        assert_eq!(head.n_out, 1);
        assert_eq!(head.b, head.w + 3 * L);
        assert_eq!(head.b + 1, s.total);
        let c1 = s.lin("cost1");
        assert_eq!((c1.n_in, c1.n_out), (3, H_COST));
    }

    #[test]
    fn rnn_layout() {
        let s = rnn_spec(4);
        assert_eq!(s.lin("head").n_out, 4);
        assert_eq!(s.lin("gru_hn").n_in, L);
        // tbl MLP + 6 GRU linears + head = 9 linears
        assert_eq!(s.segs.len(), 18);
    }
}
