//! Reference cost network (model.py `cost_forward` / `table_cost_forward`
//! / `cost_train_step`): shared table-MLP over the padded `[E, D, S, F]`
//! feature batch, masked table/device reductions, three per-device cost
//! heads + one overall head, and the Eq.-1 MSE training step.
//!
//! All entry points acquire the thread-local [`Scratch`] pool once per
//! call and recycle every intermediate (including the [`Mlp2Cache`]
//! activations) on return, so steady-state dispatches allocate nothing.

use super::math::{
    masked_reduce, masked_reduce_bwd, mlp2_bwd, mlp2_fwd, with_scratch, Mlp2Cache, Red, RedCache,
    Scratch,
};
use super::spec::{cost_spec, Spec, F, L};

/// Forward outputs: per-device cost features and overall cost.
pub struct CostOut {
    /// [e*d*3] (fwd comp, bwd comp, bwd comm), dmask-gated.
    pub q: Vec<f32>,
    /// [e] overall step cost.
    pub cost: Vec<f32>,
}

struct Caches {
    tbl: Mlp2Cache,
    red1: RedCache,
    heads: Vec<Mlp2Cache>,
    red2: RedCache,
    ovr: Mlp2Cache,
}

impl Caches {
    fn recycle(self, scr: &mut Scratch) {
        self.tbl.recycle(scr);
        self.red1.recycle(scr);
        for c in self.heads {
            c.recycle(scr);
        }
        self.red2.recycle(scr);
        self.ovr.recycle(scr);
    }
}

const HEADS: [&str; 3] = ["fwd", "bwd", "comm"];

fn x_masked(feats: &[f32], fmask: &[f32], rows: usize, scr: &mut Scratch) -> Vec<f32> {
    let mut x = scr.take(rows * F);
    for r in 0..rows {
        for (i, &fm) in fmask.iter().enumerate() {
            x[r * F + i] = feats[r * F + i] * fm;
        }
    }
    x
}

#[allow(clippy::too_many_arguments)]
fn forward_inner(
    spec: &Spec,
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
    scr: &mut Scratch,
) -> (CostOut, Caches) {
    let rows = e * d * s;
    let x = x_masked(feats, fmask, rows, scr);
    let (h, tbl) = mlp2_fwd(theta, spec.lin("tbl1"), spec.lin("tbl2"), x, rows, scr);
    let (hdev, red1) = masked_reduce(&h, mask, e * d, s, L, tr, scr);
    scr.give(h);
    let mut q = vec![0.0f32; e * d * 3];
    let mut heads = Vec::with_capacity(3);
    for (k, head) in HEADS.iter().enumerate() {
        let mut hin = scr.take(e * d * L);
        hin.copy_from_slice(&hdev);
        let (qh, cache) = mlp2_fwd(
            theta,
            spec.lin(&format!("{head}1")),
            spec.lin(&format!("{head}2")),
            hin,
            e * d,
            scr,
        );
        for ed in 0..e * d {
            q[ed * 3 + k] = qh[ed] * dmask[ed];
        }
        scr.give(qh);
        heads.push(cache);
    }
    let (hall, red2) = masked_reduce(&hdev, dmask, e, d, L, dr, scr);
    scr.give(hdev);
    let (cost, ovr) = mlp2_fwd(theta, spec.lin("ovr1"), spec.lin("ovr2"), hall, e, scr);
    (CostOut { q, cost }, Caches { tbl, red1, heads, red2, ovr })
}

/// Forward pass over `e` lanes.
#[allow(clippy::too_many_arguments)]
pub fn cost_forward(
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
) -> CostOut {
    let spec = cost_spec();
    with_scratch(|scr| {
        let (out, caches) =
            forward_inner(&spec, theta, feats, mask, dmask, fmask, e, d, s, tr, dr, scr);
        caches.recycle(scr);
        out
    })
}

/// Eq.-1 loss (cost-feature MSE + overall-cost MSE) and its full
/// parameter gradient.
#[allow(clippy::too_many_arguments)]
pub fn cost_loss_grad(
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    q_tgt: &[f32],
    c_tgt: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
) -> (f32, Vec<f32>) {
    let spec = cost_spec();
    with_scratch(|scr| {
        let (out, caches) =
            forward_inner(&spec, theta, feats, mask, dmask, fmask, e, d, s, tr, dr, scr);
        let dn: f32 = dmask.iter().sum::<f32>().max(1.0);

        let mut loss = 0.0f32;
        // dq for the dmask-gated q (dmask is 0/1, so gating twice is exact)
        let mut dq = scr.take(e * d * 3);
        for ed in 0..e * d {
            for k in 0..3 {
                let diff = out.q[ed * 3 + k] - q_tgt[ed * 3 + k];
                loss += diff * diff * dmask[ed] / (dn * 3.0);
                dq[ed * 3 + k] = 2.0 * diff * dmask[ed] / (dn * 3.0);
            }
        }
        let mut dc = scr.take(e);
        for lane in 0..e {
            let diff = out.cost[lane] - c_tgt[lane];
            loss += diff * diff / e as f32;
            dc[lane] = 2.0 * diff / e as f32;
        }

        let mut grad = vec![0.0f32; spec.total];
        // overall head -> hall -> hdev
        let dhall = mlp2_bwd(
            theta,
            &mut grad,
            spec.lin("ovr1"),
            spec.lin("ovr2"),
            &caches.ovr,
            &dc,
            true,
            scr,
        );
        let mut dhdev = masked_reduce_bwd(&dhall, dmask, e, d, L, dr, &caches.red2, scr);
        scr.give(dhall);
        scr.give(dc);
        // three per-device heads -> hdev
        for (k, head) in HEADS.iter().enumerate() {
            let mut dy = scr.take(e * d);
            for ed in 0..e * d {
                dy[ed] = dq[ed * 3 + k] * dmask[ed];
            }
            let dh = mlp2_bwd(
                theta,
                &mut grad,
                spec.lin(&format!("{head}1")),
                spec.lin(&format!("{head}2")),
                &caches.heads[k],
                &dy,
                true,
                scr,
            );
            for (a, b) in dhdev.iter_mut().zip(dh.iter()) {
                *a += b;
            }
            scr.give(dh);
            scr.give(dy);
        }
        scr.give(dq);
        // table reduction -> shared table MLP
        let dh = masked_reduce_bwd(&dhdev, mask, e * d, s, L, tr, &caches.red1, scr);
        scr.give(dhdev);
        mlp2_bwd(theta, &mut grad, spec.lin("tbl1"), spec.lin("tbl2"), &caches.tbl, &dh, false, scr);
        scr.give(dh);
        caches.recycle(scr);
        (loss, grad)
    })
}

/// Predicted single-table total cost (sum of the three heads) for each of
/// `n` feature rows (model.py `table_cost_forward`).
///
/// Rows are strictly independent — each row's cost depends only on that
/// row's `F` features — which is what lets the reference backend
/// row-split one large `[N, F]` batch across intra-op helper threads
/// (see `runtime/reference/mod.rs`).
pub fn table_cost_forward(theta: &[f32], feats: &[f32], fmask: &[f32], n: usize) -> Vec<f32> {
    let mut total = vec![0.0f32; n];
    table_cost_forward_into(theta, feats, fmask, n, &mut total);
    total
}

/// [`table_cost_forward`] writing into a caller slice: the intra-op
/// split hands each helper thread a disjoint chunk of one output buffer.
pub fn table_cost_forward_into(
    theta: &[f32],
    feats: &[f32],
    fmask: &[f32],
    n: usize,
    total: &mut [f32],
) {
    debug_assert_eq!(total.len(), n);
    let spec = cost_spec();
    with_scratch(|scr| {
        let x = x_masked(feats, fmask, n, scr);
        let (h, tbl) = mlp2_fwd(theta, spec.lin("tbl1"), spec.lin("tbl2"), x, n, scr);
        total.fill(0.0);
        for head in HEADS {
            let mut hin = scr.take(n * L);
            hin.copy_from_slice(&h);
            let (qh, cache) = mlp2_fwd(
                theta,
                spec.lin(&format!("{head}1")),
                spec.lin(&format!("{head}2")),
                hin,
                n,
                scr,
            );
            for (t, &v) in total.iter_mut().zip(qh.iter()) {
                *t += v;
            }
            scr.give(qh);
            cache.recycle(scr);
        }
        scr.give(h);
        tbl.recycle(scr);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::math::{fd_check, rand_vec};
    use crate::util::Rng;

    fn tiny_inputs(
        rng: &mut Rng,
        e: usize,
        d: usize,
        s: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let feats: Vec<f32> = rand_vec(e * d * s * F, 1.0, rng).iter().map(|v| v.abs()).collect();
        let mut mask = vec![0.0f32; e * d * s];
        let mut dmask = vec![0.0f32; e * d];
        for lane in 0..e {
            for dev in 0..d {
                dmask[lane * d + dev] = 1.0;
                // one device left empty in lane 0 to hit the empty-group path
                let fill = if lane == 0 && dev == d - 1 { 0 } else { 1 + (dev % s.max(1)) };
                for slot in 0..fill.min(s) {
                    mask[(lane * d + dev) * s + slot] = 1.0;
                }
            }
        }
        let fmask = vec![1.0f32; F];
        let q_tgt = rand_vec(e * d * 3, 1.0, rng);
        let c_tgt = rand_vec(e, 1.0, rng);
        (feats, mask, dmask, fmask, q_tgt, c_tgt)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let mut rng = Rng::new(11);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let (e, d, s) = (2usize, 2usize, 3usize);
        let (feats, mask, dmask, fmask, _, _) = tiny_inputs(&mut rng, e, d, s);
        let out = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(out.q.len(), e * d * 3);
        assert_eq!(out.cost.len(), e);
        assert!(out.q.iter().chain(out.cost.iter()).all(|v| v.is_finite()));
        // deterministic (and scratch reuse across calls changes nothing)
        let out2 = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(out.q, out2.q);
        assert_eq!(out.cost, out2.cost);
    }

    #[test]
    fn zeroed_fmask_column_ignores_feature() {
        let mut rng = Rng::new(12);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let (e, d, s) = (1usize, 2usize, 2usize);
        let (mut feats, mask, dmask, mut fmask, _, _) = tiny_inputs(&mut rng, e, d, s);
        fmask[0] = 0.0;
        let a = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        for r in 0..e * d * s {
            feats[r * F] = 123.0; // masked column: must not matter
        }
        let b = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.q, b.q);
    }

    #[test]
    fn cost_gradcheck_all_reductions() {
        let mut rng = Rng::new(13);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.15, &mut rng);
        let (e, d, s) = (2usize, 2usize, 3usize);
        let (feats, mask, dmask, fmask, q_tgt, c_tgt) = tiny_inputs(&mut rng, e, d, s);
        for (tr, dr) in [(Red::Sum, Red::Max), (Red::Mean, Red::Sum), (Red::Max, Red::Mean)] {
            let loss = |th: &[f32]| -> f32 {
                cost_loss_grad(th, &feats, &mask, &dmask, &q_tgt, &c_tgt, &fmask, e, d, s, tr, dr).0
            };
            let (_, grad) = cost_loss_grad(
                &theta, &feats, &mask, &dmask, &q_tgt, &c_tgt, &fmask, e, d, s, tr, dr,
            );
            fd_check(loss, &theta, &grad, 25, 77 + tr as u64 * 3 + dr as u64);
        }
    }

    #[test]
    fn table_cost_is_sum_of_heads() {
        let mut rng = Rng::new(14);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let feats = rand_vec(3 * F, 1.0, &mut rng);
        let fmask = vec![1.0f32; F];
        let t = table_cost_forward(&theta, &feats, &fmask, 3);
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn table_cost_into_matches_alloc() {
        let mut rng = Rng::new(15);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let feats = rand_vec(5 * F, 1.0, &mut rng);
        let fmask = vec![1.0f32; F];
        let a = table_cost_forward(&theta, &feats, &fmask, 5);
        let mut b = vec![7.0f32; 5]; // pre-dirtied: _into must fully overwrite
        table_cost_forward_into(&theta, &feats, &fmask, 5, &mut b);
        assert_eq!(a, b);
    }
}
