//! Reference cost network (model.py `cost_forward` / `table_cost_forward`
//! / `cost_train_step`): shared table-MLP over the padded `[E, D, S, F]`
//! feature batch, masked table/device reductions, three per-device cost
//! heads + one overall head, and the Eq.-1 MSE training step.

use super::math::{
    masked_reduce, masked_reduce_bwd, mlp2_bwd, mlp2_fwd, Mlp2Cache, Red, RedCache,
};
use super::spec::{cost_spec, Spec, F, L};

/// Forward outputs: per-device cost features and overall cost.
pub struct CostOut {
    /// [e*d*3] (fwd comp, bwd comp, bwd comm), dmask-gated.
    pub q: Vec<f32>,
    /// [e] overall step cost.
    pub cost: Vec<f32>,
}

struct Caches {
    tbl: Mlp2Cache,
    red1: RedCache,
    heads: Vec<Mlp2Cache>,
    red2: RedCache,
    ovr: Mlp2Cache,
}

const HEADS: [&str; 3] = ["fwd", "bwd", "comm"];

fn x_masked(feats: &[f32], fmask: &[f32], rows: usize) -> Vec<f32> {
    let mut x = vec![0.0f32; rows * F];
    for r in 0..rows {
        for (i, &fm) in fmask.iter().enumerate() {
            x[r * F + i] = feats[r * F + i] * fm;
        }
    }
    x
}

#[allow(clippy::too_many_arguments)]
fn forward_inner(
    spec: &Spec,
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
) -> (CostOut, Caches) {
    let rows = e * d * s;
    let x = x_masked(feats, fmask, rows);
    let (h, tbl) = mlp2_fwd(theta, spec.lin("tbl1"), spec.lin("tbl2"), x, rows);
    let (hdev, red1) = masked_reduce(&h, mask, e * d, s, L, tr);
    let mut q = vec![0.0f32; e * d * 3];
    let mut heads = Vec::with_capacity(3);
    for (k, head) in HEADS.iter().enumerate() {
        let (qh, cache) = mlp2_fwd(
            theta,
            spec.lin(&format!("{head}1")),
            spec.lin(&format!("{head}2")),
            hdev.clone(),
            e * d,
        );
        for ed in 0..e * d {
            q[ed * 3 + k] = qh[ed] * dmask[ed];
        }
        heads.push(cache);
    }
    let (hall, red2) = masked_reduce(&hdev, dmask, e, d, L, dr);
    let (cost, ovr) = mlp2_fwd(theta, spec.lin("ovr1"), spec.lin("ovr2"), hall, e);
    (CostOut { q, cost }, Caches { tbl, red1, heads, red2, ovr })
}

/// Forward pass over `e` lanes.
#[allow(clippy::too_many_arguments)]
pub fn cost_forward(
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
) -> CostOut {
    let spec = cost_spec();
    forward_inner(&spec, theta, feats, mask, dmask, fmask, e, d, s, tr, dr).0
}

/// Eq.-1 loss (cost-feature MSE + overall-cost MSE) and its full
/// parameter gradient.
#[allow(clippy::too_many_arguments)]
pub fn cost_loss_grad(
    theta: &[f32],
    feats: &[f32],
    mask: &[f32],
    dmask: &[f32],
    q_tgt: &[f32],
    c_tgt: &[f32],
    fmask: &[f32],
    e: usize,
    d: usize,
    s: usize,
    tr: Red,
    dr: Red,
) -> (f32, Vec<f32>) {
    let spec = cost_spec();
    let (out, caches) = forward_inner(&spec, theta, feats, mask, dmask, fmask, e, d, s, tr, dr);
    let dn: f32 = dmask.iter().sum::<f32>().max(1.0);

    let mut loss = 0.0f32;
    // dq for the dmask-gated q (dmask is 0/1, so gating twice is exact)
    let mut dq = vec![0.0f32; e * d * 3];
    for ed in 0..e * d {
        for k in 0..3 {
            let diff = out.q[ed * 3 + k] - q_tgt[ed * 3 + k];
            loss += diff * diff * dmask[ed] / (dn * 3.0);
            dq[ed * 3 + k] = 2.0 * diff * dmask[ed] / (dn * 3.0);
        }
    }
    let mut dc = vec![0.0f32; e];
    for lane in 0..e {
        let diff = out.cost[lane] - c_tgt[lane];
        loss += diff * diff / e as f32;
        dc[lane] = 2.0 * diff / e as f32;
    }

    let mut grad = vec![0.0f32; spec.total];
    // overall head -> hall -> hdev
    let dhall = mlp2_bwd(theta, &mut grad, spec.lin("ovr1"), spec.lin("ovr2"), &caches.ovr, &dc, true);
    let mut dhdev = masked_reduce_bwd(&dhall, dmask, e, d, L, dr, &caches.red2);
    // three per-device heads -> hdev
    for (k, head) in HEADS.iter().enumerate() {
        let mut dy = vec![0.0f32; e * d];
        for ed in 0..e * d {
            dy[ed] = dq[ed * 3 + k] * dmask[ed];
        }
        let dh = mlp2_bwd(
            theta,
            &mut grad,
            spec.lin(&format!("{head}1")),
            spec.lin(&format!("{head}2")),
            &caches.heads[k],
            &dy,
            true,
        );
        for (a, b) in dhdev.iter_mut().zip(dh.iter()) {
            *a += b;
        }
    }
    // table reduction -> shared table MLP
    let dh = masked_reduce_bwd(&dhdev, mask, e * d, s, L, tr, &caches.red1);
    mlp2_bwd(theta, &mut grad, spec.lin("tbl1"), spec.lin("tbl2"), &caches.tbl, &dh, false);
    (loss, grad)
}

/// Predicted single-table total cost (sum of the three heads) for each of
/// `n` feature rows (model.py `table_cost_forward`).
pub fn table_cost_forward(theta: &[f32], feats: &[f32], fmask: &[f32], n: usize) -> Vec<f32> {
    let spec = cost_spec();
    let x = x_masked(feats, fmask, n);
    let (h, _) = mlp2_fwd(theta, spec.lin("tbl1"), spec.lin("tbl2"), x, n);
    let mut total = vec![0.0f32; n];
    for head in HEADS {
        let (qh, _) = mlp2_fwd(
            theta,
            spec.lin(&format!("{head}1")),
            spec.lin(&format!("{head}2")),
            h.clone(),
            n,
        );
        for (t, &v) in total.iter_mut().zip(qh.iter()) {
            *t += v;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::math::tests::{fd_check, rand_vec};
    use crate::util::Rng;

    fn tiny_inputs(
        rng: &mut Rng,
        e: usize,
        d: usize,
        s: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let feats: Vec<f32> = rand_vec(e * d * s * F, 1.0, rng).iter().map(|v| v.abs()).collect();
        let mut mask = vec![0.0f32; e * d * s];
        let mut dmask = vec![0.0f32; e * d];
        for lane in 0..e {
            for dev in 0..d {
                dmask[lane * d + dev] = 1.0;
                // one device left empty in lane 0 to hit the empty-group path
                let fill = if lane == 0 && dev == d - 1 { 0 } else { 1 + (dev % s.max(1)) };
                for slot in 0..fill.min(s) {
                    mask[(lane * d + dev) * s + slot] = 1.0;
                }
            }
        }
        let fmask = vec![1.0f32; F];
        let q_tgt = rand_vec(e * d * 3, 1.0, rng);
        let c_tgt = rand_vec(e, 1.0, rng);
        (feats, mask, dmask, fmask, q_tgt, c_tgt)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let mut rng = Rng::new(11);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let (e, d, s) = (2usize, 2usize, 3usize);
        let (feats, mask, dmask, fmask, _, _) = tiny_inputs(&mut rng, e, d, s);
        let out = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(out.q.len(), e * d * 3);
        assert_eq!(out.cost.len(), e);
        assert!(out.q.iter().chain(out.cost.iter()).all(|v| v.is_finite()));
        // deterministic
        let out2 = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(out.q, out2.q);
        assert_eq!(out.cost, out2.cost);
    }

    #[test]
    fn zeroed_fmask_column_ignores_feature() {
        let mut rng = Rng::new(12);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let (e, d, s) = (1usize, 2usize, 2usize);
        let (mut feats, mask, dmask, mut fmask, _, _) = tiny_inputs(&mut rng, e, d, s);
        fmask[0] = 0.0;
        let a = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        for r in 0..e * d * s {
            feats[r * F] = 123.0; // masked column: must not matter
        }
        let b = cost_forward(&theta, &feats, &mask, &dmask, &fmask, e, d, s, Red::Sum, Red::Max);
        assert_eq!(a.cost, b.cost);
        assert_eq!(a.q, b.q);
    }

    #[test]
    fn cost_gradcheck_all_reductions() {
        let mut rng = Rng::new(13);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.15, &mut rng);
        let (e, d, s) = (2usize, 2usize, 3usize);
        let (feats, mask, dmask, fmask, q_tgt, c_tgt) = tiny_inputs(&mut rng, e, d, s);
        for (tr, dr) in [(Red::Sum, Red::Max), (Red::Mean, Red::Sum), (Red::Max, Red::Mean)] {
            let loss = |th: &[f32]| -> f32 {
                cost_loss_grad(th, &feats, &mask, &dmask, &q_tgt, &c_tgt, &fmask, e, d, s, tr, dr).0
            };
            let (_, grad) = cost_loss_grad(
                &theta, &feats, &mask, &dmask, &q_tgt, &c_tgt, &fmask, e, d, s, tr, dr,
            );
            fd_check(loss, &theta, &grad, 25, 77 + tr as u64 * 3 + dr as u64);
        }
    }

    #[test]
    fn table_cost_is_sum_of_heads() {
        let mut rng = Rng::new(14);
        let spec = cost_spec();
        let theta = rand_vec(spec.total, 0.1, &mut rng);
        let feats = rand_vec(3 * F, 1.0, &mut rng);
        let fmask = vec![1.0f32; F];
        let t = table_cost_forward(&theta, &feats, &fmask, 3);
        assert_eq!(t.len(), 3);
        assert!(t.iter().all(|v| v.is_finite()));
    }
}
