//! Numeric core of the reference backend: dense layers, masked
//! reductions, the REINFORCE loss, and Adam — forward *and* backward,
//! mirroring `python/compile/model.py` + `kernels/ref.py` semantics.
//!
//! Everything operates on flat `&[f32]` buffers with explicit dims (the
//! same row-major layout the tensors use), and every backward helper
//! *accumulates* into a caller-owned flat gradient vector so shared
//! layers (e.g. the table MLP used by two input paths) compose naturally.
//!
//! # Blocking scheme and the bit-identity guarantee
//!
//! The dense kernels are cache-blocked: [`linear_fwd`] tiles over rows
//! ([`ROW_BLOCK`]) and output columns ([`COL_BLOCK`]) so a tile of the
//! weight matrix stays hot across a block of input rows, and
//! [`linear_bwd`] tiles its dW accumulation and dx row sweeps the same
//! way. The blocking **never reorders the floating-point operations that
//! feed any single output element**: the k-accumulation in the forward
//! pass still walks `i = 0..n_in` in order within each (row, column-tile)
//! pair, the dW/db accumulations still walk rows in ascending order per
//! (i, j) element, and the dx inner sum still walks `j = 0..n_out` in
//! order. Tiles only change *which element* is computed next, never the
//! operand sequence *within* an element — so every f32 sum is exactly
//! the value the naive triple loop produces, and the backend parity
//! tests stay byte-for-byte pins rather than tolerance checks. The naive
//! implementations are kept as oracles ([`linear_fwd_naive`],
//! [`linear_bwd_naive`], [`mlp2_fwd_naive`], [`mlp2_bwd_naive`]) and
//! `rust/tests/kernels.rs` asserts bit-identity across a randomized
//! shape sweep.
//!
//! # Scratch buffers
//!
//! The per-dispatch `vec![0.0; …]` churn is replaced by a per-thread
//! [`Scratch`] free-list ([`with_scratch`]): forward/backward entry
//! points take buffers from the pool (always zeroed, so behavior is
//! bit-identical to a fresh allocation) and recycle them — including
//! the [`Mlp2Cache`] activations — when the call returns. Worker-pool
//! threads are persistent, so steady-state serving reuses the same
//! handful of buffers across every dispatch.

use crate::err;
use crate::util::error::Result;
use std::cell::RefCell;

pub use super::spec::Lin;

/// Row-tile size for the blocked dense kernels.
pub const ROW_BLOCK: usize = 64;
/// Output-column (and dW input-row) tile size for the blocked kernels.
pub const COL_BLOCK: usize = 64;

// ---------------------------------------------------------------------
// scratch buffers
// ---------------------------------------------------------------------

/// Free-list of flat `f32` buffers reused across kernel calls.
///
/// [`Scratch::take`] hands out a buffer zeroed to the requested length —
/// bit-identical to `vec![0.0; len]` but reusing capacity — and
/// [`Scratch::give`] returns one for later reuse. The pool is
/// thread-local (see [`with_scratch`]); buffers that escape a call (e.g.
/// an output tensor) simply never come back, which is fine.
#[derive(Default)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
}

impl Scratch {
    /// A zeroed buffer of exactly `len` elements, reusing pooled capacity.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.pool.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer to the pool for a later [`Scratch::take`].
    pub fn give(&mut self, v: Vec<f32>) {
        self.pool.push(v);
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// Run `f` against this thread's scratch pool.
///
/// Worker-pool threads are persistent, so the pool amortizes across
/// dispatches. Calls must not nest (the entry points in
/// `runtime/reference/{cost,policy,rnn}.rs` each acquire the pool once
/// per dispatch and thread `&mut Scratch` through their helpers).
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|cell| f(&mut cell.borrow_mut()))
}

// ---------------------------------------------------------------------
// dense layers
// ---------------------------------------------------------------------

/// `y = x @ w + b` (+ optional ReLU). x: [rows, n_in] -> [rows, n_out].
///
/// Cache-blocked; bit-identical to [`linear_fwd_naive`] (see module docs).
pub fn linear_fwd(theta: &[f32], l: Lin, x: &[f32], rows: usize, relu: bool) -> Vec<f32> {
    let mut y = vec![0.0f32; rows * l.n_out];
    linear_fwd_into(theta, l, x, rows, relu, &mut y);
    y
}

/// [`linear_fwd`] writing into a caller buffer (pooled via [`Scratch`]).
pub fn linear_fwd_s(
    theta: &[f32],
    l: Lin,
    x: &[f32],
    rows: usize,
    relu: bool,
    scr: &mut Scratch,
) -> Vec<f32> {
    let mut y = scr.take(rows * l.n_out);
    linear_fwd_into(theta, l, x, rows, relu, &mut y);
    y
}

/// Blocked forward kernel. Every element of `y` is written (bias copy
/// first), so the buffer's prior contents never leak through.
pub fn linear_fwd_into(theta: &[f32], l: Lin, x: &[f32], rows: usize, relu: bool, y: &mut [f32]) {
    let (k, m) = (l.n_in, l.n_out);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(y.len(), rows * m);
    let w = &theta[l.w..l.w + k * m];
    let b = &theta[l.b..l.b + m];
    for r0 in (0..rows).step_by(ROW_BLOCK) {
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for j0 in (0..m).step_by(COL_BLOCK) {
            let j1 = (j0 + COL_BLOCK).min(m);
            for r in r0..r1 {
                let yr = &mut y[r * m + j0..r * m + j1];
                yr.copy_from_slice(&b[j0..j1]);
                let xr = &x[r * k..(r + 1) * k];
                // k-accumulation order is i = 0..k ascending per output
                // element, exactly as in the naive loop (bit-identity).
                for (i, &xi) in xr.iter().enumerate() {
                    if xi != 0.0 {
                        let wr = &w[i * m + j0..i * m + j1];
                        for (yj, &wj) in yr.iter_mut().zip(wr.iter()) {
                            *yj += xi * wj;
                        }
                    }
                }
                if relu {
                    for yj in yr.iter_mut() {
                        if *yj < 0.0 {
                            *yj = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// The original naive triple loop, kept as the bit-identity oracle for
/// `rust/tests/kernels.rs` and the blocked-vs-naive bench section. Do
/// not optimize this one.
pub fn linear_fwd_naive(theta: &[f32], l: Lin, x: &[f32], rows: usize, relu: bool) -> Vec<f32> {
    let (k, m) = (l.n_in, l.n_out);
    debug_assert_eq!(x.len(), rows * k);
    let w = &theta[l.w..l.w + k * m];
    let b = &theta[l.b..l.b + m];
    let mut y = vec![0.0f32; rows * m];
    for r in 0..rows {
        let yr = &mut y[r * m..(r + 1) * m];
        yr.copy_from_slice(b);
        let xr = &x[r * k..(r + 1) * k];
        for (i, &xi) in xr.iter().enumerate() {
            if xi != 0.0 {
                let wr = &w[i * m..(i + 1) * m];
                for (yj, &wj) in yr.iter_mut().zip(wr.iter()) {
                    *yj += xi * wj;
                }
            }
        }
        if relu {
            for yj in yr.iter_mut() {
                if *yj < 0.0 {
                    *yj = 0.0;
                }
            }
        }
    }
    y
}

/// dW/db accumulation phase of the blocked backward. Rows are walked in
/// ascending order within and across row blocks, so each (i, j) element
/// of dW (and each j of db) sees exactly the naive accumulation order.
fn linear_bwd_params(grad: &mut [f32], l: Lin, x: &[f32], dy: &[f32], rows: usize) {
    let (k, m) = (l.n_in, l.n_out);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(dy.len(), rows * m);
    for r0 in (0..rows).step_by(ROW_BLOCK) {
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for r in r0..r1 {
            let dyr = &dy[r * m..(r + 1) * m];
            for (gb, &d) in grad[l.b..l.b + m].iter_mut().zip(dyr.iter()) {
                *gb += d;
            }
        }
        // dW: tile the input-row (i) axis so a band of grad rows stays
        // cache-hot across the whole row block.
        for i0 in (0..k).step_by(COL_BLOCK) {
            let i1 = (i0 + COL_BLOCK).min(k);
            for r in r0..r1 {
                let xr = &x[r * k..(r + 1) * k];
                let dyr = &dy[r * m..(r + 1) * m];
                for (i, &xi) in xr[i0..i1].iter().enumerate() {
                    if xi != 0.0 {
                        let row = i0 + i;
                        let gw = &mut grad[l.w + row * m..l.w + (row + 1) * m];
                        for (g, &d) in gw.iter_mut().zip(dyr.iter()) {
                            *g += xi * d;
                        }
                    }
                }
            }
        }
    }
}

/// dx phase of the blocked backward: `dx[r,i] = sum_j dy[r,j] w[i,j]`.
/// The j-sum stays sequential per element; only the (r, i) visit order
/// is tiled (each element is written exactly once), so values are
/// bit-identical to the naive loop.
fn linear_bwd_dx_into(theta: &[f32], l: Lin, dy: &[f32], rows: usize, dx: &mut [f32]) {
    let (k, m) = (l.n_in, l.n_out);
    debug_assert_eq!(dy.len(), rows * m);
    debug_assert_eq!(dx.len(), rows * k);
    let w = &theta[l.w..l.w + k * m];
    for r0 in (0..rows).step_by(ROW_BLOCK) {
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for i in 0..k {
            let wr = &w[i * m..(i + 1) * m];
            for r in r0..r1 {
                let dyr = &dy[r * m..(r + 1) * m];
                let mut acc = 0.0f32;
                for (&d, &wj) in dyr.iter().zip(wr.iter()) {
                    acc += d * wj;
                }
                dx[r * k + i] = acc;
            }
        }
    }
}

/// Backward of [`linear_fwd`] (callers gate `dy` for ReLU themselves).
/// Accumulates dW/db into `grad`; returns dx when `want_dx`.
///
/// Blocked; bit-identical to [`linear_bwd_naive`] (see module docs).
pub fn linear_bwd(
    theta: &[f32],
    grad: &mut [f32],
    l: Lin,
    x: &[f32],
    dy: &[f32],
    rows: usize,
    want_dx: bool,
) -> Vec<f32> {
    linear_bwd_params(grad, l, x, dy, rows);
    if !want_dx {
        return Vec::new();
    }
    let mut dx = vec![0.0f32; rows * l.n_in];
    linear_bwd_dx_into(theta, l, dy, rows, &mut dx);
    dx
}

/// [`linear_bwd`] with the dx buffer pooled via [`Scratch`].
#[allow(clippy::too_many_arguments)]
pub fn linear_bwd_s(
    theta: &[f32],
    grad: &mut [f32],
    l: Lin,
    x: &[f32],
    dy: &[f32],
    rows: usize,
    want_dx: bool,
    scr: &mut Scratch,
) -> Vec<f32> {
    linear_bwd_params(grad, l, x, dy, rows);
    if !want_dx {
        return Vec::new();
    }
    let mut dx = scr.take(rows * l.n_in);
    linear_bwd_dx_into(theta, l, dy, rows, &mut dx);
    dx
}

/// The original naive backward, kept as the bit-identity oracle for
/// `rust/tests/kernels.rs`. Do not optimize this one.
pub fn linear_bwd_naive(
    theta: &[f32],
    grad: &mut [f32],
    l: Lin,
    x: &[f32],
    dy: &[f32],
    rows: usize,
    want_dx: bool,
) -> Vec<f32> {
    let (k, m) = (l.n_in, l.n_out);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(dy.len(), rows * m);
    // dW[i,j] += sum_r x[r,i] dy[r,j]; db[j] += sum_r dy[r,j]
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let dyr = &dy[r * m..(r + 1) * m];
        for (gb, &d) in grad[l.b..l.b + m].iter_mut().zip(dyr.iter()) {
            *gb += d;
        }
        for (i, &xi) in xr.iter().enumerate() {
            if xi != 0.0 {
                let gw = &mut grad[l.w + i * m..l.w + (i + 1) * m];
                for (g, &d) in gw.iter_mut().zip(dyr.iter()) {
                    *g += xi * d;
                }
            }
        }
    }
    if !want_dx {
        return Vec::new();
    }
    // dx[r,i] = sum_j dy[r,j] w[i,j]  (both slices contiguous)
    let w = &theta[l.w..l.w + k * m];
    let mut dx = vec![0.0f32; rows * k];
    for r in 0..rows {
        let dyr = &dy[r * m..(r + 1) * m];
        let dxr = &mut dx[r * k..(r + 1) * k];
        for (i, dxi) in dxr.iter_mut().enumerate() {
            let wr = &w[i * m..(i + 1) * m];
            let mut acc = 0.0f32;
            for (&d, &wj) in dyr.iter().zip(wr.iter()) {
                acc += d * wj;
            }
            *dxi = acc;
        }
    }
    dx
}

/// Cached activations of a two-layer MLP (ReLU hidden), for backward.
pub struct Mlp2Cache {
    /// Input rows [rows, l1.n_in].
    pub x: Vec<f32>,
    /// Post-ReLU hidden rows [rows, l1.n_out].
    pub h: Vec<f32>,
    /// Number of rows.
    pub rows: usize,
}

impl Mlp2Cache {
    /// Return the cached activations to the pool for reuse by a later call.
    pub fn recycle(self, scr: &mut Scratch) {
        scr.give(self.x);
        scr.give(self.h);
    }
}

/// Two-layer MLP with ReLU hidden, over `rows` rows of `x` (consumed).
/// The hidden and output buffers come from `scr`.
pub fn mlp2_fwd(
    theta: &[f32],
    l1: Lin,
    l2: Lin,
    x: Vec<f32>,
    rows: usize,
    scr: &mut Scratch,
) -> (Vec<f32>, Mlp2Cache) {
    let mut h = scr.take(rows * l1.n_out);
    linear_fwd_into(theta, l1, &x, rows, true, &mut h);
    let mut y = scr.take(rows * l2.n_out);
    linear_fwd_into(theta, l2, &h, rows, false, &mut y);
    (y, Mlp2Cache { x, h, rows })
}

/// Naive-oracle variant of [`mlp2_fwd`] (plain allocations, naive
/// linear kernels). Kept for the kernel parity suite.
pub fn mlp2_fwd_naive(
    theta: &[f32],
    l1: Lin,
    l2: Lin,
    x: Vec<f32>,
    rows: usize,
) -> (Vec<f32>, Mlp2Cache) {
    let h = linear_fwd_naive(theta, l1, &x, rows, true);
    let y = linear_fwd_naive(theta, l2, &h, rows, false);
    (y, Mlp2Cache { x, h, rows })
}

/// Backward of [`mlp2_fwd`]. Accumulates parameter grads; returns dx
/// when `want_dx`. The dh intermediate is pooled and recycled.
#[allow(clippy::too_many_arguments)]
pub fn mlp2_bwd(
    theta: &[f32],
    grad: &mut [f32],
    l1: Lin,
    l2: Lin,
    cache: &Mlp2Cache,
    dy: &[f32],
    want_dx: bool,
    scr: &mut Scratch,
) -> Vec<f32> {
    let mut dh = linear_bwd_s(theta, grad, l2, &cache.h, dy, cache.rows, true, scr);
    for (d, &h) in dh.iter_mut().zip(cache.h.iter()) {
        if h <= 0.0 {
            *d = 0.0;
        }
    }
    let dx = linear_bwd_s(theta, grad, l1, &cache.x, &dh, cache.rows, want_dx, scr);
    scr.give(dh);
    dx
}

/// Naive-oracle variant of [`mlp2_bwd`]. Kept for the kernel parity suite.
pub fn mlp2_bwd_naive(
    theta: &[f32],
    grad: &mut [f32],
    l1: Lin,
    l2: Lin,
    cache: &Mlp2Cache,
    dy: &[f32],
    want_dx: bool,
) -> Vec<f32> {
    let mut dh = linear_bwd_naive(theta, grad, l2, &cache.h, dy, cache.rows, true);
    for (d, &h) in dh.iter_mut().zip(cache.h.iter()) {
        if h <= 0.0 {
            *d = 0.0;
        }
    }
    linear_bwd_naive(theta, grad, l1, &cache.x, &dh, cache.rows, want_dx)
}

// ---------------------------------------------------------------------
// masked reductions (model.py `_device_reduce` / `_overall_reduce`)
// ---------------------------------------------------------------------

/// Reduction flavor over the masked item axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Red {
    /// Masked sum.
    Sum,
    /// Masked mean (empty groups divide by 1).
    Mean,
    /// Masked max (empty groups reduce to 0).
    Max,
}

/// Parse a reduction name (`sum` / `mean` / `max`).
pub fn parse_red(s: &str) -> Result<Red> {
    match s {
        "sum" => Ok(Red::Sum),
        "mean" => Ok(Red::Mean),
        "max" => Ok(Red::Max),
        other => Err(err!("unknown reduction `{other}`")),
    }
}

/// Cache for [`masked_reduce`] backward.
pub struct RedCache {
    /// Masked item count per group [g].
    pub count: Vec<f32>,
    /// Winning item per (group, channel) for Max; `usize::MAX` = empty.
    pub argmax: Vec<usize>,
}

impl RedCache {
    /// Return the poolable buffers for reuse by a later call.
    pub fn recycle(self, scr: &mut Scratch) {
        scr.give(self.count);
    }
}

/// Reduce `h` [g, n, l] over its item axis under `mask` [g, n] -> [g, l].
/// Sum/mean as in jnp; max fills empty groups with 0 (model.py's
/// `where(count > 0, max, 0)` guard). Output buffers are pooled.
pub fn masked_reduce(
    h: &[f32],
    mask: &[f32],
    g: usize,
    n: usize,
    l: usize,
    red: Red,
    scr: &mut Scratch,
) -> (Vec<f32>, RedCache) {
    debug_assert_eq!(h.len(), g * n * l);
    debug_assert_eq!(mask.len(), g * n);
    let mut out = scr.take(g * l);
    let mut count = scr.take(g);
    let mut argmax = Vec::new();
    if red == Red::Max {
        argmax = vec![usize::MAX; g * l];
    }
    for gi in 0..g {
        let mrow = &mask[gi * n..(gi + 1) * n];
        let c: f32 = mrow.iter().copied().filter(|&m| m > 0.0).sum();
        count[gi] = c;
        let orow = &mut out[gi * l..(gi + 1) * l];
        match red {
            Red::Sum | Red::Mean => {
                for (i, &m) in mrow.iter().enumerate() {
                    if m != 0.0 {
                        let hrow = &h[(gi * n + i) * l..(gi * n + i + 1) * l];
                        for (o, &hv) in orow.iter_mut().zip(hrow.iter()) {
                            *o += m * hv;
                        }
                    }
                }
                if red == Red::Mean {
                    let denom = c.max(1.0);
                    for o in orow.iter_mut() {
                        *o /= denom;
                    }
                }
            }
            Red::Max => {
                if c > 0.0 {
                    let arow = &mut argmax[gi * l..(gi + 1) * l];
                    orow.fill(f32::NEG_INFINITY);
                    for (i, &m) in mrow.iter().enumerate() {
                        if m > 0.0 {
                            let hrow = &h[(gi * n + i) * l..(gi * n + i + 1) * l];
                            for ((o, a), &hv) in orow.iter_mut().zip(arow.iter_mut()).zip(hrow) {
                                if *a == usize::MAX || hv > *o {
                                    *o = hv;
                                    *a = i;
                                }
                            }
                        }
                    }
                }
                // empty groups stay 0 (guard)
            }
        }
    }
    (out, RedCache { count, argmax })
}

/// Backward of [`masked_reduce`]: dout [g, l] -> dh [g, n, l] (pooled).
#[allow(clippy::too_many_arguments)]
pub fn masked_reduce_bwd(
    dout: &[f32],
    mask: &[f32],
    g: usize,
    n: usize,
    l: usize,
    red: Red,
    cache: &RedCache,
    scr: &mut Scratch,
) -> Vec<f32> {
    let mut dh = scr.take(g * n * l);
    for gi in 0..g {
        let drow = &dout[gi * l..(gi + 1) * l];
        match red {
            Red::Sum | Red::Mean => {
                let scale = if red == Red::Mean { 1.0 / cache.count[gi].max(1.0) } else { 1.0 };
                for i in 0..n {
                    let m = mask[gi * n + i];
                    if m != 0.0 {
                        let hrow = &mut dh[(gi * n + i) * l..(gi * n + i + 1) * l];
                        for (d, &dv) in hrow.iter_mut().zip(drow.iter()) {
                            *d = m * scale * dv;
                        }
                    }
                }
            }
            Red::Max => {
                let arow = &cache.argmax[gi * l..(gi + 1) * l];
                for (ch, (&a, &dv)) in arow.iter().zip(drow.iter()).enumerate() {
                    if a != usize::MAX {
                        dh[(gi * n + a) * l + ch] = dv;
                    }
                }
            }
        }
    }
    dh
}

// ---------------------------------------------------------------------
// REINFORCE loss (model.py `_reinforce_loss`)
// ---------------------------------------------------------------------

/// Loss + dloss/dlogits for REINFORCE with entropy bonus (Eq. 2).
///
/// logits/legal: [rows, d]; action/adv/smask: [rows]. Gradient is zeroed
/// where `legal <= 0` (in the model the -1e9 fill blocks it anyway).
#[allow(clippy::too_many_arguments)]
pub fn reinforce_loss_grad(
    logits: &[f32],
    legal: &[f32],
    action: &[i32],
    adv: &[f32],
    smask: &[f32],
    rows: usize,
    d: usize,
    entropy_w: f32,
) -> (f32, Vec<f32>) {
    let n: f32 = smask.iter().sum::<f32>().max(1.0);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; rows * d];
    let mut p = vec![0.0f32; d];
    let mut lp = vec![0.0f32; d];
    for r in 0..rows {
        let sm = smask[r];
        if sm == 0.0 {
            continue;
        }
        let z = &logits[r * d..(r + 1) * d];
        let lg = &legal[r * d..(r + 1) * d];
        // an all-illegal row is a recorded dead-end fallback: it carried
        // no decision, so it contributes neither loss nor gradient (the
        // jax model never sees such rows — they predate its loss)
        if lg.iter().all(|&l| l <= 0.0) {
            continue;
        }
        let zmax = z.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for j in 0..d {
            p[j] = (z[j] - zmax).exp();
            sum += p[j];
        }
        let lse = zmax + sum.ln();
        for j in 0..d {
            lp[j] = z[j] - lse;
            p[j] /= sum;
        }
        let a = (action[r] as usize).min(d - 1);
        // ent restricted to legal entries, as in the model
        let mut ent = 0.0f32;
        let mut s1 = 0.0f32; // sum_legal p*lp
        let mut s2 = 0.0f32; // sum_legal p
        for j in 0..d {
            if lg[j] > 0.0 {
                ent -= p[j] * lp[j];
                s1 += p[j] * lp[j];
                s2 += p[j];
            }
        }
        loss -= sm * (lp[a] * adv[r] + entropy_w * ent) / n;
        for j in 0..d {
            if lg[j] <= 0.0 {
                continue; // where() blocks the gradient
            }
            let dlp_a = if j == a { 1.0 - p[j] } else { -p[j] };
            // d ent / d z_j = -p_j (lp_j + 1) + p_j (s1 + s2)
            let dent = -p[j] * (lp[j] + 1.0) + p[j] * (s1 + s2);
            dlogits[r * d + j] = -(sm / n) * (adv[r] * dlp_a + entropy_w * dent);
        }
    }
    (loss, dlogits)
}

// ---------------------------------------------------------------------
// Adam (params.py `adam_update`)
// ---------------------------------------------------------------------

/// One Adam step over flat vectors; `t` is the 1-based step count AFTER
/// this update, `lr` the already-decayed learning rate.
pub fn adam(theta: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], t: f32, lr: f32) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let c1 = 1.0 - B1.powf(t);
    let c2 = 1.0 - B2.powf(t);
    for i in 0..theta.len() {
        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
        let mhat = m[i] / c1;
        let vhat = v[i] / c2;
        theta[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
}

// ---------------------------------------------------------------------
// test oracles (finite-difference gradient checks)
// ---------------------------------------------------------------------

/// Central finite-difference check of `analytic` against `f` at
/// `theta`, probing `probes` random coordinates. Test oracle — public
/// so the integration suites (`rust/tests/kernels.rs`) and the sibling
/// reference modules can gradcheck through the public API.
pub fn fd_check<F: FnMut(&[f32]) -> f32>(
    mut f: F,
    theta: &[f32],
    analytic: &[f32],
    probes: usize,
    seed: u64,
) {
    let mut rng = crate::util::Rng::new(seed);
    let mut th = theta.to_vec();
    for _ in 0..probes {
        let i = rng.below(th.len());
        let eps = 3e-3f32;
        let orig = th[i];
        th[i] = orig + eps;
        let up = f(&th);
        th[i] = orig - eps;
        let down = f(&th);
        th[i] = orig;
        let fd = (up - down) / (2.0 * eps);
        let an = analytic[i];
        let tol = 2e-3 + 0.05 * an.abs().max(fd.abs());
        assert!(
            (fd - an).abs() <= tol,
            "grad mismatch at {i}: fd {fd} vs analytic {an}"
        );
    }
}

/// Uniform random vector in `[-scale, scale]` (test oracle helper).
pub fn rand_vec(n: usize, scale: f32, rng: &mut crate::util::Rng) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * 2.0 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn linear_matches_by_hand() {
        // theta = [w(2x2), b(2)]
        let theta = vec![1.0, 2.0, 3.0, 4.0, 0.5, -0.5];
        let l = Lin { w: 0, b: 4, n_in: 2, n_out: 2 };
        let y = linear_fwd(&theta, l, &[1.0, 1.0], 1, false);
        assert_eq!(y, vec![1.0 + 3.0 + 0.5, 2.0 + 4.0 - 0.5]);
        let yr = linear_fwd(&theta, l, &[-1.0, 0.0], 1, true);
        assert_eq!(yr, vec![0.0, 0.0]); // relu clamps -0.5 and -2.5... both negative
    }

    #[test]
    fn mlp2_gradcheck() {
        let mut rng = Rng::new(1);
        let l1 = Lin { w: 0, b: 12, n_in: 3, n_out: 4 };
        let l2 = Lin { w: 16, b: 24, n_in: 4, n_out: 2 };
        let total = 26;
        let theta = rand_vec(total, 0.5, &mut rng);
        let x = rand_vec(6, 1.0, &mut rng); // 2 rows
        // loss = sum(y^2)/2 so dy = y
        let loss = |th: &[f32]| -> f32 {
            with_scratch(|scr| {
                let (y, c) = mlp2_fwd(th, l1, l2, x.clone(), 2, scr);
                let s = y.iter().map(|v| v * v).sum::<f32>() / 2.0;
                scr.give(y);
                c.recycle(scr);
                s
            })
        };
        let (y, cache) = with_scratch(|scr| mlp2_fwd(&theta, l1, l2, x.clone(), 2, scr));
        let mut grad = vec![0.0f32; total];
        with_scratch(|scr| {
            mlp2_bwd(&theta, &mut grad, l1, l2, &cache, &y, false, scr);
        });
        fd_check(loss, &theta, &grad, 20, 7);
    }

    #[test]
    fn mlp2_input_grad() {
        let mut rng = Rng::new(2);
        let l1 = Lin { w: 0, b: 12, n_in: 3, n_out: 4 };
        let l2 = Lin { w: 16, b: 24, n_in: 4, n_out: 2 };
        let theta = rand_vec(26, 0.5, &mut rng);
        let x = rand_vec(3, 1.0, &mut rng);
        let loss = |xv: &[f32]| -> f32 {
            with_scratch(|scr| {
                let (y, c) = mlp2_fwd(&theta, l1, l2, xv.to_vec(), 1, scr);
                let s = y.iter().map(|v| v * v).sum::<f32>() / 2.0;
                scr.give(y);
                c.recycle(scr);
                s
            })
        };
        let (y, cache) = with_scratch(|scr| mlp2_fwd(&theta, l1, l2, x.clone(), 1, scr));
        let mut grad = vec![0.0f32; 26];
        let dx = with_scratch(|scr| mlp2_bwd(&theta, &mut grad, l1, l2, &cache, &y, true, scr));
        fd_check(loss, &x, &dx, 3, 8);
    }

    #[test]
    fn reduce_flavors() {
        // g=1, n=3, l=2; mask drops item 1
        let h = vec![1.0, 10.0, 5.0, 50.0, 3.0, -2.0];
        let mask = vec![1.0, 0.0, 1.0];
        with_scratch(|scr| {
            let (s, _) = masked_reduce(&h, &mask, 1, 3, 2, Red::Sum, scr);
            assert_eq!(s, vec![4.0, 8.0]);
            let (m, _) = masked_reduce(&h, &mask, 1, 3, 2, Red::Mean, scr);
            assert_eq!(m, vec![2.0, 4.0]);
            let (x, c) = masked_reduce(&h, &mask, 1, 3, 2, Red::Max, scr);
            assert_eq!(x, vec![3.0, 10.0]);
            assert_eq!(&c.argmax, &[2, 0]);
            // empty group -> zeros
            let (x0, _) = masked_reduce(&h, &[0.0, 0.0, 0.0], 1, 3, 2, Red::Max, scr);
            assert_eq!(x0, vec![0.0, 0.0]);
        });
    }

    #[test]
    fn reduce_gradcheck() {
        let mut rng = Rng::new(3);
        let (g, n, l) = (2usize, 3usize, 2usize);
        let h = rand_vec(g * n * l, 1.0, &mut rng);
        let mask = vec![1.0, 1.0, 0.0, 1.0, 0.0, 1.0];
        for red in [Red::Sum, Red::Mean, Red::Max] {
            let loss = |hv: &[f32]| -> f32 {
                with_scratch(|scr| {
                    let (o, c) = masked_reduce(hv, &mask, g, n, l, red, scr);
                    let s = o.iter().map(|v| v * v).sum::<f32>() / 2.0;
                    scr.give(o);
                    c.recycle(scr);
                    s
                })
            };
            let (o, cache) = with_scratch(|scr| masked_reduce(&h, &mask, g, n, l, red, scr));
            let dh = with_scratch(|scr| masked_reduce_bwd(&o, &mask, g, n, l, red, &cache, scr));
            fd_check(loss, &h, &dh, 12, 40 + red as u64);
        }
    }

    #[test]
    fn reinforce_gradcheck() {
        let mut rng = Rng::new(4);
        let (rows, d) = (3usize, 4usize);
        let logits = rand_vec(rows * d, 2.0, &mut rng);
        let mut legal = vec![1.0f32; rows * d];
        legal[1] = 0.0; // one illegal action in row 0
        let action = vec![0i32, 2, 3];
        let adv = vec![0.7f32, -1.2, 0.4];
        let smask = vec![1.0f32, 1.0, 0.0];
        // mask logits like the model does before the loss
        let masked = |z: &[f32]| -> Vec<f32> {
            z.iter()
                .enumerate()
                .map(|(i, &v)| if legal[i] > 0.0 { v } else { -1e9 })
                .collect()
        };
        let loss = |z: &[f32]| -> f32 {
            reinforce_loss_grad(&masked(z), &legal, &action, &adv, &smask, rows, d, 0.001).0
        };
        let (_, dz) =
            reinforce_loss_grad(&masked(&logits), &legal, &action, &adv, &smask, rows, d, 0.001);
        fd_check(loss, &logits, &dz, 12, 9);
        // masked-out row contributes nothing
        assert!(dz[2 * d..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn adam_step_matches_reference() {
        // one step from zero moments: mhat = g, vhat = g^2 -> step ~ lr*sign(g)
        let mut theta = vec![1.0f32, -1.0];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        adam(&mut theta, &mut m, &mut v, &[0.5, -0.25], 1.0, 0.1);
        assert!((theta[0] - (1.0 - 0.1)).abs() < 1e-4, "{}", theta[0]);
        assert!((theta[1] - (-1.0 + 0.1)).abs() < 1e-4, "{}", theta[1]);
        assert!((m[0] - 0.05).abs() < 1e-7);
    }

    #[test]
    fn scratch_take_is_zeroed_after_reuse() {
        let mut scr = Scratch::default();
        let mut a = scr.take(4);
        a.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        scr.give(a);
        let b = scr.take(6);
        assert_eq!(b, vec![0.0; 6]);
        scr.give(b);
        let c = scr.take(2);
        assert_eq!(c, vec![0.0; 2]);
    }
}
