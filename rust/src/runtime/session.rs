//! The runtime's execution-session layer: a small in-crate worker pool
//! (std threads + channels, zero external dependencies) behind
//! `Runtime::submit` / `Ticket::wait`.
//!
//! Every artifact execution is a `Job` pushed onto one shared queue;
//! pool workers pull jobs FIFO, run them through the shared `Dispatch`
//! core (which counts the dispatch and calls `Backend::execute`), and
//! reply on the job's private channel. A `Ticket` is the caller's end of
//! that channel: `wait` joins the execution. The blocking `Runtime::run`
//! is exactly `submit(..).wait()`, so blocking and pipelined callers
//! share one dispatch path — and one set of call-budget counters.
//!
//! Two deliberate properties:
//!
//! * **panics stay on the worker**: a panic inside `Backend::execute` is
//!   caught, converted to an `Err`, and the worker survives — callers see
//!   a normal error and the counters remain readable (no poisoned locks:
//!   the counters are atomics).
//! * **no nested dispatch**: jobs must never `submit`/`run` from inside
//!   `Backend::execute` — with a single worker that would self-deadlock.
//!   Backends are leaf executors by contract.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use super::{Dispatch, Value};
use crate::err;
use crate::util::error::Result;

/// One queued artifact execution.
struct Job {
    name: String,
    inputs: Vec<Value>,
    reply: Sender<Result<Vec<Value>>>,
}

/// A pending execution dispatched by [`Runtime::submit`]. Join it with
/// [`Ticket::wait`]; dropping it instead abandons the result (the worker
/// still executes — and counts — the job, the output is discarded).
///
/// [`Runtime::submit`]: super::Runtime::submit
pub struct Ticket {
    name: String,
    rx: Receiver<Result<Vec<Value>>>,
}

impl Ticket {
    /// Block until the pool finishes this execution and return the
    /// artifact outputs. A panic inside the backend surfaces here as an
    /// `Err` (the worker survives), never as a second panic.
    pub fn wait(self) -> Result<Vec<Value>> {
        match self.rx.recv() {
            Ok(result) => result,
            // only possible if the pool was torn down mid-flight
            Err(_) => Err(err!("runtime shut down before `{}` finished executing", self.name)),
        }
    }
}

/// The worker pool: N threads draining one shared job queue.
pub(super) struct Pool {
    /// `None` after shutdown begins; workers exit on the disconnect.
    tx: Mutex<Option<Sender<Job>>>,
    handles: Vec<thread::JoinHandle<()>>,
    n: usize,
}

impl Pool {
    pub(super) fn spawn(dispatch: Arc<Dispatch>, n: usize) -> Pool {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let dispatch = Arc::clone(&dispatch);
                thread::Builder::new()
                    .name(format!("dreamshard-exec-{i}"))
                    .spawn(move || worker(&rx, &dispatch))
                    // lint: allow(panic-policy) — Pool::spawn sits under the infallible Runtime constructors (Runtime::reference() -> Self); an OS that cannot spawn a thread at startup has no recovery path worth plumbing
                    .expect("spawn runtime worker thread")
            })
            .collect();
        Pool { tx: Mutex::new(Some(tx)), handles, n }
    }

    pub(super) fn workers(&self) -> usize {
        self.n
    }

    pub(super) fn submit(&self, name: String, inputs: Vec<Value>) -> Ticket {
        let (reply, rx) = channel();
        let ticket = Ticket { name: name.clone(), rx };
        let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(tx) = guard.as_ref() {
            // cannot fail: workers only exit once this sender is dropped
            let _ = tx.send(Job { name, inputs, reply });
        }
        ticket
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // close the queue first so blocked workers observe the disconnect
        *self.tx.lock().unwrap_or_else(|e| e.into_inner()) = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker(rx: &Mutex<Receiver<Job>>, dispatch: &Dispatch) {
    loop {
        let job = {
            let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
            match guard.recv() {
                Ok(job) => job,
                Err(_) => return, // queue closed: the runtime was dropped
            }
        };
        // a backend panic must not kill the worker (or poison anything):
        // catch it, report it as an error, keep serving. The counters the
        // dispatch already bumped are atomics, so they stay readable.
        let result = catch_unwind(AssertUnwindSafe(|| dispatch.run(&job.name, &job.inputs)))
            .unwrap_or_else(|payload| {
                Err(err!(
                    "backend panicked executing {}: {}",
                    job.name,
                    panic_message(payload.as_ref())
                ))
            });
        // the ticket may have been dropped without waiting; that is fine
        let _ = job.reply.send(result);
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
