//! Dense host tensors + the dynamically-typed [`Value`] passed across the
//! backend boundary.
//!
//! These are deliberately minimal: row-major `Vec<T>` with shape, plus
//! indexed writes used by the coordinator when building padded batches.

use crate::util::error::Result;
use crate::{bail, err};

/// Row-major f32 tensor.
#[derive(Clone, Debug)]
pub struct TensorF32 {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorF32 { dims: dims.iter().map(|&d| d as i64).collect(), data: vec![0.0; n] }
    }

    pub fn ones(dims: &[usize]) -> Self {
        let mut t = Self::zeros(dims);
        t.data.fill(1.0);
        t
    }

    pub fn from_vec(data: Vec<f32>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        TensorF32 { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    pub fn scalar1(x: f32) -> Self {
        Self::from_vec(vec![x], &[1])
    }

    /// Flat index of a multi-index (row-major).
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0usize;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!((ix as i64) < self.dims[i], "index {ix} >= dim {}", self.dims[i]);
            off = off * self.dims[i] as usize + ix;
        }
        off
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.flat(idx);
        self.data[off] = v;
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    /// Copy a contiguous row of values starting at a multi-index.
    pub fn set_row(&mut self, idx: &[usize], vals: &[f32]) {
        let off = self.flat(idx);
        self.data[off..off + vals.len()].copy_from_slice(vals);
    }

    /// Wrap (a copy of) this tensor as a backend input.
    pub fn value(&self) -> Value {
        Value::F32(self.clone())
    }

    /// Wrap this tensor as a backend input without copying.
    pub fn into_value(self) -> Value {
        Value::F32(self)
    }
}

/// Row-major i32 tensor.
#[derive(Clone, Debug)]
pub struct TensorI32 {
    pub dims: Vec<i64>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorI32 { dims: dims.iter().map(|&d| d as i64).collect(), data: vec![0; n] }
    }

    pub fn from_vec(data: Vec<i32>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        TensorI32 { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    /// Wrap (a copy of) this tensor as a backend input.
    pub fn value(&self) -> Value {
        Value::I32(self.clone())
    }

    /// Wrap this tensor as a backend input without copying.
    pub fn into_value(self) -> Value {
        Value::I32(self)
    }
}

/// A dynamically-typed tensor crossing the [`super::Backend`] boundary
/// (the role `xla::Literal` played when the runtime was PJRT-only).
#[derive(Clone, Debug)]
pub enum Value {
    F32(TensorF32),
    I32(TensorI32),
}

impl Value {
    pub fn dims(&self) -> &[i64] {
        match self {
            Value::F32(t) => &t.dims,
            Value::I32(t) => &t.dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Value::F32(t) => t.data.len(),
            Value::I32(t) => t.data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 tensor, or error with the actual dtype.
    pub fn f32s(&self) -> Result<&TensorF32> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => Err(err!("expected f32 tensor, got i32")),
        }
    }

    /// Borrow as i32 tensor, or error with the actual dtype.
    pub fn i32s(&self) -> Result<&TensorI32> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => Err(err!("expected i32 tensor, got f32")),
        }
    }
}

/// Extract a value into an f32 vec, with a length check against
/// `expect_len` (shape mismatches here mean a backend bug).
pub fn to_f32_vec(v: &Value, expect_len: usize) -> Result<Vec<f32>> {
    let t = v.f32s()?;
    if t.data.len() != expect_len {
        bail!("value has {} elements, expected {expect_len}", t.data.len());
    }
    Ok(t.data.clone())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let mut t = TensorF32::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.data[1 * 12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
        t.set_row(&[0, 1, 0], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.get(&[0, 1, 2]), 3.0);
    }

    #[test]
    fn value_roundtrip() {
        let t = TensorF32::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let v = t.value();
        assert_eq!(v.dims(), &[2, 3]);
        assert_eq!(to_f32_vec(&v, 6).unwrap(), t.data);
        assert!(to_f32_vec(&v, 5).is_err());
        let i = TensorI32::from_vec(vec![1, 2], &[2]).value();
        assert!(i.f32s().is_err());
        assert_eq!(i.i32s().unwrap().data, vec![1, 2]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF32::from_vec(vec![1.0], &[2, 2]);
    }
}
