//! Dense host tensors + literal packing for the PJRT boundary.
//!
//! These are deliberately minimal: row-major `Vec<T>` with shape, plus
//! indexed writes used by the coordinator when building padded batches.

use anyhow::{anyhow, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug)]
pub struct TensorF32 {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorF32 { dims: dims.iter().map(|&d| d as i64).collect(), data: vec![0.0; n] }
    }

    pub fn ones(dims: &[usize]) -> Self {
        let mut t = Self::zeros(dims);
        t.data.fill(1.0);
        t
    }

    pub fn from_vec(data: Vec<f32>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        TensorF32 { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    pub fn scalar1(x: f32) -> Self {
        Self::from_vec(vec![x], &[1])
    }

    /// Flat index of a multi-index (row-major).
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut off = 0usize;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!((ix as i64) < self.dims[i], "index {ix} >= dim {}", self.dims[i]);
            off = off * self.dims[i] as usize + ix;
        }
        off
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = self.flat(idx);
        self.data[off] = v;
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.flat(idx)]
    }

    /// Copy a contiguous row of values starting at a multi-index.
    pub fn set_row(&mut self, idx: &[usize], vals: &[f32]) {
        let off = self.flat(idx);
        self.data[off..off + vals.len()].copy_from_slice(vals);
    }

    pub fn literal(&self) -> xla::Literal {
        xla::Literal::vec1(&self.data).reshape(&self.dims).expect("reshape literal")
    }
}

/// Row-major i32 tensor.
#[derive(Clone, Debug)]
pub struct TensorI32 {
    pub dims: Vec<i64>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        TensorI32 { dims: dims.iter().map(|&d| d as i64).collect(), data: vec![0; n] }
    }

    pub fn from_vec(data: Vec<i32>, dims: &[usize]) -> Self {
        let n: usize = dims.iter().product();
        assert_eq!(data.len(), n, "shape/data mismatch");
        TensorI32 { dims: dims.iter().map(|&d| d as i64).collect(), data }
    }

    pub fn literal(&self) -> xla::Literal {
        xla::Literal::vec1(&self.data).reshape(&self.dims).expect("reshape literal")
    }
}

/// Extract a literal into a f32 vec, with shape check against `expect_len`.
pub fn to_f32_vec(lit: &xla::Literal, expect_len: usize) -> Result<Vec<f32>> {
    let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32: {e:?}"))?;
    if v.len() != expect_len {
        return Err(anyhow!("literal has {} elements, expected {expect_len}", v.len()));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let mut t = TensorF32::zeros(&[2, 3, 4]);
        t.set(&[1, 2, 3], 7.0);
        assert_eq!(t.data[1 * 12 + 2 * 4 + 3], 7.0);
        assert_eq!(t.get(&[1, 2, 3]), 7.0);
        t.set_row(&[0, 1, 0], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.get(&[0, 1, 2]), 3.0);
    }

    #[test]
    fn literal_roundtrip() {
        let t = TensorF32::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = t.literal();
        assert_eq!(lit.to_vec::<f32>().unwrap(), t.data);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF32::from_vec(vec![1.0], &[2, 2]);
    }
}
