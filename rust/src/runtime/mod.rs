//! Network-execution runtime behind a pluggable [`Backend`] seam.
//!
//! The coordinator only ever calls `Runtime::run(artifact_name, inputs)`
//! (or its asynchronous form, `Runtime::submit(..)` + [`Ticket::wait`])
//! with host [`Value`] tensors; what executes underneath is a backend:
//!
//! * [`ReferenceBackend`] (default, always available) — pure-Rust
//!   forward/backward evaluation of the cost / policy / RNN networks,
//!   mirroring `python/compile/model.py`. It synthesizes its own
//!   [`Manifest`] (same parameter layouts and artifact-variant grid that
//!   `make artifacts` bakes), so no `artifacts/` directory is needed.
//! * `XlaBackend` (`--features xla`) — parses `artifacts/manifest.txt`
//!   produced by `make artifacts`, lazily JIT-compiles each HLO-text
//!   artifact on the PJRT CPU client, and executes it. HLO *text* is the
//!   interchange format — xla_extension 0.5.1 rejects jax>=0.5 serialized
//!   protos (64-bit instruction ids), while the text parser reassigns ids.
//!
//! The artifact *names* (`cost_fwd_d4s48`, `policy_train_d4s48_b512`, ...)
//! are the contract both backends implement; the manifest carries their
//! baked shape metadata either way.
//!
//! ## Concurrent sessions
//!
//! [`Backend`] is `Send + Sync` and the runtime is designed to be shared
//! as `Arc<Runtime>`: executions dispatch onto a small in-crate worker
//! pool ([`Runtime::submit`] returns a [`Ticket`]; [`Ticket::wait`] joins
//! it), the blocking [`Runtime::run`] is exactly `submit(..).wait()`, and
//! the per-artifact call counters are lock-free atomics (one per manifest
//! artifact, fixed at construction) so N threads hammering one runtime
//! never contend on — or poison — a lock on the hot dispatch path. Pool
//! size comes from `DREAMSHARD_WORKERS` (default 2, always ≥ 1) or
//! [`Runtime::with_workers`].

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;
pub mod reference;
mod session;
mod tensor;

pub use manifest::{Artifact, Manifest, ParamInfo, Segment};
#[cfg(feature = "xla")]
pub use pjrt::XlaBackend;
pub use reference::ReferenceBackend;
pub use session::Ticket;
pub use tensor::{to_f32_vec, TensorF32, TensorI32, Value};

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::error::Result;
use crate::util::Rng;
use crate::{bail, err};

/// One network-execution engine. `execute` runs an artifact by manifest
/// name: values in, tuple-decomposed values out (everything is lowered
/// with `return_tuple=True`, and the reference backend matches that
/// calling convention).
///
/// Backends are `Send + Sync`: the runtime dispatches executions from a
/// worker pool and may run several concurrently, so any internal caches
/// must be contention-safe. They are also *leaf* executors — `execute`
/// must never call back into `Runtime::run`/`submit` (with one worker
/// that would self-deadlock).
///
/// Output contract: element order and total length are guaranteed;
/// output `dims()` are advisory only (the XLA backend returns flattened
/// rank-1 values, the reference backend returns shaped ones). Consume
/// outputs through [`to_f32_vec`]-style length-checked extraction.
pub trait Backend: Send + Sync {
    /// Short human-readable backend name (for logs / `dreamshard info`).
    fn name(&self) -> &'static str;

    /// Execute an artifact.
    fn execute(&self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>>;
}

/// The shared dispatch core: the backend plus its call counters. Worker
/// threads hold `Arc<Dispatch>` clones, so the pool needs no back-pointer
/// to the [`Runtime`] that owns it.
pub(crate) struct Dispatch {
    backend: Box<dyn Backend>,
    /// Total executions dispatched (see [`Runtime::run_count`]).
    calls: AtomicU64,
    /// Per-artifact execution counts: one atomic per manifest artifact,
    /// keys fixed at construction — lock-free on the hot dispatch path
    /// and unpoisonable (a panicking execution cannot wedge them).
    calls_named: HashMap<String, AtomicU64>,
}

impl Dispatch {
    fn new(backend: Box<dyn Backend>, manifest: &Manifest) -> Dispatch {
        Dispatch {
            backend,
            calls: AtomicU64::new(0),
            calls_named: manifest
                .artifacts
                .keys()
                .map(|k| (k.clone(), AtomicU64::new(0)))
                .collect(),
        }
    }

    /// Count the dispatch, then execute. Runs on pool workers.
    pub(crate) fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        if let Some(counter) = self.calls_named.get(name) {
            counter.fetch_add(1, Ordering::Relaxed);
        }
        self.backend
            .execute(name, inputs)
            .map_err(|e| e.wrap(format!("executing {name} on {}", self.backend.name())))
    }
}

/// Default worker-pool size: `DREAMSHARD_WORKERS` when set, else 2 —
/// enough to overlap feature-fill with execution without oversubscribing
/// test machines that build many runtimes. An explicitly set but
/// unusable value (not an integer ≥ 1) panics with the reason rather
/// than being silently replaced by the default — the same
/// no-silent-substitution policy [`Runtime::open_default`] applies to
/// `DREAMSHARD_ARTIFACTS` (a CI run that typos the variable must not
/// green-light an unexercised configuration). The programmatic
/// [`Runtime::with_workers`] keeps its forgiving clamp-to-1 instead.
fn default_workers() -> usize {
    match std::env::var("DREAMSHARD_WORKERS") {
        Ok(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            // lint: allow(panic-policy) — the documented no-silent-substitution policy: an explicitly set but unusable DREAMSHARD_WORKERS must abort rather than green-light an unexercised configuration
            _ => panic!(
                "DREAMSHARD_WORKERS={v} is not a valid worker count (want an integer >= 1); \
                 unset it to use the default pool size"
            ),
        },
        Err(_) => 2,
    }
}

/// Executor facade over a [`Backend`] + its [`Manifest`], shareable
/// across threads as `Arc<Runtime>`.
pub struct Runtime {
    pub manifest: Manifest,
    dispatch: Arc<Dispatch>,
    pool: session::Pool,
}

impl Runtime {
    fn build(manifest: Manifest, backend: Box<dyn Backend>, workers: usize) -> Self {
        let dispatch = Arc::new(Dispatch::new(backend, &manifest));
        let pool = session::Pool::spawn(Arc::clone(&dispatch), workers);
        Runtime { manifest, dispatch, pool }
    }

    /// The pure-Rust reference backend (no artifacts, no native code).
    ///
    /// Pool width and the backend's intra-op split width share the one
    /// `DREAMSHARD_WORKERS` knob (read here, per the env-discipline
    /// rule): the same setting that sizes the session pool also bounds
    /// how many scoped helper threads a single large `table_cost`
    /// dispatch may fan out to. [`Runtime::with_workers`] later resizes
    /// only the pool — the intra-op width is fixed at construction; use
    /// [`ReferenceBackend::with_intra_op`] + [`Runtime::with_backend`]
    /// to pick it explicitly.
    pub fn reference() -> Self {
        Self::with_backend(
            reference::reference_manifest(),
            Box::new(ReferenceBackend::with_intra_op(default_workers())),
        )
    }

    /// A runtime over any [`Backend`] implementation and its manifest
    /// (how tests inject failing/panicking backends; the named counters
    /// are allocated from the manifest's artifact set here).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn Backend>) -> Self {
        Self::build(manifest, backend, default_workers())
    }

    /// Replace the worker pool with one of `n` threads (clamped to ≥ 1).
    /// Call before wrapping the runtime in an `Arc`; counters carry over.
    pub fn with_workers(mut self, n: usize) -> Self {
        self.pool = session::Pool::spawn(Arc::clone(&self.dispatch), n);
        self
    }

    /// Worker threads serving [`Runtime::submit`].
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Open an artifact directory produced by `make artifacts` on the XLA
    /// backend. Requires `--features xla` (and a real xla-rs in place of
    /// the in-tree stub).
    #[cfg(feature = "xla")]
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        use crate::util::error::Context;
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let backend = XlaBackend::new(dir, &manifest)?;
        Ok(Self::with_backend(manifest, Box::new(backend)))
    }

    /// Without the `xla` feature there is nothing to open: artifacts are
    /// an XLA-backend concept. Kept so callers get a useful error instead
    /// of a compile break when the feature is off.
    #[cfg(not(feature = "xla"))]
    pub fn open<P: AsRef<Path>>(_dir: P) -> Result<Self> {
        bail!(
            "this build has no XLA backend (rebuild with `--features xla`); \
             use Runtime::reference() / open_default() instead"
        )
    }

    /// Default runtime. When `DREAMSHARD_ARTIFACTS` is **explicitly set**
    /// the XLA backend is mandatory: a build without the `xla` feature —
    /// or a directory that does not open — is a hard error, never a
    /// silent substitution of the reference backend. Without the
    /// variable, the XLA backend is used when it is compiled in *and*
    /// `artifacts/manifest.txt` exists, otherwise the reference backend.
    pub fn open_default() -> Result<Self> {
        match std::env::var("DREAMSHARD_ARTIFACTS") {
            Ok(dir) => {
                if cfg!(feature = "xla") {
                    Self::open(dir)
                } else {
                    bail!(
                        "DREAMSHARD_ARTIFACTS={dir} is set but this build has no XLA \
                         backend (rebuild with `--features xla`); refusing to silently \
                         substitute the reference backend — unset the variable to opt \
                         into Runtime::reference()"
                    )
                }
            }
            Err(_) => {
                if cfg!(feature = "xla") && Path::new("artifacts").join("manifest.txt").exists()
                {
                    return Self::open("artifacts");
                }
                Ok(Self::reference())
            }
        }
    }

    /// Which backend this runtime executes on.
    pub fn backend_name(&self) -> &'static str {
        self.dispatch.backend.name()
    }

    /// Dispatch an artifact execution onto the worker pool and return a
    /// [`Ticket`] for it. Errors immediately for names not in the
    /// manifest (such a dispatch is never counted). The inputs are moved
    /// to the executing worker; results come back through
    /// [`Ticket::wait`].
    pub fn submit(&self, name: &str, inputs: Vec<Value>) -> Result<Ticket> {
        if !self.manifest.artifacts.contains_key(name) {
            bail!("artifact {name} not in manifest");
        }
        Ok(self.pool.submit(name.to_string(), inputs))
    }

    /// Execute an artifact by manifest name, blocking: exactly
    /// [`Runtime::submit`] followed by [`Ticket::wait`], so blocking and
    /// pipelined call sites share one dispatch path and one set of
    /// call-budget counters. Borrowed inputs are cloned to cross onto the
    /// pool; hot loops that build their input array per call should use
    /// [`Runtime::run_owned`] and move it instead.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        self.run_owned(name, inputs.to_vec())
    }

    /// [`Runtime::run`] taking ownership of the inputs — no defensive
    /// clone before the worker hand-off. The coordinator's network
    /// forward/train calls (which assemble fresh input tensors every
    /// call) go through this.
    pub fn run_owned(&self, name: &str, inputs: Vec<Value>) -> Result<Vec<Value>> {
        self.submit(name, inputs)?.wait()
    }

    /// Total artifact executions dispatched so far (through blocking
    /// [`Runtime::run`] or [`Runtime::submit`] tickets). Diagnostics
    /// counter: the lane-batching tests use deltas of it to assert the
    /// one-backend-call-per-MDP-step contract.
    pub fn run_count(&self) -> u64 {
        self.dispatch.calls.load(Ordering::Relaxed)
    }

    /// Executions of one specific artifact so far. The serving tests use
    /// deltas of it to pin the chunk-batched `table_cost` call budget
    /// (`ceil(total_tables / N_cap)` per drained chunk). Reads a
    /// per-artifact atomic — exact under concurrent submitters, and still
    /// readable after a failed (even panicked) execution.
    pub fn run_count_for(&self, name: &str) -> u64 {
        self.dispatch
            .calls_named
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Initialize a flat parameter vector for a registered network,
    /// drawing each segment uniform(-bound, bound) (PyTorch Linear init).
    pub fn init_params(&self, net: &str, rng: &mut Rng) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .params
            .get(net)
            .ok_or_else(|| err!("network {net} not in manifest"))?;
        let mut theta = vec![0.0f32; info.total];
        for seg in &info.segments {
            for x in &mut theta[seg.offset..seg.offset + seg.len] {
                *x = (rng.uniform(-seg.bound as f64, seg.bound as f64)) as f32;
            }
        }
        Ok(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_manifest_has_core_artifacts() {
        let rt = Runtime::reference();
        for name in ["cost_fwd_d4s48", "policy_fwd_d4s48", "cost_train_d4s48", "table_cost"] {
            assert!(rt.manifest.artifacts.contains_key(name), "missing {name}");
        }
        assert!(rt.manifest.params.contains_key("cost"));
        assert!(rt.manifest.params.contains_key("policy"));
        assert_eq!(rt.backend_name(), "reference");
        assert!(rt.workers() >= 1);
    }

    #[test]
    fn init_params_within_bounds() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let info = &rt.manifest.params["cost"];
        assert_eq!(theta.len(), info.total);
        for seg in &info.segments {
            for &x in &theta[seg.offset..seg.offset + seg.len] {
                assert!(x.abs() <= seg.bound + 1e-6);
            }
        }
    }

    #[test]
    fn executes_table_cost() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let feats = TensorF32::zeros(&[n, f]);
        let fmask = TensorF32::ones(&[f]);
        let out = rt
            .run("table_cost", &[
                TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
                feats.value(),
                fmask.value(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        let v = to_f32_vec(&out[0], n).unwrap();
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn submit_wait_matches_blocking_run() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let inputs = vec![
            TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
            TensorF32::ones(&[n, f]).value(),
            TensorF32::ones(&[f]).value(),
        ];
        let blocking = rt.run("table_cost", &inputs).unwrap();
        let ticket = rt.submit("table_cost", inputs).unwrap();
        let ticketed = ticket.wait().unwrap();
        assert_eq!(
            to_f32_vec(&blocking[0], n).unwrap(),
            to_f32_vec(&ticketed[0], n).unwrap(),
            "ticketed execution is bit-identical to blocking run"
        );
        assert_eq!(rt.run_count(), 2);
        assert_eq!(rt.run_count_for("table_cost"), 2);
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let rt = Runtime::reference();
        assert!(rt.run("no_such_artifact", &[]).is_err());
        assert!(rt.submit("no_such_artifact", vec![]).is_err());
        // a failed dispatch (unknown name) is not counted
        assert_eq!(rt.run_count(), 0);
        assert_eq!(rt.run_count_for("no_such_artifact"), 0);
    }

    #[test]
    fn per_artifact_counter_tracks_dispatches() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let inputs = [
            TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
            TensorF32::zeros(&[n, f]).value(),
            TensorF32::ones(&[f]).value(),
        ];
        assert_eq!(rt.run_count_for("table_cost"), 0);
        rt.run("table_cost", &inputs).unwrap();
        rt.run("table_cost", &inputs).unwrap();
        assert_eq!(rt.run_count_for("table_cost"), 2);
        assert_eq!(rt.run_count_for("cost_fwd_d4s48"), 0);
        assert_eq!(rt.run_count(), 2);
    }

    #[test]
    fn with_workers_resizes_the_pool() {
        let rt = Runtime::reference().with_workers(3);
        assert_eq!(rt.workers(), 3);
        // the clamp: zero workers would deadlock every dispatch
        let rt = Runtime::reference().with_workers(0);
        assert_eq!(rt.workers(), 1);
        // the pool still executes after a resize
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let out = rt
            .run("table_cost", &[
                TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
                TensorF32::zeros(&[n, f]).value(),
                TensorF32::ones(&[f]).value(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn dropping_an_unwaited_ticket_does_not_wedge_the_runtime() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let inputs = vec![
            TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
            TensorF32::zeros(&[n, f]).value(),
            TensorF32::ones(&[f]).value(),
        ];
        drop(rt.submit("table_cost", inputs.clone()).unwrap());
        // the pool keeps serving, and the runtime drops cleanly afterward
        assert!(rt.run("table_cost", &inputs).is_ok());
    }
}
