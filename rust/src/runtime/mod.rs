//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `make artifacts` (python, build-time only) writes `artifacts/*.hlo.txt`
//! plus `manifest.txt`; this module parses the manifest, lazily compiles
//! each artifact on the PJRT CPU client on first use, and provides typed
//! tensor packing helpers. HLO *text* is the interchange format — the
//! crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids.

mod manifest;
mod tensor;

pub use manifest::{Artifact, Manifest, Segment};
pub use tensor::{to_f32_vec, TensorF32, TensorI32};

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::Rng;

/// Lazily-compiling executor over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("DREAMSHARD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let art = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute an artifact: literals in, tuple-decomposed literals out
    /// (everything is lowered with `return_tuple=True`).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let out = exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Initialize a flat parameter vector for a registered network,
    /// drawing each segment uniform(-bound, bound) (PyTorch Linear init).
    pub fn init_params(&self, net: &str, rng: &mut Rng) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .params
            .get(net)
            .ok_or_else(|| anyhow!("network {net} not in manifest"))?;
        let mut theta = vec![0.0f32; info.total];
        for seg in &info.segments {
            for x in &mut theta[seg.offset..seg.offset + seg.len] {
                *x = (rng.uniform(-seg.bound as f64, seg.bound as f64)) as f32;
            }
        }
        Ok(theta)
    }

    /// Number of artifacts compiled so far (for tests/metrics).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            Some(Runtime::open(dir).expect("open runtime"))
        } else {
            None // artifacts not built; skip (CI runs `make artifacts` first)
        }
    }

    #[test]
    fn manifest_has_core_artifacts() {
        let Some(rt) = runtime() else { return };
        for name in ["cost_fwd_d4s48", "policy_fwd_d4s48", "cost_train_d4s48", "table_cost"] {
            assert!(rt.manifest.artifacts.contains_key(name), "missing {name}");
        }
        assert!(rt.manifest.params.contains_key("cost"));
        assert!(rt.manifest.params.contains_key("policy"));
    }

    #[test]
    fn init_params_within_bounds() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let info = &rt.manifest.params["cost"];
        assert_eq!(theta.len(), info.total);
        for seg in &info.segments {
            for &x in &theta[seg.offset..seg.offset + seg.len] {
                assert!(x.abs() <= seg.bound + 1e-6);
            }
        }
    }

    #[test]
    fn executes_table_cost() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let feats = TensorF32::zeros(&[n, f]);
        let fmask = TensorF32::ones(&[f]);
        let out = rt
            .run("table_cost", &[
                TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).literal(),
                feats.literal(),
                fmask.literal(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(v.len(), n);
    }
}
