//! Network-execution runtime behind a pluggable [`Backend`] seam.
//!
//! The coordinator only ever calls `Runtime::run(artifact_name, inputs)`
//! with host [`Value`] tensors; what executes underneath is a backend:
//!
//! * [`ReferenceBackend`] (default, always available) — pure-Rust
//!   forward/backward evaluation of the cost / policy / RNN networks,
//!   mirroring `python/compile/model.py`. It synthesizes its own
//!   [`Manifest`] (same parameter layouts and artifact-variant grid that
//!   `make artifacts` bakes), so no `artifacts/` directory is needed.
//! * `XlaBackend` (`--features xla`) — parses `artifacts/manifest.txt`
//!   produced by `make artifacts`, lazily JIT-compiles each HLO-text
//!   artifact on the PJRT CPU client, and executes it. HLO *text* is the
//!   interchange format — xla_extension 0.5.1 rejects jax>=0.5 serialized
//!   protos (64-bit instruction ids), while the text parser reassigns ids.
//!
//! The artifact *names* (`cost_fwd_d4s48`, `policy_train_d4s48_b512`, ...)
//! are the contract both backends implement; the manifest carries their
//! baked shape metadata either way.

mod manifest;
#[cfg(feature = "xla")]
mod pjrt;
pub mod reference;
mod tensor;

pub use manifest::{Artifact, Manifest, ParamInfo, Segment};
#[cfg(feature = "xla")]
pub use pjrt::XlaBackend;
pub use reference::ReferenceBackend;
pub use tensor::{to_f32_vec, TensorF32, TensorI32, Value};

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::error::Result;
use crate::util::Rng;
use crate::{bail, err};

/// One network-execution engine. `execute` runs an artifact by manifest
/// name: values in, tuple-decomposed values out (everything is lowered
/// with `return_tuple=True`, and the reference backend matches that
/// calling convention).
///
/// Output contract: element order and total length are guaranteed;
/// output `dims()` are advisory only (the XLA backend returns flattened
/// rank-1 values, the reference backend returns shaped ones). Consume
/// outputs through [`to_f32_vec`]-style length-checked extraction.
pub trait Backend {
    /// Short human-readable backend name (for logs / `dreamshard info`).
    fn name(&self) -> &'static str;

    /// Execute an artifact.
    fn execute(&self, artifact: &str, inputs: &[Value]) -> Result<Vec<Value>>;
}

/// Executor facade over a [`Backend`] + its [`Manifest`].
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Executions dispatched through [`Runtime::run`] (see [`Runtime::run_count`]).
    calls: AtomicU64,
    /// Per-artifact execution counts (see [`Runtime::run_count_for`]).
    calls_named: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    /// The pure-Rust reference backend (no artifacts, no native code).
    pub fn reference() -> Self {
        Runtime {
            manifest: reference::reference_manifest(),
            backend: Box::new(ReferenceBackend::new()),
            calls: AtomicU64::new(0),
            calls_named: Mutex::new(HashMap::new()),
        }
    }

    /// Open an artifact directory produced by `make artifacts` on the XLA
    /// backend. Requires `--features xla` (and a real xla-rs in place of
    /// the in-tree stub).
    #[cfg(feature = "xla")]
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        use crate::util::error::Context;
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse_file(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let backend = XlaBackend::new(dir, &manifest)?;
        Ok(Runtime {
            manifest,
            backend: Box::new(backend),
            calls: AtomicU64::new(0),
            calls_named: Mutex::new(HashMap::new()),
        })
    }

    /// Without the `xla` feature there is nothing to open: artifacts are
    /// an XLA-backend concept. Kept so callers get a useful error instead
    /// of a compile break when the feature is off.
    #[cfg(not(feature = "xla"))]
    pub fn open<P: AsRef<Path>>(_dir: P) -> Result<Self> {
        bail!(
            "this build has no XLA backend (rebuild with `--features xla`); \
             use Runtime::reference() / open_default() instead"
        )
    }

    /// Default runtime: the XLA backend when it is compiled in *and* its
    /// artifacts exist (`DREAMSHARD_ARTIFACTS`, default `artifacts/`),
    /// otherwise the reference backend.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("DREAMSHARD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        if cfg!(feature = "xla") && Path::new(&dir).join("manifest.txt").exists() {
            return Self::open(dir);
        }
        Ok(Self::reference())
    }

    /// Which backend this runtime executes on.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execute an artifact by manifest name.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        if !self.manifest.artifacts.contains_key(name) {
            bail!("artifact {name} not in manifest");
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        {
            // allocate the key only the first time an artifact is seen
            let mut named = self.calls_named.lock().unwrap();
            match named.get_mut(name) {
                Some(count) => *count += 1,
                None => {
                    named.insert(name.to_string(), 1);
                }
            }
        }
        self.backend
            .execute(name, inputs)
            .map_err(|e| e.wrap(format!("executing {name} on {}", self.backend.name())))
    }

    /// Total artifact executions dispatched through [`Runtime::run`] so
    /// far. Diagnostics counter: the lane-batching tests use deltas of it
    /// to assert the one-backend-call-per-MDP-step contract.
    pub fn run_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Executions of one specific artifact so far. The serving tests use
    /// deltas of it to pin the chunk-batched `table_cost` call budget
    /// (`ceil(total_tables / N_cap)` per drained chunk).
    pub fn run_count_for(&self, name: &str) -> u64 {
        self.calls_named.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Initialize a flat parameter vector for a registered network,
    /// drawing each segment uniform(-bound, bound) (PyTorch Linear init).
    pub fn init_params(&self, net: &str, rng: &mut Rng) -> Result<Vec<f32>> {
        let info = self
            .manifest
            .params
            .get(net)
            .ok_or_else(|| err!("network {net} not in manifest"))?;
        let mut theta = vec![0.0f32; info.total];
        for seg in &info.segments {
            for x in &mut theta[seg.offset..seg.offset + seg.len] {
                *x = (rng.uniform(-seg.bound as f64, seg.bound as f64)) as f32;
            }
        }
        Ok(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_manifest_has_core_artifacts() {
        let rt = Runtime::reference();
        for name in ["cost_fwd_d4s48", "policy_fwd_d4s48", "cost_train_d4s48", "table_cost"] {
            assert!(rt.manifest.artifacts.contains_key(name), "missing {name}");
        }
        assert!(rt.manifest.params.contains_key("cost"));
        assert!(rt.manifest.params.contains_key("policy"));
        assert_eq!(rt.backend_name(), "reference");
    }

    #[test]
    fn init_params_within_bounds() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let info = &rt.manifest.params["cost"];
        assert_eq!(theta.len(), info.total);
        for seg in &info.segments {
            for &x in &theta[seg.offset..seg.offset + seg.len] {
                assert!(x.abs() <= seg.bound + 1e-6);
            }
        }
    }

    #[test]
    fn executes_table_cost() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let feats = TensorF32::zeros(&[n, f]);
        let fmask = TensorF32::ones(&[f]);
        let out = rt
            .run("table_cost", &[
                TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
                feats.value(),
                fmask.value(),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        let v = to_f32_vec(&out[0], n).unwrap();
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn unknown_artifact_is_an_error() {
        let rt = Runtime::reference();
        assert!(rt.run("no_such_artifact", &[]).is_err());
        // a failed dispatch (unknown name) is not counted
        assert_eq!(rt.run_count(), 0);
        assert_eq!(rt.run_count_for("no_such_artifact"), 0);
    }

    #[test]
    fn per_artifact_counter_tracks_dispatches() {
        let rt = Runtime::reference();
        let mut rng = Rng::new(0);
        let theta = rt.init_params("cost", &mut rng).unwrap();
        let n = rt.manifest.artifact_meta("table_cost", "N").unwrap() as usize;
        let f = rt.manifest.consts["F"] as usize;
        let inputs = [
            TensorF32::from_vec(theta, &[rt.manifest.params["cost"].total]).value(),
            TensorF32::zeros(&[n, f]).value(),
            TensorF32::ones(&[f]).value(),
        ];
        assert_eq!(rt.run_count_for("table_cost"), 0);
        rt.run("table_cost", &inputs).unwrap();
        rt.run("table_cost", &inputs).unwrap();
        assert_eq!(rt.run_count_for("table_cost"), 2);
        assert_eq!(rt.run_count_for("cost_fwd_d4s48"), 0);
        assert_eq!(rt.run_count(), 2);
    }
}
