//! Parser for the whitespace-separated `manifest.txt` emitted by
//! `python -m compile.aot` (see that module's docstring for the grammar).

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{Context, Result};
use crate::{bail, err};

/// One named parameter slice inside a network's flat parameter vector.
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub len: usize,
    /// Uniform init bound (PyTorch Linear default: 1/sqrt(fan_in)).
    pub bound: f32,
}

/// Flat-parameter layout of one network.
#[derive(Clone, Debug, Default)]
pub struct ParamInfo {
    pub total: usize,
    pub segments: Vec<Segment>,
}

/// One lowered HLO artifact and its baked shape metadata.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub file: String,
    pub meta: HashMap<String, String>,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub consts: HashMap<String, i64>,
    pub params: HashMap<String, ParamInfo>,
    pub artifacts: HashMap<String, Artifact>,
    pub dlrm_hash: Vec<u64>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let ctx = || format!("manifest line {}: {line}", lineno + 1);
            let kind = it.next().ok_or_else(|| err!(ctx()))?;
            match kind {
                "const" => {
                    let k = it.next().ok_or_else(|| err!(ctx()))?;
                    let v: i64 = it.next().ok_or_else(|| err!(ctx()))?.parse().with_context(ctx)?;
                    m.consts.insert(k.to_string(), v);
                }
                "params" => {
                    let net = it.next().ok_or_else(|| err!(ctx()))?;
                    let total: usize =
                        it.next().ok_or_else(|| err!(ctx()))?.parse().with_context(ctx)?;
                    m.params.entry(net.to_string()).or_default().total = total;
                }
                "segment" => {
                    let net = it.next().ok_or_else(|| err!(ctx()))?.to_string();
                    let name = it.next().ok_or_else(|| err!(ctx()))?.to_string();
                    let offset: usize =
                        it.next().ok_or_else(|| err!(ctx()))?.parse().with_context(ctx)?;
                    let len: usize =
                        it.next().ok_or_else(|| err!(ctx()))?.parse().with_context(ctx)?;
                    let bound: f32 =
                        it.next().ok_or_else(|| err!(ctx()))?.parse().with_context(ctx)?;
                    m.params
                        .entry(net)
                        .or_default()
                        .segments
                        .push(Segment { name, offset, len, bound });
                }
                "dlrm_hash" => {
                    m.dlrm_hash = it.map(|v| v.parse().unwrap_or(0)).collect();
                }
                "artifact" => {
                    let name = it.next().ok_or_else(|| err!(ctx()))?.to_string();
                    let file = it.next().ok_or_else(|| err!(ctx()))?.to_string();
                    let mut meta = HashMap::new();
                    for kv in it {
                        if let Some((k, v)) = kv.split_once('=') {
                            meta.insert(k.to_string(), v.to_string());
                        }
                    }
                    m.artifacts.insert(name, Artifact { file, meta });
                }
                other => bail!("unknown manifest record `{other}` at line {}", lineno + 1),
            }
        }
        if m.artifacts.is_empty() {
            bail!("manifest contains no artifacts");
        }
        Ok(m)
    }

    pub fn parse_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    /// Integer metadata of an artifact (e.g. the baked `D`, `S`, `B`).
    pub fn artifact_meta(&self, artifact: &str, key: &str) -> Option<i64> {
        self.artifacts.get(artifact)?.meta.get(key)?.parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
const F 21
params cost 100
segment cost tbl1.w 0 80 0.21821789
segment cost tbl1.b 80 20 0.21821789
dlrm_hash 1000 2000
artifact cost_fwd_d4s48 cost_fwd_d4s48.hlo.txt E=16 D=4 S=48
";

    #[test]
    fn parses_all_records() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.consts["F"], 21);
        assert_eq!(m.params["cost"].total, 100);
        assert_eq!(m.params["cost"].segments.len(), 2);
        assert_eq!(m.params["cost"].segments[1].offset, 80);
        assert_eq!(m.dlrm_hash, vec![1000, 2000]);
        assert_eq!(m.artifact_meta("cost_fwd_d4s48", "D"), Some(4));
        assert_eq!(m.artifacts["cost_fwd_d4s48"].file, "cost_fwd_d4s48.hlo.txt");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line here\n").is_err());
        assert!(Manifest::parse("const F 21\n").is_err(), "no artifacts");
    }

    #[test]
    fn segments_cover_total() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let info = &m.params["cost"];
        let covered: usize = info.segments.iter().map(|s| s.len).sum();
        assert_eq!(covered, info.total);
    }
}
