//! The placement MDP (paper section 3.1): tables are placed one-by-one;
//! the state is the per-device table sets (augmented with cost features),
//! the action is a device id, the final reward is the negative overall
//! cost. Legal actions enforce the memory cap and the padded slot count.
//!
//! The same state machine backs both MDP flavours: the **estimated** MDP
//! (cost features + reward from the cost network — no simulator calls)
//! and the **real** MDP (simulator-backed, used for data collection, the
//! RNN baseline, and the Fig. 8 with/without-estimation comparison).

use crate::bail;
use crate::sim::Simulator;
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::Result;

/// One in-flight placement episode.
#[derive(Clone, Debug)]
pub struct PlacementState<'a> {
    pub ds: &'a Dataset,
    pub task: &'a Task,
    /// Order in which tables are placed: indices into `task.table_ids`,
    /// sorted descending by (predicted) single-table cost (section B.4.2).
    pub order: Vec<usize>,
    /// Per-device lists of already-placed indices (into `task.table_ids`).
    pub groups: Vec<Vec<usize>>,
    /// `placement[i]` = device of `task.table_ids[i]` (usize::MAX = unplaced).
    pub placement: Vec<usize>,
    pub step: usize,
    /// Max tables per device (the AOT slot count `S`).
    pub max_slots: usize,
    /// Previous device of each table (`usize::MAX` = none). Present only
    /// in warm-started states ([`PlacementState::warm_start`]), where it
    /// drives the per-step "stay" legality bias once the discretionary
    /// move budget is spent.
    pub prev: Option<Vec<usize>>,
    /// Discretionary moves still allowed: decremented when `apply` sends
    /// a table anywhere but its (still valid) previous device. Forced
    /// moves — no previous device, or a device the task no longer has —
    /// are exempt. `usize::MAX` (the cold-start value) = unlimited.
    pub moves_left: usize,
}

impl<'a> PlacementState<'a> {
    pub fn new(ds: &'a Dataset, task: &'a Task, order: Vec<usize>, max_slots: usize) -> Self {
        assert_eq!(order.len(), task.n_tables());
        PlacementState {
            ds,
            task,
            order,
            groups: vec![vec![]; task.n_devices],
            placement: vec![usize::MAX; task.n_tables()],
            step: 0,
            max_slots,
            prev: None,
            moves_left: usize::MAX,
        }
    }

    /// Warm-start an episode from a prior assignment: every table index
    /// NOT in `order` is pinned to its previous device (`prev[i]`, which
    /// must be valid for pinned tables), and only the tables in `order`
    /// are rolled out. `max_moves` bounds *discretionary* re-placements:
    /// once spent, a table whose previous device is still legal sees its
    /// action mask collapse to that device alone ("stay" bias), so a
    /// rollout can express "move at most K tables". Forced moves (prev =
    /// `usize::MAX` or a device `>= n_devices`) never consume budget.
    ///
    /// With `order` covering all tables and `prev` all-`usize::MAX`, the
    /// state evolves bit-identically to [`PlacementState::new`].
    pub fn warm_start(
        ds: &'a Dataset,
        task: &'a Task,
        order: Vec<usize>,
        max_slots: usize,
        prev: Vec<usize>,
        max_moves: usize,
    ) -> Self {
        assert_eq!(prev.len(), task.n_tables());
        assert!(order.len() <= task.n_tables());
        let mut st = PlacementState {
            ds,
            task,
            order,
            groups: vec![vec![]; task.n_devices],
            placement: vec![usize::MAX; task.n_tables()],
            step: 0,
            max_slots,
            prev: None,
            moves_left: max_moves,
        };
        let mut in_order = vec![false; task.n_tables()];
        for &i in &st.order {
            assert!(!in_order[i], "duplicate index {i} in warm-start order");
            in_order[i] = true;
        }
        for i in 0..task.n_tables() {
            if !in_order[i] {
                let p = prev[i];
                assert!(p < task.n_devices, "pinned table {i} has no valid previous device");
                st.groups[p].push(i);
                st.placement[i] = p;
            }
        }
        st.prev = Some(prev);
        st
    }

    pub fn done(&self) -> bool {
        self.step >= self.order.len()
    }

    /// Index (into `task.table_ids`) of the table being placed now.
    pub fn current(&self) -> usize {
        self.order[self.step]
    }

    /// Whether any device can legally take the current table. `legal()`
    /// can be all-false (memory cap + slot cap): callers must either
    /// check this or use [`PlacementState::fallback_device`].
    pub fn any_legal(&self, sim: &Simulator) -> bool {
        self.legal(sim).iter().any(|&ok| ok)
    }

    /// Dead-end fallback: the least-loaded (by memory) device that still
    /// has a free slot, ignoring the memory cap — the resulting placement
    /// may then exceed the cap, which the caller surfaces via the
    /// simulator's memory accounting. `None` only when every device's
    /// slots are full (no placement of this episode can be completed).
    pub fn fallback_device(&self) -> Option<usize> {
        let mem = |dev: usize| -> f64 {
            let tables: Vec<&crate::tables::Table> = self.groups[dev]
                .iter()
                .map(|&i| &self.ds.tables[self.task.table_ids[i]])
                .collect();
            Simulator::mem_gb(&tables)
        };
        (0..self.task.n_devices)
            .filter(|&d| self.groups[d].len() < self.max_slots)
            .min_by(|&a, &b| mem(a).total_cmp(&mem(b)))
    }

    /// Legal-action mask over devices: memory cap + free slot. In a
    /// warm-started state with the move budget spent, the mask of a table
    /// whose previous device is still legal collapses to that device
    /// alone (the "stay" bias); if staying is itself illegal the table is
    /// a forced move and the full mask applies.
    pub fn legal(&self, sim: &Simulator) -> Vec<bool> {
        let t = &self.ds.tables[self.task.table_ids[self.current()]];
        let mut mask: Vec<bool> = (0..self.task.n_devices)
            .map(|d| {
                if self.groups[d].len() >= self.max_slots {
                    return false;
                }
                let tables: Vec<&crate::tables::Table> = self.groups[d]
                    .iter()
                    .map(|&i| &self.ds.tables[self.task.table_ids[i]])
                    .collect();
                sim.fits(&tables, t)
            })
            .collect();
        if self.moves_left == 0 {
            if let Some(prev) = &self.prev {
                let p = prev[self.current()];
                if p < self.task.n_devices && mask[p] {
                    for (d, m) in mask.iter_mut().enumerate() {
                        *m = d == p;
                    }
                }
            }
        }
        mask
    }

    /// Apply an action (device id) for the current table. A discretionary
    /// deviation from a still-valid previous device consumes one unit of
    /// the move budget (saturating — cold-start states never run out).
    pub fn apply(&mut self, device: usize) {
        assert!(!self.done());
        assert!(device < self.task.n_devices);
        let idx = self.current();
        if let Some(prev) = &self.prev {
            let p = prev[idx];
            if p < self.task.n_devices && device != p {
                self.moves_left = self.moves_left.saturating_sub(1);
            }
        }
        self.groups[device].push(idx);
        self.placement[idx] = device;
        self.step += 1;
    }

    /// Fill one lane of a padded `[E, D, S, F]` feature batch (plus its
    /// `[E, D, S]` mask and `[E, D]` device mask) with this state.
    /// `d_cap`/`s_cap` are the artifact's baked dims (>= task dims).
    ///
    /// A device group larger than `s_cap` would silently produce wrong
    /// features, so it is a debug assertion and a (propagated) error in
    /// release builds — never a truncation.
    pub fn fill_feats(
        &self,
        lane: usize,
        d_cap: usize,
        s_cap: usize,
        feats: &mut crate::runtime::TensorF32,
        mask: &mut crate::runtime::TensorF32,
        dmask: &mut crate::runtime::TensorF32,
    ) -> Result<()> {
        assert!(self.task.n_devices <= d_cap);
        for d in 0..self.task.n_devices {
            debug_assert!(
                self.groups[d].len() <= s_cap,
                "device {d} holds {} tables > slot cap {s_cap}",
                self.groups[d].len()
            );
            if self.groups[d].len() > s_cap {
                bail!(
                    "device {d} holds {} tables, exceeding the slot cap {s_cap} \
                     (placement built against a larger variant?)",
                    self.groups[d].len()
                );
            }
            dmask.set(&[lane, d], 1.0);
            for (s, &i) in self.groups[d].iter().enumerate() {
                let f = self.ds.tables[self.task.table_ids[i]].features();
                feats.set_row(&[lane, d, s, 0], &f);
                mask.set(&[lane, d, s], 1.0);
            }
        }
        Ok(())
    }

    /// Features of the table currently being placed.
    pub fn current_features(&self) -> [f32; NUM_FEATURES] {
        self.ds.tables[self.task.table_ids[self.current()]].features()
    }

    /// Real (simulator) evaluation of the current partial placement.
    pub fn evaluate(&self, sim: &Simulator) -> crate::sim::Evaluation {
        sim.evaluate(self.ds, self.task, &self.placement)
    }
}

/// Default placement order when no cost network is available: descending
/// dim x pooling (the lookup-workload heuristic).
pub fn heuristic_order(ds: &Dataset, task: &Task) -> Vec<usize> {
    let mut order: Vec<usize> = (0..task.n_tables()).collect();
    let key = |i: &usize| {
        let t = &ds.tables[task.table_ids[*i]];
        t.dim as f64 * t.pooling as f64
    };
    // total_cmp: a NaN feature (corrupt input table) must not panic the
    // sort — NaNs order deterministically, the rest exactly as before
    order.sort_by(|a, b| key(b).total_cmp(&key(a)));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorF32;
    use crate::sim::{SimConfig, Simulator};
    use crate::tables::{gen_dlrm, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 20, 4, 1, 2).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn episode_runs_to_completion() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        let mut step = 0;
        while !st.done() {
            let legal = st.legal(&sim);
            let d = legal.iter().position(|&l| l).expect("some legal action");
            st.apply(d);
            step += 1;
        }
        assert_eq!(step, 20);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        let eval = st.evaluate(&sim);
        assert!(eval.latency > 0.0);
    }

    #[test]
    fn slot_cap_limits_actions() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 3);
        // stuff device 0 with 3 tables -> no longer legal
        for _ in 0..3 {
            st.apply(0);
        }
        let legal = st.legal(&sim);
        assert!(!legal[0]);
        assert!(legal[1]);
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic)]
    fn fill_feats_rejects_slot_overflow() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        for _ in 0..4 {
            st.apply(0); // 4 tables on device 0
        }
        let mut feats = TensorF32::zeros(&[1, 4, 2, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[1, 4, 2]);
        let mut dmask = TensorF32::zeros(&[1, 4]);
        // s_cap = 2 < 4 held: debug builds assert, release builds error
        let r = st.fill_feats(0, 4, 2, &mut feats, &mut mask, &mut dmask);
        assert!(r.is_err());
    }

    #[test]
    fn dead_end_falls_back_to_least_loaded() {
        let (ds, task, _) = setup();
        // a memory cap so tiny that nothing is ever legal
        let sim = Simulator::new(SimConfig { mem_cap_gb: 1e-6, ..SimConfig::default() });
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        let mut steps = 0;
        while !st.done() {
            assert!(!st.any_legal(&sim), "cap must forbid everything");
            let d = st.fallback_device().expect("slots are plentiful");
            st.apply(d);
            steps += 1;
        }
        assert_eq!(steps, 20);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        // slot-cap exhaustion is the only None case
        let mut tiny = PlacementState::new(&ds, &task, heuristic_order(&ds, &task), 5);
        for _ in 0..20 {
            match tiny.fallback_device() {
                Some(d) => tiny.apply(d),
                None => break,
            }
        }
        assert!(tiny.fallback_device().is_none(), "4 devices x 5 slots = 20 all full");
        // and the fallback spread the load while slots lasted
        assert!(tiny.groups.iter().all(|g| g.len() == 5));
    }

    #[test]
    fn warm_start_pins_tables_outside_the_order() {
        let (ds, task, sim) = setup();
        let prev: Vec<usize> = (0..20).map(|i| i % 4).collect();
        // re-place only tables 3 and 7; everything else stays pinned
        let mut st = PlacementState::warm_start(&ds, &task, vec![3, 7], 48, prev.clone(), usize::MAX);
        for i in 0..20 {
            if i == 3 || i == 7 {
                assert_eq!(st.placement[i], usize::MAX, "table {i} must await the rollout");
            } else {
                assert_eq!(st.placement[i], prev[i], "table {i} must be pinned");
            }
        }
        assert!(!st.done());
        while !st.done() {
            let legal = st.legal(&sim);
            let d = legal.iter().position(|&l| l).expect("some legal action");
            st.apply(d);
        }
        assert_eq!(st.step, 2);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        // pinned groups feed fill_feats like any mid-episode state
        let mut feats = TensorF32::zeros(&[1, 4, 48, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[1, 4, 48]);
        let mut dmask = TensorF32::zeros(&[1, 4]);
        st.fill_feats(0, 4, 48, &mut feats, &mut mask, &mut dmask).unwrap();
        assert_eq!(mask.get(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn spent_budget_collapses_mask_to_stay() {
        let (ds, task, sim) = setup();
        let prev: Vec<usize> = (0..20).map(|i| i % 4).collect();
        let order: Vec<usize> = (0..20).collect();
        let mut st = PlacementState::warm_start(&ds, &task, order, 48, prev.clone(), 1);
        // first table: full mask (budget not yet spent)
        assert!(st.legal(&sim).iter().filter(|&&m| m).count() > 1);
        // spend the single move: deviate from prev
        let dev = (prev[st.current()] + 1) % 4;
        st.apply(dev);
        assert_eq!(st.moves_left, 0);
        // every later table with a legal prev device must now stay put
        while !st.done() {
            let cur = st.current();
            let legal = st.legal(&sim);
            assert_eq!(
                legal.iter().filter(|&&m| m).count(),
                1,
                "stay bias must pin table {cur}"
            );
            assert!(legal[prev[cur]]);
            let d = legal.iter().position(|&l| l).unwrap();
            st.apply(d);
        }
        // exactly one table ended up off its previous device
        let moved = st.placement.iter().zip(&prev).filter(|(a, b)| a != b).count();
        assert_eq!(moved, 1);
    }

    #[test]
    fn forced_and_stay_moves_never_consume_budget() {
        let (ds, task, _) = setup();
        // prev has no placement for table 0 (forced) and a lost device
        // for table 1 (also forced)
        let mut prev: Vec<usize> = (0..20).map(|i| i % 4).collect();
        prev[0] = usize::MAX;
        prev[1] = 9; // >= n_devices: device lost
        let order: Vec<usize> = (0..20).collect();
        let mut st = PlacementState::warm_start(&ds, &task, order, 48, prev.clone(), 2);
        st.apply(2); // forced (no prior): free
        st.apply(3); // forced (lost device): free
        assert_eq!(st.moves_left, 2);
        st.apply(prev[2]); // staying put: free
        assert_eq!(st.moves_left, 2);
        st.apply((prev[3] + 1) % 4); // discretionary deviation: pays
        assert_eq!(st.moves_left, 1);
    }

    #[test]
    fn warm_start_with_vacant_prev_matches_cold_start() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut cold = PlacementState::new(&ds, &task, order.clone(), 48);
        let mut warm =
            PlacementState::warm_start(&ds, &task, order, 48, vec![usize::MAX; 20], usize::MAX);
        while !cold.done() {
            assert_eq!(cold.legal(&sim), warm.legal(&sim));
            let d = cold.legal(&sim).iter().position(|&l| l).unwrap();
            cold.apply(d);
            warm.apply(d);
        }
        assert_eq!(cold.placement, warm.placement);
        assert_eq!(cold.groups, warm.groups);
    }

    #[test]
    fn heuristic_order_survives_nan_features() {
        let (mut ds, task, _) = setup();
        let id = task.table_ids[3];
        ds.tables[id].pooling = f32::NAN;
        let order = heuristic_order(&ds, &task);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>(), "still a permutation");
    }

    #[test]
    fn heuristic_order_is_descending() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let cost = |i: usize| {
            let t = &ds.tables[task.table_ids[i]];
            t.dim as f64 * t.pooling as f64
        };
        for w in order.windows(2) {
            assert!(cost(w[0]) >= cost(w[1]));
        }
    }

    #[test]
    fn fill_feats_pads_correctly() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        st.apply(1);
        st.apply(1);
        st.apply(0);
        let (e, d_cap, s_cap) = (2, 8, 48);
        let mut feats = TensorF32::zeros(&[e, d_cap, s_cap, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[e, d_cap, s_cap]);
        let mut dmask = TensorF32::zeros(&[e, d_cap]);
        st.fill_feats(1, d_cap, s_cap, &mut feats, &mut mask, &mut dmask).unwrap();
        // lane 0 untouched
        assert_eq!(mask.get(&[0, 1, 0]), 0.0);
        // lane 1: device 1 has 2 tables, device 0 has 1
        assert_eq!(mask.get(&[1, 1, 0]), 1.0);
        assert_eq!(mask.get(&[1, 1, 1]), 1.0);
        assert_eq!(mask.get(&[1, 1, 2]), 0.0);
        assert_eq!(mask.get(&[1, 0, 0]), 1.0);
        // devices beyond the task are masked out
        assert_eq!(dmask.get(&[1, 4]), 0.0);
        assert_eq!(dmask.get(&[1, 0]), 1.0);
        // features actually written
        assert!(feats.get(&[1, 1, 0, 0]) > 0.0);
    }
}
