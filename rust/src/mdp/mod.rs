//! The placement MDP (paper section 3.1): tables are placed one-by-one;
//! the state is the per-device table sets (augmented with cost features),
//! the action is a device id, the final reward is the negative overall
//! cost. Legal actions enforce the memory cap and the padded slot count.
//!
//! The same state machine backs both MDP flavours: the **estimated** MDP
//! (cost features + reward from the cost network — no simulator calls)
//! and the **real** MDP (simulator-backed, used for data collection, the
//! RNN baseline, and the Fig. 8 with/without-estimation comparison).

use crate::sim::Simulator;
use crate::tables::{Dataset, Task, NUM_FEATURES};

/// One in-flight placement episode.
#[derive(Clone, Debug)]
pub struct PlacementState<'a> {
    pub ds: &'a Dataset,
    pub task: &'a Task,
    /// Order in which tables are placed: indices into `task.table_ids`,
    /// sorted descending by (predicted) single-table cost (section B.4.2).
    pub order: Vec<usize>,
    /// Per-device lists of already-placed indices (into `task.table_ids`).
    pub groups: Vec<Vec<usize>>,
    /// `placement[i]` = device of `task.table_ids[i]` (usize::MAX = unplaced).
    pub placement: Vec<usize>,
    pub step: usize,
    /// Max tables per device (the AOT slot count `S`).
    pub max_slots: usize,
}

impl<'a> PlacementState<'a> {
    pub fn new(ds: &'a Dataset, task: &'a Task, order: Vec<usize>, max_slots: usize) -> Self {
        assert_eq!(order.len(), task.n_tables());
        PlacementState {
            ds,
            task,
            order,
            groups: vec![vec![]; task.n_devices],
            placement: vec![usize::MAX; task.n_tables()],
            step: 0,
            max_slots,
        }
    }

    pub fn done(&self) -> bool {
        self.step >= self.order.len()
    }

    /// Index (into `task.table_ids`) of the table being placed now.
    pub fn current(&self) -> usize {
        self.order[self.step]
    }

    /// Legal-action mask over devices: memory cap + free slot.
    pub fn legal(&self, sim: &Simulator) -> Vec<bool> {
        let t = &self.ds.tables[self.task.table_ids[self.current()]];
        (0..self.task.n_devices)
            .map(|d| {
                if self.groups[d].len() >= self.max_slots {
                    return false;
                }
                let tables: Vec<&crate::tables::Table> = self.groups[d]
                    .iter()
                    .map(|&i| &self.ds.tables[self.task.table_ids[i]])
                    .collect();
                sim.fits(&tables, t)
            })
            .collect()
    }

    /// Apply an action (device id) for the current table.
    pub fn apply(&mut self, device: usize) {
        assert!(!self.done());
        assert!(device < self.task.n_devices);
        let idx = self.current();
        self.groups[device].push(idx);
        self.placement[idx] = device;
        self.step += 1;
    }

    /// Fill one lane of a padded `[E, D, S, F]` feature batch (plus its
    /// `[E, D, S]` mask and `[E, D]` device mask) with this state.
    /// `d_cap`/`s_cap` are the artifact's baked dims (>= task dims).
    pub fn fill_feats(
        &self,
        lane: usize,
        d_cap: usize,
        s_cap: usize,
        feats: &mut crate::runtime::TensorF32,
        mask: &mut crate::runtime::TensorF32,
        dmask: &mut crate::runtime::TensorF32,
    ) {
        assert!(self.task.n_devices <= d_cap);
        for d in 0..self.task.n_devices {
            dmask.set(&[lane, d], 1.0);
            for (s, &i) in self.groups[d].iter().enumerate().take(s_cap) {
                let f = self.ds.tables[self.task.table_ids[i]].features();
                feats.set_row(&[lane, d, s, 0], &f);
                mask.set(&[lane, d, s], 1.0);
            }
        }
    }

    /// Features of the table currently being placed.
    pub fn current_features(&self) -> [f32; NUM_FEATURES] {
        self.ds.tables[self.task.table_ids[self.current()]].features()
    }

    /// Real (simulator) evaluation of the current partial placement.
    pub fn evaluate(&self, sim: &Simulator) -> crate::sim::Evaluation {
        sim.evaluate(self.ds, self.task, &self.placement)
    }
}

/// Default placement order when no cost network is available: descending
/// dim x pooling (the lookup-workload heuristic).
pub fn heuristic_order(ds: &Dataset, task: &Task) -> Vec<usize> {
    let mut order: Vec<usize> = (0..task.n_tables()).collect();
    let key = |i: &usize| {
        let t = &ds.tables[task.table_ids[*i]];
        t.dim as f64 * t.pooling as f64
    };
    order.sort_by(|a, b| key(b).partial_cmp(&key(a)).unwrap());
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorF32;
    use crate::sim::{SimConfig, Simulator};
    use crate::tables::{gen_dlrm, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 20, 4, 1, 2).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn episode_runs_to_completion() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        let mut step = 0;
        while !st.done() {
            let legal = st.legal(&sim);
            let d = legal.iter().position(|&l| l).expect("some legal action");
            st.apply(d);
            step += 1;
        }
        assert_eq!(step, 20);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        let eval = st.evaluate(&sim);
        assert!(eval.latency > 0.0);
    }

    #[test]
    fn slot_cap_limits_actions() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 3);
        // stuff device 0 with 3 tables -> no longer legal
        for _ in 0..3 {
            st.apply(0);
        }
        let legal = st.legal(&sim);
        assert!(!legal[0]);
        assert!(legal[1]);
    }

    #[test]
    fn heuristic_order_is_descending() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let cost = |i: usize| {
            let t = &ds.tables[task.table_ids[i]];
            t.dim as f64 * t.pooling as f64
        };
        for w in order.windows(2) {
            assert!(cost(w[0]) >= cost(w[1]));
        }
    }

    #[test]
    fn fill_feats_pads_correctly() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        st.apply(1);
        st.apply(1);
        st.apply(0);
        let (e, d_cap, s_cap) = (2, 8, 48);
        let mut feats = TensorF32::zeros(&[e, d_cap, s_cap, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[e, d_cap, s_cap]);
        let mut dmask = TensorF32::zeros(&[e, d_cap]);
        st.fill_feats(1, d_cap, s_cap, &mut feats, &mut mask, &mut dmask);
        // lane 0 untouched
        assert_eq!(mask.get(&[0, 1, 0]), 0.0);
        // lane 1: device 1 has 2 tables, device 0 has 1
        assert_eq!(mask.get(&[1, 1, 0]), 1.0);
        assert_eq!(mask.get(&[1, 1, 1]), 1.0);
        assert_eq!(mask.get(&[1, 1, 2]), 0.0);
        assert_eq!(mask.get(&[1, 0, 0]), 1.0);
        // devices beyond the task are masked out
        assert_eq!(dmask.get(&[1, 4]), 0.0);
        assert_eq!(dmask.get(&[1, 0]), 1.0);
        // features actually written
        assert!(feats.get(&[1, 1, 0, 0]) > 0.0);
    }
}
