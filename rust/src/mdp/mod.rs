//! The placement MDP (paper section 3.1): tables are placed one-by-one;
//! the state is the per-device table sets (augmented with cost features),
//! the action is a device id, the final reward is the negative overall
//! cost. Legal actions enforce the memory cap and the padded slot count.
//!
//! The same state machine backs both MDP flavours: the **estimated** MDP
//! (cost features + reward from the cost network — no simulator calls)
//! and the **real** MDP (simulator-backed, used for data collection, the
//! RNN baseline, and the Fig. 8 with/without-estimation comparison).

use crate::bail;
use crate::sim::Simulator;
use crate::tables::{Dataset, Task, NUM_FEATURES};
use crate::util::error::Result;

/// One in-flight placement episode.
#[derive(Clone, Debug)]
pub struct PlacementState<'a> {
    pub ds: &'a Dataset,
    pub task: &'a Task,
    /// Order in which tables are placed: indices into `task.table_ids`,
    /// sorted descending by (predicted) single-table cost (section B.4.2).
    pub order: Vec<usize>,
    /// Per-device lists of already-placed indices (into `task.table_ids`).
    pub groups: Vec<Vec<usize>>,
    /// `placement[i]` = device of `task.table_ids[i]` (usize::MAX = unplaced).
    pub placement: Vec<usize>,
    pub step: usize,
    /// Max tables per device (the AOT slot count `S`).
    pub max_slots: usize,
}

impl<'a> PlacementState<'a> {
    pub fn new(ds: &'a Dataset, task: &'a Task, order: Vec<usize>, max_slots: usize) -> Self {
        assert_eq!(order.len(), task.n_tables());
        PlacementState {
            ds,
            task,
            order,
            groups: vec![vec![]; task.n_devices],
            placement: vec![usize::MAX; task.n_tables()],
            step: 0,
            max_slots,
        }
    }

    pub fn done(&self) -> bool {
        self.step >= self.order.len()
    }

    /// Index (into `task.table_ids`) of the table being placed now.
    pub fn current(&self) -> usize {
        self.order[self.step]
    }

    /// Whether any device can legally take the current table. `legal()`
    /// can be all-false (memory cap + slot cap): callers must either
    /// check this or use [`PlacementState::fallback_device`].
    pub fn any_legal(&self, sim: &Simulator) -> bool {
        self.legal(sim).iter().any(|&ok| ok)
    }

    /// Dead-end fallback: the least-loaded (by memory) device that still
    /// has a free slot, ignoring the memory cap — the resulting placement
    /// may then exceed the cap, which the caller surfaces via the
    /// simulator's memory accounting. `None` only when every device's
    /// slots are full (no placement of this episode can be completed).
    pub fn fallback_device(&self) -> Option<usize> {
        let mem = |dev: usize| -> f64 {
            let tables: Vec<&crate::tables::Table> = self.groups[dev]
                .iter()
                .map(|&i| &self.ds.tables[self.task.table_ids[i]])
                .collect();
            Simulator::mem_gb(&tables)
        };
        (0..self.task.n_devices)
            .filter(|&d| self.groups[d].len() < self.max_slots)
            .min_by(|&a, &b| mem(a).total_cmp(&mem(b)))
    }

    /// Legal-action mask over devices: memory cap + free slot.
    pub fn legal(&self, sim: &Simulator) -> Vec<bool> {
        let t = &self.ds.tables[self.task.table_ids[self.current()]];
        (0..self.task.n_devices)
            .map(|d| {
                if self.groups[d].len() >= self.max_slots {
                    return false;
                }
                let tables: Vec<&crate::tables::Table> = self.groups[d]
                    .iter()
                    .map(|&i| &self.ds.tables[self.task.table_ids[i]])
                    .collect();
                sim.fits(&tables, t)
            })
            .collect()
    }

    /// Apply an action (device id) for the current table.
    pub fn apply(&mut self, device: usize) {
        assert!(!self.done());
        assert!(device < self.task.n_devices);
        let idx = self.current();
        self.groups[device].push(idx);
        self.placement[idx] = device;
        self.step += 1;
    }

    /// Fill one lane of a padded `[E, D, S, F]` feature batch (plus its
    /// `[E, D, S]` mask and `[E, D]` device mask) with this state.
    /// `d_cap`/`s_cap` are the artifact's baked dims (>= task dims).
    ///
    /// A device group larger than `s_cap` would silently produce wrong
    /// features, so it is a debug assertion and a (propagated) error in
    /// release builds — never a truncation.
    pub fn fill_feats(
        &self,
        lane: usize,
        d_cap: usize,
        s_cap: usize,
        feats: &mut crate::runtime::TensorF32,
        mask: &mut crate::runtime::TensorF32,
        dmask: &mut crate::runtime::TensorF32,
    ) -> Result<()> {
        assert!(self.task.n_devices <= d_cap);
        for d in 0..self.task.n_devices {
            debug_assert!(
                self.groups[d].len() <= s_cap,
                "device {d} holds {} tables > slot cap {s_cap}",
                self.groups[d].len()
            );
            if self.groups[d].len() > s_cap {
                bail!(
                    "device {d} holds {} tables, exceeding the slot cap {s_cap} \
                     (placement built against a larger variant?)",
                    self.groups[d].len()
                );
            }
            dmask.set(&[lane, d], 1.0);
            for (s, &i) in self.groups[d].iter().enumerate() {
                let f = self.ds.tables[self.task.table_ids[i]].features();
                feats.set_row(&[lane, d, s, 0], &f);
                mask.set(&[lane, d, s], 1.0);
            }
        }
        Ok(())
    }

    /// Features of the table currently being placed.
    pub fn current_features(&self) -> [f32; NUM_FEATURES] {
        self.ds.tables[self.task.table_ids[self.current()]].features()
    }

    /// Real (simulator) evaluation of the current partial placement.
    pub fn evaluate(&self, sim: &Simulator) -> crate::sim::Evaluation {
        sim.evaluate(self.ds, self.task, &self.placement)
    }
}

/// Default placement order when no cost network is available: descending
/// dim x pooling (the lookup-workload heuristic).
pub fn heuristic_order(ds: &Dataset, task: &Task) -> Vec<usize> {
    let mut order: Vec<usize> = (0..task.n_tables()).collect();
    let key = |i: &usize| {
        let t = &ds.tables[task.table_ids[*i]];
        t.dim as f64 * t.pooling as f64
    };
    // total_cmp: a NaN feature (corrupt input table) must not panic the
    // sort — NaNs order deterministically, the rest exactly as before
    order.sort_by(|a, b| key(b).total_cmp(&key(a)));
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TensorF32;
    use crate::sim::{SimConfig, Simulator};
    use crate::tables::{gen_dlrm, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 20, 4, 1, 2).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn episode_runs_to_completion() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        let mut step = 0;
        while !st.done() {
            let legal = st.legal(&sim);
            let d = legal.iter().position(|&l| l).expect("some legal action");
            st.apply(d);
            step += 1;
        }
        assert_eq!(step, 20);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        let eval = st.evaluate(&sim);
        assert!(eval.latency > 0.0);
    }

    #[test]
    fn slot_cap_limits_actions() {
        let (ds, task, sim) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 3);
        // stuff device 0 with 3 tables -> no longer legal
        for _ in 0..3 {
            st.apply(0);
        }
        let legal = st.legal(&sim);
        assert!(!legal[0]);
        assert!(legal[1]);
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic)]
    fn fill_feats_rejects_slot_overflow() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        for _ in 0..4 {
            st.apply(0); // 4 tables on device 0
        }
        let mut feats = TensorF32::zeros(&[1, 4, 2, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[1, 4, 2]);
        let mut dmask = TensorF32::zeros(&[1, 4]);
        // s_cap = 2 < 4 held: debug builds assert, release builds error
        let r = st.fill_feats(0, 4, 2, &mut feats, &mut mask, &mut dmask);
        assert!(r.is_err());
    }

    #[test]
    fn dead_end_falls_back_to_least_loaded() {
        let (ds, task, _) = setup();
        // a memory cap so tiny that nothing is ever legal
        let sim = Simulator::new(SimConfig { mem_cap_gb: 1e-6, ..SimConfig::default() });
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        let mut steps = 0;
        while !st.done() {
            assert!(!st.any_legal(&sim), "cap must forbid everything");
            let d = st.fallback_device().expect("slots are plentiful");
            st.apply(d);
            steps += 1;
        }
        assert_eq!(steps, 20);
        assert!(st.placement.iter().all(|&p| p != usize::MAX));
        // slot-cap exhaustion is the only None case
        let mut tiny = PlacementState::new(&ds, &task, heuristic_order(&ds, &task), 5);
        for _ in 0..20 {
            match tiny.fallback_device() {
                Some(d) => tiny.apply(d),
                None => break,
            }
        }
        assert!(tiny.fallback_device().is_none(), "4 devices x 5 slots = 20 all full");
        // and the fallback spread the load while slots lasted
        assert!(tiny.groups.iter().all(|g| g.len() == 5));
    }

    #[test]
    fn heuristic_order_survives_nan_features() {
        let (mut ds, task, _) = setup();
        let id = task.table_ids[3];
        ds.tables[id].pooling = f32::NAN;
        let order = heuristic_order(&ds, &task);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>(), "still a permutation");
    }

    #[test]
    fn heuristic_order_is_descending() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let cost = |i: usize| {
            let t = &ds.tables[task.table_ids[i]];
            t.dim as f64 * t.pooling as f64
        };
        for w in order.windows(2) {
            assert!(cost(w[0]) >= cost(w[1]));
        }
    }

    #[test]
    fn fill_feats_pads_correctly() {
        let (ds, task, _) = setup();
        let order = heuristic_order(&ds, &task);
        let mut st = PlacementState::new(&ds, &task, order, 48);
        st.apply(1);
        st.apply(1);
        st.apply(0);
        let (e, d_cap, s_cap) = (2, 8, 48);
        let mut feats = TensorF32::zeros(&[e, d_cap, s_cap, NUM_FEATURES]);
        let mut mask = TensorF32::zeros(&[e, d_cap, s_cap]);
        let mut dmask = TensorF32::zeros(&[e, d_cap]);
        st.fill_feats(1, d_cap, s_cap, &mut feats, &mut mask, &mut dmask).unwrap();
        // lane 0 untouched
        assert_eq!(mask.get(&[0, 1, 0]), 0.0);
        // lane 1: device 1 has 2 tables, device 0 has 1
        assert_eq!(mask.get(&[1, 1, 0]), 1.0);
        assert_eq!(mask.get(&[1, 1, 1]), 1.0);
        assert_eq!(mask.get(&[1, 1, 2]), 0.0);
        assert_eq!(mask.get(&[1, 0, 0]), 1.0);
        // devices beyond the task are masked out
        assert_eq!(dmask.get(&[1, 4]), 0.0);
        assert_eq!(dmask.get(&[1, 0]), 1.0);
        // features actually written
        assert!(feats.get(&[1, 1, 0, 0]) > 0.0);
    }
}
