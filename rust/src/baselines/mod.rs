//! Placement baselines (paper section D.1): random, and the four greedy
//! human-expert strategies used in production workflows. Each expert
//! assigns every table an estimated cost, sorts descending, and places
//! each table on the device with the lowest cost sum so far, subject to
//! the memory constraint.
//!
//! These are the raw algorithms; callers normally reach them through the
//! [`crate::placer`] facade ([`crate::placer::by_name`] with `"random"` /
//! `"greedy:dim"` / ...), which also routes the MDP's slot cap into the
//! `*_capped` variants so every strategy obeys the same legality rules.

use crate::sim::Simulator;
use crate::tables::{Dataset, Table, Task};
use crate::util::Rng;

/// The cost function a greedy expert balances.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Expert {
    /// Table size (bytes): balances memory, correlates with dim x hash.
    Size,
    /// Embedding dimension: the theoretical communication workload.
    Dim,
    /// dim x pooling: the lookup computation workload.
    Lookup,
    /// dim x pooling x size: the most comprehensive hand-built estimate.
    SizeLookup,
}

pub const ALL_EXPERTS: [Expert; 4] =
    [Expert::Size, Expert::Dim, Expert::Lookup, Expert::SizeLookup];

impl Expert {
    pub fn name(&self) -> &'static str {
        match self {
            Expert::Size => "size-based",
            Expert::Dim => "dim-based",
            Expert::Lookup => "lookup-based",
            Expert::SizeLookup => "size-lookup-based",
        }
    }

    /// Short registry key: the `<key>` of the `greedy:<key>` placer name.
    pub fn key(&self) -> &'static str {
        match self {
            Expert::Size => "size",
            Expert::Dim => "dim",
            Expert::Lookup => "lookup",
            Expert::SizeLookup => "size-lookup",
        }
    }

    /// The expert's scalar load contribution of one table — public so
    /// the migration-aware greedy `replace` can balance the same metric
    /// its cold-start `place` balances.
    pub fn cost(&self, t: &Table) -> f64 {
        let size = t.size_gb() as f64;
        let dim = t.dim as f64;
        let pool = t.pooling as f64;
        match self {
            Expert::Size => size,
            Expert::Dim => dim,
            Expert::Lookup => dim * pool,
            Expert::SizeLookup => dim * pool * size,
        }
    }
}

/// Uniform-random legal placement (no slot cap).
pub fn random_placement(ds: &Dataset, task: &Task, sim: &Simulator, rng: &mut Rng) -> Vec<usize> {
    random_placement_capped(ds, task, sim, rng, usize::MAX)
}

/// Uniform-random legal placement under the MDP's legality rules: a
/// device is eligible only while it has a free slot (`max_slots`) *and*
/// the memory cap holds. When no device passes both, falls back to the
/// least-loaded (by memory) device with a free slot — ignoring the slot
/// cap only in the degenerate case where every slot in the cluster is
/// already taken (such a task has no legal placement at all).
pub fn random_placement_capped(
    ds: &Dataset,
    task: &Task,
    sim: &Simulator,
    rng: &mut Rng,
    max_slots: usize,
) -> Vec<usize> {
    let mut groups: Vec<Vec<&Table>> = vec![vec![]; task.n_devices];
    task.table_ids
        .iter()
        .map(|&tid| {
            let t = &ds.tables[tid];
            // rejection-sample a device that fits (falls back to least loaded)
            for _ in 0..8 {
                let d = rng.below(task.n_devices);
                if groups[d].len() < max_slots && sim.fits(&groups[d], t) {
                    groups[d].push(t);
                    return d;
                }
            }
            // total_cmp: a NaN memory sum (corrupt table) must not panic
            let least_loaded = |devs: &mut dyn Iterator<Item = usize>| {
                devs.min_by(|&a, &b| {
                    Simulator::mem_gb(&groups[a]).total_cmp(&Simulator::mem_gb(&groups[b]))
                })
            };
            let d = least_loaded(&mut (0..task.n_devices).filter(|&d| groups[d].len() < max_slots))
                .or_else(|| least_loaded(&mut (0..task.n_devices)))
                .unwrap();
            groups[d].push(t);
            d
        })
        .collect()
}

/// Greedy balancing placement for one expert cost function (no slot cap).
pub fn greedy_placement(ds: &Dataset, task: &Task, sim: &Simulator, expert: Expert) -> Vec<usize> {
    greedy_placement_capped(ds, task, sim, expert, usize::MAX)
}

/// Greedy balancing placement under the MDP's legality rules (see
/// [`random_placement_capped`] for the slot-cap/fallback semantics).
pub fn greedy_placement_capped(
    ds: &Dataset,
    task: &Task,
    sim: &Simulator,
    expert: Expert,
    max_slots: usize,
) -> Vec<usize> {
    let mut order: Vec<usize> = (0..task.n_tables()).collect();
    let costs: Vec<f64> =
        task.table_ids.iter().map(|&tid| expert.cost(&ds.tables[tid])).collect();
    // total_cmp: a NaN cost (corrupt table feature) must not panic the
    // sort — NaNs order deterministically, the rest exactly as before
    order.sort_by(|&a, &b| costs[b].total_cmp(&costs[a]));

    let mut placement = vec![usize::MAX; task.n_tables()];
    let mut load = vec![0.0f64; task.n_devices];
    let mut groups: Vec<Vec<&Table>> = vec![vec![]; task.n_devices];
    for &i in &order {
        let t = &ds.tables[task.table_ids[i]];
        // lowest-load device with a free slot that satisfies memory;
        // fall back to lowest-load with a free slot, then lowest-load
        let mut best: Option<usize> = None;
        for d in 0..task.n_devices {
            if groups[d].len() < max_slots
                && sim.fits(&groups[d], t)
                && best.map_or(true, |b| load[d] < load[b])
            {
                best = Some(d);
            }
        }
        let d = best
            .or_else(|| {
                (0..task.n_devices)
                    .filter(|&d| groups[d].len() < max_slots)
                    .min_by(|&a, &b| load[a].total_cmp(&load[b]))
            })
            .unwrap_or_else(|| {
                (0..task.n_devices).min_by(|&a, &b| load[a].total_cmp(&load[b])).unwrap()
            });
        placement[i] = d;
        load[d] += costs[i];
        groups[d].push(t);
    }
    placement
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;
    use crate::tables::{gen_dlrm, gen_prod, sample_tasks, split_pools};

    fn setup() -> (Dataset, Task, Simulator) {
        let ds = gen_dlrm(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 40, 4, 1, 3).remove(0);
        (ds, task, Simulator::new(SimConfig::default()))
    }

    #[test]
    fn greedy_balances_loads() {
        let (ds, task, sim) = setup();
        for e in ALL_EXPERTS {
            let p = greedy_placement(&ds, &task, &sim, e);
            assert!(p.iter().all(|&d| d < task.n_devices));
            // per-device table counts are not wildly skewed
            let mut counts = vec![0usize; task.n_devices];
            for &d in &p {
                counts[d] += 1;
            }
            assert!(counts.iter().all(|&c| c >= 2), "{:?} counts {counts:?}", e);
        }
    }

    #[test]
    fn experts_beat_random_on_average() {
        let (ds, task, sim) = setup();
        let mut rng = Rng::new(11);
        let rand_costs: Vec<f64> = (0..20)
            .map(|_| sim.evaluate(&ds, &task, &random_placement(&ds, &task, &sim, &mut rng)).latency)
            .collect();
        let rand_mean = crate::util::mean(&rand_costs);
        let lookup = sim
            .evaluate(&ds, &task, &greedy_placement(&ds, &task, &sim, Expert::Lookup))
            .latency;
        assert!(
            lookup < rand_mean,
            "lookup-based {lookup} should beat random mean {rand_mean}"
        );
    }

    #[test]
    fn dim_based_balances_dims_exactly_on_uniform_dims() {
        let (ds, task, sim) = setup();
        let p = greedy_placement(&ds, &task, &sim, Expert::Dim);
        let eval = sim.evaluate(&ds, &task, &p);
        let dims: Vec<f64> = eval.devices.iter().map(|t| t.dim_sum).collect();
        let max = dims.iter().cloned().fold(0.0, f64::max);
        let min = dims.iter().cloned().fold(f64::MAX, f64::min);
        // all DLRM dims are 16, 40 tables over 4 devices -> exactly 10 each
        assert!(max - min <= 16.0, "dims {dims:?}");
    }

    #[test]
    fn works_on_prod_dataset() {
        let ds = gen_prod(856, 0);
        let (pool, _) = split_pools(&ds, 1);
        let task = sample_tasks(&pool, 40, 4, 1, 3).remove(0);
        let sim = Simulator::new(SimConfig::v100());
        for e in ALL_EXPERTS {
            let p = greedy_placement(&ds, &task, &sim, e);
            let eval = sim.evaluate(&ds, &task, &p);
            assert!(eval.latency > 0.0);
        }
    }

    #[test]
    fn greedy_survives_nan_costs() {
        // total_cmp: a corrupt table (NaN pooling) must not panic the sort
        let (mut ds, task, sim) = setup();
        ds.tables[task.table_ids[2]].pooling = f32::NAN;
        for e in ALL_EXPERTS {
            let p = greedy_placement(&ds, &task, &sim, e);
            assert_eq!(p.len(), task.n_tables());
            assert!(p.iter().all(|&d| d < task.n_devices), "{e:?}");
        }
        let mut rng = Rng::new(8);
        let p = random_placement(&ds, &task, &sim, &mut rng);
        assert!(p.iter().all(|&d| d < task.n_devices));
    }

    #[test]
    fn capped_variants_obey_slot_cap() {
        let (ds, task, sim) = setup(); // 40 tables on 4 devices
        let cap = 10; // exactly 40 / 4: the cap binds
        let mut rng = Rng::new(3);
        let p = random_placement_capped(&ds, &task, &sim, &mut rng, cap);
        let mut counts = vec![0usize; task.n_devices];
        for &d in &p {
            counts[d] += 1;
        }
        assert!(counts.iter().all(|&c| c <= cap), "random: {counts:?}");
        for e in ALL_EXPERTS {
            let p = greedy_placement_capped(&ds, &task, &sim, e, cap);
            let mut counts = vec![0usize; task.n_devices];
            for &d in &p {
                counts[d] += 1;
            }
            assert!(counts.iter().all(|&c| c <= cap), "{e:?}: {counts:?}");
        }
    }

    #[test]
    fn random_respects_memory_mostly() {
        let (ds, task, sim) = setup();
        let mut rng = Rng::new(5);
        let p = random_placement(&ds, &task, &sim, &mut rng);
        let eval = sim.evaluate(&ds, &task, &p);
        for d in &eval.devices {
            assert!(d.mem_gb <= sim.cfg.mem_cap_gb as f64 * 1.5);
        }
    }
}
