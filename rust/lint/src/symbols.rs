//! Phase 1: per-file symbol tables.
//!
//! One linear walk over a file's token stream collects everything the
//! interprocedural phase needs: `fn` items (with their enclosing
//! `impl`/`trait` type, body span, and whether the signature returns a
//! `Result`), call sites (bare, method, and `Type::`-qualified), lock
//! acquisitions with the guards live at each point, raw-clock uses,
//! identifiers declared as `HashMap`/`HashSet`, hash-iteration sites,
//! and discarded-call statements. No name resolution happens here —
//! [`crate::graph::Program`] merges the per-file tables and resolves
//! calls crate-wide in phase 2.

use crate::lexer::{ident_at, match_delim, punct_at, Lexed, Tok, Token};

/// Reserved words that can precede `(` without being a call.
const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "in", "as", "ref", "move", "let",
    "break", "continue", "unsafe", "dyn", "impl", "where", "use", "pub", "mod", "struct", "enum",
    "union", "trait", "type", "const", "static", "crate", "super", "await", "async", "yield",
    "fn", "extern", "box",
];

/// Iterator-producing methods whose order is the backing map's.
const ITER_METHODS: &[&str] =
    &["iter", "iter_mut", "into_iter", "keys", "values", "values_mut", "drain", "into_keys", "into_values"];

/// How a call site names its callee.
#[derive(Clone, Debug, PartialEq)]
pub enum Callee {
    /// `foo(..)` — a free function.
    Bare(String),
    /// `recv.foo(..)` — a method on some value.
    Method(String),
    /// `Qualifier::foo(..)` — an associated function or a module path.
    Qualified(String, String),
}

impl Callee {
    pub fn name(&self) -> &str {
        match self {
            Callee::Bare(n) | Callee::Method(n) | Callee::Qualified(_, n) => n,
        }
    }
}

/// One `fn` item (including bodyless trait signatures).
pub struct FnItem {
    pub name: String,
    /// Innermost enclosing `impl`/`trait` type, if any.
    pub self_type: Option<String>,
    pub line: u32,
    /// Token range `[open, close]` of the braced body, if there is one.
    pub body: Option<(usize, usize)>,
    /// The signature's return type mentions `Result`.
    pub returns_result: bool,
    /// Defined inside a `#[test]` fn or `#[cfg(test)]` item.
    pub in_test: bool,
}

/// A call site inside some function body. `fn_idx` is the index of the
/// innermost enclosing [`FnItem`]; top-level call-shaped tokens outside
/// any body (e.g. in const initializers) are dropped.
pub struct CallSite {
    pub fn_idx: usize,
    pub callee: Callee,
    pub line: u32,
}

/// A `.lock()` acquisition. `lock` is the receiver identity: the
/// identifier immediately left of `.lock` (`self.tx.lock()` → `tx`).
pub struct LockAcq {
    pub fn_idx: usize,
    pub lock: String,
    pub line: u32,
}

/// A `.lock()` of `lock` reached while a guard on `held` is live in the
/// same function — a direct edge of the lock-acquisition graph.
pub struct LockEdge {
    pub fn_idx: usize,
    pub held: String,
    pub lock: String,
    pub line: u32,
}

/// An in-crate-resolvable call made while a guard on `held` is live —
/// the transitive edges come from the callee's lock summary.
pub struct HeldCall {
    pub fn_idx: usize,
    pub held: String,
    pub callee: Callee,
    pub line: u32,
}

/// A literal `Instant::now` / `SystemTime::now` token sequence.
pub struct ClockUse {
    /// Innermost enclosing fn, if inside one.
    pub fn_idx: Option<usize>,
    pub line: u32,
    pub what: &'static str,
}

/// An iteration over an identifier (`for .. in x`, `x.keys()`, ...)
/// whose map-ness is decided crate-wide in phase 2.
pub struct IterUse {
    pub name: String,
    pub line: u32,
    pub in_test: bool,
}

/// A statement that discards a call's value: `let _ = f(..);` or a bare
/// `f(..);`.
pub struct Discard {
    pub callee: Callee,
    pub line: u32,
    pub in_test: bool,
    /// Self type of the enclosing fn (for `Self::` resolution parity).
    pub self_type: Option<String>,
}

/// Everything phase 1 knows about one file.
pub struct FileSyms {
    pub fns: Vec<FnItem>,
    pub calls: Vec<CallSite>,
    pub acqs: Vec<LockAcq>,
    pub edges: Vec<LockEdge>,
    pub held_calls: Vec<HeldCall>,
    pub clock_uses: Vec<ClockUse>,
    /// Identifiers declared with a `HashMap`/`HashSet` type or
    /// initializer anywhere in this file (fields, params, lets).
    pub map_names: Vec<String>,
    pub iter_uses: Vec<IterUse>,
    pub discards: Vec<Discard>,
    pub test_spans: Vec<(usize, usize)>,
}

// ---------------------------------------------------------------------
// Test-code spans
// ---------------------------------------------------------------------

/// Token-index ranges `[start, end)` covering `#[test]` functions and
/// `#[cfg(test)]` / `#[cfg(all(test, ..))]` items (`#[cfg(not(test))]`
/// is deliberately NOT a test span).
pub fn test_spans(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if punct_at(toks, i, '#') && punct_at(toks, i + 1, '[') {
            let Some(close) = match_delim(toks, i + 1, '[', ']') else {
                i += 1;
                continue;
            };
            let attr = &toks[i + 2..close];
            let has = |w: &str| attr.iter().any(|t| matches!(&t.kind, Tok::Ident(s) if s == w));
            let exact_test = attr.len() == 1 && has("test");
            let cfg_test = ident_at(toks, i + 2) == Some("cfg") && has("test") && !has("not");
            if exact_test || cfg_test {
                // skip the attributed item: to the matching `}` of its
                // first brace, or to a top-level `;` (e.g. a `use`)
                let mut depth = 0i64;
                let mut j = close + 1;
                while j < toks.len() {
                    if punct_at(toks, j, '{') {
                        depth += 1;
                    } else if punct_at(toks, j, '}') {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    } else if punct_at(toks, j, ';') && depth == 0 {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                spans.push((i, j));
                i = j;
                continue;
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], i: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= i && i < b)
}

// ---------------------------------------------------------------------
// Items: impl / trait regions, fn items
// ---------------------------------------------------------------------

/// Skip a balanced generic-argument list starting at `<`, treating `->`
/// as an arrow (its `>` does not close a bracket).
fn skip_generics(toks: &[Token], mut i: usize) -> usize {
    if !punct_at(toks, i, '<') {
        return i;
    }
    let mut depth = 0i64;
    while i < toks.len() {
        if punct_at(toks, i, '-') && punct_at(toks, i + 1, '>') {
            i += 2;
            continue;
        }
        if punct_at(toks, i, '<') {
            depth += 1;
        } else if punct_at(toks, i, '>') {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    i
}

/// Last identifier of a `::`-separated path starting at `i`; returns
/// `(name, next_index)` or `None` if `i` is not an identifier.
fn path_tail(toks: &[Token], mut i: usize) -> Option<(String, usize)> {
    let mut last = ident_at(toks, i)?.to_string();
    i += 1;
    loop {
        let i2 = skip_generics(toks, i);
        if punct_at(toks, i2, ':') && punct_at(toks, i2 + 1, ':') {
            if let Some(n) = ident_at(toks, i2 + 2) {
                last = n.to_string();
                i = i2 + 3;
                continue;
            }
        }
        return Some((last, i2));
    }
}

/// `impl`/`trait` regions: token span of the braced body + the type name
/// whose methods it holds.
fn impl_regions(toks: &[Token]) -> Vec<(usize, usize, String)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        match ident_at(toks, i) {
            Some("impl") => {
                let mut j = skip_generics(toks, i + 1);
                // skip `&`, `mut`, `dyn` decorations on the first path
                while punct_at(toks, j, '&') || matches!(ident_at(toks, j), Some("mut" | "dyn")) {
                    j += 1;
                }
                let Some((first, mut k)) = path_tail(toks, j) else {
                    i += 1;
                    continue;
                };
                let mut ty = first;
                // `impl Trait for Type { .. }`: the type follows `for`
                while k < toks.len() && !punct_at(toks, k, '{') && !punct_at(toks, k, ';') {
                    if ident_at(toks, k) == Some("for") {
                        let mut m = k + 1;
                        while punct_at(toks, m, '&') || matches!(ident_at(toks, m), Some("mut" | "dyn")) {
                            m += 1;
                        }
                        if let Some((t, m2)) = path_tail(toks, m) {
                            ty = t;
                            k = m2;
                            continue;
                        }
                    }
                    k += 1;
                }
                if punct_at(toks, k, '{') {
                    if let Some(close) = match_delim(toks, k, '{', '}') {
                        out.push((k, close, ty));
                        i = k + 1;
                        continue;
                    }
                }
                i = k + 1;
            }
            Some("trait") => {
                if let Some(name) = ident_at(toks, i + 1) {
                    let name = name.to_string();
                    let mut k = i + 2;
                    while k < toks.len() && !punct_at(toks, k, '{') && !punct_at(toks, k, ';') {
                        k += 1;
                    }
                    if punct_at(toks, k, '{') {
                        if let Some(close) = match_delim(toks, k, '{', '}') {
                            out.push((k, close, name));
                            i = k + 1;
                            continue;
                        }
                    }
                    i = k + 1;
                } else {
                    i += 1;
                }
            }
            _ => i += 1,
        }
    }
    out
}

fn fn_items(toks: &[Token], regions: &[(usize, usize, String)], tests: &[(usize, usize)]) -> Vec<FnItem> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if ident_at(toks, i) != Some("fn") {
            i += 1;
            continue;
        }
        let Some(name) = ident_at(toks, i + 1) else {
            i += 1; // `fn(..)` pointer type
            continue;
        };
        let name = name.to_string();
        let line = toks[i + 1].line;
        let mut j = skip_generics(toks, i + 2);
        if !punct_at(toks, j, '(') {
            i += 1;
            continue;
        }
        let Some(args_close) = match_delim(toks, j, '(', ')') else {
            i += 1;
            continue;
        };
        // return type: tokens between `)` and the body `{` / `;` /
        // `where` — generics-aware so `-> Result<Vec<T>, E>` scans whole
        let mut returns_result = false;
        j = args_close + 1;
        while j < toks.len() {
            if punct_at(toks, j, '{') || punct_at(toks, j, ';') || ident_at(toks, j) == Some("where")
            {
                break;
            }
            if ident_at(toks, j) == Some("Result") {
                returns_result = true;
            }
            j = if punct_at(toks, j, '<') { skip_generics(toks, j) } else { j + 1 };
        }
        // body open: first `{` before a `;` (where-clauses carry none)
        while j < toks.len() && !punct_at(toks, j, '{') && !punct_at(toks, j, ';') {
            j += 1;
        }
        let body = if punct_at(toks, j, '{') { match_delim(toks, j, '{', '}').map(|c| (j, c)) } else { None };
        let self_type = regions
            .iter()
            .filter(|&&(a, b, _)| a <= i && i <= b)
            .min_by_key(|&&(a, b, _)| b - a)
            .map(|(_, _, t)| t.clone());
        out.push(FnItem {
            name,
            self_type,
            line,
            body,
            returns_result,
            in_test: in_spans(tests, i),
        });
        i += 1; // keep scanning inside the body: nested fns are items too
    }
    out
}

/// Innermost fn whose body contains token `i`.
fn owner_of(fns: &[FnItem], i: usize) -> Option<usize> {
    fns.iter()
        .enumerate()
        .filter(|(_, f)| f.body.map(|(a, b)| a < i && i < b).unwrap_or(false))
        .min_by_key(|(_, f)| {
            let (a, b) = f.body.unwrap_or((0, usize::MAX));
            b - a
        })
        .map(|(idx, _)| idx)
}

// ---------------------------------------------------------------------
// Call sites and effects
// ---------------------------------------------------------------------

/// Classify the call-shaped token at `i` (`Ident` followed by `(`).
fn classify_call(toks: &[Token], i: usize) -> Option<Callee> {
    let name = ident_at(toks, i)?;
    if KEYWORDS.contains(&name) || !punct_at(toks, i + 1, '(') {
        return None;
    }
    if i >= 1 && punct_at(toks, i - 1, '.') {
        return Some(Callee::Method(name.to_string()));
    }
    if i >= 3 && punct_at(toks, i - 1, ':') && punct_at(toks, i - 2, ':') {
        if let Some(q) = ident_at(toks, i - 3) {
            return Some(Callee::Qualified(q.to_string(), name.to_string()));
        }
        return None; // `<T as Trait>::f(..)` and friends: unresolvable
    }
    if i >= 1 && matches!(ident_at(toks, i - 1), Some("fn")) {
        return None; // a definition, not a call
    }
    Some(Callee::Bare(name.to_string()))
}

/// Collect identifiers declared as `HashMap`/`HashSet`: `name: HashMap`
/// type annotations (fields, params, lets) and `let name = HashMap::new()`
/// style initializers.
fn map_names(toks: &[Token]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if !matches!(ident_at(toks, i), Some("HashMap" | "HashSet")) {
            continue;
        }
        // walk back over a `std::collections::` style path prefix
        let mut j = i;
        while j >= 3 && punct_at(toks, j - 1, ':') && punct_at(toks, j - 2, ':') && ident_at(toks, j - 3).is_some()
        {
            j -= 3;
        }
        // `name : HashMap` — a type annotation
        if j >= 2 && punct_at(toks, j - 1, ':') && !(j >= 2 && punct_at(toks, j - 2, ':')) {
            if let Some(name) = ident_at(toks, j - 2) {
                if name != "_" {
                    out.push(name.to_string());
                    continue;
                }
            }
        }
        // `let [mut] name [: ..] = [path::]HashMap::..` — an initializer
        if punct_at(toks, i + 1, ':') && punct_at(toks, i + 2, ':') {
            let mut k = j;
            while k > 0 {
                k -= 1;
                if punct_at(toks, k, ';') || punct_at(toks, k, '{') || punct_at(toks, k, '}') {
                    break;
                }
                if ident_at(toks, k) == Some("let") {
                    let mut m = k + 1;
                    if ident_at(toks, m) == Some("mut") {
                        m += 1;
                    }
                    if let Some(name) = ident_at(toks, m) {
                        if name != "_" {
                            out.push(name.to_string());
                        }
                    }
                    break;
                }
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Iteration sites: `recv.iter()`-family calls and `for .. in recv {`.
fn iter_uses(toks: &[Token], tests: &[(usize, usize)]) -> Vec<IterUse> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if let Some(m) = ident_at(toks, i) {
            if ITER_METHODS.contains(&m)
                && i >= 2
                && punct_at(toks, i - 1, '.')
                && punct_at(toks, i + 1, '(')
            {
                if let Some(recv) = ident_at(toks, i - 2) {
                    out.push(IterUse {
                        name: recv.to_string(),
                        line: toks[i].line,
                        in_test: in_spans(tests, i),
                    });
                }
            }
            // `for pat in [&][mut] a.b.c {` — the chain's last ident
            if m == "in" {
                let mut j = i + 1;
                while punct_at(toks, j, '&') || ident_at(toks, j) == Some("mut") {
                    j += 1;
                }
                let mut last: Option<(String, usize)> = None;
                while let Some(id) = ident_at(toks, j) {
                    last = Some((id.to_string(), j));
                    if punct_at(toks, j + 1, '.') && ident_at(toks, j + 2).is_some() {
                        j += 2;
                    } else {
                        j += 1;
                        break;
                    }
                }
                if let Some((name, at)) = last {
                    if punct_at(toks, j, '{') {
                        out.push(IterUse {
                            name,
                            line: toks[at].line,
                            in_test: in_spans(tests, at),
                        });
                    }
                }
            }
        }
    }
    out
}

/// Discarded-call statements: `let _ = <expr ending in a call>;` and
/// bare `recv.f(..);` / `f(..);` statements.
fn discards(toks: &[Token], fns: &[FnItem], tests: &[(usize, usize)]) -> Vec<Discard> {
    let mut out = Vec::new();
    let self_ty = |i: usize| owner_of(fns, i).and_then(|f| fns[f].self_type.clone());
    for i in 0..toks.len() {
        // `let _ = ...;` — the value of the trailing top-level call
        if ident_at(toks, i) == Some("let")
            && ident_at(toks, i + 1) == Some("_")
            && punct_at(toks, i + 2, '=')
        {
            let mut depth = 0i64;
            let mut j = i + 3;
            let mut last_call: Option<usize> = None;
            while j < toks.len() {
                if punct_at(toks, j, '(') || punct_at(toks, j, '[') || punct_at(toks, j, '{') {
                    depth += 1;
                } else if punct_at(toks, j, ')') || punct_at(toks, j, ']') || punct_at(toks, j, '}') {
                    depth -= 1;
                } else if punct_at(toks, j, ';') && depth == 0 {
                    break;
                } else if depth == 0 && ident_at(toks, j).is_some() && punct_at(toks, j + 1, '(') {
                    last_call = Some(j);
                }
                j += 1;
            }
            if let Some(c) = last_call {
                if let Some(callee) = classify_call(toks, c) {
                    out.push(Discard {
                        callee,
                        line: toks[i].line,
                        in_test: in_spans(tests, i),
                        self_type: self_ty(i),
                    });
                }
            }
            continue;
        }
        // bare call statement: starts a statement, is nothing but a
        // field/path/method chain ending in a call, ends with `;`
        let starts_stmt = i == 0
            || punct_at(toks, i - 1, ';')
            || punct_at(toks, i - 1, '{')
            || punct_at(toks, i - 1, '}');
        if !starts_stmt {
            continue;
        }
        let Some(first) = ident_at(toks, i) else { continue };
        if KEYWORDS.contains(&first) || first == "_" {
            continue;
        }
        let mut j = i;
        let mut last_call: Option<usize> = None;
        loop {
            if punct_at(toks, j + 1, '(') {
                last_call = Some(j);
                let Some(close) = match_delim(toks, j + 1, '(', ')') else { break };
                if punct_at(toks, close + 1, ';') {
                    if let Some(c) = last_call {
                        if let Some(callee) = classify_call(toks, c) {
                            out.push(Discard {
                                callee,
                                line: toks[c].line,
                                in_test: in_spans(tests, c),
                                self_type: self_ty(c),
                            });
                        }
                    }
                    break;
                }
                // continue a method chain: `).f(` — anything else ends it
                if punct_at(toks, close + 1, '.') && ident_at(toks, close + 2).is_some() {
                    j = close + 2;
                    continue;
                }
                break;
            }
            if punct_at(toks, j + 1, '.') && ident_at(toks, j + 2).is_some() {
                j += 2;
                continue;
            }
            if punct_at(toks, j + 1, ':') && punct_at(toks, j + 2, ':') && ident_at(toks, j + 3).is_some()
            {
                j += 3;
                continue;
            }
            break;
        }
    }
    out
}

// ---------------------------------------------------------------------
// Lock-acquisition walk (per fn body)
// ---------------------------------------------------------------------

struct LockWalkOut {
    acqs: Vec<LockAcq>,
    edges: Vec<LockEdge>,
    held_calls: Vec<HeldCall>,
}

/// Walk one fn body tracking live lock guards (the same state machine
/// the local `lock-across-wait` rule uses), recording every acquisition,
/// every direct held-while-locking edge, and every in-crate-shaped call
/// made while a guard is live.
fn walk_locks(toks: &[Token], fn_idx: usize, body: (usize, usize), out: &mut LockWalkOut) {
    struct Guard {
        name: Option<String>,
        lock: String,
        depth: i64,
    }
    let (open, close) = body;
    let mut depth: i64 = 0;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt_let_name: Option<String> = None;
    let mut stmt_has_let = false;
    let mut expect_let_name = false;
    let mut stmt_lock: Option<String> = None;
    let mut i = open;
    while i <= close {
        match &toks[i].kind {
            Tok::Punct('{') => {
                depth += 1;
                // `if let` / `while let` guard: scoped to this block
                if stmt_has_let {
                    if let (Some(n), Some(l)) = (stmt_let_name.take(), stmt_lock.take()) {
                        guards.push(Guard { name: Some(n), lock: l, depth });
                    }
                }
                stmt_has_let = false;
                stmt_let_name = None;
                stmt_lock = None;
                expect_let_name = false;
            }
            Tok::Punct('}') => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
                stmt_has_let = false;
                stmt_let_name = None;
                stmt_lock = None;
                expect_let_name = false;
            }
            Tok::Punct(';') => {
                // plain `let g = ..lock()..;`: guard lives to scope end
                if stmt_has_let {
                    if let (Some(n), Some(l)) = (stmt_let_name.take(), stmt_lock.take()) {
                        guards.push(Guard { name: Some(n), lock: l, depth });
                    }
                }
                stmt_has_let = false;
                stmt_let_name = None;
                stmt_lock = None;
                expect_let_name = false;
            }
            Tok::Ident(w) => {
                if expect_let_name {
                    if w != "mut" {
                        stmt_let_name = Some(w.clone());
                        expect_let_name = false;
                    }
                } else if w == "let" && !stmt_has_let {
                    stmt_has_let = true;
                    expect_let_name = true;
                } else if w == "lock" && i > open && punct_at(toks, i - 1, '.') && punct_at(toks, i + 1, '(')
                {
                    let id = (i >= 2)
                        .then(|| ident_at(toks, i - 2))
                        .flatten()
                        .unwrap_or("<anon>")
                        .to_string();
                    out.acqs.push(LockAcq { fn_idx, lock: id.clone(), line: toks[i].line });
                    for g in guards.iter().map(|g| &g.lock).chain(stmt_lock.iter()) {
                        out.edges.push(LockEdge {
                            fn_idx,
                            held: g.clone(),
                            lock: id.clone(),
                            line: toks[i].line,
                        });
                    }
                    stmt_lock = Some(id);
                } else if w == "drop" && punct_at(toks, i + 1, '(') {
                    if let Some(n) = ident_at(toks, i + 2) {
                        if punct_at(toks, i + 3, ')') {
                            guards.retain(|g| g.name.as_deref() != Some(n));
                        }
                    }
                } else if let Some(callee) = classify_call(toks, i) {
                    for g in guards.iter().map(|g| &g.lock).chain(stmt_lock.iter()) {
                        out.held_calls.push(HeldCall {
                            fn_idx,
                            held: g.clone(),
                            callee: callee.clone(),
                            line: toks[i].line,
                        });
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------

pub fn parse_file(lx: &Lexed) -> FileSyms {
    let toks = &lx.toks;
    let tests = test_spans(toks);
    let regions = impl_regions(toks);
    let fns = fn_items(toks, &regions, &tests);

    let mut calls = Vec::new();
    let mut clock_uses = Vec::new();
    for i in 0..toks.len() {
        if let Some(callee) = classify_call(toks, i) {
            if let Some(fn_idx) = owner_of(&fns, i) {
                calls.push(CallSite { fn_idx, callee, line: toks[i].line });
            }
        }
        if let Some(ty) = ident_at(toks, i) {
            if (ty == "Instant" || ty == "SystemTime")
                && punct_at(toks, i + 1, ':')
                && punct_at(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                clock_uses.push(ClockUse {
                    fn_idx: owner_of(&fns, i),
                    line: toks[i].line,
                    what: if ty == "Instant" { "Instant::now" } else { "SystemTime::now" },
                });
            }
        }
    }

    let mut lw = LockWalkOut { acqs: Vec::new(), edges: Vec::new(), held_calls: Vec::new() };
    for (idx, f) in fns.iter().enumerate() {
        if let Some(body) = f.body {
            // nested fns get their own walk; the outer walk crossing the
            // nested body is harmless (guards are scoped by depth)
            walk_locks(toks, idx, body, &mut lw);
        }
    }

    FileSyms {
        map_names: map_names(toks),
        iter_uses: iter_uses(toks, &tests),
        discards: discards(toks, &fns, &tests),
        fns,
        calls,
        acqs: lw.acqs,
        edges: lw.edges,
        held_calls: lw.held_calls,
        clock_uses,
        test_spans: tests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn fn_items_capture_impl_type_and_result() {
        let src = "
impl Pool {
    pub fn submit(&self, n: usize) -> Result<Ticket, E> { helper(n) }
}
fn helper(n: usize) -> usize { n }
trait Clock { fn now(&self) -> Instant; }
";
        let s = parse_file(&lex(src));
        let names: Vec<(String, Option<String>, bool)> =
            s.fns.iter().map(|f| (f.name.clone(), f.self_type.clone(), f.returns_result)).collect();
        assert_eq!(
            names,
            vec![
                ("submit".into(), Some("Pool".into()), true),
                ("helper".into(), None, false),
                ("now".into(), Some("Clock".into()), false),
            ]
        );
        assert_eq!(s.calls.len(), 1);
        assert_eq!(s.calls[0].callee, Callee::Bare("helper".into()));
        assert_eq!(s.calls[0].fn_idx, 0);
    }

    #[test]
    fn impl_trait_for_type_resolves_to_type() {
        let src = "impl Clock for SystemClock { fn now(&self) -> Instant { Instant::now() } }";
        let s = parse_file(&lex(src));
        assert_eq!(s.fns[0].self_type.as_deref(), Some("SystemClock"));
        assert_eq!(s.clock_uses.len(), 1);
        assert_eq!(s.clock_uses[0].fn_idx, Some(0));
    }

    #[test]
    fn map_names_and_iter_uses() {
        let src = "
struct S { by_dev: std::collections::HashMap<usize, Vec<usize>> }
fn f(m: HashMap<String, f32>) {
    let mut seen = HashSet::new();
    for (k, _) in &m { touch(k); }
    let n: Vec<_> = seen.iter().collect();
    for v in self.by_dev { use_it(v); }
}
";
        let s = parse_file(&lex(src));
        assert_eq!(s.map_names, vec!["by_dev".to_string(), "m".into(), "seen".into()]);
        let iters: Vec<&str> = s.iter_uses.iter().map(|u| u.name.as_str()).collect();
        assert_eq!(iters, vec!["m", "seen", "by_dev"]);
    }

    #[test]
    fn discard_shapes() {
        let src = "
fn f(s: &mut S) {
    let _ = s.flush();
    s.flush();
    requeue(s);
    let n = s.flush();
    s.flush()?;
    self.stats.count += grow(s);
}
";
        let s = parse_file(&lex(src));
        let got: Vec<(&str, u32)> = s.discards.iter().map(|d| (d.callee.name(), d.line)).collect();
        assert_eq!(got, vec![("flush", 3), ("flush", 4), ("requeue", 5)]);
    }

    #[test]
    fn lock_walk_edges_and_held_calls() {
        let src = "
fn f(s: &S) {
    let ga = s.a.lock().unwrap_or_else(poison);
    let gb = s.b.lock().unwrap_or_else(poison);
    helper(s);
    drop(gb);
    drop(ga);
    tail(s);
}
";
        let s = parse_file(&lex(src));
        assert_eq!(s.acqs.iter().map(|a| a.lock.as_str()).collect::<Vec<_>>(), vec!["a", "b"]);
        assert_eq!(s.edges.len(), 1);
        assert_eq!((s.edges[0].held.as_str(), s.edges[0].lock.as_str()), ("a", "b"));
        // helper(s) runs under both guards; unwrap_or_else(poison) under
        // the just-taken temp; tail(s) under none
        let held: Vec<(&str, &str)> =
            s.held_calls.iter().map(|h| (h.held.as_str(), h.callee.name())).collect();
        assert!(held.contains(&("a", "helper")) && held.contains(&("b", "helper")));
        assert!(!held.iter().any(|&(_, c)| c == "tail"));
    }
}
