//! The two-phase driver: lex + phase-1 symbols per file, local rules
//! under the per-path policy, then the crate-wide graph and the
//! interprocedural rules, then pragma suppression and dedup.

use std::collections::HashSet;

use crate::graph::{in_dir, Program};
use crate::interproc;
use crate::lexer::{lex, Lexed};
use crate::report::Violation;
use crate::rules;
use crate::symbols::{parse_file, test_spans, FileSyms};

/// Path policy: which local rules run on a file (forward-slash paths;
/// the interprocedural rules carry their own scopes in
/// [`crate::interproc`]).
pub fn applies(rule: &str, path: &str) -> bool {
    match rule {
        "nan-ordering" | "lock-across-wait" => true,
        "env-discipline" => {
            !path.replace('\\', "/").ends_with("runtime/mod.rs") && !in_dir(path, "bench")
        }
        "panic-policy" => in_dir(path, "serve") || in_dir(path, "placer") || in_dir(path, "runtime"),
        _ => false,
    }
}

/// Lint a set of in-memory sources as one program. Returns violations
/// sorted by `(file, line, rule)`, pragma-suppressed and deduped.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Violation> {
    let lexed: Vec<Lexed> = files.iter().map(|(_, src)| lex(src)).collect();
    let syms: Vec<FileSyms> = lexed.iter().map(parse_file).collect();
    let paths: Vec<String> = files.iter().map(|(p, _)| p.clone()).collect();

    let mut found: Vec<Violation> = Vec::new();
    let mut viols: Vec<Violation> = Vec::new();
    let mut allowed: HashSet<(String, u32, String)> = HashSet::new();

    for (i, (path, _)) in files.iter().enumerate() {
        let lx = &lexed[i];
        let (file_allowed, mut pragma_viols) = rules::parse_pragmas(path, lx);
        for (line, rule) in file_allowed {
            allowed.insert((path.clone(), line, rule));
        }
        viols.append(&mut pragma_viols);
        if applies("nan-ordering", path) {
            rules::rule_nan_ordering(path, &lx.toks, &mut found);
        }
        if applies("env-discipline", path) {
            rules::rule_env_discipline(path, &lx.toks, &mut found);
        }
        if applies("panic-policy", path) {
            let spans = test_spans(&lx.toks);
            rules::rule_panic_policy(path, &lx.toks, &spans, &mut found);
        }
        if applies("lock-across-wait", path) {
            rules::rule_lock_across_wait(path, &lx.toks, &mut found);
        }
    }

    let prog = Program::build(paths, &syms);
    interproc::rule_lock_order(&prog, &mut found);
    interproc::rule_clock_transitive(&prog, &mut found);
    interproc::rule_map_iter_determinism(&prog, &mut found);
    interproc::rule_swallowed_result(&prog, &mut found);

    // suppress pragma'd lines, then dedup repeated (file, line, rule)
    found.retain(|v| !allowed.contains(&(v.file.clone(), v.line, v.rule.to_string())));
    let mut seen: HashSet<(String, u32, &'static str)> = HashSet::new();
    for v in found {
        if seen.insert((v.file.clone(), v.line, v.rule)) {
            viols.push(v);
        }
    }
    viols.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    viols
}

#[cfg(test)]
pub fn lint_one(path: &str, src: &str) -> Vec<Violation> {
    lint_sources(&[(path.to_string(), src.to_string())])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_of(viols: &[Violation], rule: &str) -> Vec<u32> {
        viols.iter().filter(|v| v.rule == rule).map(|v| v.line).collect()
    }

    #[test]
    fn strings_and_comments_never_match() {
        let src = r#"
// a.partial_cmp(&b).unwrap() in a comment
/* Instant::now() in a block comment */
fn f() {
    let s = "x.partial_cmp(&y).unwrap() and Instant::now()";
    let r = r"std::env::var and panic!";
}
"#;
        let v = lint_one("rust/src/serve/x.rs", src);
        assert!(v.is_empty(), "{:?}", v.iter().map(|v| (v.line, v.rule)).collect::<Vec<_>>());
    }

    #[test]
    fn multiline_partial_cmp_matches() {
        let src = "fn f(v: &mut Vec<f32>) {\n    let o = a\n        .partial_cmp(&b)\n        .unwrap();\n}\n";
        let v = lint_one("rust/src/sim/x.rs", src);
        assert_eq!(lines_of(&v, "nan-ordering"), vec![3]);
    }

    #[test]
    fn pragma_suppresses_next_line_and_requires_reason() {
        let good = "fn f() {\n    // lint: allow(clock-transitive) — test fixture timing\n    let t = Instant::now();\n}\n";
        let v = lint_one("rust/src/serve/x.rs", good);
        assert!(v.is_empty(), "{:?}", v.iter().map(|v| (v.line, v.rule)).collect::<Vec<_>>());
        let bad = "fn f() {\n    let t = Instant::now(); // lint: allow(clock-transitive)\n}\n";
        let v = lint_one("rust/src/serve/x.rs", bad);
        assert_eq!(lines_of(&v, "pragma"), vec![2]);
        assert_eq!(lines_of(&v, "clock-transitive"), vec![2]);
    }

    #[test]
    fn cfg_test_is_exempt_from_panic_policy() {
        let src = "fn lib() -> u32 {\n    x.unwrap()\n}\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n";
        let v = lint_one("rust/src/runtime/x.rs", src);
        assert_eq!(lines_of(&v, "panic-policy"), vec![2]);
    }

    #[test]
    fn lock_guard_across_wait_flags() {
        let src = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n    let r = t.wait();\n}\n";
        let v = lint_one("rust/src/util/x.rs", src);
        assert_eq!(lines_of(&v, "lock-across-wait"), vec![3]);
        let dropped = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n    drop(g);\n    let r = t.wait();\n}\n";
        let v = lint_one("rust/src/util/x.rs", dropped);
        assert!(lines_of(&v, "lock-across-wait").is_empty());
    }

    #[test]
    fn interprocedural_rules_run_through_the_engine() {
        let files = vec![
            (
                "rust/src/serve/s.rs".to_string(),
                "fn drain() { let t = stamp(); }".to_string(),
            ),
            (
                "rust/src/util/t.rs".to_string(),
                "pub fn stamp() -> u64 { let t = Instant::now(); 0 }".to_string(),
            ),
        ];
        let v = lint_sources(&files);
        assert_eq!(v.len(), 1);
        assert_eq!((v[0].file.as_str(), v[0].line, v[0].rule), ("rust/src/serve/s.rs", 1, "clock-transitive"));
    }
}
