//! The local (single-file) rules and the pragma engine.
//!
//! These run on one file's token stream alone: `nan-ordering`,
//! `env-discipline`, `panic-policy`, and `lock-across-wait`. The
//! interprocedural rules live in [`crate::interproc`]. Pragma parsing is
//! here because suppression is a per-file, per-line concern regardless
//! of which phase produced the finding.

use std::collections::HashSet;

use crate::lexer::{ident_at, match_delim, punct_at, Lexed, Token};
use crate::report::Violation;

/// The enforced rules (the `pragma` meta-rule reports malformed escapes
/// and is not itself escapable).
pub const RULES: [&str; 8] = [
    "nan-ordering",
    "env-discipline",
    "panic-policy",
    "lock-across-wait",
    "lock-order",
    "clock-transitive",
    "map-iter-determinism",
    "swallowed-result",
];

// ---------------------------------------------------------------------
// Pragmas
// ---------------------------------------------------------------------

/// Parse `lint: allow(<rule>) — <reason>` comments. Returns the set of
/// `(target_line, rule)` suppressions plus violations for malformed
/// pragmas (missing reason, unknown rule, unparseable body).
pub fn parse_pragmas(path: &str, lx: &Lexed) -> (HashSet<(u32, String)>, Vec<Violation>) {
    let mut allowed: HashSet<(u32, String)> = HashSet::new();
    let mut viols: Vec<Violation> = Vec::new();
    for c in &lx.comments {
        let t = c.text.trim_start_matches(['/', '!']).trim();
        let Some(rest) = t.strip_prefix("lint:") else { continue };
        let rest = rest.trim();
        let body = rest.strip_prefix("allow").map(str::trim_start);
        let parsed = body.and_then(|b| {
            let inner = b.strip_prefix('(')?;
            let close = inner.find(')')?;
            Some((inner[..close].to_string(), inner[close + 1..].to_string()))
        });
        let Some((rules, reason)) = parsed else {
            viols.push(Violation {
                file: path.to_string(),
                line: c.line,
                rule: "pragma",
                msg: format!("unparseable lint pragma `{t}`; use `lint: allow(<rule>) — <reason>`"),
            });
            continue;
        };
        if !reason.chars().any(|ch| ch.is_alphanumeric()) {
            viols.push(Violation {
                file: path.to_string(),
                line: c.line,
                rule: "pragma",
                msg: "lint pragma has no justification; append `— <reason>`".to_string(),
            });
            continue;
        }
        // own-line pragmas target the next line that has code on it
        let target = if c.own_line {
            lx.toks.iter().map(|t| t.line).find(|&l| l > c.line).unwrap_or(c.line)
        } else {
            c.line
        };
        for r in rules.split(',') {
            let r = r.trim();
            if RULES.contains(&r) {
                allowed.insert((target, r.to_string()));
            } else {
                viols.push(Violation {
                    file: path.to_string(),
                    line: c.line,
                    rule: "pragma",
                    msg: format!("unknown rule `{r}` in lint pragma (rules: {})", RULES.join(", ")),
                });
            }
        }
    }
    (allowed, viols)
}

// ---------------------------------------------------------------------
// Local rules
// ---------------------------------------------------------------------

pub fn rule_nan_ordering(path: &str, toks: &[Token], out: &mut Vec<Violation>) {
    for i in 0..toks.len() {
        if ident_at(toks, i) == Some("partial_cmp") && punct_at(toks, i + 1, '(') {
            if let Some(close) = match_delim(toks, i + 1, '(', ')') {
                if punct_at(toks, close + 1, '.')
                    && matches!(ident_at(toks, close + 2), Some("unwrap") | Some("expect"))
                    && punct_at(toks, close + 3, '(')
                {
                    out.push(Violation {
                        file: path.to_string(),
                        line: toks[i].line,
                        rule: "nan-ordering",
                        msg: "partial_cmp(..).unwrap()/.expect(..) panics on NaN; \
                              use total_cmp for a NaN-safe total order"
                            .to_string(),
                    });
                }
            }
        }
        if let Some(name) = ident_at(toks, i) {
            if matches!(name, "sort_by" | "sort_unstable_by" | "max_by" | "min_by")
                && punct_at(toks, i + 1, '(')
            {
                if let Some(close) = match_delim(toks, i + 1, '(', ')') {
                    if (i + 2..close).any(|j| ident_at(toks, j) == Some("partial_cmp")) {
                        out.push(Violation {
                            file: path.to_string(),
                            line: toks[i].line,
                            rule: "nan-ordering",
                            msg: format!(
                                "`{name}` comparator built on partial_cmp; \
                                 use total_cmp for a NaN-safe total order"
                            ),
                        });
                    }
                }
            }
        }
    }
}

pub fn rule_env_discipline(path: &str, toks: &[Token], out: &mut Vec<Violation>) {
    for i in 0..toks.len() {
        if ident_at(toks, i) == Some("env")
            && punct_at(toks, i + 1, ':')
            && punct_at(toks, i + 2, ':')
            && matches!(ident_at(toks, i + 3), Some("var") | Some("var_os"))
        {
            out.push(Violation {
                file: path.to_string(),
                line: toks[i].line,
                rule: "env-discipline",
                msg: "std::env::var outside runtime/mod.rs and bench/ creates untracked \
                      config surface; plumb the setting through an explicit parameter"
                    .to_string(),
            });
        }
    }
}

pub fn rule_panic_policy(
    path: &str,
    toks: &[Token],
    spans: &[(usize, usize)],
    out: &mut Vec<Violation>,
) {
    let in_test = |i: usize| spans.iter().any(|&(a, b)| a <= i && i < b);
    for i in 0..toks.len() {
        if in_test(i) {
            continue;
        }
        if punct_at(toks, i, '.')
            && matches!(ident_at(toks, i + 1), Some("unwrap") | Some("expect"))
            && punct_at(toks, i + 2, '(')
        {
            let what = ident_at(toks, i + 1).unwrap_or("unwrap");
            out.push(Violation {
                file: path.to_string(),
                line: toks[i + 1].line,
                rule: "panic-policy",
                msg: format!(
                    ".{what}(..) in a library hot path panics the shard; route through \
                     util::error (Result/Context/bail!) or justify with a lint pragma"
                ),
            });
        }
        if ident_at(toks, i) == Some("panic") && punct_at(toks, i + 1, '!') {
            out.push(Violation {
                file: path.to_string(),
                line: toks[i].line,
                rule: "panic-policy",
                msg: "panic! in a library hot path takes down the shard; route through \
                      util::error (Result/Context/bail!) or justify with a lint pragma"
                    .to_string(),
            });
        }
    }
}

pub fn rule_lock_across_wait(path: &str, toks: &[Token], out: &mut Vec<Violation>) {
    struct Guard {
        name: String,
        depth: i64,
    }
    let mut depth: i64 = 0;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt_has_let = false;
    let mut stmt_let_name: Option<String> = None;
    let mut stmt_lock = false;
    let mut expect_let_name = false;
    for i in 0..toks.len() {
        match &toks[i].kind {
            crate::lexer::Tok::Punct('{') => {
                depth += 1;
                // `if let` / `while let` guard: scoped to this block
                if stmt_has_let && stmt_lock {
                    if let Some(n) = stmt_let_name.take() {
                        guards.push(Guard { name: n, depth });
                    }
                }
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            crate::lexer::Tok::Punct('}') => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            crate::lexer::Tok::Punct(';') => {
                // plain `let g = ...lock()...;` guard: lives to scope end
                if stmt_has_let && stmt_lock {
                    if let Some(n) = stmt_let_name.take() {
                        guards.push(Guard { name: n, depth });
                    }
                }
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            crate::lexer::Tok::Ident(w) => {
                if expect_let_name {
                    if w != "mut" {
                        stmt_let_name = Some(w.clone());
                        expect_let_name = false;
                    }
                } else if w == "let" && !stmt_has_let {
                    stmt_has_let = true;
                    expect_let_name = true;
                } else if w == "lock" && i > 0 && punct_at(toks, i - 1, '.') && punct_at(toks, i + 1, '(')
                {
                    stmt_lock = true;
                } else if (w == "wait" || w == "submit")
                    && i > 0
                    && punct_at(toks, i - 1, '.')
                    && punct_at(toks, i + 1, '(')
                {
                    if !guards.is_empty() || stmt_lock {
                        let held = guards
                            .last()
                            .map(|g| g.name.clone())
                            .unwrap_or_else(|| "<temporary>".to_string());
                        out.push(Violation {
                            file: path.to_string(),
                            line: toks[i].line,
                            rule: "lock-across-wait",
                            msg: format!(
                                ".{w}(..) while lock guard `{held}` is live can deadlock \
                                 the worker pool; drop the guard before dispatching"
                            ),
                        });
                    }
                } else if w == "drop" && punct_at(toks, i + 1, '(') {
                    if let Some(n) = ident_at(toks, i + 2) {
                        if punct_at(toks, i + 3, ')') {
                            guards.retain(|g| g.name != n);
                        }
                    }
                }
            }
            _ => {}
        }
    }
}
