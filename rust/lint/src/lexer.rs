//! The comment/string-aware Rust lexer both analysis phases share.
//!
//! Produces a flat token stream (identifiers, punctuation, literal
//! markers) with 1-based lines **and** char-index spans, plus the line
//! comments (kept for pragma parsing) and block-comment spans. Literal
//! bodies are not kept: a rule can never match inside a string, char, or
//! lifetime — that is the point. Spans exist so the fuzz test can prove
//! the lexer consumes every non-whitespace char exactly once (tokens,
//! comments, and whitespace tile the input) on arbitrary byte soup.

/// One lexical token kind. `Str` covers plain, raw, and byte strings.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Punct(char),
    Num,
    Str,
    CharLit,
    Lifetime,
}

/// A token with its 1-based start line and `[start, end)` char span.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Tok,
    pub line: u32,
    pub start: usize,
    pub end: usize,
}

/// A line comment, kept for pragma parsing. `own_line` is true when no
/// code token precedes it on its line.
#[derive(Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
    pub own_line: bool,
    pub start: usize,
    pub end: usize,
}

pub struct Lexed {
    /// Tokens with non-decreasing start lines.
    pub toks: Vec<Token>,
    pub comments: Vec<Comment>,
    /// `[start, end)` char spans of block comments (not pragma-bearing).
    pub blocks: Vec<(usize, usize)>,
}

fn scan_string(cs: &[char], open: usize, line: &mut u32) -> usize {
    let mut i = open + 1;
    while i < cs.len() {
        match cs[i] {
            // an escape may hide a newline (`\<newline>` continuation)
            '\\' => {
                if i + 1 < cs.len() && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i.min(cs.len())
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut toks: Vec<Token> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut blocks: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut last_tok_line: u32 = 0;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers /// and //! doc comments)
        if c == '/' && i + 1 < cs.len() && cs[i + 1] == '/' {
            let open = i;
            let start = i + 2;
            let mut j = start;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            let text: String = cs[start..j].iter().collect();
            comments.push(Comment {
                line,
                text,
                own_line: last_tok_line != line,
                start: open,
                end: j,
            });
            i = j;
            continue;
        }
        // block comment, nesting-aware
        if c == '/' && i + 1 < cs.len() && cs[i + 1] == '*' {
            let open = i;
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < cs.len() && depth > 0 {
                if cs[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if cs[j] == '/' && j + 1 < cs.len() && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < cs.len() && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            blocks.push((open, j.min(cs.len())));
            i = j;
            continue;
        }
        let tline = line;
        let tstart = i;
        if c == '"' {
            i = scan_string(&cs, i, &mut line);
            toks.push(Token { kind: Tok::Str, line: tline, start: tstart, end: i });
            last_tok_line = tline;
            continue;
        }
        if c == '\'' {
            // lifetime vs char literal
            if i + 1 < cs.len() && cs[i + 1] == '\\' {
                // escaped char: '\n', '\'', '\u{1F}', ...
                let mut j = i + 3; // past the escape introducer + one char
                while j < cs.len() && cs[j] != '\'' {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = (j + 1).min(cs.len());
                toks.push(Token { kind: Tok::CharLit, line: tline, start: tstart, end: i });
            } else if i + 1 < cs.len()
                && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_')
                && !(i + 2 < cs.len() && cs[i + 2] == '\'')
            {
                let mut j = i + 1;
                while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                i = j;
                toks.push(Token { kind: Tok::Lifetime, line: tline, start: tstart, end: i });
            } else {
                let mut j = i + 1;
                while j < cs.len() && cs[j] != '\'' {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = (j + 1).min(cs.len());
                toks.push(Token { kind: Tok::CharLit, line: tline, start: tstart, end: i });
            }
            last_tok_line = tline;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            let word: String = cs[start..j].iter().collect();
            // raw / byte string prefixes: r"..", r#".."#, b"..", br#".."#
            if (word == "r" || word == "b" || word == "br" || word == "rb")
                && j < cs.len()
                && (cs[j] == '"' || cs[j] == '#')
            {
                let mut hashes = 0usize;
                let mut k = j;
                while k < cs.len() && cs[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < cs.len() && cs[k] == '"' {
                    if word == "b" && hashes == 0 {
                        // byte string: normal escape rules
                        i = scan_string(&cs, k, &mut line);
                    } else {
                        // raw string: ends at `"` followed by `hashes` #s
                        k += 1;
                        while k < cs.len() {
                            if cs[k] == '\n' {
                                line += 1;
                                k += 1;
                                continue;
                            }
                            if cs[k] == '"' {
                                let mut h = 0usize;
                                let mut m = k + 1;
                                while m < cs.len() && cs[m] == '#' && h < hashes {
                                    h += 1;
                                    m += 1;
                                }
                                if h == hashes {
                                    k = m;
                                    break;
                                }
                            }
                            k += 1;
                        }
                        i = k.min(cs.len());
                    }
                    toks.push(Token { kind: Tok::Str, line: tline, start: tstart, end: i });
                    last_tok_line = tline;
                    continue;
                }
                // `r#ident` raw identifier or stray hash: fall through
            }
            toks.push(Token { kind: Tok::Ident(word), line: tline, start: tstart, end: j });
            last_tok_line = tline;
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            // fractional part — but not `0..n` ranges or `x.0` that follow
            if j + 1 < cs.len() && cs[j] == '.' && cs[j + 1].is_ascii_digit() {
                j += 1;
                while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
            }
            toks.push(Token { kind: Tok::Num, line: tline, start: tstart, end: j });
            last_tok_line = tline;
            i = j;
            continue;
        }
        toks.push(Token { kind: Tok::Punct(c), line: tline, start: tstart, end: i + 1 });
        last_tok_line = tline;
        i += 1;
    }
    Lexed { toks, comments, blocks }
}

// ---------------------------------------------------------------------
// Token helpers shared by every rule and the symbol parser
// ---------------------------------------------------------------------

pub fn ident_at(toks: &[Token], i: usize) -> Option<&str> {
    match toks.get(i) {
        Some(Token { kind: Tok::Ident(s), .. }) => Some(s.as_str()),
        _ => None,
    }
}

pub fn punct_at(toks: &[Token], i: usize, c: char) -> bool {
    matches!(toks.get(i), Some(Token { kind: Tok::Punct(p), .. }) if *p == c)
}

/// Index of the `)`/`]`/`}` matching the opener at `open`, if any.
pub fn match_delim(toks: &[Token], open: usize, oc: char, cc: char) -> Option<usize> {
    let mut depth = 0i64;
    let mut i = open;
    while i < toks.len() {
        if punct_at(toks, i, oc) {
            depth += 1;
        } else if punct_at(toks, i, cc) {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_tile_simple_source() {
        let src = "fn f() { let s = \"a b\"; /* x */ s.len() } // tail\n";
        assert_tiles(src);
    }

    /// Deterministic LCG-driven fuzz: random soups of lexer-hostile chars
    /// must lex without panicking, with in-bounds, non-overlapping,
    /// ordered spans whose complement is pure whitespace — i.e. tokens,
    /// comments, and whitespace tile the input exactly.
    #[test]
    fn fuzz_byte_soup_tiles_and_never_panics() {
        let alphabet: Vec<char> = "ab_ \"'\\/*#r!{}()<>:;.,0129 \n\t-=&|éλ\u{1F600}"
            .chars()
            .collect();
        let mut state: u64 = 0x5EED_CAFE_F00D_0001;
        let mut next = move || {
            // Knuth MMIX LCG — deterministic across runs and platforms
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };
        for case in 0..300 {
            let len = next() % 160;
            let src: String = (0..len).map(|_| alphabet[next() % alphabet.len()]).collect();
            let lx = lex(&src); // must not panic
            check_tiles(&src, &lx, case);
        }
    }

    fn assert_tiles(src: &str) {
        let lx = lex(src);
        check_tiles(src, &lx, usize::MAX);
    }

    fn check_tiles(src: &str, lx: &Lexed, case: usize) {
        let cs: Vec<char> = src.chars().collect();
        let mut spans: Vec<(usize, usize)> = lx.toks.iter().map(|t| (t.start, t.end)).collect();
        spans.extend(lx.comments.iter().map(|c| (c.start, c.end)));
        spans.extend(lx.blocks.iter().copied());
        spans.sort();
        let mut covered = vec![false; cs.len()];
        let mut prev_end = 0usize;
        for &(s, e) in &spans {
            assert!(s <= e && e <= cs.len(), "case {case}: span ({s},{e}) out of bounds");
            assert!(s >= prev_end, "case {case}: span ({s},{e}) overlaps previous");
            prev_end = e;
            for slot in covered.iter_mut().take(e).skip(s) {
                *slot = true;
            }
        }
        for (i, &c) in cs.iter().enumerate() {
            if !covered[i] {
                assert!(
                    c.is_whitespace(),
                    "case {case}: uncovered non-whitespace char {c:?} at {i} in {src:?}"
                );
            }
        }
        // token start lines are non-decreasing and 1-based
        let mut prev = 1u32;
        for t in &lx.toks {
            assert!(t.line >= prev && t.line >= 1, "case {case}: line order broke");
            prev = t.line;
        }
    }
}
