//! Violation type and the three output encoders.
//!
//! * text (default): `file:line: rule: message`, one line per finding;
//! * `--json`: one machine-readable document on stdout — schema below,
//!   round-tripped by `rust/lint/tests/rules.rs`;
//! * `--github`: GitHub Actions workflow commands (`::error
//!   file=..,line=..,title=..::message`) so CI findings render as inline
//!   annotations in the PR diff.
//!
//! JSON schema (`version` gates future changes):
//!
//! ```text
//! {
//!   "version": 1,
//!   "files_checked": <int>,
//!   "violations": [
//!     { "file": <string>, "line": <int>, "rule": <string>, "message": <string> },
//!     ...
//!   ]
//! }
//! ```

#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

#[derive(Clone, Copy, PartialEq)]
pub enum Format {
    Text,
    Json,
    Github,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// GitHub workflow commands percent-escape their property/data fields.
fn gh_escape(s: &str, property: bool) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '%' => out.push_str("%25"),
            '\r' => out.push_str("%0D"),
            '\n' => out.push_str("%0A"),
            ':' if property => out.push_str("%3A"),
            ',' if property => out.push_str("%2C"),
            c => out.push(c),
        }
    }
    out
}

/// Render all findings to stdout in the chosen format. `quiet`
/// suppresses the per-violation lines (the summary still goes to
/// stderr, and `--json` output is machine-consumed, so it stays).
pub fn emit(viols: &[Violation], files_checked: usize, fmt: Format, quiet: bool) {
    match fmt {
        Format::Text => {
            if !quiet {
                for v in viols {
                    println!("{}:{}: {}: {}", v.file, v.line, v.rule, v.msg);
                }
            }
        }
        Format::Github => {
            if !quiet {
                for v in viols {
                    println!(
                        "::error file={},line={},title=dreamshard-lint {}::{}",
                        gh_escape(&v.file, true),
                        v.line,
                        gh_escape(v.rule, true),
                        gh_escape(&v.msg, false)
                    );
                }
            }
        }
        Format::Json => {
            let mut out = String::new();
            out.push_str("{\n");
            out.push_str("  \"version\": 1,\n");
            out.push_str(&format!("  \"files_checked\": {files_checked},\n"));
            out.push_str("  \"violations\": [");
            for (i, v) in viols.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n    {{ \"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\" }}",
                    json_escape(&v.file),
                    v.line,
                    json_escape(v.rule),
                    json_escape(&v.msg)
                ));
            }
            if !viols.is_empty() {
                out.push_str("\n  ");
            }
            out.push_str("]\n}");
            println!("{out}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escaping_covers_quotes_and_control() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn github_escaping_covers_separators() {
        assert_eq!(gh_escape("a,b:c%d", true), "a%2Cb%3Ac%25d");
        assert_eq!(gh_escape("m: x, y %", false), "m: x, y %25");
    }
}
