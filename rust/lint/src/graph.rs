//! Phase 2: the crate-wide symbol graph.
//!
//! Merges every file's [`FileSyms`](crate::symbols::FileSyms) into one
//! [`Program`], resolves call sites by name (bare calls to free
//! functions, method calls to any `impl`/`trait` method of that name,
//! `Type::f` to the matching impl — `Self::f` through the caller's impl
//! type), and computes the two whole-program summaries the
//! interprocedural rules consume:
//!
//! * **lock summaries** — for every function, the set of lock identities
//!   it may acquire directly or through any call chain (monotone
//!   fixpoint, so recursion converges);
//! * **clock taint** — whether a function reaches a literal
//!   `Instant::now`/`SystemTime::now` through any call chain, with the
//!   first step of a witness chain kept for diagnostics. Functions
//!   defined in `serve/clock.rs` are the sanctioned seam: they neither
//!   carry nor propagate taint.
//!
//! Resolution is deliberately name-based (no types): linking a call to
//! every same-named candidate over-approximates, which is the right
//! direction for deadlock/determinism rules — a missed link hides a bug,
//! an extra link costs at worst a justified pragma.

use std::collections::{BTreeSet, HashMap};

use crate::symbols::{Callee, FileSyms};

/// `true` when `path` has a directory component exactly named `seg`.
pub fn in_dir(path: &str, seg: &str) -> bool {
    let p = format!("/{}", path.replace('\\', "/"));
    p.contains(&format!("/{seg}/"))
}

/// The sanctioned raw-clock module: the `serve::Clock` seam itself.
pub fn is_clock_seam(path: &str) -> bool {
    in_dir(path, "serve") && path.replace('\\', "/").ends_with("/clock.rs")
}

/// Link unit of a file: which other files its calls may bind to.
///
/// Name-based resolution must not cross binary boundaries: a helper in a
/// bench, example, or integration-test file can never be linked into the
/// library, so a `fn place` defined in `rust/tests/` must not taint the
/// library's `.place(..)` call sites. Unit 0 is the library (`rust/src`
/// plus anything unclassified, e.g. lint fixtures, which form a
/// self-contained pretend tree); unit 1 is the lint crate's own sources
/// (zero-dep: they see neither the library nor the fixtures); each
/// bench/example/test FILE is its own binary unit (`2 + file index`).
fn unit_of(path: &str, file_idx: usize) -> usize {
    if in_dir(path, "benches")
        || in_dir(path, "examples")
        || (in_dir(path, "tests") && !in_dir(path, "fixtures"))
    {
        return 2 + file_idx;
    }
    if in_dir(path, "lint") && !in_dir(path, "fixtures") {
        return 1;
    }
    0
}

/// One function in the crate-wide graph.
pub struct GFn {
    pub file: usize,
    /// Index into that file's `FileSyms::fns`.
    pub local: usize,
    pub name: String,
    pub self_type: Option<String>,
    pub returns_result: bool,
}

/// How a tainted function first reaches the raw clock, for diagnostics.
#[derive(Clone)]
pub enum ClockWitness {
    Direct { what: &'static str, line: u32 },
    Call { callee: usize, line: u32 },
}

pub struct Program<'a> {
    pub paths: Vec<String>,
    pub files: &'a [FileSyms],
    /// Per-file link unit (see [`unit_of`]).
    units: Vec<usize>,
    pub fns: Vec<GFn>,
    /// fn name -> indices into `fns`.
    by_name: HashMap<String, Vec<usize>>,
    /// (file, local fn idx) -> global fn idx.
    by_site: HashMap<(usize, usize), usize>,
    /// Per-fn resolved callees (global indices), deduped.
    pub callees: Vec<Vec<usize>>,
    /// Per-fn may-acquire lock identities (transitive).
    pub lock_summary: Vec<BTreeSet<String>>,
    /// Per-fn clock taint witness (None = clean or sanctioned).
    pub clock_taint: Vec<Option<ClockWitness>>,
}

impl<'a> Program<'a> {
    pub fn build(paths: Vec<String>, files: &'a [FileSyms]) -> Program<'a> {
        let mut fns = Vec::new();
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        let mut by_site = HashMap::new();
        for (fi, fsym) in files.iter().enumerate() {
            for (li, f) in fsym.fns.iter().enumerate() {
                let gid = fns.len();
                by_name.entry(f.name.clone()).or_default().push(gid);
                by_site.insert((fi, li), gid);
                fns.push(GFn {
                    file: fi,
                    local: li,
                    name: f.name.clone(),
                    self_type: f.self_type.clone(),
                    returns_result: f.returns_result,
                });
            }
        }
        let units = (0..files.len()).map(|fi| unit_of(&paths[fi], fi)).collect();
        let mut prog = Program {
            paths,
            files,
            units,
            fns,
            by_name,
            by_site,
            callees: Vec::new(),
            lock_summary: Vec::new(),
            clock_taint: Vec::new(),
        };
        prog.link();
        prog.summarize_locks();
        prog.summarize_clock();
        prog
    }

    pub fn global_id(&self, file: usize, local: usize) -> usize {
        self.by_site[&(file, local)]
    }

    /// A call in `caller_file` may only bind to symbols its binary can
    /// link: its own unit, or the library from a downstream unit.
    fn visible(&self, caller_file: usize, callee_file: usize) -> bool {
        let (cu, ce) = (self.units[caller_file], self.units[callee_file]);
        cu == ce || (ce == 0 && cu >= 2)
    }

    /// All in-crate candidates a call from `caller_file` could bind to.
    /// `caller_self` is the caller's impl type, for `Self::f` qualifiers.
    pub fn resolve(&self, callee: &Callee, caller_self: Option<&str>, caller_file: usize) -> Vec<usize> {
        let ids = |name: &str| -> Vec<usize> {
            self.by_name
                .get(name)
                .map(|v| v.as_slice())
                .unwrap_or(&[])
                .iter()
                .copied()
                .filter(|&g| self.visible(caller_file, self.fns[g].file))
                .collect()
        };
        match callee {
            // a bare call is a free function (methods always go through
            // `self.` / `Type::` in Rust)
            Callee::Bare(n) => {
                ids(n).into_iter().filter(|&g| self.fns[g].self_type.is_none()).collect()
            }
            // a method call binds to any impl/trait method of that name
            Callee::Method(n) => {
                ids(n).into_iter().filter(|&g| self.fns[g].self_type.is_some()).collect()
            }
            Callee::Qualified(q, n) => {
                let q = if q == "Self" { caller_self.unwrap_or("Self") } else { q.as_str() };
                let typed: Vec<usize> = ids(n)
                    .into_iter()
                    .filter(|&g| self.fns[g].self_type.as_deref() == Some(q))
                    .collect();
                if !typed.is_empty() {
                    return typed;
                }
                // `module::f(..)`: fall back to free functions by name
                ids(n).into_iter().filter(|&g| self.fns[g].self_type.is_none()).collect()
            }
        }
    }

    fn link(&mut self) {
        let mut callees: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); self.fns.len()];
        for (fi, fsym) in self.files.iter().enumerate() {
            for call in &fsym.calls {
                let caller = self.global_id(fi, call.fn_idx);
                let self_ty = self.fns[caller].self_type.clone();
                for g in self.resolve(&call.callee, self_ty.as_deref(), fi) {
                    callees[caller].insert(g);
                }
            }
        }
        self.callees = callees.into_iter().map(|s| s.into_iter().collect()).collect();
    }

    fn summarize_locks(&mut self) {
        let mut summary: Vec<BTreeSet<String>> = vec![BTreeSet::new(); self.fns.len()];
        for (fi, fsym) in self.files.iter().enumerate() {
            for acq in &fsym.acqs {
                let g = self.global_id(fi, acq.fn_idx);
                summary[g].insert(acq.lock.clone());
            }
        }
        // monotone fixpoint over the call graph (bounded by the finite
        // set of lock identities, so this terminates on recursion too)
        loop {
            let mut changed = false;
            for f in 0..self.fns.len() {
                for &c in &self.callees[f] {
                    if c == f {
                        continue;
                    }
                    let add: Vec<String> =
                        summary[c].iter().filter(|l| !summary[f].contains(*l)).cloned().collect();
                    if !add.is_empty() {
                        summary[f].extend(add);
                        changed = true;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        self.lock_summary = summary;
    }

    fn summarize_clock(&mut self) {
        let sanctioned: Vec<bool> =
            self.fns.iter().map(|f| is_clock_seam(&self.paths[f.file])).collect();
        let mut taint: Vec<Option<ClockWitness>> = vec![None; self.fns.len()];
        for (fi, fsym) in self.files.iter().enumerate() {
            for cu in &fsym.clock_uses {
                if let Some(local) = cu.fn_idx {
                    let g = self.global_id(fi, local);
                    if !sanctioned[g] {
                        taint[g] = Some(ClockWitness::Direct { what: cu.what, line: cu.line });
                    }
                }
            }
        }
        loop {
            let mut changed = false;
            for (fi, fsym) in self.files.iter().enumerate() {
                for call in &fsym.calls {
                    let caller = self.global_id(fi, call.fn_idx);
                    if taint[caller].is_some() || sanctioned[caller] {
                        continue;
                    }
                    let self_ty = self.fns[caller].self_type.clone();
                    for g in self.resolve(&call.callee, self_ty.as_deref(), fi) {
                        if g != caller && taint[g].is_some() && !sanctioned[g] {
                            taint[caller] = Some(ClockWitness::Call { callee: g, line: call.line });
                            changed = true;
                            break;
                        }
                    }
                }
            }
            if !changed {
                break;
            }
        }
        self.clock_taint = taint;
    }

    /// Render a witness chain `f -> g -> Instant::now` for diagnostics.
    pub fn clock_chain(&self, mut f: usize) -> String {
        let mut parts = vec![self.fns[f].name.clone()];
        for _ in 0..32 {
            match &self.clock_taint[f] {
                Some(ClockWitness::Call { callee, .. }) => {
                    parts.push(self.fns[*callee].name.clone());
                    f = *callee;
                }
                Some(ClockWitness::Direct { what, .. }) => {
                    parts.push((*what).to_string());
                    break;
                }
                None => break,
            }
        }
        parts.join(" -> ")
    }

    /// Strongly connected components of the crate-wide lock-acquisition
    /// graph given its edge set, as `lock id -> component id`. An edge's
    /// endpoints sharing a component (or a self-edge) means a cycle.
    pub fn lock_sccs(edges: &[(String, String)]) -> HashMap<String, usize> {
        // iterative Kosaraju: small graphs, zero recursion depth risk
        let mut nodes: Vec<String> = Vec::new();
        let mut id: HashMap<String, usize> = HashMap::new();
        for (a, b) in edges {
            for n in [a, b] {
                if !id.contains_key(n) {
                    id.insert(n.clone(), nodes.len());
                    nodes.push(n.clone());
                }
            }
        }
        let n = nodes.len();
        let mut fwd: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut rev: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (a, b) in edges {
            fwd[id[a]].push(id[b]);
            rev[id[b]].push(id[a]);
        }
        let mut order = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        for s in 0..n {
            if seen[s] {
                continue;
            }
            let mut stack = vec![(s, 0usize)];
            seen[s] = true;
            while let Some(&mut (v, ref mut ei)) = stack.last_mut() {
                if *ei < fwd[v].len() {
                    let w = fwd[v][*ei];
                    *ei += 1;
                    if !seen[w] {
                        seen[w] = true;
                        stack.push((w, 0));
                    }
                } else {
                    order.push(v);
                    stack.pop();
                }
            }
        }
        let mut comp = vec![usize::MAX; n];
        let mut next = 0usize;
        for &s in order.iter().rev() {
            if comp[s] != usize::MAX {
                continue;
            }
            let mut stack = vec![s];
            comp[s] = next;
            while let Some(v) = stack.pop() {
                for &w in &rev[v] {
                    if comp[w] == usize::MAX {
                        comp[w] = next;
                        stack.push(w);
                    }
                }
            }
            next += 1;
        }
        nodes.into_iter().enumerate().map(|(i, name)| (name, comp[i])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::symbols::parse_file;

    fn build(files: &[(&str, &str)]) -> (Vec<String>, Vec<FileSyms>) {
        let paths: Vec<String> = files.iter().map(|(p, _)| p.to_string()).collect();
        let syms: Vec<FileSyms> = files.iter().map(|(_, s)| parse_file(&lex(s))).collect();
        (paths, syms)
    }

    #[test]
    fn cross_file_clock_taint_with_chain() {
        let (paths, syms) = build(&[
            ("rust/src/serve/service.rs", "fn drain() { helper(); }"),
            ("rust/src/util/t.rs", "fn helper() { let t = Instant::now(); }"),
        ]);
        let p = Program::build(paths, &syms);
        let drain = p.global_id(0, 0);
        assert!(p.clock_taint[drain].is_some());
        assert_eq!(p.clock_chain(drain), "drain -> helper -> Instant::now");
    }

    #[test]
    fn clock_seam_is_sanctioned_and_does_not_propagate() {
        let (paths, syms) = build(&[
            ("rust/src/serve/service.rs", "fn drain(c: &C) { c.now(); }"),
            (
                "rust/src/serve/clock.rs",
                "impl Clock for SystemClock { fn now(&self) -> Instant { Instant::now() } }",
            ),
        ]);
        let p = Program::build(paths, &syms);
        assert!(p.clock_taint[p.global_id(0, 0)].is_none());
        assert!(p.clock_taint[p.global_id(1, 0)].is_none());
    }

    #[test]
    fn lock_summary_is_transitive() {
        let (paths, syms) = build(&[(
            "rust/src/a.rs",
            "fn outer(s: &S) { inner(s); }\nfn inner(s: &S) { let g = s.tx.lock(); }",
        )]);
        let p = Program::build(paths, &syms);
        let outer = p.global_id(0, 0);
        assert!(p.lock_summary[outer].contains("tx"));
    }

    #[test]
    fn integration_test_fns_do_not_taint_the_library() {
        // a `fn place` with a raw clock inside rust/tests/ (its own
        // binary) must not taint the library's `.place(..)` sites
        let (paths, syms) = build(&[
            ("rust/src/serve/service.rs", "fn drain(p: &P) { p.place(0); }"),
            (
                "rust/tests/sharded.rs",
                "impl Placer for GatedPlacer { fn place(&self, i: usize) { let t = Instant::now(); } }",
            ),
        ]);
        let p = Program::build(paths, &syms);
        assert!(p.clock_taint[p.global_id(0, 0)].is_none(), "cross-unit call must not bind");
        // but the test binary itself still sees the library
        let (paths, syms) = build(&[
            ("rust/src/util/t.rs", "pub fn stamp() -> u64 { Instant::now(); 0 }"),
            ("rust/tests/sharded.rs", "fn t() { let s = stamp(); }"),
        ]);
        let p = Program::build(paths, &syms);
        assert!(p.clock_taint[p.global_id(1, 0)].is_some(), "test -> lib call must bind");
    }

    #[test]
    fn sccs_find_two_lock_cycle() {
        let edges = vec![
            ("a".to_string(), "b".to_string()),
            ("b".to_string(), "a".to_string()),
            ("a".to_string(), "c".to_string()),
        ];
        let comp = Program::lock_sccs(&edges);
        assert_eq!(comp["a"], comp["b"]);
        assert_ne!(comp["a"], comp["c"]);
    }
}
