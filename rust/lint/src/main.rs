//! `dreamshard-lint` — the tree's executable invariants, v2.
//!
//! A zero-dependency two-phase static analyzer. **Phase 1** lexes every
//! `.rs` file with a comment/string-aware Rust lexer (text inside string
//! literals, doc comments, and `/* */` blocks never trips a rule;
//! patterns split across lines still match) and parses each token stream
//! into a lightweight symbol table: `fn` items with their enclosing
//! `impl`/`trait` type, call sites, lock acquisitions with live guards,
//! raw-clock uses, hash-container declarations, and discarded-call
//! statements. **Phase 2** merges the tables into one crate-wide call
//! graph and runs the interprocedural rules over it, so a helper that
//! takes a second lock or reads the wall clock is caught across any
//! number of function and file boundaries. Violations print as
//! `file:line: rule: message` and fail the run.
//!
//! Run it from the repo root (CI runs exactly this as a hard gate):
//!
//! ```text
//! cargo run -p dreamshard-lint                 # default walk (see below)
//! cargo run -p dreamshard-lint -- <paths>      # lint explicit files/dirs
//! cargo run -p dreamshard-lint -- --json       # machine-readable report
//! cargo run -p dreamshard-lint -- --github     # ::error workflow annotations
//! cargo run -p dreamshard-lint -- --quiet      # summary line only
//! ```
//!
//! The default walk covers `rust/src`, `rust/lint/src`, `benches/`,
//! `examples/`, and `rust/tests/` — every path-scoped rule applies only
//! where its invariant lives (table below). Exit codes: **0** clean,
//! **1** findings, **2** I/O or usage error (unreadable paths are an
//! error, never a panic or a silent skip). The `--json` schema is
//! documented in [`report`] and pinned by an integration test.
//!
//! # Escaping a rule
//!
//! Every rule can be silenced for one line — and only with a written
//! justification — by a pragma comment on the flagged line or on its own
//! line directly above (an own-line pragma targets the next line of
//! code):
//!
//! ```text
//! // lint: allow(panic-policy) — <why this site is genuinely safe>
//! ```
//!
//! A pragma with no reason text, or naming an unknown rule, is itself a
//! violation: the escape hatch documents, it does not hide.
//!
//! # The rules
//!
//! ## Local (single-file) rules
//!
//! ### `nan-ordering`
//!
//! The cost features driving every placement are raw floats (PAPER.md
//! §4); one corrupt table feature must not panic a serving drain. The
//! crate's ordering convention is `total_cmp`, so this rule forbids
//! `partial_cmp(..).unwrap()` / `.expect(..)` chains (multi-line aware)
//! and any `sort_by` / `sort_unstable_by` / `max_by` / `min_by`
//! comparator built on `partial_cmp`. Applies everywhere.
//!
//! ### `env-discipline`
//!
//! Environment variables are configuration read at two sanctioned
//! places: `runtime/mod.rs` (`DREAMSHARD_WORKERS`, `DREAMSHARD_ARTIFACTS`
//! — with the documented no-silent-substitution policy) and the `bench/`
//! harness. `std::env::var` anywhere else creates untracked config
//! surface that CI matrices cannot see, so it is forbidden.
//!
//! ### `panic-policy`
//!
//! `serve/`, `placer/`, and `runtime/` are library hot paths shared by
//! every drain thread: a panic there takes down a shard, not a test. In
//! those paths `.unwrap()`, `.expect(..)`, and `panic!` are forbidden in
//! non-test code — route recoverable conditions through `util::error`
//! (`Result`, `Context`, `bail!`), or justify the true invariants with a
//! pragma. `#[cfg(test)]` modules and `#[test]` functions are exempt.
//!
//! ### `lock-across-wait`
//!
//! The runtime is shared as `Arc<Runtime>` over a small worker pool;
//! `submit`/`Ticket::wait` is the dispatch path. Holding a `.lock()`
//! guard across a `.submit(..)` or `.wait(..)` in the same scope is the
//! deadlock shape that stalls every shard at once (the pool cannot make
//! progress the guard is waiting on). Tracks `let`-bound guards until
//! their scope closes or an explicit `drop(guard)`. Applies everywhere.
//!
//! ## Interprocedural (crate-graph) rules
//!
//! ### `lock-order`
//!
//! Builds the global lock-acquisition graph: an edge `a -> b` wherever a
//! `.lock()` of `b` is reached — directly or transitively through any
//! in-crate call chain — while a guard on `a` is live. A cycle in that
//! graph is a static deadlock (two threads interleaving those paths each
//! hold one lock and wait for the other); every acquisition site on a
//! cyclic edge is flagged, including same-lock re-entry. Applies
//! everywhere. Pragma: `lint: allow(lock-order) — <why the orders can
//! never interleave>`.
//!
//! ### `clock-transitive`
//!
//! Supersedes v1's direct-only `clock-discipline`. The serving
//! controller's trajectories are deterministic because every timestamp
//! in `serve/` flows through the swappable `serve::Clock` seam
//! (`tests/control.rs` replays whole control runs on a `TestClock`).
//! This rule flags every literal `Instant::now()`/`SystemTime::now()` in
//! `serve/` (outside `serve/clock.rs`, the sanctioned seam), **and**
//! every `serve/` call site whose callee reaches a raw clock through any
//! in-crate call chain — the witness chain is printed. Direct raw-clock
//! reads in `benches/` and `examples/` are also flagged so wall-clock
//! timing sections are visibly pragma-justified rather than ambient.
//!
//! ### `map-iter-determinism`
//!
//! `HashMap`/`HashSet` iteration order is randomized per process; in
//! `placer/`, `serve/`, `sim/`, and `mdp/` non-test code that order can
//! leak into plans and break the bit-identity guarantees (`place_many`
//! identical to sequential `place`). Identifiers are classified as hash
//! containers by any declaration in the walked tree — a `HashMap` field
//! declared in one file is caught when iterated from another. Use
//! `BTreeMap`, sort first, or pragma-justify an order-insensitive fold.
//!
//! ### `swallowed-result`
//!
//! In `serve/`, `placer/`, and `runtime/` non-test code, `let _ = f(..);`
//! or a bare `f(..);` statement whose in-crate callee returns `Result`
//! silently drops an error on a library hot path. Handle it (`?`,
//! match), or pragma-justify a genuinely fire-and-forget call.

mod engine;
mod graph;
mod interproc;
mod lexer;
mod report;
mod rules;
mod symbols;

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

use report::Format;

struct Options {
    roots: Vec<PathBuf>,
    format: Format,
    quiet: bool,
}

fn parse_args(args: &[String]) -> Result<Options, String> {
    let mut opts = Options { roots: Vec::new(), format: Format::Text, quiet: false };
    for a in args {
        match a.as_str() {
            "--json" => opts.format = Format::Json,
            "--github" => opts.format = Format::Github,
            "--quiet" => opts.quiet = true,
            "--help" | "-h" => {
                return Err("usage: dreamshard-lint [--json|--github] [--quiet] [paths..]"
                    .to_string())
            }
            f if f.starts_with('-') => return Err(format!("unknown flag `{f}` (try --help)")),
            p => opts.roots.push(PathBuf::from(p)),
        }
    }
    if opts.roots.is_empty() {
        opts.roots = ["rust/src", "rust/lint/src", "benches", "examples", "rust/tests"]
            .iter()
            .map(PathBuf::from)
            .collect();
    }
    Ok(opts)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("error walking {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// The fallible core `main` delegates to: collects files, lints them as
/// one program, emits the report. `Ok(n)` is the number of violations;
/// `Err` is an I/O or usage failure (exit code 2).
fn run(args: &[String]) -> Result<usize, String> {
    let opts = parse_args(args)?;
    let mut files: Vec<PathBuf> = Vec::new();
    for root in &opts.roots {
        if root.is_file() {
            files.push(root.clone());
        } else if root.is_dir() {
            walk(root, &mut files)?;
        } else {
            return Err(format!(
                "{} not found (run from the repo root, or pass paths)",
                root.display()
            ));
        }
    }
    let mut sources: Vec<(String, String)> = Vec::with_capacity(files.len());
    for f in &files {
        let display = f.to_string_lossy().replace('\\', "/");
        let src =
            fs::read_to_string(f).map_err(|e| format!("error reading {display}: {e}"))?;
        sources.push((display, src));
    }
    let viols = engine::lint_sources(&sources);
    report::emit(&viols, files.len(), opts.format, opts.quiet);
    if viols.is_empty() {
        eprintln!("dreamshard-lint: {} file(s) clean", files.len());
    } else {
        eprintln!(
            "dreamshard-lint: {} violation(s) in {} file(s) checked",
            viols.len(),
            files.len()
        );
    }
    Ok(viols.len())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(0) => ExitCode::SUCCESS,
        Ok(_) => ExitCode::from(1),
        Err(msg) => {
            eprintln!("dreamshard-lint: {msg}");
            ExitCode::from(2)
        }
    }
}
