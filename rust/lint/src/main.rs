//! `dreamshard-lint` — the tree's executable invariants.
//!
//! A zero-dependency static-analysis pass over `rust/src` (and this
//! crate's own `src`, so the linter lints itself). It lexes every `.rs`
//! file with a small comment/string-aware Rust lexer — so text inside
//! string literals, doc comments, and `/* */` blocks never trips a rule,
//! and patterns split across lines still match — then runs a fixed rule
//! set, printing `file:line: rule: message` per violation and exiting
//! nonzero if any survive.
//!
//! Run it from the repo root (CI runs exactly this as a hard gate):
//!
//! ```text
//! cargo run -p dreamshard-lint            # walk rust/src + rust/lint/src
//! cargo run -p dreamshard-lint -- <path>  # walk explicit files/dirs
//! ```
//!
//! # Escaping a rule
//!
//! Every rule can be silenced for one line — and only with a written
//! justification — by a pragma comment on the flagged line or on its own
//! line directly above (an own-line pragma targets the next line of
//! code):
//!
//! ```text
//! // lint: allow(panic-policy) — <why this site is genuinely safe>
//! ```
//!
//! A pragma with no reason text, or naming an unknown rule, is itself a
//! violation: the escape hatch documents, it does not hide.
//!
//! # The rules
//!
//! ## `nan-ordering`
//!
//! The cost features driving every placement are raw floats (PAPER.md
//! §4); one corrupt table feature must not panic a serving drain. The
//! crate's ordering convention is `total_cmp`, so this rule forbids
//! `partial_cmp(..).unwrap()` / `.expect(..)` chains (multi-line aware)
//! and any `sort_by` / `sort_unstable_by` / `max_by` / `min_by`
//! comparator built on `partial_cmp`. Supersedes the single-line CI grep
//! that used to guard this.
//!
//! ## `clock-discipline`
//!
//! The serving controller's trajectories are deterministic because every
//! timestamp in `serve/` flows through the swappable `serve::Clock` seam
//! (`tests/control.rs` replays whole control runs on a `TestClock`). A
//! single `Instant::now()` / `SystemTime::now()` inside `serve/` outside
//! `serve/clock.rs` silently breaks that replay, so it is forbidden.
//!
//! ## `env-discipline`
//!
//! Environment variables are configuration read at two sanctioned
//! places: `runtime/mod.rs` (`DREAMSHARD_WORKERS`, `DREAMSHARD_ARTIFACTS`
//! — with the documented no-silent-substitution policy) and the `bench/`
//! harness. `std::env::var` anywhere else creates untracked config
//! surface that CI matrices cannot see, so it is forbidden.
//!
//! ## `panic-policy`
//!
//! `serve/`, `placer/`, and `runtime/` are library hot paths shared by
//! every drain thread: a panic there takes down a shard, not a test. In
//! those paths `.unwrap()`, `.expect(..)`, and `panic!` are forbidden in
//! non-test code — route recoverable conditions through `util::error`
//! (`Result`, `Context`, `bail!`), or justify the true invariants with a
//! pragma. `#[cfg(test)]` modules and `#[test]` functions are exempt.
//!
//! ## `lock-across-wait`
//!
//! The runtime is shared as `Arc<Runtime>` over a small worker pool;
//! `submit`/`Ticket::wait` is the dispatch path. Holding a `.lock()`
//! guard across a `.submit(..)` or `.wait(..)` in the same scope is the
//! deadlock shape that stalls every shard at once (the pool cannot make
//! progress the guard is waiting on). The rule tracks `let`-bound lock
//! guards (including `if let`/`while let`) until their scope closes or
//! an explicit `drop(guard)`, and flags any `submit`/`wait` call made
//! while one is live. Heuristic by design: a `match m.lock()` guard is
//! not tracked — keep lock scopes small enough that this never matters.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};

/// The five enforced rules (the `pragma` meta-rule reports malformed
/// escapes and is not itself escapable).
const RULES: [&str; 5] = [
    "nan-ordering",
    "clock-discipline",
    "env-discipline",
    "panic-policy",
    "lock-across-wait",
];

// ---------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------

/// One lexical token. Literal bodies are not kept: a rule can never
/// match inside a string, char, or lifetime — that is the point.
#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Punct(char),
    Num,
    Str,
    CharLit,
    Lifetime,
}

/// A line comment, kept for pragma parsing. `own_line` is true when no
/// code token precedes it on its line.
#[derive(Debug)]
struct Comment {
    line: u32,
    text: String,
    own_line: bool,
}

struct Lexed {
    /// Tokens with their 1-based start line (non-decreasing).
    toks: Vec<(Tok, u32)>,
    comments: Vec<Comment>,
}

fn scan_string(cs: &[char], open: usize, line: &mut u32) -> usize {
    let mut i = open + 1;
    while i < cs.len() {
        match cs[i] {
            // an escape may hide a newline (`\<newline>` continuation)
            '\\' => {
                if i + 1 < cs.len() && cs[i + 1] == '\n' {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let mut toks: Vec<(Tok, u32)> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut last_tok_line: u32 = 0;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers /// and //! doc comments)
        if c == '/' && i + 1 < cs.len() && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            let text: String = cs[start..j].iter().collect();
            comments.push(Comment { line, text, own_line: last_tok_line != line });
            i = j;
            continue;
        }
        // block comment, nesting-aware
        if c == '/' && i + 1 < cs.len() && cs[i + 1] == '*' {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < cs.len() && depth > 0 {
                if cs[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if cs[j] == '/' && j + 1 < cs.len() && cs[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && j + 1 < cs.len() && cs[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        let tline = line;
        if c == '"' {
            i = scan_string(&cs, i, &mut line);
            toks.push((Tok::Str, tline));
            last_tok_line = tline;
            continue;
        }
        if c == '\'' {
            // lifetime vs char literal
            if i + 1 < cs.len() && cs[i + 1] == '\\' {
                // escaped char: '\n', '\'', '\u{1F}', ...
                let mut j = i + 3; // past the escape introducer + one char
                while j < cs.len() && cs[j] != '\'' {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = j + 1;
                toks.push((Tok::CharLit, tline));
            } else if i + 1 < cs.len()
                && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_')
                && !(i + 2 < cs.len() && cs[i + 2] == '\'')
            {
                let mut j = i + 1;
                while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                i = j;
                toks.push((Tok::Lifetime, tline));
            } else {
                let mut j = i + 1;
                while j < cs.len() && cs[j] != '\'' {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = j + 1;
                toks.push((Tok::CharLit, tline));
            }
            last_tok_line = tline;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            let word: String = cs[start..j].iter().collect();
            // raw / byte string prefixes: r"..", r#".."#, b"..", br#".."#
            if (word == "r" || word == "b" || word == "br" || word == "rb")
                && j < cs.len()
                && (cs[j] == '"' || cs[j] == '#')
            {
                let mut hashes = 0usize;
                let mut k = j;
                while k < cs.len() && cs[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < cs.len() && cs[k] == '"' {
                    if word == "b" && hashes == 0 {
                        // byte string: normal escape rules
                        i = scan_string(&cs, k, &mut line);
                    } else {
                        // raw string: ends at `"` followed by `hashes` #s
                        k += 1;
                        while k < cs.len() {
                            if cs[k] == '\n' {
                                line += 1;
                                k += 1;
                                continue;
                            }
                            if cs[k] == '"' {
                                let mut h = 0usize;
                                let mut m = k + 1;
                                while m < cs.len() && cs[m] == '#' && h < hashes {
                                    h += 1;
                                    m += 1;
                                }
                                if h == hashes {
                                    k = m;
                                    break;
                                }
                            }
                            k += 1;
                        }
                        i = k;
                    }
                    toks.push((Tok::Str, tline));
                    last_tok_line = tline;
                    continue;
                }
                // `r#ident` raw identifier or stray hash: fall through
            }
            toks.push((Tok::Ident(word), tline));
            last_tok_line = tline;
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            // fractional part — but not `0..n` ranges or `x.0` that follow
            if j + 1 < cs.len() && cs[j] == '.' && cs[j + 1].is_ascii_digit() {
                j += 1;
                while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
            }
            toks.push((Tok::Num, tline));
            last_tok_line = tline;
            i = j;
            continue;
        }
        toks.push((Tok::Punct(c), tline));
        last_tok_line = tline;
        i += 1;
    }
    Lexed { toks, comments }
}

// ---------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------

fn ident_at<'a>(toks: &'a [(Tok, u32)], i: usize) -> Option<&'a str> {
    match toks.get(i) {
        Some((Tok::Ident(s), _)) => Some(s.as_str()),
        _ => None,
    }
}

fn punct_at(toks: &[(Tok, u32)], i: usize, c: char) -> bool {
    matches!(toks.get(i), Some((Tok::Punct(p), _)) if *p == c)
}

/// Index of the `)`/`]`/`}` matching the opener at `open`, if any.
fn match_delim(toks: &[(Tok, u32)], open: usize, oc: char, cc: char) -> Option<usize> {
    let mut depth = 0i64;
    let mut i = open;
    while i < toks.len() {
        if punct_at(toks, i, oc) {
            depth += 1;
        } else if punct_at(toks, i, cc) {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

// ---------------------------------------------------------------------
// Test-code spans (panic-policy exemption)
// ---------------------------------------------------------------------

/// Token-index ranges `[start, end)` covering `#[test]` functions and
/// `#[cfg(test)]` / `#[cfg(all(test, ..))]` items (`#[cfg(not(test))]`
/// is deliberately NOT a test span).
fn test_spans(toks: &[(Tok, u32)]) -> Vec<(usize, usize)> {
    let mut spans: Vec<(usize, usize)> = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if punct_at(toks, i, '#') && punct_at(toks, i + 1, '[') {
            let Some(close) = match_delim(toks, i + 1, '[', ']') else {
                i += 1;
                continue;
            };
            let attr = &toks[i + 2..close];
            let has = |w: &str| attr.iter().any(|(t, _)| matches!(t, Tok::Ident(s) if s == w));
            let exact_test = attr.len() == 1 && has("test");
            let cfg_test = ident_at(toks, i + 2) == Some("cfg") && has("test") && !has("not");
            if exact_test || cfg_test {
                // skip the attributed item: to the matching `}` of its
                // first brace, or to a top-level `;` (e.g. a `use`)
                let mut depth = 0i64;
                let mut j = close + 1;
                while j < toks.len() {
                    if punct_at(toks, j, '{') {
                        depth += 1;
                    } else if punct_at(toks, j, '}') {
                        depth -= 1;
                        if depth == 0 {
                            j += 1;
                            break;
                        }
                    } else if punct_at(toks, j, ';') && depth == 0 {
                        j += 1;
                        break;
                    }
                    j += 1;
                }
                spans.push((i, j));
                i = j;
                continue;
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    spans
}

// ---------------------------------------------------------------------
// Pragmas
// ---------------------------------------------------------------------

struct Violation {
    line: u32,
    rule: &'static str,
    msg: String,
}

/// Parse `lint: allow(<rule>) — <reason>` comments. Returns the set of
/// `(target_line, rule)` suppressions plus violations for malformed
/// pragmas (missing reason, unknown rule, unparseable body).
fn parse_pragmas(lx: &Lexed) -> (HashSet<(u32, String)>, Vec<Violation>) {
    let mut allowed: HashSet<(u32, String)> = HashSet::new();
    let mut viols: Vec<Violation> = Vec::new();
    for c in &lx.comments {
        let t = c.text.trim_start_matches(['/', '!']).trim();
        let Some(rest) = t.strip_prefix("lint:") else { continue };
        let rest = rest.trim();
        let body = rest.strip_prefix("allow").map(str::trim_start);
        let parsed = body.and_then(|b| {
            let inner = b.strip_prefix('(')?;
            let close = inner.find(')')?;
            Some((inner[..close].to_string(), inner[close + 1..].to_string()))
        });
        let Some((rules, reason)) = parsed else {
            viols.push(Violation {
                line: c.line,
                rule: "pragma",
                msg: format!("unparseable lint pragma `{t}`; use `lint: allow(<rule>) — <reason>`"),
            });
            continue;
        };
        if !reason.chars().any(|ch| ch.is_alphanumeric()) {
            viols.push(Violation {
                line: c.line,
                rule: "pragma",
                msg: "lint pragma has no justification; append `— <reason>`".to_string(),
            });
            continue;
        }
        // own-line pragmas target the next line that has code on it
        let target = if c.own_line {
            lx.toks
                .iter()
                .map(|&(_, l)| l)
                .find(|&l| l > c.line)
                .unwrap_or(c.line)
        } else {
            c.line
        };
        for r in rules.split(',') {
            let r = r.trim();
            if RULES.contains(&r) {
                allowed.insert((target, r.to_string()));
            } else {
                viols.push(Violation {
                    line: c.line,
                    rule: "pragma",
                    msg: format!("unknown rule `{r}` in lint pragma (rules: {})", RULES.join(", ")),
                });
            }
        }
    }
    (allowed, viols)
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

fn rule_nan_ordering(toks: &[(Tok, u32)], out: &mut Vec<Violation>) {
    for i in 0..toks.len() {
        if ident_at(toks, i) == Some("partial_cmp") && punct_at(toks, i + 1, '(') {
            if let Some(close) = match_delim(toks, i + 1, '(', ')') {
                if punct_at(toks, close + 1, '.')
                    && matches!(ident_at(toks, close + 2), Some("unwrap") | Some("expect"))
                    && punct_at(toks, close + 3, '(')
                {
                    out.push(Violation {
                        line: toks[i].1,
                        rule: "nan-ordering",
                        msg: "partial_cmp(..).unwrap()/.expect(..) panics on NaN; \
                              use total_cmp for a NaN-safe total order"
                            .to_string(),
                    });
                }
            }
        }
        if let Some(name) = ident_at(toks, i) {
            if matches!(name, "sort_by" | "sort_unstable_by" | "max_by" | "min_by")
                && punct_at(toks, i + 1, '(')
            {
                if let Some(close) = match_delim(toks, i + 1, '(', ')') {
                    if (i + 2..close).any(|j| ident_at(toks, j) == Some("partial_cmp")) {
                        out.push(Violation {
                            line: toks[i].1,
                            rule: "nan-ordering",
                            msg: format!(
                                "`{name}` comparator built on partial_cmp; \
                                 use total_cmp for a NaN-safe total order"
                            ),
                        });
                    }
                }
            }
        }
    }
}

fn rule_clock_discipline(toks: &[(Tok, u32)], out: &mut Vec<Violation>) {
    for i in 0..toks.len() {
        if let Some(ty) = ident_at(toks, i) {
            if (ty == "Instant" || ty == "SystemTime")
                && punct_at(toks, i + 1, ':')
                && punct_at(toks, i + 2, ':')
                && ident_at(toks, i + 3) == Some("now")
            {
                out.push(Violation {
                    line: toks[i].1,
                    rule: "clock-discipline",
                    msg: format!(
                        "{ty}::now() inside serve/ breaks TestClock replay determinism; \
                         read time through the serve::Clock seam (serve/clock.rs)"
                    ),
                });
            }
        }
    }
}

fn rule_env_discipline(toks: &[(Tok, u32)], out: &mut Vec<Violation>) {
    for i in 0..toks.len() {
        if ident_at(toks, i) == Some("env")
            && punct_at(toks, i + 1, ':')
            && punct_at(toks, i + 2, ':')
            && matches!(ident_at(toks, i + 3), Some("var") | Some("var_os"))
        {
            out.push(Violation {
                line: toks[i].1,
                rule: "env-discipline",
                msg: "std::env::var outside runtime/mod.rs and bench/ creates untracked \
                      config surface; plumb the setting through an explicit parameter"
                    .to_string(),
            });
        }
    }
}

fn rule_panic_policy(toks: &[(Tok, u32)], spans: &[(usize, usize)], out: &mut Vec<Violation>) {
    let in_test = |i: usize| spans.iter().any(|&(a, b)| a <= i && i < b);
    for i in 0..toks.len() {
        if in_test(i) {
            continue;
        }
        if punct_at(toks, i, '.')
            && matches!(ident_at(toks, i + 1), Some("unwrap") | Some("expect"))
            && punct_at(toks, i + 2, '(')
        {
            let what = ident_at(toks, i + 1).unwrap_or("unwrap");
            out.push(Violation {
                line: toks[i + 1].1,
                rule: "panic-policy",
                msg: format!(
                    ".{what}(..) in a library hot path panics the shard; route through \
                     util::error (Result/Context/bail!) or justify with a lint pragma"
                ),
            });
        }
        if ident_at(toks, i) == Some("panic") && punct_at(toks, i + 1, '!') {
            out.push(Violation {
                line: toks[i].1,
                rule: "panic-policy",
                msg: "panic! in a library hot path takes down the shard; route through \
                      util::error (Result/Context/bail!) or justify with a lint pragma"
                    .to_string(),
            });
        }
    }
}

fn rule_lock_across_wait(toks: &[(Tok, u32)], out: &mut Vec<Violation>) {
    struct Guard {
        name: String,
        depth: i64,
    }
    let mut depth: i64 = 0;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt_has_let = false;
    let mut stmt_let_name: Option<String> = None;
    let mut stmt_lock = false;
    let mut expect_let_name = false;
    for i in 0..toks.len() {
        match &toks[i].0 {
            Tok::Punct('{') => {
                depth += 1;
                // `if let` / `while let` guard: scoped to this block
                if stmt_has_let && stmt_lock {
                    if let Some(n) = stmt_let_name.take() {
                        guards.push(Guard { name: n, depth });
                    }
                }
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            Tok::Punct('}') => {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            Tok::Punct(';') => {
                // plain `let g = ...lock()...;` guard: lives to scope end
                if stmt_has_let && stmt_lock {
                    if let Some(n) = stmt_let_name.take() {
                        guards.push(Guard { name: n, depth });
                    }
                }
                stmt_has_let = false;
                stmt_lock = false;
                stmt_let_name = None;
                expect_let_name = false;
            }
            Tok::Ident(w) => {
                if expect_let_name {
                    if w != "mut" {
                        stmt_let_name = Some(w.clone());
                        expect_let_name = false;
                    }
                } else if w == "let" && !stmt_has_let {
                    stmt_has_let = true;
                    expect_let_name = true;
                } else if w == "lock" && i > 0 && punct_at(toks, i - 1, '.') && punct_at(toks, i + 1, '(')
                {
                    stmt_lock = true;
                } else if (w == "wait" || w == "submit")
                    && i > 0
                    && punct_at(toks, i - 1, '.')
                    && punct_at(toks, i + 1, '(')
                {
                    if !guards.is_empty() || stmt_lock {
                        let held = guards
                            .last()
                            .map(|g| g.name.clone())
                            .unwrap_or_else(|| "<temporary>".to_string());
                        out.push(Violation {
                            line: toks[i].1,
                            rule: "lock-across-wait",
                            msg: format!(
                                ".{w}(..) while lock guard `{held}` is live can deadlock \
                                 the worker pool; drop the guard before dispatching"
                            ),
                        });
                    }
                } else if w == "drop" && punct_at(toks, i + 1, '(') {
                    if let Some(n) = ident_at(toks, i + 2) {
                        if punct_at(toks, i + 3, ')') {
                            guards.retain(|g| g.name != n);
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------

/// Path policy: which rules run on a file (forward-slash paths).
fn applies(rule: &str, path: &str) -> bool {
    match rule {
        "nan-ordering" | "lock-across-wait" => true,
        "clock-discipline" => path.contains("/serve/") && !path.ends_with("serve/clock.rs"),
        "env-discipline" => !path.ends_with("runtime/mod.rs") && !path.contains("/bench/"),
        "panic-policy" => {
            path.contains("/serve/") || path.contains("/placer/") || path.contains("/runtime/")
        }
        _ => false,
    }
}

fn lint_source(path: &str, src: &str) -> Vec<Violation> {
    let lx = lex(src);
    let (allowed, mut viols) = parse_pragmas(&lx);
    let mut found: Vec<Violation> = Vec::new();
    if applies("nan-ordering", path) {
        rule_nan_ordering(&lx.toks, &mut found);
    }
    if applies("clock-discipline", path) {
        rule_clock_discipline(&lx.toks, &mut found);
    }
    if applies("env-discipline", path) {
        rule_env_discipline(&lx.toks, &mut found);
    }
    if applies("panic-policy", path) {
        let spans = test_spans(&lx.toks);
        rule_panic_policy(&lx.toks, &spans, &mut found);
    }
    if applies("lock-across-wait", path) {
        rule_lock_across_wait(&lx.toks, &mut found);
    }
    // suppress pragma'd lines, then dedup repeated (line, rule) reports
    found.retain(|v| !allowed.contains(&(v.line, v.rule.to_string())));
    let mut seen: HashSet<(u32, &'static str)> = HashSet::new();
    for v in found {
        if seen.insert((v.line, v.rule)) {
            viols.push(v);
        }
    }
    viols.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    viols
}

// ---------------------------------------------------------------------
// Walk + main
// ---------------------------------------------------------------------

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("rust/src"), PathBuf::from("rust/lint/src")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for root in &roots {
        if root.is_file() {
            files.push(root.clone());
        } else if root.is_dir() {
            if let Err(e) = walk(root, &mut files) {
                eprintln!("dreamshard-lint: error walking {}: {e}", root.display());
                std::process::exit(2);
            }
        } else {
            eprintln!(
                "dreamshard-lint: {} not found (run from the repo root, or pass paths)",
                root.display()
            );
            std::process::exit(2);
        }
    }
    let mut total = 0usize;
    for f in &files {
        let display = f.to_string_lossy().replace('\\', "/");
        let src = match fs::read_to_string(f) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("dreamshard-lint: error reading {display}: {e}");
                std::process::exit(2);
            }
        };
        for v in lint_source(&display, &src) {
            println!("{display}:{}: {}: {}", v.line, v.rule, v.msg);
            total += 1;
        }
    }
    if total > 0 {
        eprintln!(
            "dreamshard-lint: {total} violation(s) in {} file(s) checked",
            files.len()
        );
        std::process::exit(1);
    }
    eprintln!("dreamshard-lint: {} file(s) clean", files.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_of(viols: &[Violation], rule: &str) -> Vec<u32> {
        viols.iter().filter(|v| v.rule == rule).map(|v| v.line).collect()
    }

    #[test]
    fn strings_and_comments_never_match() {
        let src = r#"
// a.partial_cmp(&b).unwrap() in a comment
/* Instant::now() in a block comment */
fn f() {
    let s = "x.partial_cmp(&y).unwrap() and Instant::now()";
    let r = r"std::env::var and panic!";
}
"#;
        let v = lint_source("rust/src/serve/x.rs", src);
        assert!(v.is_empty(), "{:?}", v.iter().map(|v| (v.line, v.rule)).collect::<Vec<_>>());
    }

    #[test]
    fn multiline_partial_cmp_matches() {
        let src = "fn f(v: &mut Vec<f32>) {\n    let o = a\n        .partial_cmp(&b)\n        .unwrap();\n}\n";
        let v = lint_source("rust/src/sim/x.rs", src);
        assert_eq!(lines_of(&v, "nan-ordering"), vec![3]);
    }

    #[test]
    fn pragma_suppresses_next_line_and_requires_reason() {
        let good = "fn f() {\n    // lint: allow(clock-discipline) — test fixture timing\n    let t = Instant::now();\n}\n";
        let v = lint_source("rust/src/serve/x.rs", good);
        assert!(v.is_empty());
        let bad = "fn f() {\n    let t = Instant::now(); // lint: allow(clock-discipline)\n}\n";
        let v = lint_source("rust/src/serve/x.rs", bad);
        assert_eq!(lines_of(&v, "pragma"), vec![2]);
        assert_eq!(lines_of(&v, "clock-discipline"), vec![2]);
    }

    #[test]
    fn cfg_test_is_exempt_from_panic_policy() {
        let src = "fn lib() -> u32 {\n    x.unwrap()\n}\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n";
        let v = lint_source("rust/src/runtime/x.rs", src);
        assert_eq!(lines_of(&v, "panic-policy"), vec![2]);
    }

    #[test]
    fn lock_guard_across_wait_flags() {
        let src = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n    let r = t.wait();\n}\n";
        let v = lint_source("rust/src/util/x.rs", src);
        assert_eq!(lines_of(&v, "lock-across-wait"), vec![3]);
        let dropped = "fn f() {\n    let g = m.lock().unwrap_or_else(|e| e.into_inner());\n    drop(g);\n    let r = t.wait();\n}\n";
        let v = lint_source("rust/src/util/x.rs", dropped);
        assert!(lines_of(&v, "lock-across-wait").is_empty());
    }
}
