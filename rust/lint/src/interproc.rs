//! Phase-2 rules: interprocedural analyses over the crate-wide
//! [`Program`] graph. Each rule's invariant is documented in the rustdoc
//! header of `main.rs` (the user-facing rule table).

use std::collections::BTreeSet;

use crate::graph::{in_dir, is_clock_seam, ClockWitness, Program};
use crate::report::Violation;

fn path_of<'a>(prog: &'a Program<'_>, file: usize) -> &'a str {
    &prog.paths[file]
}

// ---------------------------------------------------------------------
// lock-order
// ---------------------------------------------------------------------

/// Build the global lock-acquisition-order graph — `a -> b` when a
/// `.lock()` of `b` is reachable (directly or through any call chain)
/// while a guard on `a` is live — and flag every acquisition site whose
/// edge participates in a cycle. A cycle means two threads interleaving
/// those paths can each hold one lock and wait for the other: a static
/// deadlock, independent of timing.
pub fn rule_lock_order(prog: &Program<'_>, out: &mut Vec<Violation>) {
    // edge -> sites: (held, acquired) with the file/line that creates it
    let mut edges: Vec<(String, String, usize, u32, String)> = Vec::new();
    for (fi, fsym) in prog.files.iter().enumerate() {
        for e in &fsym.edges {
            edges.push((e.held.clone(), e.lock.clone(), fi, e.line, "directly".to_string()));
        }
        for hc in &fsym.held_calls {
            let caller = prog.global_id(fi, hc.fn_idx);
            let self_ty = prog.fns[caller].self_type.clone();
            let mut locks: BTreeSet<&String> = BTreeSet::new();
            for g in prog.resolve(&hc.callee, self_ty.as_deref(), fi) {
                locks.extend(prog.lock_summary[g].iter());
            }
            for l in locks {
                edges.push((
                    hc.held.clone(),
                    l.clone(),
                    fi,
                    hc.line,
                    format!("through `{}(..)`", hc.callee.name()),
                ));
            }
        }
    }
    let plain: Vec<(String, String)> =
        edges.iter().map(|(a, b, _, _, _)| (a.clone(), b.clone())).collect();
    let comp = Program::lock_sccs(&plain);
    for (held, lock, fi, line, how) in &edges {
        let cyclic = (held == lock) || comp.get(held) == comp.get(lock);
        if cyclic {
            let shape = if held == lock {
                format!("re-acquires `{held}` while already held")
            } else {
                format!("`{held}` -> `{lock}` closes a cycle with the reverse ordering elsewhere")
            };
            out.push(Violation {
                file: path_of(prog, *fi).to_string(),
                line: *line,
                rule: "lock-order",
                msg: format!(
                    "lock `{lock}` acquired {how} while guard on `{held}` is live; {shape} \
                     in the global lock-acquisition graph — impose one acquisition order \
                     or drop the guard first"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// clock-transitive
// ---------------------------------------------------------------------

/// Where direct raw-clock reads are forbidden: all of `serve/` except
/// the `serve::Clock` seam itself, plus the root `benches/` and
/// `examples/` trees (their wall-clock timing sections must be visibly
/// pragma-justified, not ambient).
fn clock_direct_scope(path: &str) -> bool {
    (in_dir(path, "serve") && !is_clock_seam(path)) || in_dir(path, "benches") || in_dir(path, "examples")
}

/// Supersedes the v1 direct-only `clock-discipline`: flags every literal
/// `Instant::now`/`SystemTime::now` in scope, and — the interprocedural
/// half — every call site in `serve/` whose callee reaches a raw clock
/// through any in-crate call chain, with the witness chain in the
/// message.
pub fn rule_clock_transitive(prog: &Program<'_>, out: &mut Vec<Violation>) {
    for (fi, fsym) in prog.files.iter().enumerate() {
        let path = path_of(prog, fi);
        if clock_direct_scope(path) {
            for cu in &fsym.clock_uses {
                let where_ = if in_dir(path, "serve") {
                    "inside serve/ breaks TestClock replay determinism; read time through \
                     the serve::Clock seam (serve/clock.rs)"
                } else {
                    "in benches/examples must be a justified timing site; pragma it \
                     (`lint: allow(clock-transitive) — <why>`) or read through serve::Clock"
                };
                out.push(Violation {
                    file: path.to_string(),
                    line: cu.line,
                    rule: "clock-transitive",
                    msg: format!("{}() {where_}", cu.what),
                });
            }
        }
        // the transitive half: serve/ call sites reaching a raw clock
        if !in_dir(path, "serve") || is_clock_seam(path) {
            continue;
        }
        for call in &fsym.calls {
            let caller = prog.global_id(fi, call.fn_idx);
            let self_ty = prog.fns[caller].self_type.clone();
            for g in prog.resolve(&call.callee, self_ty.as_deref(), fi) {
                if g == caller {
                    continue;
                }
                if prog.clock_taint[g].is_some() {
                    out.push(Violation {
                        file: path.to_string(),
                        line: call.line,
                        rule: "clock-transitive",
                        msg: format!(
                            "`{}(..)` reaches a raw clock through an in-crate call chain \
                             ({}); serve/ time must flow through the serve::Clock seam",
                            call.callee.name(),
                            prog.clock_chain(g)
                        ),
                    });
                    break;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// map-iter-determinism
// ---------------------------------------------------------------------

fn map_iter_scope(path: &str) -> bool {
    in_dir(path, "placer") || in_dir(path, "serve") || in_dir(path, "sim") || in_dir(path, "mdp")
}

/// Iterating a `HashMap`/`HashSet` yields a randomized order per process
/// (`RandomState`); in plan-producing code that order can leak into
/// device assignments and break the bit-identity guarantees
/// (`place_many` == sequential `place`, deterministic `TestClock`
/// trajectories). Identifiers are classified as hash containers by any
/// declaration anywhere in the walked tree (fields, params, lets), so a
/// `HashMap` field declared in one file is still caught when iterated
/// from another.
pub fn rule_map_iter_determinism(prog: &Program<'_>, out: &mut Vec<Violation>) {
    let mut maps: BTreeSet<&str> = BTreeSet::new();
    for fsym in prog.files {
        maps.extend(fsym.map_names.iter().map(|s| s.as_str()));
    }
    for (fi, fsym) in prog.files.iter().enumerate() {
        let path = path_of(prog, fi);
        if !map_iter_scope(path) {
            continue;
        }
        for iu in &fsym.iter_uses {
            if iu.in_test || !maps.contains(iu.name.as_str()) {
                continue;
            }
            out.push(Violation {
                file: path.to_string(),
                line: iu.line,
                rule: "map-iter-determinism",
                msg: format!(
                    "iterating `{}` (declared as a HashMap/HashSet) has randomized order \
                     that can leak into plans and break bit-identity; use a BTreeMap/Vec, \
                     sort first, or pragma-justify an order-insensitive fold",
                    iu.name
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// swallowed-result
// ---------------------------------------------------------------------

fn swallowed_scope(path: &str) -> bool {
    in_dir(path, "serve") || in_dir(path, "placer") || in_dir(path, "runtime")
}

/// `let _ = f(..);` or a bare `f(..);` statement where every in-crate
/// candidate for `f` returns a `Result` silently drops an error on a
/// library hot path — the failure mode that turns a requeue/drain bug
/// into corrupted serving stats instead of an `Err`. Route the value
/// through `?`/match, or pragma-justify a genuinely fire-and-forget
/// call.
pub fn rule_swallowed_result(prog: &Program<'_>, out: &mut Vec<Violation>) {
    for (fi, fsym) in prog.files.iter().enumerate() {
        let path = path_of(prog, fi);
        if !swallowed_scope(path) {
            continue;
        }
        for d in &fsym.discards {
            if d.in_test {
                continue;
            }
            let cands = prog.resolve(&d.callee, d.self_type.as_deref(), fi);
            if cands.is_empty() || !cands.iter().all(|&g| prog.fns[g].returns_result) {
                continue;
            }
            out.push(Violation {
                file: path.to_string(),
                line: d.line,
                rule: "swallowed-result",
                msg: format!(
                    "discarded Result of in-crate `{}(..)`; handle the error (`?`, match) \
                     or pragma-justify the drop",
                    d.callee.name()
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::symbols::{parse_file, FileSyms};

    fn run(files: &[(&str, &str)], rule: fn(&Program<'_>, &mut Vec<Violation>)) -> Vec<(String, u32)> {
        let paths: Vec<String> = files.iter().map(|(p, _)| p.to_string()).collect();
        let syms: Vec<FileSyms> = files.iter().map(|(_, s)| parse_file(&lex(s))).collect();
        let prog = Program::build(paths, &syms);
        let mut out = Vec::new();
        rule(&prog, &mut out);
        out.into_iter().map(|v| (v.file, v.line)).collect()
    }

    #[test]
    fn lock_order_cycle_across_two_fns() {
        let src = "
fn fwd(s: &S) {
    let ga = s.a.lock().unwrap_or_else(p);
    let gb = s.b.lock().unwrap_or_else(p);
}
fn bwd(s: &S) {
    let gb = s.b.lock().unwrap_or_else(p);
    take_a(s);
}
fn take_a(s: &S) {
    let ga = s.a.lock().unwrap_or_else(p);
}
";
        let hits = run(&[("rust/src/x.rs", src)], rule_lock_order);
        let lines: Vec<u32> = hits.iter().map(|(_, l)| *l).collect();
        assert_eq!(lines, vec![4, 8], "a->b direct edge and b->a held-call edge");
    }

    #[test]
    fn lock_order_consistent_is_clean() {
        let src = "
fn fwd(s: &S) {
    let ga = s.a.lock().unwrap_or_else(p);
    let gb = s.b.lock().unwrap_or_else(p);
}
fn also_fwd(s: &S) {
    let ga = s.a.lock().unwrap_or_else(p);
    take_b(s);
}
fn take_b(s: &S) {
    let gb = s.b.lock().unwrap_or_else(p);
}
";
        assert!(run(&[("rust/src/x.rs", src)], rule_lock_order).is_empty());
    }

    #[test]
    fn clock_transitive_cross_file_leak() {
        let files = [
            ("rust/src/serve/service.rs", "fn drain() { let t = stamp(); }"),
            ("rust/src/util/t.rs", "fn stamp() -> u64 { Instant::now(); 0 }"),
        ];
        let hits = run(&files, rule_clock_transitive);
        assert_eq!(hits, vec![("rust/src/serve/service.rs".to_string(), 1)]);
    }

    #[test]
    fn map_iter_flags_cross_file_field() {
        let files = [
            ("rust/src/util/tbl.rs", "struct S { by_dev: HashMap<usize, f32> }"),
            ("rust/src/placer/p.rs", "fn f(s: &S) { for v in s.by_dev { touch(v); } }"),
        ];
        let hits = run(&files, rule_map_iter_determinism);
        assert_eq!(hits, vec![("rust/src/placer/p.rs".to_string(), 1)]);
    }

    #[test]
    fn swallowed_result_needs_result_signature() {
        let src = "
impl S {
    fn flush(&mut self) -> Result<usize> { Ok(0) }
    fn poke(&mut self) { }
    fn go(&mut self) {
        let _ = self.flush();
        self.poke();
    }
}
";
        let hits = run(&[("rust/src/serve/s.rs", src)], rule_swallowed_result);
        assert_eq!(hits, vec![("rust/src/serve/s.rs".to_string(), 6)]);
    }
}
