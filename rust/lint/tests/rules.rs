//! End-to-end tests of the compiled `dreamshard-lint` binary: every rule
//! has a known-bad fixture asserted down to the exact `(file, line,
//! rule)` triples it must report, a known-good fixture that must stay
//! silent (string/comment traps, path exemptions, pragma escapes), and
//! the real sources must lint clean — the same contract CI gates with
//! `cargo run -p dreamshard-lint`.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::process::Command;

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

/// Run the binary on `paths`, returning its exit code plus the
/// fixture-relative `(file, line, rule)` triples parsed from stdout.
fn lint(paths: &[PathBuf]) -> (Option<i32>, BTreeSet<(String, u32, String)>) {
    let out = Command::new(env!("CARGO_BIN_EXE_dreamshard-lint"))
        .args(paths)
        .output()
        .expect("spawn dreamshard-lint");
    let mut hits = BTreeSet::new();
    for l in String::from_utf8_lossy(&out.stdout).lines() {
        // `<path>:<line>: <rule>: <message>`
        let mut parts = l.splitn(3, ": ");
        let file_line = parts.next().expect("file:line field");
        let rule = parts.next().expect("rule field").to_string();
        assert!(parts.next().is_some(), "missing message in `{l}`");
        let (file, line) = file_line.rsplit_once(':').expect("line suffix");
        let file = file.replace('\\', "/");
        let rel = file
            .rsplit_once("tests/fixtures/")
            .map(|(_, r)| r.to_string())
            .unwrap_or(file);
        hits.insert((rel, line.parse().expect("numeric line"), rule));
    }
    (out.status.code(), hits)
}

fn expected(entries: &[(&str, u32, &str)]) -> BTreeSet<(String, u32, String)> {
    entries.iter().map(|&(f, l, r)| (f.to_string(), l, r.to_string())).collect()
}

#[test]
fn bad_fixtures_flag_exact_lines() {
    let (code, hits) = lint(&[fixture("bad")]);
    assert_eq!(code, Some(1), "bad fixtures must fail the gate");
    assert_eq!(
        hits,
        expected(&[
            ("bad/envy.rs", 4, "env-discipline"),
            ("bad/envy.rs", 8, "env-discipline"),
            ("bad/lock.rs", 5, "lock-across-wait"),
            ("bad/lock.rs", 11, "lock-across-wait"),
            ("bad/nan.rs", 4, "nan-ordering"),
            ("bad/nan.rs", 9, "nan-ordering"),
            ("bad/nan.rs", 14, "nan-ordering"),
            ("bad/nan.rs", 18, "nan-ordering"),
            ("bad/nan.rs", 22, "nan-ordering"),
            ("bad/pragmas.rs", 4, "pragma"),
            ("bad/pragmas.rs", 5, "nan-ordering"),
            ("bad/pragmas.rs", 9, "pragma"),
            ("bad/pragmas.rs", 10, "nan-ordering"),
            ("bad/serve/clocky.rs", 4, "clock-discipline"),
            ("bad/serve/clocky.rs", 8, "clock-discipline"),
            ("bad/serve/panics.rs", 4, "panic-policy"),
            ("bad/serve/panics.rs", 8, "panic-policy"),
            ("bad/serve/panics.rs", 12, "panic-policy"),
        ]),
    );
}

#[test]
fn good_fixtures_are_clean() {
    let (code, hits) = lint(&[fixture("good")]);
    assert_eq!(hits, BTreeSet::new(), "good fixtures must produce no violations");
    assert_eq!(code, Some(0));
}

#[test]
fn each_bad_fixture_fails_alone() {
    let files =
        ["nan.rs", "serve/clocky.rs", "envy.rs", "serve/panics.rs", "lock.rs", "pragmas.rs"];
    for f in files {
        let (code, hits) = lint(&[fixture("bad").join(f)]);
        assert_eq!(code, Some(1), "{f} must fail on its own");
        assert!(!hits.is_empty(), "{f} must report at least one violation");
    }
}

#[test]
fn missing_path_is_a_usage_error() {
    let (code, hits) = lint(&[fixture("no/such/path")]);
    assert_eq!(code, Some(2), "unknown roots are an IO error, not a lint pass");
    assert!(hits.is_empty());
}

/// The gate CI enforces, from inside the test suite: the real sources
/// (including this crate's own) carry zero violations.
#[test]
fn real_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let (code, hits) = lint(&[root.join("../src"), root.join("src")]);
    assert_eq!(hits, BTreeSet::new(), "rust/src and rust/lint/src must lint clean");
    assert_eq!(code, Some(0));
}
